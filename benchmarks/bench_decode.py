"""Paper Table 1c — decode vs generation cost.

No GPU here, so the per-image decode latency is (a) derived from the v5e
roofline of our decoder (compute-bound: conv FLOPs / peak) — this is the
T_decode the cluster simulator uses — and (b) cross-checked by measuring
the actual jitted decode on CPU at small resolution and verifying the
compute-bound scaling (latency ~ linear in batch, quadratic in res).

Also sweeps the serving engine's microbatch buckets {1, 2, 4, 8} and
reports per-image decode ms per bucket — the measurable win of the
DecodeBatcher in repro.serve.engine."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, Timer, scale
from repro.vae.model import VAE, VAEConfig
from repro.vae.serve import (decode_ms_estimate, decoder_bytes_per_image,
                             decoder_flops_per_image)


def run() -> Rows:
    rows = Rows()
    for res in (512, 1024):
        est = decode_ms_estimate(res)
        rows.add(f"decode.v5e.{res}.flops_g", derived=round(est["flops"] / 1e9, 1))
        rows.add(f"decode.v5e.{res}.compute_ms",
                 derived=round(est["compute_ms"], 1))
        rows.add(f"decode.v5e.{res}.memory_ms",
                 derived=round(est["memory_ms"], 1))
        rows.add(f"decode.v5e.{res}.decode_ms",
                 derived=round(est["decode_ms"], 1))
    # paper-reported GPU decode times for context
    rows.add("decode.paper.h100_ms", derived=32.6)
    rows.add("decode.paper.rtx5090_ms", derived=47.3)
    rows.add("decode.paper.generation_ms", derived=3905)
    rows.add("decode.ratio_generation_over_decode", derived=round(
        3905 / decode_ms_estimate(1024)["decode_ms"], 0))

    # CPU cross-check: small decoder, batch scaling ~ linear (compute-bound)
    cfg = VAEConfig(name="tiny", latent_channels=4,
                    block_out_channels=(32, 64), layers_per_block=1,
                    groups=8)
    vae = VAE(cfg, with_encoder=False)
    times = {}
    for b in (1, 2, 4):
        z = jnp.zeros((b, 16, 16, 4), jnp.float32)
        vae.decode(z).block_until_ready()
        with Timer() as t:
            for _ in range(5):
                vae.decode(z).block_until_ready()
        times[b] = t.us / 5
        rows.add(f"decode.cpu_tiny.b{b}.us", times[b], round(times[b], 0))
    rows.add("decode.cpu_scaling_b4_over_b1",
             derived=round(times[4] / times[1], 2))

    # microbatching sweep over the engine's decode buckets: fixed per-batch
    # overhead (dispatch, halo materialization, weight streaming) amortizes
    # across the batch, so per-image ms should fall as the bucket grows
    rng = np.random.default_rng(0)
    per_image = {}
    for b in (1, 2, 4, 8):
        z = jnp.asarray(rng.standard_normal((b, 16, 16, 4)), jnp.float32)
        vae.decode(z).block_until_ready()            # compile this bucket
        samples = []
        for _ in range(9):                           # median tames CPU noise
            with Timer() as t:
                vae.decode(z).block_until_ready()
            samples.append(t.us)
        per_image[b] = float(np.median(samples)) / b / 1e3
        rows.add(f"decode.bucket.b{b}.per_image_ms",
                 derived=round(per_image[b], 3))
    rows.add("decode.bucket.b8_over_b1",
             derived=round(per_image[8] / per_image[1], 3))
    return rows


def main():
    rows = run()
    rows.print()
    print(f"# saved {rows.save_json('bench_decode')}")


if __name__ == "__main__":
    main()
