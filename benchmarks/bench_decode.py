"""Paper Table 1c — decode vs generation cost, plus the PR-4 regeneration
fast path before/after.

No GPU here, so three complementary measurements:

(a) the v5e roofline of our decoder (fused upsampler + uint8 epilogue vs
    the pre-fusion traffic model) — this is the T_decode the cluster
    simulator uses;
(b) a CPU cross-check that the jitted decode scales like the roofline
    says (latency ~ linear in batch);
(c) the **fast-path A/B**: per-image wall clock of the DecodeBatcher at
    each batch bucket, pre-PR baseline (float32 pixels, serialized host
    DEFLATE, ``block_until_ready`` between chunks) vs the fast path
    (uint8 fused-epilogue decode, memoized decompression, pipelined
    async dispatch), interleaved A/B windows so machine noise hits both
    arms equally.  The headline ``decode.fastpath.b8.speedup`` row is the
    acceptance metric recorded in ``BENCH_decode.json``
    (``python -m benchmarks.run --trajectory``).

plus (d) the **quantized-decoder A/B**: bf16 vs f32 ``decode_u8`` per
batch bucket with a freshly tuned kernel cache active, gated at ±1 LSB on
every bucket (``StoreConfig.weight_dtype`` — :mod:`repro.vae.quantize`).

``--smoke`` runs (c)+(d) at reduced repetitions for CI and writes
``BENCH_decode.json`` at the repo root.
"""

from __future__ import annotations

import argparse
import os
import time
import types

import numpy as np

import jax.numpy as jnp

from benchmarks.common import Rows, Timer
from repro.compression.latentcodec import compress_latent
from repro.serve.engine import DecodeBatcher
from repro.vae.model import VAE, VAEConfig
from repro.vae.serve import decode_ms_estimate

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: the fast-path A/B decoder: latents heavy enough that host DEFLATE is a
#: visible fraction of the decode wall (as on the paper's 512 KB blobs),
#: decode small enough for CI
FAST_LATENT = (16, 16, 128)
FAST_CFG = VAEConfig(name="bench_fast", latent_channels=128,
                     block_out_channels=(4, 8), layers_per_block=1, groups=4)


def roofline_rows(rows: Rows) -> None:
    for res in (512, 1024):
        est = decode_ms_estimate(res)                      # fused fast path
        base = decode_ms_estimate(res, fused_upsampler=False,
                                  uint8_output=False)      # pre-PR model
        rows.add(f"decode.v5e.{res}.flops_g", derived=round(est["flops"] / 1e9, 1))
        rows.add(f"decode.v5e.{res}.compute_ms",
                 derived=round(est["compute_ms"], 1))
        rows.add(f"decode.v5e.{res}.memory_ms",
                 derived=round(est["memory_ms"], 1))
        rows.add(f"decode.v5e.{res}.decode_ms",
                 derived=round(est["decode_ms"], 1))
        rows.add(f"decode.v5e.{res}.unfused.decode_ms",
                 derived=round(base["decode_ms"], 1))
        rows.add(f"decode.v5e.{res}.fused_bytes_saved_mb",
                 derived=round((base["bytes"] - est["bytes"]) / 1e6, 1))
    # paper-reported GPU decode times for context
    rows.add("decode.paper.h100_ms", derived=32.6)
    rows.add("decode.paper.rtx5090_ms", derived=47.3)
    rows.add("decode.paper.generation_ms", derived=3905)
    rows.add("decode.ratio_generation_over_decode", derived=round(
        3905 / decode_ms_estimate(1024)["decode_ms"], 0))


def cpu_crosscheck_rows(rows: Rows) -> None:
    # CPU cross-check: small decoder, batch scaling ~ linear (compute-bound)
    cfg = VAEConfig(name="tiny", latent_channels=4,
                    block_out_channels=(32, 64), layers_per_block=1,
                    groups=8)
    vae = VAE(cfg, with_encoder=False)
    times = {}
    for b in (1, 2, 4):
        z = jnp.zeros((b, 16, 16, 4), jnp.float32)
        vae.decode(z).block_until_ready()
        with Timer() as t:
            for _ in range(5):
                vae.decode(z).block_until_ready()
        times[b] = t.us / 5
        rows.add(f"decode.cpu_tiny.b{b}.us", times[b], round(times[b], 0))
    rows.add("decode.cpu_scaling_b4_over_b1",
             derived=round(times[4] / times[1], 2))


def _fastpath_batchers(vae):
    """(baseline, fast): the pre-PR decode path vs the PR-4 fast path."""
    base = DecodeBatcher(vae, (1, 2, 4, 8), pixel_format="float32",
                         pipeline=False, memo_entries=0)
    fast = DecodeBatcher(vae, (1, 2, 4, 8), pixel_format="uint8",
                         pipeline=True, memo_entries=256)
    base.prewarm(FAST_LATENT)
    fast.prewarm(FAST_LATENT)
    return base, fast


def fastpath_rows(rows: Rows, reps: int = 12) -> None:
    """Interleaved A/B of the regeneration fast path per batch bucket."""
    vae = VAE(FAST_CFG, with_encoder=False)
    rng = np.random.default_rng(0)
    n_oids = 16
    blobs = {i: compress_latent(
        rng.standard_normal(FAST_LATENT).astype(np.float16))
        for i in range(n_oids)}
    node = types.SimpleNamespace(tuner=None)       # no tuner in the bench

    with Timer() as t:
        for _ in range(5):
            from repro.compression.latentcodec import decompress_latent
            decompress_latent(blobs[0])
    rows.add("decode.fastpath.blob_kb", derived=round(len(blobs[0]) / 1e3, 1))
    rows.add("decode.fastpath.decompress_ms", derived=round(t.us / 5 / 1e3, 3))

    base, fast = _fastpath_batchers(vae)

    def windows(batcher, oids, reps):
        """Median per-image ms over repeated serving windows (steady
        state: repeat traffic, so the memo is allowed to work)."""
        samples = []
        for _ in range(reps):
            for i in oids:
                batcher.submit(i, blobs[i], node)
            t0 = time.perf_counter()
            batcher.flush()
            samples.append((time.perf_counter() - t0) * 1e3 / len(oids))
        return samples

    # per-bucket sweep: windows of exactly b oids -> one bucket-b chunk
    for b in (1, 2, 4):
        oids = list(range(b))
        sb, sf = [], []
        for _ in range(reps):                      # interleave the arms
            sb += windows(base, oids, 1)
            sf += windows(fast, oids, 1)
        mb, mf = np.median(sb[1:]), np.median(sf[1:])
        rows.add(f"decode.fastpath.b{b}.base_per_image_ms",
                 derived=round(float(mb), 3))
        rows.add(f"decode.fastpath.b{b}.fast_per_image_ms",
                 derived=round(float(mf), 3))
        rows.add(f"decode.fastpath.b{b}.speedup",
                 derived=round(float(mb / mf), 2))

    # the batch-8 bucket (acceptance metric): 16-oid windows = two
    # bucket-8 chunks, so codec/decode pipelining is live
    oids = list(range(n_oids))
    sb, sf = [], []
    for _ in range(reps):
        sb += windows(base, oids, 1)
        sf += windows(fast, oids, 1)
    mb, mf = np.median(sb[2:]), np.median(sf[2:])
    rows.add("decode.fastpath.b8.base_per_image_ms",
             derived=round(float(mb), 3))
    rows.add("decode.fastpath.b8.fast_per_image_ms",
             derived=round(float(mf), 3))
    rows.add("decode.fastpath.b8.speedup", derived=round(float(mb / mf), 2))

    # pixel-tier byte economics of the two formats at this decoder's
    # output shape (what the DualFormatCache now actually charges)
    h = FAST_LATENT[0] * 2 ** (len(FAST_CFG.block_out_channels) - 1)
    u8 = float(h * h * 3)
    rows.add("decode.pixel_bytes_per_object.uint8", derived=u8)
    rows.add("decode.pixel_bytes_per_object.float32", derived=u8 * 4)
    rows.add("decode.pixel_bytes_per_object.ratio", derived=4.0)
    rows.add("decode.fastpath.memo_hits", derived=fast.stats["memo_hits"])
    rows.add("decode.fastpath.decompressions",
             derived=fast.stats["decompressions"])


def quantized_rows(rows: Rows, smoke: bool = False) -> None:
    """bf16-vs-f32 ``decode_u8`` A/B per bucket, run with a freshly tuned
    kernel cache active: per-image ms of both arms from the same run,
    plus the ±1-LSB gate asserted on every bucket (the admission contract
    of ``StoreConfig.weight_dtype`` — :mod:`repro.vae.quantize`)."""
    from repro.kernels import autotune as at
    from repro.vae import quantize as Q
    from repro.vae.model import demo_vae
    latent, buckets = (8, 8, 4), (1, 2, 4, 8)
    vae = demo_vae(seed=0, weight_dtype="bfloat16")
    st = Q.decoder_storage(vae._params_for("bfloat16"))
    rows.add("decode.quantized.bf16_bytes_per_param",
             derived=round(st["bytes_per_param"], 2))
    # tune the decode shape set first, so the A/B serves tuned blockings
    cache = at.TuningCache(None)
    tuner = at.KernelAutotuner(cache, vae.cfg, weight_dtype="bfloat16",
                               impl="pallas_interpret", reps=1,
                               rows_grid=(8, 16), block_cout_grid=(32, 64))
    for b in buckets:
        tuner.note_bucket(b, latent)
    while tuner.pending:
        tuner.step(8)
    rows.add("decode.quantized.tuned_keys", derived=len(cache))
    reps = 3 if smoke else 8
    with at.active_cache(cache):
        vae.refresh_kernels()               # retrace under the tuned cache
        lsb = Q.gate_max_lsb(vae, buckets, latent)
        for b in buckets:
            assert lsb[b] <= 1, f"bucket {b} breaches the gate: {lsb[b]} LSB"
            z = Q.probe_latents(latent, b, seed=5)
            for prec in ("float32", None):  # warm both arms
                vae.decode_u8(jnp.asarray(z), precision=prec
                              ).block_until_ready()
            tf, tq = [], []
            for _ in range(reps):           # interleave the arms
                t0 = time.perf_counter()
                vae.decode_u8(jnp.asarray(z),
                              precision="float32").block_until_ready()
                tf.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                vae.decode_u8(jnp.asarray(z)).block_until_ready()
                tq.append(time.perf_counter() - t0)
            mf = float(np.median(tf)) * 1e3
            mq = float(np.median(tq)) * 1e3
            rows.add(f"decode.quantized.b{b}.f32_ms", derived=round(mf, 3))
            rows.add(f"decode.quantized.b{b}.bf16_ms", derived=round(mq, 3))
            rows.add(f"decode.quantized.b{b}.speedup",
                     derived=round(mf / max(mq, 1e-9), 2))
            rows.add(f"decode.quantized.b{b}.max_lsb", derived=lsb[b])
    vae.refresh_kernels()                   # drop cache-bound compilations


def run(smoke: bool = False) -> Rows:
    rows = Rows()
    roofline_rows(rows)
    if not smoke:
        cpu_crosscheck_rows(rows)
    fastpath_rows(rows, reps=4 if smoke else 12)
    quantized_rows(rows, smoke=smoke)
    return rows


def trajectory(out_dir: str = REPO_ROOT, smoke: bool = False) -> Rows:
    """The perf-trajectory artifact: ``<out_dir>/BENCH_decode.json``."""
    rows = run(smoke=smoke)
    path = rows.save_json("BENCH_decode", out_dir=out_dir)
    print(f"# saved {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fast-path A/B; writes BENCH_decode.json "
                         "at the repo root")
    args = ap.parse_args()
    if args.smoke:
        trajectory(smoke=True).print()
        return
    rows = run()
    rows.print()
    print(f"# saved {rows.save_json('bench_decode')}")


if __name__ == "__main__":
    main()
