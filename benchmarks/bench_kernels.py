"""Kernel-layer microbenchmark: Pallas (interpret) vs jnp oracle
correctness at bench shapes + the analytic HBM-traffic win of each fusion
on the decode hot path, plus the quantized-weight (bf16 / int8 in-kernel
dequant) error sweep and the autotuner's tuned-vs-default A/B
(:mod:`repro.kernels.autotune`).  Rows persist as JSON under artifacts/ (local,
untracked); ``--smoke`` additionally writes ``BENCH_kernels.json`` at the
repo root (the perf-trajectory artifact CI uploads)."""

from __future__ import annotations

import argparse
import os

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, Timer
from repro.kernels import ops, ref

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run() -> Rows:
    rows = Rows()
    rng = np.random.default_rng(0)

    # gn+silu fusion: unfused = 2 extra r/w of the activation
    n, h, w, c = 1, 64, 64, 512
    x = jnp.asarray(rng.standard_normal((n, h, w, c)), jnp.float32)
    s = jnp.asarray(rng.standard_normal(c), jnp.float32)
    b = jnp.asarray(rng.standard_normal(c), jnp.float32)
    from repro.kernels.gn_silu import group_norm_silu
    out = group_norm_silu(x, s, b, interpret=True)
    err = float(jnp.abs(out - ref.group_norm_silu_ref(x, s, b)).max())
    rows.add("kernel.gn_silu.max_err", derived=f"{err:.1e}")
    act = n * h * w * c * 4
    rows.add("kernel.gn_silu.traffic_fused_mb", derived=round(3 * act / 1e6, 1))
    rows.add("kernel.gn_silu.traffic_unfused_mb",
             derived=round(5 * act / 1e6, 1))

    # flash attention: removes the S^2 score materialization
    s_len, d = 1024, 64
    q = jnp.asarray(rng.standard_normal((1, 1, s_len, d)), jnp.float32)
    from repro.kernels.flash_attention import flash_attention
    with Timer() as t:
        o = flash_attention(q, q, q, interpret=True, block_q=128,
                            block_kv=128)
    err = float(jnp.abs(o - ref.flash_attention_ref(q, q, q)).max())
    rows.add("kernel.flash_attn.max_err", t.us, f"{err:.1e}")
    s_mid = 128 * 128                    # VAE mid-block at 1024px
    rows.add("kernel.flash_attn.scores_bytes_xla_mb",
             derived=round(3 * s_mid * s_mid * 4 / 1e6, 0))
    rows.add("kernel.flash_attn.scores_bytes_flash_mb", derived=0)

    # conv3x3 implicit GEMM: VMEM tiling legality at decode shapes
    from repro.kernels.conv3x3 import VMEM_BUDGET
    for (hh, ww, cin) in ((128, 128, 512), (512, 512, 512), (1024, 1024, 128)):
        rows_band = 32
        while rows_band > 1 and (rows_band + 2) * (ww + 2) * cin * 2 \
                > VMEM_BUDGET:
            rows_band //= 2
        vmem = (rows_band + 2) * (ww + 2) * cin * 2 / 2 ** 20
        rows.add(f"kernel.conv3x3.{hh}x{ww}x{cin}.band_rows",
                 derived=rows_band)
        rows.add(f"kernel.conv3x3.{hh}x{ww}x{cin}.vmem_mb",
                 derived=round(vmem, 1))

    # fused gn+silu+conv3x3 (res-block hot path): correctness at decode
    # shapes + the HBM round-trip of the normalized activation it removes
    from repro.kernels.gn_silu_conv import gn_silu_conv3x3
    for (n, hh, ww, cin, cout, g) in ((1, 16, 16, 64, 64, 8),
                                      (2, 8, 8, 32, 64, 8),
                                      (1, 32, 32, 64, 128, 8)):
        x = jnp.asarray(rng.standard_normal((n, hh, ww, cin)), jnp.float32)
        sc = jnp.asarray(rng.standard_normal(cin), jnp.float32)
        bi = jnp.asarray(rng.standard_normal(cin), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((3, 3, cin, cout)) * 0.1,
                         jnp.float32)
        bc = jnp.asarray(rng.standard_normal(cout), jnp.float32)
        with Timer() as t:
            o = gn_silu_conv3x3(x, sc, bi, wt, bc, groups=g, rows=8,
                                interpret=True)
        err = float(jnp.abs(
            o - ref.gn_silu_conv3x3_ref(x, sc, bi, wt, bc, groups=g)).max())
        tag = f"kernel.gn_silu_conv.{n}x{hh}x{ww}x{cin}to{cout}"
        rows.add(f"{tag}.max_err", t.us, f"{err:.1e}")
        act = n * hh * ww * cin * 4
        # unfused: gn+silu writes y, conv re-reads y -> 2 extra activation
        # passes the fusion keeps in VMEM
        rows.add(f"{tag}.traffic_saved_mb", derived=round(2 * act / 1e6, 2))

    # fused nearest-2x upsample + conv3x3 (decoder upsampler): the phase
    # decomposition never materializes the 4x intermediate in HBM and
    # collapses 9 taps over 4x pixels into 16 taps over 1x pixels
    from repro.kernels.upsample_conv import upsample_conv3x3
    for (n, hh, ww, cin, cout) in ((1, 16, 16, 64, 64), (2, 8, 12, 32, 64)):
        x = jnp.asarray(rng.standard_normal((n, hh, ww, cin)), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((3, 3, cin, cout)) * 0.1,
                         jnp.float32)
        bc = jnp.asarray(rng.standard_normal(cout), jnp.float32)
        with Timer() as t:
            o = upsample_conv3x3(x, wt, bc, rows=8, interpret=True)
        err = float(jnp.abs(o - ref.upsample_conv3x3_ref(x, wt, bc)).max())
        tag = f"kernel.upsample_conv.{n}x{hh}x{ww}x{cin}to{cout}"
        rows.add(f"{tag}.max_err", t.us, f"{err:.1e}")
        # unfused: the upsampled [2h, 2w, c] intermediate is written by
        # the repeat and re-read by the conv
        inter = n * 4 * hh * ww * cin * 4
        rows.add(f"{tag}.intermediate_saved_mb",
                 derived=round(2 * inter / 1e6, 2))
        rows.add(f"{tag}.mac_ratio", derived=round(36 / 16, 2))

    # fused output epilogue (GN+SiLU+conv_out+clamp+uint8): the decode's
    # last write is the displayable image at 1/4 the float32 bytes
    from repro.kernels.output_epilogue import output_epilogue
    for (n, hh, ww, cin, g) in ((1, 16, 16, 64, 8), (2, 8, 8, 32, 8)):
        x = jnp.asarray(rng.standard_normal((n, hh, ww, cin)), jnp.float32)
        sc = jnp.asarray(rng.standard_normal(cin), jnp.float32)
        bi = jnp.asarray(rng.standard_normal(cin), jnp.float32)
        wt = jnp.asarray(rng.standard_normal((3, 3, cin, 3)) * 0.1,
                         jnp.float32)
        bc = jnp.asarray(rng.standard_normal(3) * 0.1, jnp.float32)
        with Timer() as t:
            o = output_epilogue(x, sc, bi, wt, bc, groups=g, rows=8,
                                interpret=True)
        want = ref.output_epilogue_ref(x, sc, bi, wt, bc, groups=g)
        lsb = int(np.abs(np.asarray(o, np.int16)
                         - np.asarray(want, np.int16)).max())
        tag = f"kernel.output_epilogue.{n}x{hh}x{ww}x{cin}"
        rows.add(f"{tag}.max_lsb", t.us, lsb)
        rows.add(f"{tag}.out_bytes_ratio_f32_over_u8", derived=4.0)

    # decode attention: streams the KV cache exactly once
    n, hq, hkv, S, d = 2, 8, 2, 512, 64
    q1 = jnp.asarray(rng.standard_normal((n, hq, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((n, hkv, S, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((n, hkv, S, d)), jnp.float32)
    lens = jnp.full((n,), S, jnp.int32)
    from repro.kernels.decode_attention import decode_attention
    o = decode_attention(q1, kc, vc, lens, interpret=True)
    err = float(jnp.abs(o - ref.decode_attention_ref(q1, kc, vc, lens)).max())
    rows.add("kernel.decode_attn.max_err", derived=f"{err:.1e}")
    gqa_reread = hq // hkv
    rows.add("kernel.decode_attn.kv_reads_xla", derived=gqa_reread)
    rows.add("kernel.decode_attn.kv_reads_kernel", derived=1)

    quantized_rows(rows)
    tuned_rows(rows)
    return rows


def quantized_rows(rows: Rows) -> None:
    """weight_dtype sweep per kernel x bucket: in-kernel dequant (bf16 /
    per-channel int8, :mod:`repro.vae.quantize`) vs the f32 oracle at the
    demo decoder's dispatch shapes — us/call and max output error."""
    from repro.kernels import autotune as at
    from repro.vae.model import DEMO_VAE
    for bucket in (1, 2):
        specs = {}
        for s in at.decode_shapes(DEMO_VAE, (8, 8, 4), bucket):
            specs.setdefault(s["kernel"], s)     # one shape per kernel
        for kernel, spec in specs.items():
            oracle = None
            for wd in ("float32", "bfloat16", "int8"):
                thunk = at._make_thunk(spec, wd, "pallas_interpret",
                                       at.DEFAULTS[kernel])
                with Timer() as t:
                    out = np.asarray(jax.block_until_ready(thunk()))
                if wd == "float32":
                    oracle = out
                    continue
                if out.dtype == np.uint8:        # epilogue compares in LSB
                    err = int(np.abs(out.astype(np.int16)
                                     - oracle.astype(np.int16)).max())
                else:
                    err = f"{float(np.abs(out - oracle).max()):.1e}"
                rows.add(f"kernel.quantized.{kernel}.b{bucket}.{wd}.max_err",
                         t.us, err)


def tuned_rows(rows: Rows) -> None:
    """In-bench autotune A/B over the demo decode shapes: the persisted
    winner's us vs the measured default's, from the same sweep.  A winner
    slower than the default can never be recorded silently — candidate 0
    is always the default and ties keep it, and this bench asserts the
    invariant on every entry it emits."""
    from benchmarks.common import ART
    from repro.kernels import autotune as at
    from repro.vae.model import DEMO_VAE
    path = os.path.join(ART, at.CACHE_FILENAME)
    cache = at.TuningCache.load(path)
    if len(cache) == 0:                          # cold: defaults serve
        rows.add("tuning.fallback",
                 derived="cold cache: hand-picked defaults until tuned")
    tuner = at.KernelAutotuner(cache, DEMO_VAE, impl="pallas_interpret",
                               reps=2, rows_grid=(8, 16, 32),
                               block_cout_grid=(32, 64, 128))
    for b in (1, 2):
        tuner.note_bucket(b, (8, 8, 4))
    while tuner.pending:
        tuner.step(4)
    for key, e in sorted(cache.entries.items()):
        assert e["us"] <= e["default_us"], \
            f"tuned {key} regressed vs its own default measurement"
        rows.add(f"kernel.tuned.{key}.us", e["us"],
                 round(e["default_us"] / max(e["us"], 1e-9), 2))
        rows.add(f"kernel.tuned.{key}.default_us", e["default_us"])
    rows.add("kernel.tuned.keys", derived=len(cache))


def trajectory(out_dir: str = REPO_ROOT) -> Rows:
    """The perf-trajectory artifact: ``<out_dir>/BENCH_kernels.json``."""
    rows = run()
    path = rows.save_json("BENCH_kernels", out_dir=out_dir)
    print(f"# saved {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="interpret-mode kernel sweep; writes "
                         "BENCH_kernels.json at the repo root")
    args = ap.parse_args()
    if args.smoke:
        trajectory().print()
        return
    rows = run()
    rows.print()
    print(f"# saved {rows.save_json('bench_kernels')}")


if __name__ == "__main__":
    main()
