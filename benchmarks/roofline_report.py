"""Assignment §Roofline — three-term roofline per (arch x shape x mesh)
from the dry-run artifacts, baseline vs optimized, printed as CSV rows
plus the human-readable table (EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import os

from benchmarks.common import Rows
from repro.launch.roofline import ART_DIR, format_table, full_table

BASE_DIR = os.path.join(os.path.dirname(ART_DIR), "dryrun_baseline")


def run(print_tables: bool = False) -> Rows:
    rows = Rows()
    for tag, art in (("opt", ART_DIR), ("base", BASE_DIR)):
        if not os.path.isdir(art):
            continue
        for mesh in ("single", "multi") if tag == "opt" else ("single",):
            table = full_table(mesh, art_dir=art)
            if print_tables:
                print(f"\n=== roofline {tag} ({mesh}-pod) ===")
                print(format_table(table))
            for r in table:
                key = f"roofline.{tag}.{r['arch']}.{r['shape']}.{mesh}"
                if r.get("status") != "ok":
                    rows.add(f"{key}.status", derived=r.get("status"))
                    continue
                rows.add(f"{key}.dominant", derived=r["dominant"])
                rows.add(f"{key}.fraction", derived=r["roofline_fraction"])
                rows.add(f"{key}.compute_s", derived=r["compute_s"])
                rows.add(f"{key}.collective_s", derived=r["collective_s"])
    return rows


def main():
    run(print_tables=True).print()


if __name__ == "__main__":
    main()
