"""Cost benchmarks — the long-term projection AND the live serving bill.

Two sections:

1. ``fig8_rows()`` — paper Fig. 8 + §6.4: long-term cost projection to
   2050, normalized so ImgStore at trace end (2026.25) = 1.  Four setups
   x two price scenarios.  Pure closed-form model, no replay.

2. ``trace_rows()`` — the elastic-autoscaler headline: replay ``diurnal``
   and ``zipf_drift`` open-loop arrival streams through the simulator
   backend under three plants —

     * ``static_small``  1 decode GPU/node (cheap; overloads at peak),
     * ``static_peak``   2 decode GPUs/node (provisioned for the peak,
                         idle in the trough),
     * ``autoscaled``    starts at 1 GPU/node with the cost-model-driven
                         :class:`~repro.core.autoscale.AutoscaleController`
                         trading decode GPUs against cache bytes live —

   and report $-per-million-requests (provisioned-resource integrals
   priced by :func:`~repro.core.cost_model.dollars_per_million_requests`)
   at a fixed 250 ms latency SLO.  The certified operating point
   (``diurnal`` at ``load_factor=1.0``) asserts the headline: the
   autoscaled plant is strictly cheaper than static-peak at equal SLO
   attainment, with nonzero hysteresis-bounded scale-up AND scale-down
   event counts.

Promotion is disabled in the replay config so the plant stays
decode-bound (same idiom as ``bench_runtime``): a warmed pixel cache
would turn the sweep into a no-queue image-hit run and measure nothing.

``--smoke`` (the CI step) runs 2 load factors and versions the result as
``BENCH_cost.json`` at the repo root via ``trajectory()``; the nightly
job runs the full load ladder (``REPRO_BENCH_SCALE=full``).
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from benchmarks.common import Rows, scale
from repro.core.autoscale import AutoscaleConfig
from repro.core.cost_model import (CostParams, CostScenario,
                                   dollars_per_million_requests,
                                   normalized_horizons, params_for_store,
                                   project)
from repro.core.regen_tier import Recipe
from repro.core.tuner import TunerConfig
from repro.store import LatentBox, StoreConfig
from repro.trace.synth import TraceConfig, make_trace

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Base arrival rate (req/s) the trace span is normalized to at
#: ``load_factor=1.0`` — sized so the mean load (~2.5 GPUs of decode
#: demand at 31 ms/decode) fits the static-small plant's 4 GPUs, while
#: the diurnal peak (amplitude 0.8, ~1.8x the mean, ~4.5 GPUs) overloads
#: it; static-peak's 8 GPUs ride the peak out but idle in the trough —
#: the dilemma the autoscaler resolves.
BASE_RATE_RPS = 80.0

#: The fixed latency SLO the $-per-million-requests comparison holds
#: constant: a request attains it iff end-to-end latency <= this.
SLO_MS = 250.0


# ---------------------------------------------------------------------------
# section 1 — paper Fig. 8 long-term projection
# ---------------------------------------------------------------------------

def fig8_rows() -> Rows:
    rows = Rows()
    for tag, sc in (("const", CostScenario()),
                    ("decline", CostScenario(gpu_price_decline_yr=0.20,
                                             storage_price_decline_yr=0.10))):
        curves = project(CostParams(), sc)
        norm = normalized_horizons(curves)
        for setup, vals in norm.items():
            for yr, v in vals.items():
                rows.add(f"cost.{tag}.{setup}.{yr:g}", derived=round(v, 2))
        # headline savings
        ref = norm["imgstore"][2050.0]
        for setup in ("lb_5090", "lb_h100", "imgstore_glacier"):
            sav = 100 * (1 - norm[setup][2050.0] / ref)
            rows.add(f"cost.{tag}.{setup}.saving_2050_pct",
                     derived=round(sav, 1))
        sav_vs_glacier = 100 * (1 - norm["lb_5090"][2050.0]
                                / norm["imgstore_glacier"][2050.0])
        rows.add(f"cost.{tag}.lb5090_vs_glacier_pct",
                 derived=round(sav_vs_glacier, 1))
    return rows


# ---------------------------------------------------------------------------
# section 2 — trace-driven $-per-million-requests at a fixed SLO
# ---------------------------------------------------------------------------

def _cfg(gpus_per_node: int, autoscale: bool = False) -> StoreConfig:
    """Decode-bound replay plant (the ``bench_runtime`` idiom: promotion
    and the marginal-hit tuner disabled so every request decodes)."""
    return StoreConfig(
        n_nodes=4, cache_bytes_per_node=2e4, image_bytes=768.0,
        latent_bytes=6e2, promote_threshold=10**6,
        tuner=TunerConfig(window=10**9),
        gpus_per_node=gpus_per_node, autoscale=autoscale,
        # window ~0.5 s of trace time and a 1-window cooldown: react to a
        # diurnal ramp within a couple of seconds.  util_high=0.70 buys
        # scale-up headroom before the queue builds; cache_gain=0.05
        # because this plant is decode-bound by construction (promotion
        # off), so the marginal cache benefit really is ~0.
        autoscale_cfg=AutoscaleConfig(window=48, cooldown_windows=1,
                                      util_high=0.70, cache_gain=0.05,
                                      max_gpus_per_node=4)
        if autoscale else None)


#: The three plants of the A-B-C: name -> (gpus_per_node, autoscale).
PLANTS = (("static_small", 1, False),
          ("static_peak", 2, False),
          ("autoscaled", 1, True))


def _replay(cfg: StoreConfig, scenario: str, n_objects: int,
            n_requests: int, load_factor: float) -> dict:
    """Put ``n_objects``, replay the open-loop stream in request windows
    of 8, and return summary + attainment + $-per-million-requests."""
    span_days = n_requests / (BASE_RATE_RPS * 86_400.0)
    knobs = {}
    if scenario == "diurnal":
        # one full sinusoid over the span: a ramp to peak, a trough —
        # exactly the shape that makes static provisioning a dilemma
        knobs["period_days"] = span_days
    # Low Zipf skew: with the paper's alpha one hot object pins ~20 % of
    # all traffic on a single node's queue, and the benchmark would
    # measure consistent-hash placement skew, not provisioning.  The
    # cost A-B-C wants aggregate capacity to be the binding constraint.
    tcfg = TraceConfig(n_objects=n_objects, n_requests=n_requests,
                       span_days=span_days, zipf_alpha=0.3, seed=11)
    tr = make_trace(scenario, config=tcfg, load_factor=load_factor, **knobs)
    box = LatentBox.simulated(cfg)
    for oid in range(n_objects):
        box.put(oid, recipe=Recipe(seed=1000 + oid, height=16, width=16),
                nbytes=600.0)
    ts_ms = tr.timestamps * 1e3
    ids = tr.object_ids
    n_results = 0
    for s in range(0, len(ids), 8):
        n_results += len(box.get_many(ids[s:s + 8],
                                      timestamps_ms=ts_ms[s:s + 8]))
    assert n_results == n_requests, "request lost in replay"
    summ = box.summary()
    lat = np.asarray(box.backend.log.latency_ms, dtype=np.float64)
    assert len(lat) == n_requests, "request missing from the log"
    return {
        "summary": summ,
        "attainment": float(np.mean(lat <= SLO_MS)),
        "p99_ms": float(np.percentile(lat, 99)),
        "dpm": dollars_per_million_requests(
            summ, n_requests, params=params_for_store(cfg)),
    }


def trace_rows(smoke: bool = False) -> Rows:
    rows = Rows()
    # 64 objects x 600 B stay fully latent-resident (~16 x 600 B per
    # node against the 2e4 cache): after the first pass every request is
    # a latent hit, so latency is queue + decode and the SLO measures
    # provisioning, not durable-fetch tails.
    n_objects = 64
    n_requests = 4_800 if smoke else int(scale(4_800, 9_600))
    load_factors = (0.7, 1.0) if smoke else \
        tuple(scale((0.7, 1.0), (0.5, 0.7, 1.0, 1.5, 2.0)))

    for scenario in ("diurnal", "zipf_drift"):
        for lf in load_factors:
            tag = f"dpm.{scenario}.lf{lf}"
            res = {}
            for name, gpus, auto in PLANTS:
                r = _replay(_cfg(gpus, auto), scenario, n_objects,
                            n_requests, lf)
                res[name] = r
                s = r["summary"]
                rows.add(f"{tag}.{name}.dollars_per_mreq",
                         derived=round(r["dpm"], 4))
                rows.add(f"{tag}.{name}.slo_attainment",
                         derived=round(r["attainment"], 4))
                rows.add(f"{tag}.{name}.p99_ms",
                         derived=round(r["p99_ms"], 1))
                rows.add(f"{tag}.{name}.decode_gpus_end",
                         derived=int(s["decode_gpus"]))
                if auto:
                    rows.add(f"{tag}.{name}.scale_up_events",
                             derived=int(s["scale_up_events"]))
                    rows.add(f"{tag}.{name}.scale_down_events",
                             derived=int(s["scale_down_events"]))

            auto, peak = res["autoscaled"], res["static_peak"]
            rows.add(f"{tag}.autoscaled_vs_peak_saving_pct",
                     derived=round(100 * (1 - auto["dpm"] / peak["dpm"]), 1))

            if scenario == "diurnal" and lf == 1.0:
                # the certified operating point (acceptance criteria):
                # autoscaled strictly cheaper than static-peak at equal
                # SLO attainment, with hysteresis-bounded event counts
                # in BOTH directions (it scaled up for the peak and back
                # down for the trough — not a one-way ratchet)
                assert auto["dpm"] < peak["dpm"], \
                    f"{tag}: autoscaled not cheaper than static-peak"
                assert auto["attainment"] >= peak["attainment"] - 0.02, \
                    f"{tag}: autoscaled gave up SLO attainment"
                ups = int(auto["summary"]["scale_up_events"])
                downs = int(auto["summary"]["scale_down_events"])
                assert 1 <= ups <= 12, f"{tag}: scale-ups {ups}"
                assert 1 <= downs <= 12, f"{tag}: scale-downs {downs}"
    return rows


def run(smoke: bool = False) -> Rows:
    rows = fig8_rows()
    rows.extend(trace_rows(smoke=smoke))
    return rows


def trajectory(out_dir: str = REPO_ROOT, smoke: bool = False) -> Rows:
    """The cost-trajectory artifact: ``<out_dir>/BENCH_cost.json`` —
    Fig. 8 projections plus the trace-driven $-per-million-requests
    A-B-C (static-small / static-peak / autoscaled) at a fixed 250 ms
    SLO, versioned at the repo root so later checkouts regress against
    it."""
    rows = run(smoke=smoke)
    path = rows.save_json("BENCH_cost", out_dir=out_dir)
    print(f"# saved {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; writes BENCH_cost.json at the "
                         "repo root")
    args = ap.parse_args()
    if args.smoke:
        trajectory(smoke=True).print()
        return
    run().print()


if __name__ == "__main__":
    main()
