"""Paper Fig. 8 + §6.4 — long-term cost projection to 2050, normalized so
ImgStore at trace end (2026.25) = 1.  Four setups x two price scenarios."""

from __future__ import annotations

from benchmarks.common import Rows
from repro.core.cost_model import (CostParams, CostScenario,
                                   normalized_horizons, project)


def run() -> Rows:
    rows = Rows()
    for tag, sc in (("const", CostScenario()),
                    ("decline", CostScenario(gpu_price_decline_yr=0.20,
                                             storage_price_decline_yr=0.10))):
        curves = project(CostParams(), sc)
        norm = normalized_horizons(curves)
        for setup, vals in norm.items():
            for yr, v in vals.items():
                rows.add(f"cost.{tag}.{setup}.{yr:g}", derived=round(v, 2))
        # headline savings
        ref = norm["imgstore"][2050.0]
        for setup in ("lb_5090", "lb_h100", "imgstore_glacier"):
            sav = 100 * (1 - norm[setup][2050.0] / ref)
            rows.add(f"cost.{tag}.{setup}.saving_2050_pct",
                     derived=round(sav, 1))
        sav_vs_glacier = 100 * (1 - norm["lb_5090"][2050.0]
                                / norm["imgstore_glacier"][2050.0])
        rows.add(f"cost.{tag}.lb5090_vs_glacier_pct",
                 derived=round(sav_vs_glacier, 1))
    return rows


def main():
    run().print()


if __name__ == "__main__":
    main()
