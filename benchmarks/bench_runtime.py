"""Serving-runtime benchmark — queueing, QoS, and SLO attainment under load.

Replays open-loop arrival streams (``make_trace(..., load_factor=...)``)
through the event-loop serving runtime on the simulator backend and
reports, per scenario x load factor, a QoS/admission ON vs OFF A-B:

* per-class p50/p99 end-to-end latency (queue delay INCLUDED — the number
  a closed-loop replay structurally cannot produce);
* SLO attainment per class (interactive 250 ms / batch 4 s deadlines);
* shed/degraded fractions, mean dispatched batch size, forced-dispatch
  share.

The headline the acceptance criteria pin: at overload, the full stack
(queue-jump + weighted-fair dequeue + shed admission) holds the
interactive class inside its deadline while confining damage to the batch
class; with the stack off, every class's tail collapses together.

Promotion is disabled in the benchmark config so the plant stays
decode-bound at every load factor (a warmed pixel cache would turn the
sweep into a no-queue image-hit run and measure nothing).

``--smoke`` (the CI step) runs 3 load factors and versions the result as
``BENCH_runtime.json`` at the repo root via ``trajectory()``; the nightly
job runs the full load ladder (``REPRO_BENCH_SCALE=full``).
"""

from __future__ import annotations

import argparse
import os

from benchmarks.common import Rows, scale
from repro.core.regen_tier import Recipe
from repro.core.tuner import TunerConfig
from repro.serve.runtime import (AdmissionConfig, RuntimeConfig,
                                 SLO_BATCH, SLO_INTERACTIVE,
                                 requests_from_trace)
from repro.store import LatentBox, StoreConfig
from repro.trace.synth import make_trace

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Base arrival rate (req/s) the trace span is normalized to at
#: ``load_factor=1.0`` — roughly the virtual decode capacity of one full
#: bucket pipeline, so 1.0 sits at the knee and >1 is genuine overload.
BASE_RATE_RPS = 100.0


def _cfg(**kw) -> StoreConfig:
    base = dict(n_nodes=8, cache_bytes_per_node=2e4, image_bytes=768.0,
                latent_bytes=6e2, promote_threshold=10**6,
                tuner=TunerConfig(window=10**9))
    base.update(kw)
    return StoreConfig(**base)


def _box(n_objects: int) -> LatentBox:
    box = LatentBox.simulated(_cfg())
    for oid in range(n_objects):
        box.put(oid, recipe=Recipe(seed=1000 + oid, height=16, width=16),
                nbytes=600.0)
    return box


def _requests(scenario: str, n_objects: int, n_requests: int,
              load_factor: float):
    """Open-loop request stream: the trace span is sized so arrivals come
    at ``BASE_RATE_RPS * load_factor``; multi_tenant carries tenants and
    SLO classes natively, flash_crowd gets 1-in-10 interactive arrivals
    (the user-facing slice of a spike that is mostly bulk refetch), so the
    interactive class alone stays below plant capacity until ~6x load."""
    span_days = n_requests / (BASE_RATE_RPS * 86_400.0)
    tr = make_trace(scenario, n_objects=n_objects, n_requests=n_requests,
                    span_days=span_days, seed=7, load_factor=load_factor)
    if scenario == "multi_tenant":
        return requests_from_trace(tr)
    reqs = []
    for k, r in enumerate(requests_from_trace(tr)):
        r.slo = SLO_INTERACTIVE if k % 10 == 0 else SLO_BATCH
        r.tenant = k % 3
        reqs.append(r)
    return reqs


def _runtime_cfg(qos: bool) -> RuntimeConfig:
    if qos:
        return RuntimeConfig(qos=True, admission=AdmissionConfig(
            enabled=True, policy="shed"))
    return RuntimeConfig(qos=False, admission=AdmissionConfig(enabled=False))


def _emit(rows: Rows, tag: str, rep) -> dict:
    s = rep.summary()
    for cls in (SLO_INTERACTIVE, SLO_BATCH):
        for key in ("p50_ms", "p99_ms", "slo_attainment",
                    "shed_frac", "degraded_frac", "queue_delay_p99_ms"):
            v = s.get(f"{cls}.{key}")
            if v is not None:
                rows.add(f"{tag}.{cls}.{key}", derived=round(float(v), 4))
    rows.add(f"{tag}.mean_batch",
             derived=round(s["batched_requests"]
                           / max(1.0, s["dispatches"]), 3))
    rows.add(f"{tag}.forced_dispatch_frac",
             derived=round(s["forced_dispatches"]
                           / max(1.0, s["dispatches"]), 4))
    rows.add(f"{tag}.shed", derived=int(s["shed"]))
    rows.add(f"{tag}.makespan_ms", derived=round(rep.makespan_ms, 1))
    return s


def sweep_rows(smoke: bool = False) -> Rows:
    rows = Rows()
    # stream shape is pinned across scales (same spike realization, same
    # certified operating points); scale extends the load-factor ladder
    n_objects, n_requests = 24, 600
    load_factors = (0.5, 2.0, 6.0) if smoke else \
        tuple(scale((0.5, 1.0, 2.0, 4.0, 6.0), (0.25, 0.5, 1, 2, 3, 4, 6, 8)))
    deadline = _runtime_cfg(True).interactive_deadline_ms

    for scenario in ("flash_crowd", "multi_tenant"):
        for lf in load_factors:
            tag = f"runtime.{scenario}.lf{lf}"
            summaries = {}
            for qos in (True, False):
                reqs = _requests(scenario, n_objects, n_requests, lf)
                rep = _box(n_objects).serve_stream(
                    reqs, runtime_cfg=_runtime_cfg(qos))
                name = "qos" if qos else "fifo"
                summaries[name] = _emit(rows, f"{tag}.{name}", rep)

            on, off = summaries["qos"], summaries["fifo"]
            int_p99 = on[f"{SLO_INTERACTIVE}.p99_ms"]
            rows.add(f"{tag}.qos.interactive_slo_held",
                     derived=bool(int_p99 <= deadline))

            # invariants the artifact certifies (acceptance criteria):
            # damage is confined to the batch class at every operating
            # point, and under overload the stack beats FIFO's interactive
            # tail outright
            assert on[f"{SLO_INTERACTIVE}.shed_frac"] == 0.0, tag
            assert on.get(f"{SLO_INTERACTIVE}.degraded_frac", 0.0) == 0.0, tag
            if lf >= 2.0:
                assert int_p99 < 0.8 * off[f"{SLO_INTERACTIVE}.p99_ms"], \
                    f"{tag}: QoS did not beat FIFO's interactive tail"
                assert on[f"{SLO_INTERACTIVE}.slo_attainment"] >= \
                    off[f"{SLO_INTERACTIVE}.slo_attainment"], tag
            if scenario == "flash_crowd" and lf == 2.0:
                # the headline (certified overload point, in every
                # ladder): at 2x overload the interactive class stays
                # inside its deadline while batch-class work is shed
                assert int_p99 <= deadline, \
                    f"{tag}: interactive p99 blew its SLO under overload"
                assert on["shed"] > 0, tag
    return rows


def run(smoke: bool = False) -> Rows:
    return sweep_rows(smoke=smoke)


def trajectory(out_dir: str = REPO_ROOT, smoke: bool = False) -> Rows:
    """The runtime-trajectory artifact: ``<out_dir>/BENCH_runtime.json``
    — per-class tails + SLO attainment at 3 load factors, QoS on/off,
    versioned at the repo root so later checkouts regress against it."""
    rows = run(smoke=smoke)
    path = rows.save_json("BENCH_runtime", out_dir=out_dir)
    print(f"# saved {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; writes BENCH_runtime.json at the "
                         "repo root")
    args = ap.parse_args()
    if args.smoke:
        trajectory(smoke=True).print()
        return
    run().print()


if __name__ == "__main__":
    main()
