"""Fault-tolerance benchmark — the tail + recovery numbers of PR 6.

Three measurements on a 4-shard R=2 replicated cluster:

* **hedging** — p50/p99 of a hot trace with one replica stalling
  mid-run, hedged reads off vs on.  The headline: hedging pulls the
  slow-replica p99 back toward the healthy baseline while firing zero
  extra decodes (``hedge_wins`` counts races won post-hoc).
* **failover** — mean/p99 read latency with one shard dead, replicas
  serving its keys, vs the healthy cluster.
* **recovery** — wall-clock seconds for a killed persistent shard to
  restart, replay its own log, and delta-catch-up from its peers until
  ``under_replicated_objects() == 0``.

``--smoke`` (the CI step) shrinks the trace and versions the result as
``BENCH_resilience.json`` at the repo root via ``trajectory()``.
"""

from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import Rows, scale
from repro.core.regen_tier import Recipe
from repro.core.tuner import TunerConfig
from repro.store import FaultPlan, HedgeConfig, LatentBox, StoreConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SHARDS = 4
REPLICATION = 2


def _cfg(**kw) -> StoreConfig:
    base = dict(n_nodes=2, cache_bytes_per_node=2e4, image_bytes=768.0,
                latent_bytes=6e2, promote_threshold=2,
                tuner=TunerConfig(window=10**9))
    base.update(kw)
    return StoreConfig(**base)


def _trace(n_objects: int, length: int, seed: int = 11):
    rng = np.random.default_rng(seed)
    hot = rng.choice(max(1, n_objects // 4), size=length // 2)
    cold = rng.choice(n_objects, size=length - len(hot))
    seq = np.concatenate([hot, cold])
    rng.shuffle(seq)
    return [int(x) for x in seq]


def _fill(box, n_objects: int) -> None:
    for oid in range(n_objects):
        box.put(oid, recipe=Recipe(seed=1000 + oid, height=16, width=16),
                nbytes=600.0)


def _drive(box, trace, window: int = 8):
    out = []
    for s in range(0, len(trace), window):
        out += box.get_many(trace[s:s + window])
    return out


def _pcts(results):
    lat = [r.total_ms for r in results]
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def hedging_rows(smoke: bool = False) -> Rows:
    rows = Rows()
    n = 24 if smoke else scale(64, 256)
    length = 240 if smoke else scale(1200, 4800)
    trace = _trace(n, length)
    stall_at, stall_ms = length // 10, 400.0

    def run(hedge):
        box = LatentBox.simulated(
            _cfg(), shards=SHARDS, replication=REPLICATION, hedge=hedge,
            fault_plan=FaultPlan.stall(0, stall_at, stall_ms))
        _fill(box, n)
        res = _drive(box, trace)
        return box, res

    healthy_box = LatentBox.simulated(_cfg(), shards=SHARDS,
                                      replication=REPLICATION)
    _fill(healthy_box, n)
    p50_h, p99_h = _pcts(_drive(healthy_box, trace))
    rows.add("resilience.healthy.p50_ms", derived=round(p50_h, 3))
    rows.add("resilience.healthy.p99_ms", derived=round(p99_h, 3))

    off_box, off = run(HedgeConfig(enabled=False))
    p50_off, p99_off = _pcts(off)
    rows.add("resilience.slow_replica.hedge_off.p50_ms",
             derived=round(p50_off, 3))
    rows.add("resilience.slow_replica.hedge_off.p99_ms",
             derived=round(p99_off, 3))

    on_box, on = run(HedgeConfig(quantile=0.9, min_samples=8))
    p50_on, p99_on = _pcts(on)
    s = on_box.summary()
    rows.add("resilience.slow_replica.hedge_on.p50_ms",
             derived=round(p50_on, 3))
    rows.add("resilience.slow_replica.hedge_on.p99_ms",
             derived=round(p99_on, 3))
    rows.add("resilience.hedges_fired", derived=s["hedges_fired"])
    rows.add("resilience.hedge_wins", derived=s["hedge_wins"])
    rows.add("resilience.hedge_p99_reduction_ms",
             derived=round(p99_off - p99_on, 3))
    # the single-flight invariant the tests pin down, surfaced as data:
    # hedging re-times requests, it never adds serving work
    off_s = off_box.summary()
    rows.add("resilience.hedge_extra_work",
             derived=int(sum(s[k] - off_s[k] for k in
                             ("image_hit", "latent_hit", "full_miss",
                              "regen_miss", "total"))))
    assert s["hedge_wins"] > 0, "hedging never won a race — check the knobs"
    assert p99_on <= p99_off, "hedging made the tail WORSE"
    return rows


def failover_rows(smoke: bool = False) -> Rows:
    rows = Rows()
    n = 24 if smoke else scale(64, 256)
    length = 240 if smoke else scale(1200, 4800)
    trace = _trace(n, length)

    healthy = LatentBox.simulated(_cfg(), shards=SHARDS,
                                  replication=REPLICATION)
    hurt = LatentBox.simulated(_cfg(), shards=SHARDS,
                               replication=REPLICATION,
                               fault_plan=FaultPlan.kill(1, length // 10))
    for box in (healthy, hurt):
        _fill(box, n)
    res_h = _drive(healthy, trace)
    res_d = _drive(hurt, trace)
    same = ([(r.hit_class, r.node) for r in res_h]
            == [(r.hit_class, r.node) for r in res_d])
    p50_h, p99_h = _pcts(res_h)
    p50_d, p99_d = _pcts(res_d)
    fo = [r.total_ms for r in res_d if r.failover]
    rows.add("resilience.dead_shard.p50_ms", derived=round(p50_d, 3))
    rows.add("resilience.dead_shard.p99_ms", derived=round(p99_d, 3))
    rows.add("resilience.failover_reads", derived=len(fo))
    rows.add("resilience.failover_read_mean_ms",
             derived=round(float(np.mean(fo)), 3) if fo else 0.0)
    rows.add("resilience.dead_shard.conformant", derived=same)
    assert same, "dead-shard run diverged from healthy classification"
    assert hurt.summary()["failovers"] > 0
    return rows


def recovery_rows(smoke: bool = False) -> Rows:
    rows = Rows()
    n = 24 if smoke else scale(96, 384)
    length = 160 if smoke else scale(800, 3200)
    kill_at, restart_at = length // 8, length // 2
    root = tempfile.mkdtemp(prefix="bench_resilience_")
    try:
        box = LatentBox.open(
            root, mode="sim", config=_cfg(write_behind=True),
            shards=SHARDS, replication=REPLICATION,
            fault_plan=FaultPlan.kill_restart(2, kill_at, restart_at))
        _fill(box, n)
        trace = _trace(n, length)
        # drive up to (but not past) the restart boundary, then time the
        # window that crosses it: that window pays the full recovery —
        # log replay + peer delta catch-up
        t_restart = None
        for s in range(0, len(trace), 8):
            crosses = s <= restart_at < s + 8
            t0 = time.perf_counter()
            box.get_many(trace[s:s + 8])
            if crosses:
                t_restart = time.perf_counter() - t0
        under = box.backend.under_replicated_objects()
        rows.add("resilience.recovery.catch_up_s",
                 derived=round(t_restart or 0.0, 4))
        rows.add("resilience.recovery.under_replicated", derived=under)
        rows.add("resilience.recovery.restarts",
                 derived=box.summary()["restarts"])
        assert under == 0, "restart left objects under-replicated"
        box.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def run(smoke: bool = False) -> Rows:
    rows = Rows()
    rows.extend(hedging_rows(smoke=smoke))
    rows.extend(failover_rows(smoke=smoke))
    rows.extend(recovery_rows(smoke=smoke))
    return rows


def trajectory(out_dir: str = REPO_ROOT, smoke: bool = False) -> Rows:
    """The resilience-trajectory artifact:
    ``<out_dir>/BENCH_resilience.json`` — hedged-tail, failover, and
    recovery numbers versioned at the repo root so later checkouts have
    a trend to regress against."""
    rows = run(smoke=smoke)
    path = rows.save_json("BENCH_resilience", out_dir=out_dir)
    print(f"# saved {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; writes BENCH_resilience.json at "
                         "the repo root")
    args = ap.parse_args()
    if args.smoke:
        trajectory(smoke=True).print()
        return
    run().print()


if __name__ == "__main__":
    main()
