"""Cache-split tuning: paper Fig. 9 (the MARGINAL-HIT tuner's adaptive
alpha vs the oracle-picked static split, plus its trajectory) and Fig. 11
(sensitivity to Delta, W, tau, h).

This benches ``repro.core.tuner.MarginalHitTuner`` — the *cache policy*
tuner that moves the image/latent capacity split alpha online.  It is a
different animal from the *kernel* autotuner
(:mod:`repro.kernels.autotune`), which sweeps Pallas block/band shapes
per decode shape and persists winners to a tuning cache; that one is
benched by ``bench_kernels.tuned_rows`` / ``bench_decode.quantized_rows``
(see README "Performance")."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Rows, Timer, bench_trace, scale
from repro.core.replay import ReplayConfig, replay, sweep_static_alpha
from repro.core.tuner import TunerConfig

IMG_B = 1.4e6


def run(sweep: bool = True) -> Rows:
    rows = Rows()
    tr = bench_trace()
    ids = tr.object_ids[:scale(2_000_000, 10_000_000)]
    wss = len(np.unique(ids)) * IMG_B
    cap = 0.01 * wss
    window = scale(100_000, 1_000_000)

    # --- Fig. 9: adaptive vs oracle-picked static
    stat = sweep_static_alpha(ids, [0.3, 0.4, 0.5, 0.6, 0.7],
                              ReplayConfig(cache_bytes=cap))
    best_alpha, best = min(stat.items(), key=lambda kv: kv[1].mean_ms)
    rows.add("tuning.best_static_alpha", derived=best_alpha)
    rows.add("tuning.best_static_mean_ms", derived=round(best.mean_ms, 2))

    ad_cfg = ReplayConfig(cache_bytes=cap, adaptive=True,
                          tuner=TunerConfig(window=window))
    with Timer() as t:
        ad = replay(ids, ad_cfg)
    rows.add("tuning.adaptive_mean_ms", t.us / ad.n, round(ad.mean_ms, 2))
    rows.add("tuning.adaptive_vs_static_pct", derived=round(
        100 * (best.mean_ms - ad.mean_ms) / best.mean_ms, 2))
    # window-win fraction vs the oracle static
    sb = stat[best_alpha]
    m = min(len(ad.window_mean_ms), len(sb.window_mean_ms))
    wins = float(np.mean(ad.window_mean_ms[:m] <= sb.window_mean_ms[:m]))
    rows.add("tuning.window_win_frac", derived=round(wins, 3))
    rows.add("tuning.alpha_trajectory", derived="|".join(
        f"{a:.2f}" for a in ad.window_alpha[:: max(1, len(ad.window_alpha)
                                                   // 12)]))

    if not sweep:
        return rows

    # --- Fig. 11: parameter sensitivity
    base = dict(cache_bytes=cap, adaptive=True)

    def one(name, **tuner_kw):
        cfg = ReplayConfig(**base, tuner=TunerConfig(window=window,
                                                     **tuner_kw))
        r = replay(ids, cfg)
        rows.add(f"sensitivity.{name}", derived=round(r.mean_ms, 2))

    for step in (0.001, 0.005, 0.02, 0.05):
        one(f"delta.{step:g}", step=step)
    for w in (scale(10_000, 10_000), scale(50_000, 200_000),
              scale(200_000, 2_000_000)):
        cfg = ReplayConfig(**base, tuner=TunerConfig(window=w))
        r = replay(ids, cfg)
        rows.add(f"sensitivity.window.{w}", derived=round(r.mean_ms, 2))
    for tau in (0.01, 0.05, 0.1, 0.3):
        cfg = dataclasses.replace(ReplayConfig(**base), tau=tau,
                                  tuner=TunerConfig(window=window))
        r = replay(ids, cfg)
        rows.add(f"sensitivity.tau.{tau:g}", derived=round(r.mean_ms, 2))
    for h in (1, 4, 8, 32):
        cfg = dataclasses.replace(ReplayConfig(**base), promote_threshold=h,
                                  tuner=TunerConfig(window=window))
        r = replay(ids, cfg)
        rows.add(f"sensitivity.h.{h}", derived=round(r.mean_ms, 2))
    return rows


def main():
    run().print()


if __name__ == "__main__":
    main()
