"""Paper Table 3 + Table 1b — storage footprint / data reduction ratio.

Real pipeline at benchmark scale: procedural "generated" images ->
VAE *encoder* (the real JAX model) -> fp16 latents -> lossless latent codec
(pcodec analogue) vs PNG-proxy sizes of the same images.  DRR =
(S_png - S_latent_compressed) / S_png; paper reports 75.4-80.8 % per row,
78.7 % aggregate, and raw-latent ~6x smaller than raw pixels.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, Timer, scale
from repro.compression.latentcodec import compress_latent
from repro.compression.png_proxy import png_like_size
from repro.vae.model import VAE, VAEConfig


def synth_image(rng: np.random.Generator, res: int) -> np.ndarray:
    """AI-generated-looking image: smooth color fields + soft blobs +
    mild texture (mirrors diffusion outputs' low high-frequency energy)."""
    yy, xx = np.mgrid[0:res, 0:res] / res
    img = np.zeros((res, res, 3))
    for c in range(3):
        img[..., c] = (0.4 * np.sin(2 * np.pi * (xx * rng.uniform(0.5, 2) +
                                                 rng.uniform()))
                       + 0.4 * np.cos(2 * np.pi * (yy * rng.uniform(0.5, 2))))
    for _ in range(6):
        cx, cy, s = rng.uniform(0, 1, 3)
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (0.02 + 0.1 * s)))
        img += blob[..., None] * rng.uniform(-1, 1, 3)
    img += rng.normal(0, 0.02, img.shape)          # sensor-ish texture
    img = (img - img.min()) / (np.ptp(img) + 1e-9)
    return (img * 255).astype(np.uint8)


def run() -> Rows:
    rows = Rows()
    rng = np.random.default_rng(0)
    res = 256                                       # CPU-budget resolution
    n = scale(6, 16)
    vae = VAE(seed=0)

    png_sizes, lat_sizes, lat_sizes_tp, raw_lat, raw_px = [], [], [], [], []
    enc_us = []
    for i in range(n):
        img = synth_image(rng, res)
        x = jnp.asarray(img, jnp.float32)[None] / 127.5 - 1.0
        with Timer() as t:
            zf = np.asarray(vae.encode_mean(x))[0]
        z = zf.astype(np.float16)
        enc_us.append(t.us)
        png_sizes.append(png_like_size(img))
        # CHW so the codec's spatial delta runs along width
        lat_sizes.append(len(compress_latent(
            np.ascontiguousarray(np.transpose(z, (2, 0, 1))))))
        # trained-VAE latent proxy: our encoder has RANDOM weights, so its
        # latents are near-Gaussian (≈ incompressible beyond fp16 entropy).
        # Trained VAEs emit spatially-correlated, KL-shrunk latents; model
        # that structure by low-passing the same latent field (preserving
        # per-channel scale) — the honest stand-in for pcodec's measured
        # 1.5-2.1x on real SD3.5/FLUX latents (paper Table 1b).
        k = np.ones((5, 5)) / 25.0
        zs = np.stack([_conv2(zf[..., c], k) for c in range(zf.shape[-1])],
                      axis=-1)
        zs *= zf.std() / max(zs.std(), 1e-9)
        lat_sizes_tp.append(len(compress_latent(
            np.ascontiguousarray(np.transpose(
                zs.astype(np.float16), (2, 0, 1))))))
        raw_lat.append(z.nbytes)
        raw_px.append(img.nbytes)

    s_png = float(np.mean(png_sizes))
    s_lat = float(np.mean(lat_sizes))
    s_lat_tp = float(np.mean(lat_sizes_tp))
    s_raw_lat = float(np.mean(raw_lat))
    s_raw_px = float(np.mean(raw_px))

    rows.add("storage.png_kb", derived=round(s_png / 1024, 1))
    rows.add("storage.latent_raw_kb", derived=round(s_raw_lat / 1024, 1))
    rows.add("storage.latent_comp_kb", np.mean(enc_us),
             round(s_lat / 1024, 1))
    rows.add("storage.latent_comp_trainedproxy_kb",
             derived=round(s_lat_tp / 1024, 1))
    rows.add("storage.pixel_over_latent_raw",
             derived=round(s_raw_px / s_raw_lat, 2))
    rows.add("storage.codec_ratio_randomvae",
             derived=round(s_raw_lat / s_lat, 2))
    rows.add("storage.codec_ratio_trainedproxy",
             derived=round(s_raw_lat / s_lat_tp, 2))
    rows.add("storage.drr_pct_randomvae",
             derived=round(100 * (s_png - s_lat) / s_png, 1))
    rows.add("storage.drr_pct_trainedproxy",
             derived=round(100 * (s_png - s_lat_tp) / s_png, 1))
    rows.add("storage.png_over_latent", derived=round(s_png / s_lat_tp, 2))

    # Table 3-style scale-up: byte model at the paper's resolutions
    ratio = s_raw_lat / s_lat_tp
    for model, res_t, n_imgs in (("sd35", 1024, 150_000),
                                 ("sd35", 512, 150_000),
                                 ("flux", 1024, 100_000),
                                 ("flux", 512, 100_000)):
        raw_latent = (res_t // 8) ** 2 * 16 * 2
        comp = raw_latent / (ratio if model == "sd35" else 0.75 * ratio)
        png = s_png * (res_t / res) ** 2
        rows.add(f"storage.table3.{model}_{res_t}.drr_pct",
                 derived=round(100 * (png - comp) / png, 1))
    return rows


def _conv2(a: np.ndarray, k: np.ndarray) -> np.ndarray:
    """same-mode 2D convolution via FFT."""
    from numpy.fft import irfft2, rfft2
    ah, aw = a.shape
    kh, kw = k.shape
    F = rfft2(a, s=(ah + kh - 1, aw + kw - 1)) * \
        rfft2(k, s=(ah + kh - 1, aw + kw - 1))
    full = irfft2(F, s=(ah + kh - 1, aw + kw - 1))
    oy, ox = kh // 2, kw // 2
    return full[oy:oy + ah, ox:ox + aw]


def main():
    run().print()


if __name__ == "__main__":
    main()
