"""Paper Table 3 + Table 1b — storage footprint / data reduction ratio.

Real pipeline at benchmark scale: procedural "generated" images ->
VAE *encoder* (the real JAX model) -> fp16 latents -> lossless latent codec
(pcodec analogue) vs PNG-proxy sizes of the same images.  DRR =
(S_png - S_latent_compressed) / S_png; paper reports 75.4-80.8 % per row,
78.7 % aggregate, and raw-latent ~6x smaller than raw pixels.

Since the log-structured-store PR this module also measures the savings
ON DISK rather than as accounting fictions: ``durable_rows`` puts real
images through a persistent ``LatentBox.open`` box and reports the
segment files' byte footprint vs the pixel-equivalent baseline, the
reopen/recovery wall-clock (bit-exactness asserted), and the compaction
write amplification of a zipf_drift churn replay.  ``--trajectory`` (via
``benchmarks/run.py``) versions the result as ``BENCH_storage.json`` at
the repo root.
"""

from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, Timer, scale
from repro.compression.latentcodec import compress_latent
from repro.compression.png_proxy import png_like_size
from repro.vae.model import VAE, VAEConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def synth_image(rng: np.random.Generator, res: int) -> np.ndarray:
    """AI-generated-looking image: smooth color fields + soft blobs +
    mild texture (mirrors diffusion outputs' low high-frequency energy)."""
    yy, xx = np.mgrid[0:res, 0:res] / res
    img = np.zeros((res, res, 3))
    for c in range(3):
        img[..., c] = (0.4 * np.sin(2 * np.pi * (xx * rng.uniform(0.5, 2) +
                                                 rng.uniform()))
                       + 0.4 * np.cos(2 * np.pi * (yy * rng.uniform(0.5, 2))))
    for _ in range(6):
        cx, cy, s = rng.uniform(0, 1, 3)
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (0.02 + 0.1 * s)))
        img += blob[..., None] * rng.uniform(-1, 1, 3)
    img += rng.normal(0, 0.02, img.shape)          # sensor-ish texture
    img = (img - img.min()) / (np.ptp(img) + 1e-9)
    return (img * 255).astype(np.uint8)


def run() -> Rows:
    rows = Rows()
    rng = np.random.default_rng(0)
    res = 256                                       # CPU-budget resolution
    n = scale(6, 16)
    vae = VAE(seed=0)

    png_sizes, lat_sizes, lat_sizes_tp, raw_lat, raw_px = [], [], [], [], []
    enc_us = []
    for i in range(n):
        img = synth_image(rng, res)
        x = jnp.asarray(img, jnp.float32)[None] / 127.5 - 1.0
        with Timer() as t:
            zf = np.asarray(vae.encode_mean(x))[0]
        z = zf.astype(np.float16)
        enc_us.append(t.us)
        png_sizes.append(png_like_size(img))
        # CHW so the codec's spatial delta runs along width
        lat_sizes.append(len(compress_latent(
            np.ascontiguousarray(np.transpose(z, (2, 0, 1))))))
        # trained-VAE latent proxy: our encoder has RANDOM weights, so its
        # latents are near-Gaussian (≈ incompressible beyond fp16 entropy).
        # Trained VAEs emit spatially-correlated, KL-shrunk latents; model
        # that structure by low-passing the same latent field (preserving
        # per-channel scale) — the honest stand-in for pcodec's measured
        # 1.5-2.1x on real SD3.5/FLUX latents (paper Table 1b).
        k = np.ones((5, 5)) / 25.0
        zs = np.stack([_conv2(zf[..., c], k) for c in range(zf.shape[-1])],
                      axis=-1)
        zs *= zf.std() / max(zs.std(), 1e-9)
        lat_sizes_tp.append(len(compress_latent(
            np.ascontiguousarray(np.transpose(
                zs.astype(np.float16), (2, 0, 1))))))
        raw_lat.append(z.nbytes)
        raw_px.append(img.nbytes)

    s_png = float(np.mean(png_sizes))
    s_lat = float(np.mean(lat_sizes))
    s_lat_tp = float(np.mean(lat_sizes_tp))
    s_raw_lat = float(np.mean(raw_lat))
    s_raw_px = float(np.mean(raw_px))

    rows.add("storage.png_kb", derived=round(s_png / 1024, 1))
    rows.add("storage.latent_raw_kb", derived=round(s_raw_lat / 1024, 1))
    rows.add("storage.latent_comp_kb", np.mean(enc_us),
             round(s_lat / 1024, 1))
    rows.add("storage.latent_comp_trainedproxy_kb",
             derived=round(s_lat_tp / 1024, 1))
    rows.add("storage.pixel_over_latent_raw",
             derived=round(s_raw_px / s_raw_lat, 2))
    rows.add("storage.codec_ratio_randomvae",
             derived=round(s_raw_lat / s_lat, 2))
    rows.add("storage.codec_ratio_trainedproxy",
             derived=round(s_raw_lat / s_lat_tp, 2))
    rows.add("storage.drr_pct_randomvae",
             derived=round(100 * (s_png - s_lat) / s_png, 1))
    rows.add("storage.drr_pct_trainedproxy",
             derived=round(100 * (s_png - s_lat_tp) / s_png, 1))
    rows.add("storage.png_over_latent", derived=round(s_png / s_lat_tp, 2))

    # Table 3-style scale-up: byte model at the paper's resolutions
    ratio = s_raw_lat / s_lat_tp
    for model, res_t, n_imgs in (("sd35", 1024, 150_000),
                                 ("sd35", 512, 150_000),
                                 ("flux", 1024, 100_000),
                                 ("flux", 512, 100_000)):
        raw_latent = (res_t // 8) ** 2 * 16 * 2
        comp = raw_latent / (ratio if model == "sd35" else 0.75 * ratio)
        png = s_png * (res_t / res) ** 2
        rows.add(f"storage.table3.{model}_{res_t}.drr_pct",
                 derived=round(100 * (png - comp) / png, 1))
    rows.extend(durable_rows())          # the on-disk (measured) half
    return rows


def _dir_bytes(path: str) -> int:
    """EVERYTHING the durable store keeps on disk — segments AND the
    manifest checkpoint — so the savings claim can't hide index cost."""
    return sum(os.path.getsize(os.path.join(path, f))
               for f in os.listdir(path))


def durable_rows(smoke: bool = False) -> Rows:
    """On-disk truth: real segment bytes, recovery time, bit-exact reopen,
    and zipf_drift compaction write amplification."""
    from repro.store import LatentBox, StoreConfig
    from repro.store.durable import SegmentLogBackend
    from repro.trace.synth import make_trace

    rows = Rows()
    rng = np.random.default_rng(0)
    res = 64 if smoke else 128
    n = 4 if smoke else scale(6, 12)
    # an f8 VAE like the paper's (4 levels -> 8x spatial downsample): the
    # on-disk savings claim is about the latent-first LAYOUT, so the
    # stand-in must match the production downsample factor, not the tiny
    # f2 demo decoder the conformance tests use
    vae = VAE(VAEConfig(name="bench-f8", latent_channels=4,
                        block_out_channels=(8, 16, 16, 32),
                        layers_per_block=1, groups=4), seed=0)

    # -- section A: put real images, measure real segment bytes ------------
    root = tempfile.mkdtemp(prefix="latentbox-bench-")
    try:
        d = os.path.join(root, "box")
        box = LatentBox.open(d, mode="engine", vae=vae)
        imgs = [synth_image(rng, res) for _ in range(n)]
        png_b = float(sum(png_like_size(im) for im in imgs))
        raw_px_b = float(sum(im.nbytes for im in imgs))
        for oid, im in enumerate(imgs):
            assert box.put(oid, image=im).durable
        baseline = {oid: box.get(oid).payload for oid in range(n)}
        box.flush()
        ddir = box.backend.durable_log.path
        disk_b = float(_dir_bytes(ddir))
        box.close()

        t0 = time.perf_counter()
        box2 = LatentBox.open(d, mode="engine", vae=vae)
        reopen_ms = (time.perf_counter() - t0) * 1e3
        recovery_ms = box2.backend.durable_log.recovery_stats["ms"]
        bitexact = all(
            np.array_equal(box2.get(oid).payload, baseline[oid])
            for oid in range(n))
        box2.close()

        rows.add("storage.disk.images", derived=n)
        rows.add("storage.disk.pixel_png_baseline_kb",
                 derived=round(png_b / 1024, 1))
        rows.add("storage.disk.pixel_raw_kb",
                 derived=round(raw_px_b / 1024, 1))
        rows.add("storage.disk.latent_segment_kb",
                 derived=round(disk_b / 1024, 1))
        rows.add("storage.disk.savings_vs_png_pct",
                 derived=round(100 * (png_b - disk_b) / png_b, 1))
        rows.add("storage.disk.savings_vs_raw_px_pct",
                 derived=round(100 * (raw_px_b - disk_b) / raw_px_b, 1))
        rows.add("storage.disk.reopen_ms", derived=round(reopen_ms, 2))
        rows.add("storage.disk.recovery_scan_ms",
                 derived=round(recovery_ms, 2))
        rows.add("storage.disk.reopen_bitexact", derived=int(bitexact))

        # -- section B: zipf_drift churn -> write amplification ------------
        tr = make_trace("zipf_drift",
                        n_objects=120 if smoke else scale(400, 1200),
                        n_requests=1500 if smoke else scale(8000, 40000),
                        span_days=2.0, seed=7)
        blob_b = 1536
        backend = SegmentLogBackend.open(
            os.path.join(root, "churn"),
            segment_bytes=32 * blob_b, flush_each_put=False,
            compact_live_frac=0.6)

        def blob_of(oid: int, ver: int) -> bytes:
            return np.random.default_rng((int(oid), ver)).bytes(blob_b)

        version = {}
        last_seen = {}
        window = 64
        ids = tr.object_ids
        for s in range(0, len(ids), window):
            for i, oid in enumerate(ids[s:s + window], start=s):
                oid = int(oid)
                if oid not in version:
                    version[oid] = 0
                    backend.put_blob(oid, blob_of(oid, 0))
                elif (oid * 2654435761 + i) % 23 == 0:
                    version[oid] += 1          # content drift: overwrite
                    backend.put_blob(oid, blob_of(oid, version[oid]))
                last_seen[oid] = i
            # cold-object demotion churn: drop long-idle blobs
            for oid in [o for o, t in last_seen.items()
                        if s - t > 12 * window and backend.contains(o)]:
                backend.delete(oid)
                last_seen.pop(oid)
            backend.flush()                     # per-window write-behind ack
            backend.maybe_compact()             # one online step per window
        backend.flush()
        st = backend.stats()
        # correctness spot-check under churn: survivors are bit-exact
        live = [o for o in last_seen if backend.contains(o)][:32]
        churn_exact = all(backend.get_blob(o) == blob_of(o, version[o])
                          for o in live)
        rows.add("storage.churn.requests", derived=len(ids))
        rows.add("storage.churn.write_amplification",
                 derived=round(st["write_amplification"], 3))
        rows.add("storage.churn.segments_compacted",
                 derived=st["segments_compacted"])
        rows.add("storage.churn.on_disk_kb",
                 derived=round(st["on_disk_bytes"] / 1024, 1))
        rows.add("storage.churn.live_kb",
                 derived=round(st["live_bytes"] / 1024, 1))
        rows.add("storage.churn.dead_frac",
                 derived=round(1 - st["live_bytes"]
                               / max(st["on_disk_bytes"], 1), 3))
        rows.add("storage.churn.bitexact_survivors", derived=int(churn_exact))
        backend.close()
        t0 = time.perf_counter()
        reopened = SegmentLogBackend.open(os.path.join(root, "churn"))
        rows.add("storage.churn.reopen_ms",
                 derived=round((time.perf_counter() - t0) * 1e3, 2))
        reopened.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def trajectory(out_dir: str = REPO_ROOT, smoke: bool = False) -> Rows:
    """The storage-trajectory artifact: ``<out_dir>/BENCH_storage.json`` —
    versioned on-disk savings, recovery time, and compaction write
    amplification, so later checkouts have a trend to regress against."""
    rows = durable_rows(smoke=smoke)
    path = rows.save_json("BENCH_storage", out_dir=out_dir)
    print(f"# saved {path}")
    return rows


def _conv2(a: np.ndarray, k: np.ndarray) -> np.ndarray:
    """same-mode 2D convolution via FFT."""
    from numpy.fft import irfft2, rfft2
    ah, aw = a.shape
    kh, kw = k.shape
    F = rfft2(a, s=(ah + kh - 1, aw + kw - 1)) * \
        rfft2(k, s=(ah + kh - 1, aw + kw - 1))
    full = irfft2(F, s=(ah + kh - 1, aw + kw - 1))
    oy, ox = kh // 2, kw // 2
    return full[oy:oy + ah, ox:ox + aw]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized durable-store measurement; writes "
                         "BENCH_storage.json at the repo root")
    args = ap.parse_args()
    if args.smoke:
        trajectory(smoke=True).print()
        return
    run().print()


if __name__ == "__main__":
    main()
