"""Paper Table 4 + Fig. 7 — end-to-end read latency of the six evaluated
configurations through the discrete-event cluster (3 nodes, 2 GB caches,
48 h window replayed at 10x; generation measured on a 1 k-request subset).

The classic section replays closed-loop (each request sees only its own
service time).  The ``latency.openloop.*`` rows push a timestamped
arrival stream through the event-loop serving runtime instead, so the
reported end-to-end number INCLUDES queue delay — under load the two
diverge sharply, and only the open-loop one is what a client observes.
The old service-only columns are kept unchanged alongside.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Rows, Timer, bench_trace, scale
from repro.core.cluster import ClusterConfig, replay_cluster
from repro.core.tuner import TunerConfig

DAY_S = 86_400.0


def window_requests(tr, hours: float = 48.0, max_n: int = 120_000):
    """A contiguous window from the steady-state part of the trace,
    downsampled the way the paper does (object-level sample keeps all
    accesses)."""
    t0 = tr.timestamps[-1] * 0.55
    w = tr.window(t0, t0 + hours * 3600.0)
    ts, ids = w.timestamps[:max_n], w.object_ids[:max_n]
    return ts - ts[0], ids


def configs(cache_bytes: float):
    tun = TunerConfig(window=10_000, step=0.01)
    base = dict(n_nodes=3, cache_bytes_per_node=cache_bytes, tuner=tun)
    return {
        "decode_all": ClusterConfig(mode="decode_all", **base),
        "imgstore": ClusterConfig(mode="imgstore", **base),
        "lb_imgcache": ClusterConfig(mode="lb", alpha0=1.0, adaptive=False,
                                     admit_on_miss="image", **base),
        "lb_latentcache": ClusterConfig(mode="lb", alpha0=0.0,
                                        adaptive=False, **base),
        "lb_adaptive": ClusterConfig(mode="lb", alpha0=0.5, adaptive=True,
                                     **base),
    }


def run() -> Rows:
    rows = Rows()
    tr = bench_trace()
    ts, ids = window_requests(tr, max_n=scale(80_000, 250_000))
    wss_bytes = len(np.unique(tr.object_ids)) * 1.4e6
    cache = 0.01 * wss_bytes / 3                 # 1% of WSS across 3 nodes

    # warm-up: preceding window fills the caches
    warm_ts, warm_ids = window_requests(tr, hours=24.0,
                                        max_n=scale(40_000, 120_000))

    for name, cfg in configs(cache).items():
        with Timer() as t:
            log, sim = replay_cluster(
                cfg, np.concatenate([warm_ts, warm_ts[-1] + 60 + ts]),
                np.concatenate([warm_ids, ids]), speedup=10.0)
        s = log.summarize()
        # evaluation slice = after warm-up
        n_warm = len(warm_ts)
        lat = np.asarray(log.latency_ms)[n_warm:]
        out = np.asarray(log.outcome)[n_warm:]
        rows.add(f"latency.{name}.mean_ms", t.us / max(len(lat), 1),
                 round(float(lat.mean()), 1))
        for p in (50, 95, 99):
            rows.add(f"latency.{name}.p{p}_ms",
                     derived=round(float(np.percentile(lat, p)), 1))
        rows.add(f"latency.{name}.image_hit_frac",
                 derived=round(float(np.mean(out == 0)), 3))
        rows.add(f"latency.{name}.full_miss_frac",
                 derived=round(float(np.mean(out == 2)), 3))
        if name == "lb_adaptive":
            rows.add("latency.lb_adaptive.spillovers",
                     derived=sim.router.n_spillover)
            rows.add("latency.lb_adaptive.coalesced",
                     derived=sim.router.n_coalesced)
            rows.add("latency.lb_adaptive.alpha_final", derived=round(
                float(np.mean([n.cache.alpha for n in sim.nodes])), 3))

    # generation upper bound (1k subset, as in the paper)
    gen = ClusterConfig(mode="generation", n_nodes=3,
                        cache_bytes_per_node=cache)
    log, _ = replay_cluster(gen, ts[:1000], ids[:1000], speedup=10.0)
    lat = np.asarray(log.latency_ms)
    rows.add("latency.generation.mean_ms", derived=round(float(lat.mean()), 0))
    rows.add("latency.generation.p99_ms",
             derived=round(float(np.percentile(lat, 99)), 0))

    rows.extend(openloop_rows())
    return rows


def openloop_rows() -> Rows:
    """Queue-delay-inclusive latency through the serving runtime: the same
    store, driven open-loop at an under- and an over-loaded arrival rate.
    ``e2e_*`` is arrival -> completion (what a client sees); ``service_*``
    is the old closed-loop-style number (queue delay subtracted)."""
    from benchmarks.bench_runtime import _box, _requests, _runtime_cfg
    rows = Rows()
    for lf in (0.5, 2.0):
        rep = _box(24).serve_stream(_requests("flash_crowd", 24, 600, lf),
                                    runtime_cfg=_runtime_cfg(True))
        log = rep.log
        served = np.asarray(log.outcome) <= 3
        e2e = np.asarray(log.latency_ms)[served]
        qd = np.asarray(log.queue_delay_ms)[served]
        for p in (50, 99):
            rows.add(f"latency.openloop.lf{lf}.e2e_p{p}_ms",
                     derived=round(float(np.percentile(e2e, p)), 1))
            rows.add(f"latency.openloop.lf{lf}.service_p{p}_ms",
                     derived=round(float(np.percentile(e2e - qd, p)), 1))
        rows.add(f"latency.openloop.lf{lf}.queue_delay_p99_ms",
                 derived=round(float(np.percentile(qd, 99)), 1))
    return rows


def main():
    run().print()


if __name__ == "__main__":
    main()
