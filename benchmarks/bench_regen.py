"""Beyond-paper ablation: recipe-only regeneration tier for cold objects
(paper §3.1 O1's design implication, not implemented by the paper).

Replays the trace with monthly demotion sweeps and reports the residual
durable footprint vs pure latent-first storage, the regen-triggered
request fraction (tail-latency budget), and the break-even age."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, Timer, bench_trace, scale
from repro.core.regen_tier import RegenPolicy, RegenTierStore

MO_S = 30 * 86_400.0
LAT_B = 0.29e6


def run() -> Rows:
    rows = Rows()
    tr = bench_trace()
    n = scale(1_500_000, 6_000_000)
    ts_mo = tr.timestamps[:n] / MO_S
    ids = tr.object_ids[:n]

    pol = RegenPolicy()
    # economics: at default prices demotion pays only after ~2 years idle —
    # longer than the benchmark trace; cheap decode fleets move it in
    rows.add("regen.breakeven_age_months",
             derived=round(pol.demotion_age_months(), 2))
    rows.add("regen.breakeven_cheap_gpu_months", derived=round(
        RegenPolicy(p_gpu_hr=0.10).demotion_age_months(), 2))

    births = tr.birth_time / MO_S

    def replay(demote_age_mo: float):
        store = RegenTierStore(pol)
        seen = set()
        regen_hits = 0
        next_sweep = demote_age_mo
        for i in range(len(ids)):
            oid = int(ids[i])
            now = float(ts_mo[i])
            if oid not in seen:
                seen.add(oid)
                store.put(oid, LAT_B, now_mo=float(births[oid]))
            _, needs_regen = store.fetch(oid, now)
            if needs_regen:
                regen_hits += 1
                store.readmit(oid, LAT_B, now)
            if now >= next_sweep:
                # sweep at the forced age (tradeoff curve, not the econ
                # break-even the policy would pick on its own)
                store.run_demotion(now, age_override_mo=demote_age_mo)
                next_sweep += max(demote_age_mo / 2, 0.25)
        return store, regen_hits, len(seen)

    for age in (0.5, 1.0, 2.0):
        with Timer() as t:
            store, regen_hits, n_seen = replay(age)
        full = n_seen * LAT_B
        tier = store.latent_bytes + store.recipe_bytes
        rows.add(f"regen.age{age:g}.extra_reduction_pct", t.us / len(ids),
                 round(100 * (1 - tier / full), 1))
        rows.add(f"regen.age{age:g}.regen_request_frac",
                 derived=round(regen_hits / len(ids), 5))
    return rows


def main():
    run().print()


if __name__ == "__main__":
    main()
