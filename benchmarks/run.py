"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only name ...]

Output: ``name,us_per_call,derived`` CSV (assignment format).  Scale with
REPRO_BENCH_SCALE=small|full (default small: minutes on 1 CPU).

Paper artifact -> module map (DESIGN.md §7):
  Fig 4      bench_trace       Table 3/1b  bench_storage
  Table 4/F7 bench_latency     Table 6     bench_cache_sweep
  Fig 9/11   bench_tuning      Fig 10      bench_spillover
  Fig 8      bench_cost        Fig 12      bench_fidelity
  Table 1c   bench_decode      kernels     bench_kernels
  §Roofline  roofline_report   fault tol.  bench_resilience
  serving    bench_runtime     (QoS/SLO load sweep)
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import Rows

MODULES = [
    "bench_trace", "bench_storage", "bench_decode", "bench_kernels",
    "bench_cost", "bench_cache_sweep", "bench_tuning", "bench_spillover",
    "bench_latency", "bench_fidelity", "bench_regen",
    "bench_resilience", "bench_runtime", "roofline_report",
]


def trajectory() -> None:
    """Perf-trajectory mode: write ``BENCH_decode.json`` +
    ``BENCH_kernels.json`` + ``BENCH_storage.json`` at the repo root
    (versioned, unlike the artifacts/ scratch) — per-bucket per-image
    decode ms, fast-path speedups, kernel-vs-oracle errors and traffic
    wins, pixel-tier bytes/object, the durable store's measured
    on-disk savings / recovery ms / compaction write amplification, and
    (``BENCH_resilience.json``) the replicated cluster's hedged-tail,
    failover, and restart-recovery numbers, and
    (``BENCH_runtime.json``) the serving runtime's per-class tails and
    SLO attainment at three load factors with QoS on/off, and
    (``BENCH_fidelity.json``) the rate-distortion ladder's per-rung
    storage savings vs PSNR/SSIM, floor-gated, and
    (``BENCH_cost.json``) Fig. 8 cost projections plus the trace-driven
    $-per-million-requests A-B-C (static-small / static-peak /
    autoscaled) at a fixed 250 ms SLO — so later checkouts have a trend
    to regress against."""
    from benchmarks import (bench_cost, bench_decode, bench_fidelity,
                            bench_kernels, bench_resilience, bench_runtime,
                            bench_storage)
    bench_decode.trajectory().print()
    bench_kernels.trajectory().print()
    bench_storage.trajectory().print()
    bench_resilience.trajectory().print()
    bench_runtime.trajectory(smoke=True).print()
    bench_fidelity.trajectory(smoke=True).print()
    bench_cost.trajectory(smoke=True).print()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--trajectory", action="store_true",
                    help="write BENCH_decode.json + BENCH_kernels.json at "
                         "the repo root and exit")
    args = ap.parse_args()
    if args.trajectory:
        trajectory()
        return
    mods = args.only or MODULES

    all_rows = Rows()
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run()
            all_rows.extend(rows)
            print(f"# {name}: ok in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failures += 1
            all_rows.add(f"{name}.FAILED", derived=type(e).__name__)
            print(f"# {name}: FAILED {e}", file=sys.stderr)
            traceback.print_exc()
    all_rows.print()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
