"""Paper Table 6 — dual-format adaptive cache vs single-format baselines
across cache sizes (0.1%-10% of WSS), trace-driven simulation
(T_decode=40 ms, T_fetch=140 ms as in §6.5).  Adds the mixed-format
single-LRU strawman the paper rejects in §4.2 (beyond-paper ablation).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, Timer, bench_trace, scale
from repro.core.policies import MixedFormatLRU
from repro.store.api import DEFAULT_OBJECT_BYTES
from repro.core.replay import ReplayConfig, replay
from repro.core.tuner import TunerConfig

IMG_B, LAT_B = 1.4e6, DEFAULT_OBJECT_BYTES
T_DEC, T_FETCH = 40.0, 140.0


def run() -> Rows:
    rows = Rows()
    tr = bench_trace()
    ids = tr.object_ids[:scale(2_000_000, 10_000_000)]
    wss = len(np.unique(ids)) * IMG_B
    window = scale(100_000, 1_000_000)

    for frac in (0.001, 0.005, 0.01, 0.02, 0.05, 0.10):
        cap = wss * frac
        variants = {
            "img_only": ReplayConfig(cache_bytes=cap, alpha0=1.0,
                                     adaptive=False, admit_on_miss="image"),
            "latent_only": ReplayConfig(cache_bytes=cap, alpha0=0.0,
                                        adaptive=False),
            "adaptive": ReplayConfig(cache_bytes=cap, alpha0=0.5,
                                     adaptive=True,
                                     tuner=TunerConfig(window=window)),
        }
        for name, cfg in variants.items():
            with Timer() as t:
                r = replay(ids, cfg)
            rows.add(f"sweep.{name}.{frac:g}.mean_ms", t.us / r.n,
                     round(r.mean_ms, 1))
        # mixed-format single LRU (the §4.2 strawman)
        pol = MixedFormatLRU(cap, IMG_B, LAT_B, promote_threshold=8)
        cost = 0.0
        for oid in ids:
            oid = int(oid)
            fmt = pol.format_of(oid)
            hit = pol.access(oid)
            if hit and fmt == "image":
                pass
            elif hit:
                cost += T_DEC
            else:
                cost += T_DEC + T_FETCH
        rows.add(f"sweep.mixed_lru.{frac:g}.mean_ms",
                 derived=round(cost / len(ids), 1))
    return rows


def main():
    run().print()


if __name__ == "__main__":
    main()
