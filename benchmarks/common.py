"""Shared benchmark plumbing: trace cache, CSV/JSON rows, scale control."""

from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.trace.synth import SyntheticTrace, TraceConfig, generate_trace

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")   # small|full


def scale(small, full):
    return full if SCALE == "full" else small


_TRACE_CACHE: Dict[str, SyntheticTrace] = {}


def bench_trace(name: str = "main") -> SyntheticTrace:
    """The CompanyX stand-in trace, cached on disk across benchmark runs."""
    if name in _TRACE_CACHE:
        return _TRACE_CACHE[name]
    cfg = TraceConfig(
        n_objects=scale(150_000, 600_000),
        n_requests=scale(3_000_000, 12_000_000),
        span_days=scale(120.0, 360.0),
        seed=7)
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"trace_{name}_{SCALE}.npz")
    if os.path.exists(path):
        tr = SyntheticTrace.load(path)
    else:
        tr = generate_trace(cfg)
        tr.save(path)
    _TRACE_CACHE[name] = tr
    return tr


class Rows:
    """Collects ``name,us_per_call,derived`` rows; prints CSV and can
    persist JSON under artifacts/ (untracked local scratch), so a run on
    one checkout can be diffed against a rerun on another."""

    def __init__(self):
        self.rows: List[str] = []
        self._records: List[Dict[str, Any]] = []

    def add(self, name: str, us_per_call: float = float("nan"),
            derived: Any = "") -> None:
        self.rows.append(f"{name},{us_per_call:.3f},{derived}")
        self._records.append({
            "name": name,
            "us_per_call": None if math.isnan(us_per_call) else us_per_call,
            "derived": derived})

    def extend(self, other: "Rows") -> None:
        self.rows.extend(other.rows)
        self._records.extend(other._records)

    def print(self) -> None:
        print("name,us_per_call,derived")
        for r in self.rows:
            print(r)

    def save_json(self, name: str, out_dir: Optional[str] = None) -> str:
        """Write the rows as ``<out_dir>/<name>.json`` (default
        ``artifacts/`` — untracked scratch; the perf-trajectory mode passes
        the repo root so ``BENCH_*.json`` is versioned); returns the path."""
        out_dir = ART if out_dir is None else out_dir
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{name}.json")
        with open(path, "w") as f:
            json.dump({"bench": name, "scale": SCALE,
                       "rows": self._records}, f, indent=1, default=str)
        return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self) -> float:
        return self.s * 1e6
