"""Paper Fig. 10 — spillover dispatch vs hash-only routing under load
(6 nodes, 1000x replay speed, theta=4).  The gain concentrates in GPU
queue-wait tail (paper: mean -16.5%, P99 -23.9%, queue-wait P99 -49%)."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Rows, Timer, bench_trace, scale
from repro.core.cluster import ClusterConfig, replay_cluster
from repro.core.tuner import TunerConfig


def run() -> Rows:
    rows = Rows()
    tr = bench_trace()
    t0 = tr.timestamps[-1] * 0.55
    w = tr.window(t0, t0 + 48 * 3600.0)
    n = scale(60_000, 200_000)
    ts = w.timestamps[:n] - w.timestamps[0]
    ids = w.object_ids[:n]
    wss_bytes = len(np.unique(tr.object_ids)) * 1.4e6

    base = dict(mode="lb", n_nodes=6,
                cache_bytes_per_node=0.01 * wss_bytes / 6,
                tuner=TunerConfig(window=10_000), theta=4)
    for name, spill in (("with_spillover", True), ("hash_only", False)):
        cfg = ClusterConfig(spillover=spill, **base)
        with Timer() as t:
            log, sim = replay_cluster(cfg, ts, ids, speedup=1000.0)
        lat = np.asarray(log.latency_ms)
        qw = np.asarray(log.queue_ms)
        rows.add(f"spillover.{name}.mean_ms", t.us / len(lat),
                 round(float(lat.mean()), 1))
        rows.add(f"spillover.{name}.p99_ms",
                 derived=round(float(np.percentile(lat, 99)), 1))
        rows.add(f"spillover.{name}.queue_p99_ms",
                 derived=round(float(np.percentile(qw, 99)), 1))
        if spill:
            rows.add("spillover.count", derived=sim.router.n_spillover)
    return rows


def main():
    run().print()


if __name__ == "__main__":
    main()
