"""Paper Fig. 4 — workload characterization of the CompanyX-like trace.

(a) popularity skew (top-1%/top-10% view shares, Zipf tail),
(b) post-birth decay (rate ratio day-1 vs day-90+ by popularity quartile),
(c) miss-ratio curves for LRU / S3-FIFO / Belady at 0.1%-10% cache sizes,
(d) re-access interval CDF points (1 h / 1 d / >30 d).

Paper reference points: top1=39%, top10=71%, <10 views=69%, once=15%;
re-access 38% <1 h, 68% <1 d, 6% >30 d; S3-FIFO ~12% misses at 10%.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Rows, Timer, bench_trace, scale
from repro.core.policies import BeladyCache, LRUCache, S3FIFOCache, miss_ratio


def run() -> Rows:
    rows = Rows()
    tr = bench_trace()
    with Timer() as t:
        stats = tr.characterize()
    for k, v in stats.items():
        rows.add(f"trace.{k}", t.us / max(stats['n_requests'], 1), round(v, 4))

    # (b) post-birth decay by lifetime-view quartile
    counts = np.bincount(tr.object_ids, minlength=tr.n_objects)
    ages = tr.timestamps - tr.birth_time[tr.object_ids]
    viewed = np.nonzero(counts)[0]
    q = np.quantile(counts[viewed], [0.25, 0.5, 0.75, 0.99])
    top_ids = viewed[counts[viewed] >= q[3]]
    mask = np.isin(tr.object_ids, top_ids)
    a = ages[mask] / 86_400.0
    early = float(np.mean(a < 1.0))
    late = float(np.mean(a > 30.0))
    n_days = tr.config.span_days
    # access-rate ratio day<1 vs day>30 (normalized by exposure window)
    rate_early = early / 1.0
    rate_late = late / max(n_days - 30.0, 1.0)
    rows.add("trace.top1pct_decay_ratio", derived=round(
        rate_early / max(rate_late, 1e-9), 1))

    # (c) MRC
    ids = tr.object_ids[:scale(1_500_000, 6_000_000)]
    wss = len(np.unique(ids))
    for frac in (0.001, 0.01, 0.05, 0.10):
        cap = max(1, int(wss * frac))
        for name, pol in (("lru", LRUCache(cap)),
                          ("s3fifo", S3FIFOCache(cap)),
                          ("belady", BeladyCache(cap))):
            with Timer() as t:
                mr = miss_ratio(pol, ids)
            rows.add(f"mrc.{name}.{frac:g}", t.us / len(ids), round(mr, 4))
    return rows


def main():
    run().print()


if __name__ == "__main__":
    main()
