"""Paper Fig. 4 — workload characterization of the CompanyX-like trace,
plus an end-to-end replay of a trace slice through the ``LatentBox``
facade (simulator backend).

(a) popularity skew (top-1%/top-10% view shares, Zipf tail),
(b) post-birth decay (rate ratio day-1 vs day-90+ by popularity quartile),
(c) miss-ratio curves for LRU / S3-FIFO / Belady at 0.1%-10% cache sizes,
(d) re-access interval CDF points (1 h / 1 d / >30 d),
(e) hit-class composition of the facade tier-walk on the trace head.

Paper reference points: top1=39%, top10=71%, <10 views=69%, once=15%;
re-access 38% <1 h, 68% <1 d, 6% >30 d; S3-FIFO ~12% misses at 10%.

``--smoke`` runs only the facade replay at toy scale (CI exercises the
put -> tier-walk -> get_many path end-to-end on every push); ``--smoke
--shards 2`` additionally replays the identical trace through a sharded
cluster and asserts shard-conformant classification.  ``--scenario NAME``
replays one named workload from the scenario suite instead of the
CompanyX baseline (``--scenario list`` prints the names).
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Rows, Timer, bench_trace, scale
from repro.core.policies import BeladyCache, LRUCache, S3FIFOCache, miss_ratio
from repro.store.api import DEFAULT_OBJECT_BYTES
from repro.store import (FULL_MISS, IMAGE_HIT, LATENT_HIT, REGEN_MISS,
                         LatentBox, StoreConfig)
from repro.trace.synth import (TraceConfig, generate_trace, list_scenarios,
                               make_trace)


#: pixel-cache entry sizes at the trace's nominal 1024x1024 object: raw
#: decoded uint8 HWC (what the fused-epilogue engine actually pins) vs the
#: float32 arrays the pre-PR engine pinned — the 4x pixel-tier capacity win
PX_UINT8 = 3.15e6
PX_FLOAT32 = 4 * PX_UINT8


def facade_replay(ids: np.ndarray, timestamps_ms: np.ndarray,
                  n_nodes: int = 3, cache_frac: float = 0.05,
                  shards: int = 1, label: str = "facade",
                  image_bytes: float = PX_UINT8):
    """Replay a trace slice through the LatentBox facade only; returns
    ``(rows, summary)``.  ``n_nodes`` is the TOTAL fleet size; with
    ``shards > 1`` the same fleet is split across a sharded cluster
    (``n_nodes`` must divide evenly)."""
    rows = Rows()
    wss = int(len(np.unique(ids)))
    if n_nodes % shards:
        raise ValueError(f"{shards} shards must evenly split {n_nodes} nodes")
    box = LatentBox.simulated(StoreConfig(
        n_nodes=n_nodes // shards,
        cache_bytes_per_node=max(wss * PX_FLOAT32 * cache_frac / n_nodes,
                                 2e6),
        image_bytes=image_bytes, latent_bytes=DEFAULT_OBJECT_BYTES), shards=shards)
    for oid in np.unique(ids):
        box.put(int(oid))
    with Timer() as t:
        box.get_many([int(i) for i in ids],
                     timestamps_ms=timestamps_ms.tolist())
    s = box.summary()
    total = max(s["total"], 1)
    for cls in (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS):
        rows.add(f"{label}.{cls}_frac", t.us / total,
                 round(s[cls] / total, 4))
    rows.add(f"{label}.p95_ms", derived=round(s.get("p95_ms", 0.0), 2))
    rows.add(f"{label}.pixel_bytes_per_object",
             derived=round(s.get("pixel_bytes_per_object", 0.0), 1))
    return rows, s


def smoke(shards: int = 1) -> Rows:
    """CI-sized end-to-end pass over the facade (seconds, not minutes).
    With ``shards > 1`` the same trace additionally replays through a
    sharded cluster and the run asserts conformant classification counts
    (the cheap half of ``tests/test_shard_conformance.py``)."""
    tr = generate_trace(TraceConfig(n_objects=300, n_requests=4_000,
                                    span_days=3, seed=11))
    ids = tr.object_ids[:2_000]
    ts = tr.timestamps[:2_000] * 1e3
    rows, s = facade_replay(ids, ts, n_nodes=2, cache_frac=0.05)
    hits = sum(s[cls] for cls in
               (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS))
    assert s["total"] == len(ids) and hits == s["total"], \
        "hit classes must partition requests"
    # pixel-tier bytes/object: the uint8 fast path charges 4x below the
    # float32 arrays the pre-PR engine pinned (same fleet, same trace)
    px = s.get("pixel_bytes_per_object", 0.0)
    rows.add("facade.pixel_bytes_per_object.f32_baseline",
             derived=PX_FLOAT32)
    drop = PX_FLOAT32 / px if px else 0.0
    rows.add("facade.pixel_bytes_drop_vs_f32", derived=round(drop, 2))
    assert 3.5 <= drop <= 4.5, \
        f"uint8 pixel tier should charge ~4x below float32, got {drop}"
    if shards > 1:
        srows, ss = facade_replay(ids, ts, n_nodes=2 * shards,
                                  cache_frac=0.05, shards=shards,
                                  label=f"facade@{shards}shards")
        rows.extend(srows)
        urows, us = facade_replay(ids, ts, n_nodes=2 * shards,
                                  cache_frac=0.05, shards=1,
                                  label="facade@unsharded")
        for cls in (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS):
            assert ss[cls] == us[cls], \
                f"sharding changed {cls} classification: " \
                f"{ss[cls]} != {us[cls]}"
    return rows


def scenario_rows(scenario: str, n_requests: int = 200_000) -> Rows:
    """Replay one named workload through the facade tier walk."""
    tr = make_trace(scenario, n_objects=max(n_requests // 20, 1000),
                    n_requests=n_requests, span_days=14, seed=0)
    rows, _ = facade_replay(tr.object_ids, tr.timestamps * 1e3,
                            label=f"scenario.{scenario}")
    return rows


def run() -> Rows:
    rows = Rows()
    tr = bench_trace()
    with Timer() as t:
        stats = tr.characterize()
    for k, v in stats.items():
        rows.add(f"trace.{k}", t.us / max(stats['n_requests'], 1), round(v, 4))

    # (b) post-birth decay by lifetime-view quartile
    counts = np.bincount(tr.object_ids, minlength=tr.n_objects)
    ages = tr.timestamps - tr.birth_time[tr.object_ids]
    viewed = np.nonzero(counts)[0]
    q = np.quantile(counts[viewed], [0.25, 0.5, 0.75, 0.99])
    top_ids = viewed[counts[viewed] >= q[3]]
    mask = np.isin(tr.object_ids, top_ids)
    a = ages[mask] / 86_400.0
    early = float(np.mean(a < 1.0))
    late = float(np.mean(a > 30.0))
    n_days = tr.config.span_days
    # access-rate ratio day<1 vs day>30 (normalized by exposure window)
    rate_early = early / 1.0
    rate_late = late / max(n_days - 30.0, 1.0)
    rows.add("trace.top1pct_decay_ratio", derived=round(
        rate_early / max(rate_late, 1e-9), 1))

    # (c) MRC
    ids = tr.object_ids[:scale(1_500_000, 6_000_000)]
    wss = len(np.unique(ids))
    for frac in (0.001, 0.01, 0.05, 0.10):
        cap = max(1, int(wss * frac))
        for name, pol in (("lru", LRUCache(cap)),
                          ("s3fifo", S3FIFOCache(cap)),
                          ("belady", BeladyCache(cap))):
            with Timer() as t:
                mr = miss_ratio(pol, ids)
            rows.add(f"mrc.{name}.{frac:g}", t.us / len(ids), round(mr, 4))

    # (e) the facade's tier walk on the trace head
    n = scale(100_000, 400_000)
    rows.extend(facade_replay(tr.object_ids[:n], tr.timestamps[:n] * 1e3)[0])
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="facade-only end-to-end pass at CI scale")
    ap.add_argument("--shards", type=int, default=1,
                    help="also replay through a sharded cluster and assert "
                         "shard-conformant classification (smoke mode)")
    ap.add_argument("--scenario", default=None,
                    help="replay one named workload from the scenario "
                         "suite ('list' prints the names)")
    args = ap.parse_args()
    if args.scenario == "list":
        print("\n".join(list_scenarios()))
        return
    if args.scenario is not None:
        scenario_rows(args.scenario).print()
        return
    (smoke(shards=args.shards) if args.smoke else run()).print()


if __name__ == "__main__":
    main()
