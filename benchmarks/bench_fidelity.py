"""Paper Fig. 12 / §6.6 — reconstruction fidelity.

(a) decode determinism: the latent codec is bit-exact (asserted), so
    fidelity loss can only come from numerics; we emulate the paper's
    cross-GPU study (H100 vs L4 FMA ordering) by decoding the same latent
    at fp32 vs bf16 weights and measuring the pixel-delta distribution;
(b) LatentBox (lossless latent) vs lossy codecs (JPEG-class q50/q95) at
    comparable sizes: PSNR / SSIM against the original decode;
(c) the rate-distortion ladder: per-rung bytes/object and decoded-pixel
    PSNR / SSIM against the lossless-rung decode, *gated* on each rung's
    configured floor (``repro.compression.ladder.RUNGS``) — a codec or
    decoder change that pushes any rung under its floor fails the run.
    ``--smoke`` runs a CI-sized ladder sweep and writes the versioned
    ``BENCH_fidelity.json`` trajectory artifact at the repo root.
"""

from __future__ import annotations

import argparse
import dataclasses
import os

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, Timer, scale
from repro.compression.ladder import RUNGS, encode_at
from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.compression.lossy import jpeg_like
from repro.compression.metrics import psnr, ssim
from repro.compression.png_proxy import png_like_size
from repro.vae.model import VAE, VAEConfig, decode

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def to_u8(img_pm1: np.ndarray) -> np.ndarray:
    return np.clip((img_pm1 + 1.0) * 127.5, 0, 255).astype(np.uint8)


def run() -> Rows:
    from benchmarks.bench_storage import synth_image
    rows = Rows()
    rng = np.random.default_rng(1)
    res = 256
    n = scale(4, 10)
    vae = VAE(seed=0)

    deltas = []
    ps_lossless, ps_j95, ps_j50 = [], [], []
    ss_lossless, ss_j95 = [], []
    sz_j95, sz_j50, sz_png, sz_lat = [], [], [], []
    for i in range(n):
        img = synth_image(rng, res)
        x = jnp.asarray(img, jnp.float32)[None] / 127.5 - 1.0
        z = np.asarray(vae.encode_mean(x))[0].astype(np.float16)

        blob = compress_latent(z)
        z2 = decompress_latent(blob)
        assert np.array_equal(z, z2), "latent codec must be bit-exact"
        sz_lat.append(len(blob))
        sz_png.append(png_like_size(img))

        ref = to_u8(np.asarray(vae.decode(jnp.asarray(z2,
                                                      jnp.float32)[None]))[0])
        # (a) numerics: decode with bf16 weights (stack-variation proxy)
        dec_bf16 = jax.tree.map(lambda p: p.astype(jnp.bfloat16), vae.decoder)
        alt = to_u8(np.asarray(decode(
            dec_bf16, jnp.asarray(z2, jnp.bfloat16)[None],
            dataclasses.replace(vae.cfg, dtype=jnp.bfloat16)))[0])
        deltas.append((alt.astype(int) - ref.astype(int)).ravel())
        ps_lossless.append(psnr(ref, alt))
        ss_lossless.append(ssim(ref, alt))

        # (b) lossy codecs on the reference decode
        s95, r95 = jpeg_like(ref, quality=95)
        s50, r50 = jpeg_like(ref, quality=50)
        sz_j95.append(s95)
        sz_j50.append(s50)
        ps_j95.append(psnr(ref, r95))
        ss_j95.append(ssim(ref, r95))
        ps_j50.append(psnr(ref, r50))

    d = np.concatenate(deltas)
    rows.add("fidelity.bitexact_latent", derived=1)
    rows.add("fidelity.pixel_unchanged_frac",
             derived=round(float(np.mean(d == 0)), 3))
    rows.add("fidelity.pixel_within_pm3_frac",
             derived=round(float(np.mean(np.abs(d) <= 3)), 4))
    rows.add("fidelity.stackvar_psnr_db",
             derived=round(float(np.mean(ps_lossless)), 1))
    rows.add("fidelity.stackvar_ssim",
             derived=round(float(np.mean(ss_lossless)), 4))
    rows.add("fidelity.jpeg_q95_psnr_db",
             derived=round(float(np.mean(ps_j95)), 1))
    rows.add("fidelity.jpeg_q95_ssim", derived=round(float(np.mean(ss_j95)), 4))
    rows.add("fidelity.jpeg_q50_psnr_db",
             derived=round(float(np.mean(ps_j50)), 1))
    rows.add("fidelity.size_latent_kb",
             derived=round(float(np.mean(sz_lat)) / 1024, 1))
    rows.add("fidelity.size_jpeg_q95_kb",
             derived=round(float(np.mean(sz_j95)) / 1024, 1))
    rows.add("fidelity.size_jpeg_q50_kb",
             derived=round(float(np.mean(sz_j50)) / 1024, 1))
    rows.add("fidelity.size_png_kb",
             derived=round(float(np.mean(sz_png)) / 1024, 1))
    rows.extend(ladder_rows())
    return rows


class FloorBreach(AssertionError):
    """A ladder rung's measured fidelity fell under its configured floor."""


def ladder_rows(smoke: bool = False) -> Rows:
    """(c) the rate-distortion ladder sweep: for every lossy rung, mean
    bytes/object, storage savings vs the lossless rung, and decoded-pixel
    PSNR / SSIM against the lossless-rung decode — plus the recipe rung's
    bit-exact-regeneration check (same recipe, same encoder, same latent:
    its 'fidelity' is identity at near-zero stored bytes).  Raises
    :class:`FloorBreach` if any rung misses its configured floor."""
    from benchmarks.bench_storage import synth_image
    rows = Rows()
    rng = np.random.default_rng(2)
    res = 64 if smoke else 256
    n = 2 if smoke else scale(4, 10)
    vae = VAE(seed=0)

    lossy = [r for r in RUNGS if r.lossy]
    nbytes = {r.index: [] for r in lossy}
    ps = {r.index: [] for r in lossy}
    ss = {r.index: [] for r in lossy}
    sz_lossless = []
    for i in range(n):
        img = synth_image(rng, res)
        x = jnp.asarray(img, jnp.float32)[None] / 127.5 - 1.0
        z = np.asarray(vae.encode_mean(x))[0].astype(np.float16)
        sz_lossless.append(len(compress_latent(z)))
        ref = to_u8(np.asarray(vae.decode(jnp.asarray(z,
                                                      jnp.float32)[None]))[0])
        for r in lossy:
            blob = encode_at(z, r)
            zq = decompress_latent(blob)
            px = to_u8(np.asarray(vae.decode(
                jnp.asarray(zq, jnp.float32)[None]))[0])
            nbytes[r.index].append(len(blob))
            ps[r.index].append(psnr(ref, px))
            ss[r.index].append(ssim(ref, px))
        # recipe rung: regeneration is deterministic, so re-deriving the
        # latent from the same pixels must be bit-exact
        z_again = np.asarray(vae.encode_mean(x))[0].astype(np.float16)
        assert np.array_equal(z, z_again), "regen must be bit-exact"

    base = float(np.mean(sz_lossless))
    rows.add("fidelity.ladder.lossless.bytes_per_object",
             derived=round(base, 1))
    breaches = []
    for r in lossy:
        b = float(np.mean(nbytes[r.index]))
        p_min, s_min = float(np.min(ps[r.index])), float(np.min(ss[r.index]))
        rows.add(f"fidelity.ladder.{r.name}.bytes_per_object",
                 derived=round(b, 1))
        rows.add(f"fidelity.ladder.{r.name}.savings_vs_lossless",
                 derived=round(1.0 - b / base, 3))
        rows.add(f"fidelity.ladder.{r.name}.psnr_db",
                 derived=round(float(np.mean(ps[r.index])), 1))
        rows.add(f"fidelity.ladder.{r.name}.ssim",
                 derived=round(float(np.mean(ss[r.index])), 4))
        rows.add(f"fidelity.ladder.{r.name}.psnr_floor_db",
                 derived=r.psnr_floor_db)
        rows.add(f"fidelity.ladder.{r.name}.ssim_floor",
                 derived=r.ssim_floor)
        if p_min < r.psnr_floor_db:
            breaches.append(f"{r.name}: psnr {p_min:.1f} dB < floor "
                            f"{r.psnr_floor_db}")
        if s_min < r.ssim_floor:
            breaches.append(f"{r.name}: ssim {s_min:.4f} < floor "
                            f"{r.ssim_floor}")
    rows.add("fidelity.ladder.recipe.bytes_per_object", derived=0.0)
    rows.add("fidelity.ladder.recipe.bitexact_regen", derived=1)
    if breaches:
        raise FloorBreach("; ".join(breaches))
    return rows


def trajectory(out_dir: str = REPO_ROOT, smoke: bool = False) -> Rows:
    """The fidelity-trajectory artifact: ``<out_dir>/BENCH_fidelity.json``
    — versioned per-rung storage savings vs PSNR/SSIM, so later checkouts
    have a rate-distortion trend to regress against (and CI fails on any
    rung under its floor)."""
    rows = ladder_rows(smoke=smoke)
    path = rows.save_json("BENCH_fidelity", out_dir=out_dir)
    print(f"# saved {path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized ladder sweep; writes BENCH_fidelity.json "
                         "at the repo root and fails on any floor breach")
    args = ap.parse_args()
    if args.smoke:
        trajectory(smoke=True).print()
        return
    run().print()


if __name__ == "__main__":
    main()
