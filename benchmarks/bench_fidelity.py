"""Paper Fig. 12 / §6.6 — reconstruction fidelity.

(a) decode determinism: the latent codec is bit-exact (asserted), so
    fidelity loss can only come from numerics; we emulate the paper's
    cross-GPU study (H100 vs L4 FMA ordering) by decoding the same latent
    at fp32 vs bf16 weights and measuring the pixel-delta distribution;
(b) LatentBox (lossless latent) vs lossy codecs (JPEG-class q50/q95) at
    comparable sizes: PSNR / SSIM against the original decode.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Rows, Timer, scale
from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.compression.lossy import jpeg_like
from repro.compression.metrics import psnr, ssim
from repro.compression.png_proxy import png_like_size
from repro.vae.model import VAE, VAEConfig, decode


def to_u8(img_pm1: np.ndarray) -> np.ndarray:
    return np.clip((img_pm1 + 1.0) * 127.5, 0, 255).astype(np.uint8)


def run() -> Rows:
    from benchmarks.bench_storage import synth_image
    rows = Rows()
    rng = np.random.default_rng(1)
    res = 256
    n = scale(4, 10)
    vae = VAE(seed=0)

    deltas = []
    ps_lossless, ps_j95, ps_j50 = [], [], []
    ss_lossless, ss_j95 = [], []
    sz_j95, sz_j50, sz_png, sz_lat = [], [], [], []
    for i in range(n):
        img = synth_image(rng, res)
        x = jnp.asarray(img, jnp.float32)[None] / 127.5 - 1.0
        z = np.asarray(vae.encode_mean(x))[0].astype(np.float16)

        blob = compress_latent(z)
        z2 = decompress_latent(blob)
        assert np.array_equal(z, z2), "latent codec must be bit-exact"
        sz_lat.append(len(blob))
        sz_png.append(png_like_size(img))

        ref = to_u8(np.asarray(vae.decode(jnp.asarray(z2,
                                                      jnp.float32)[None]))[0])
        # (a) numerics: decode with bf16 weights (stack-variation proxy)
        dec_bf16 = jax.tree.map(lambda p: p.astype(jnp.bfloat16), vae.decoder)
        alt = to_u8(np.asarray(decode(
            dec_bf16, jnp.asarray(z2, jnp.bfloat16)[None],
            dataclasses.replace(vae.cfg, dtype=jnp.bfloat16)))[0])
        deltas.append((alt.astype(int) - ref.astype(int)).ravel())
        ps_lossless.append(psnr(ref, alt))
        ss_lossless.append(ssim(ref, alt))

        # (b) lossy codecs on the reference decode
        s95, r95 = jpeg_like(ref, quality=95)
        s50, r50 = jpeg_like(ref, quality=50)
        sz_j95.append(s95)
        sz_j50.append(s50)
        ps_j95.append(psnr(ref, r95))
        ss_j95.append(ssim(ref, r95))
        ps_j50.append(psnr(ref, r50))

    d = np.concatenate(deltas)
    rows.add("fidelity.bitexact_latent", derived=1)
    rows.add("fidelity.pixel_unchanged_frac",
             derived=round(float(np.mean(d == 0)), 3))
    rows.add("fidelity.pixel_within_pm3_frac",
             derived=round(float(np.mean(np.abs(d) <= 3)), 4))
    rows.add("fidelity.stackvar_psnr_db",
             derived=round(float(np.mean(ps_lossless)), 1))
    rows.add("fidelity.stackvar_ssim",
             derived=round(float(np.mean(ss_lossless)), 4))
    rows.add("fidelity.jpeg_q95_psnr_db",
             derived=round(float(np.mean(ps_j95)), 1))
    rows.add("fidelity.jpeg_q95_ssim", derived=round(float(np.mean(ss_j95)), 4))
    rows.add("fidelity.jpeg_q50_psnr_db",
             derived=round(float(np.mean(ps_j50)), 1))
    rows.add("fidelity.size_latent_kb",
             derived=round(float(np.mean(sz_lat)) / 1024, 1))
    rows.add("fidelity.size_jpeg_q95_kb",
             derived=round(float(np.mean(sz_j95)) / 1024, 1))
    rows.add("fidelity.size_jpeg_q50_kb",
             derived=round(float(np.mean(sz_j50)) / 1024, 1))
    rows.add("fidelity.size_png_kb",
             derived=round(float(np.mean(sz_png)) / 1024, 1))
    return rows


def main():
    run().print()


if __name__ == "__main__":
    main()
