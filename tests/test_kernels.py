"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in kernels/ref.py (assignment deliverable c)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.conv3x3 import conv3x3
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gn_silu import group_norm_silu
from repro.kernels.gn_silu_conv import gn_silu_conv3x3
from repro.kernels.output_epilogue import output_epilogue
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.upsample_conv import upsample_conv3x3

R = np.random.default_rng(0)


def arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(R.standard_normal(shape) * scale, dtype)


def tol(dtype):
    return 2e-5 if dtype == jnp.float32 else 6e-2


@pytest.mark.parametrize("shape,groups", [
    ((1, 8, 8, 64), 8), ((2, 16, 16, 128), 32), ((1, 7, 9, 32), 4),
    ((3, 4, 4, 256), 32), ((1, 1, 1, 16), 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gn_silu(shape, groups, dtype):
    x = arr(shape, dtype)
    s = arr(shape[-1:], dtype)
    b = arr(shape[-1:], dtype)
    out = group_norm_silu(x, s, b, groups=groups, interpret=True)
    want = ref.group_norm_silu_ref(x, s, b, groups=groups)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("n,h,w,cin,cout,groups", [
    (1, 8, 8, 16, 32, 4), (2, 16, 12, 8, 8, 2), (1, 32, 32, 64, 128, 8),
    (1, 5, 7, 4, 4, 2), (3, 4, 4, 32, 16, 8), (1, 16, 16, 64, 64, 32),
])
def test_gn_silu_conv3x3(n, h, w, cin, cout, groups):
    """Fused GN+SiLU+conv3x3 (res-block hot path) vs composed oracles."""
    x = arr((n, h, w, cin))
    s = arr((cin,))
    gb = arr((cin,))
    wt = arr((3, 3, cin, cout), scale=0.1)
    b = arr((cout,))
    out = gn_silu_conv3x3(x, s, gb, wt, b, groups=groups, rows=8,
                          interpret=True)
    want = ref.gn_silu_conv3x3_ref(x, s, gb, wt, b, groups=groups)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_band_rows_divides_h_under_vmem_pressure():
    """The VMEM halving must land on a divisor of h (e.g. h=18 would
    otherwise shrink 18 -> 9 -> 4, and 4 does not divide 18)."""
    from repro.kernels.conv3x3 import VMEM_BUDGET, band_rows
    for h in (18, 24, 7, 5, 96):
        for width, cin in ((1024, 384), (16, 8), (4096, 512)):
            r = band_rows(h, width, cin, 4, 32)
            assert h % r == 0
            assert r == 1 or (r + 2) * (width + 2) * cin * 4 <= VMEM_BUDGET


def test_conv3x3_non_power_of_two_height_vmem_fallback():
    """End-to-end at a height whose halvings aren't all divisors."""
    x = arr((1, 18, 12, 8))
    wt = arr((3, 3, 8, 8), scale=0.1)
    b = arr((8,))
    out = conv3x3(x, wt, b, rows=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv3x3_ref(x, wt, b)),
                               atol=1e-4)


def test_gn_silu_conv3x3_no_bias():
    x = arr((1, 8, 8, 8))
    s = arr((8,))
    gb = arr((8,))
    wt = arr((3, 3, 8, 8), scale=0.1)
    out = gn_silu_conv3x3(x, s, gb, wt, groups=2, interpret=True)
    want = ref.gn_silu_conv3x3_ref(x, s, gb, wt, groups=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_gn_silu_conv3x3_bf16():
    x = arr((1, 8, 8, 32), jnp.bfloat16)
    s = arr((32,), jnp.bfloat16)
    gb = arr((32,), jnp.bfloat16)
    wt = arr((3, 3, 32, 32), jnp.bfloat16, scale=0.1)
    b = arr((32,), jnp.bfloat16)
    out = gn_silu_conv3x3(x, s, gb, wt, b, groups=8, interpret=True)
    want = ref.gn_silu_conv3x3_ref(x, s, gb, wt, b, groups=8)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(jnp.bfloat16), rtol=tol(jnp.bfloat16))


@pytest.mark.parametrize("n,h,w,cin,cout", [
    (1, 8, 8, 16, 32), (2, 16, 12, 8, 8), (1, 32, 32, 64, 128),
    (1, 5, 7, 4, 4), (1, 1, 1, 8, 8), (3, 4, 4, 32, 16),
])
def test_upsample_conv3x3(n, h, w, cin, cout):
    """Fused nearest-2x upsample + conv (phase-decomposed) vs the
    upsample-then-conv oracle — the 4x intermediate never materializes."""
    x = arr((n, h, w, cin))
    wt = arr((3, 3, cin, cout), scale=0.1)
    b = arr((cout,))
    out = upsample_conv3x3(x, wt, b, rows=8, interpret=True)
    assert out.shape == (n, 2 * h, 2 * w, cout)
    want = ref.upsample_conv3x3_ref(x, wt, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_upsample_conv3x3_no_bias():
    x = arr((1, 8, 8, 8))
    wt = arr((3, 3, 8, 8), scale=0.1)
    out = upsample_conv3x3(x, wt, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.upsample_conv3x3_ref(x, wt)),
                               atol=1e-4)


def test_upsample_conv3x3_bf16():
    x = arr((1, 8, 8, 16), jnp.bfloat16)
    wt = arr((3, 3, 16, 16), jnp.bfloat16, scale=0.1)
    b = arr((16,), jnp.bfloat16)
    out = upsample_conv3x3(x, wt, b, interpret=True)
    want = ref.upsample_conv3x3_ref(x, wt, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(jnp.bfloat16), rtol=tol(jnp.bfloat16))


def test_upsample_conv3x3_matches_unfused_decode_path():
    """The fused op must agree with what the decoder used to compute:
    jnp.repeat upsample followed by the conv3x3 kernel."""
    x = arr((1, 6, 6, 8))
    wt = arr((3, 3, 8, 8), scale=0.1)
    b = arr((8,))
    fused = upsample_conv3x3(x, wt, b, rows=4, interpret=True)
    x2 = jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)
    unfused = conv3x3(x2, wt, b, rows=4, interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=1e-4)


@pytest.mark.parametrize("n,h,w,cin,groups", [
    (1, 8, 8, 16, 4), (2, 16, 12, 8, 2), (1, 5, 7, 4, 2),
    (1, 32, 32, 64, 8), (3, 4, 4, 32, 8),
])
def test_output_epilogue(n, h, w, cin, groups):
    """Fused GN+SiLU+conv_out+clamp+uint8 vs the composed oracle: any
    disagreement is at most the 1-LSB rounding boundary."""
    x = arr((n, h, w, cin))
    s = arr((cin,))
    gb = arr((cin,))
    wt = arr((3, 3, cin, 3), scale=0.1)
    b = arr((3,), scale=0.1)
    out = output_epilogue(x, s, gb, wt, b, groups=groups, rows=8,
                          interpret=True)
    assert out.dtype == jnp.uint8
    want = ref.output_epilogue_ref(x, s, gb, wt, b, groups=groups)
    lsb = np.abs(np.asarray(out, np.int16) - np.asarray(want, np.int16))
    assert lsb.max() <= 1


def test_output_epilogue_saturates():
    """Large pre-activations clamp to exactly 0 / 255, never wrap."""
    x = arr((1, 8, 8, 8), scale=5.0)
    s = arr((8,), scale=5.0)
    gb = arr((8,), scale=5.0)
    wt = arr((3, 3, 8, 3), scale=5.0)
    out = np.asarray(output_epilogue(x, s, gb, wt, groups=2, rows=8,
                                     interpret=True))
    want = np.asarray(ref.output_epilogue_ref(x, s, gb, wt, groups=2))
    assert set(np.unique(out)) <= set(np.unique(want)) | {0, 255}
    assert np.abs(out.astype(np.int16) - want.astype(np.int16)).max() <= 1


def test_quantize_u8_round_trip_anchors():
    """The display mapping hits the exact anchor bytes."""
    y = jnp.asarray([-2.0, -1.0, 0.0, 1.0, 2.0], jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ref.quantize_u8_ref(y)), [0, 0, 128, 255, 255])


@pytest.mark.parametrize("n,hq,hkv,sq,skv,d,causal,window", [
    (1, 1, 1, 64, 64, 32, False, None),
    (2, 4, 2, 128, 128, 64, True, None),
    (1, 8, 2, 128, 128, 32, True, 64),
    (1, 2, 1, 32, 96, 16, True, None),
    (1, 4, 4, 64, 64, 128, False, None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(n, hq, hkv, sq, skv, d, causal, window, dtype):
    q = arr((n, hq, sq, d), dtype)
    k = arr((n, hkv, skv, d), dtype)
    v = arr((n, hkv, skv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_kv=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


@pytest.mark.parametrize("n,hq,hkv,S,d", [
    (2, 4, 2, 128, 32), (1, 8, 1, 512, 64), (3, 6, 3, 256, 16),
    (1, 16, 2, 64, 128),
])
def test_decode_attention(n, hq, hkv, S, d):
    q = arr((n, hq, d))
    kc = arr((n, hkv, S, d))
    vc = arr((n, hkv, S, d))
    lens = jnp.asarray(R.integers(1, S + 1, n), jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_kv=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("n,h,w,cin,cout", [
    (1, 8, 8, 16, 32), (2, 16, 12, 8, 8), (1, 32, 32, 64, 128),
    (1, 5, 7, 4, 4), (1, 9, 16, 32, 16),
])
def test_conv3x3(n, h, w, cin, cout):
    x = arr((n, h, w, cin))
    wt = arr((3, 3, cin, cout), scale=0.1)
    b = arr((cout,))
    out = conv3x3(x, wt, b, rows=8, interpret=True)
    want = ref.conv3x3_ref(x, wt, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_conv3x3_no_bias():
    x = arr((1, 8, 8, 8))
    wt = arr((3, 3, 8, 8), scale=0.1)
    np.testing.assert_allclose(np.asarray(conv3x3(x, wt, interpret=True)),
                               np.asarray(ref.conv3x3_ref(x, wt)), atol=1e-4)


@pytest.mark.parametrize("n,h,t,d,chunk", [
    (1, 2, 32, 16, 16), (2, 4, 64, 32, 32), (1, 1, 48, 8, 8),
])
def test_rwkv6_scan(n, h, t, d, chunk):
    r = arr((n, h, t, d), scale=0.5)
    k = arr((n, h, t, d), scale=0.5)
    v = arr((n, h, t, d), scale=0.5)
    w = arr((n, h, t, d), scale=0.3) - 1.0
    u = arr((h, d), scale=0.3)
    out, sT = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    want, sW = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sW), atol=3e-4)


def test_chunked_model_forms_match_ref():
    """The XLA chunked forms used by the models (ssm.py) match the
    sequential oracle too."""
    from repro.models.ssm import rwkv6_chunked
    n, h, t, d = 2, 3, 96, 16
    r, k, v = (arr((n, h, t, d), scale=0.5) for _ in range(3))
    w = arr((n, h, t, d), scale=0.5)
    u = arr((h, d), scale=0.3)
    s0 = jnp.zeros((n, h, d, d), jnp.float32)
    oc, sc = rwkv6_chunked(r, k, v, w, u, s0, chunk=32)
    orf, srf = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(orf), atol=3e-4)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(srf), atol=3e-4)
