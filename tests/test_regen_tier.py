"""Regeneration tier: the break-even demotion inequality, sweep behavior,
and a trace-driven check that demoted-cold objects regenerate through the
new tier-walk and get re-admitted to warmer tiers."""

import numpy as np
import pytest

from repro.core.regen_tier import (Recipe, RegenPolicy, RegenTierStore,
                                   synthesize_image)
from repro.core.tuner import TunerConfig
from repro.store import FULL_MISS, LATENT_HIT, REGEN_MISS, LatentBox, \
    StoreConfig
from repro.trace.synth import TraceConfig, generate_trace

MO_S = 30 * 86_400.0


class TestBreakEvenInequality:
    def test_demotion_age_is_the_cost_crossover(self):
        """Demote exactly when S_lat * P_s3 > lambda(a) * t_gen_hr * P_gpu:
        below the break-even age regeneration is the costlier option, above
        it storage is."""
        pol = RegenPolicy()
        a_star = pol.demotion_age_months()
        s = pol.storage_cost_per_month()
        assert pol.regen_cost_per_month(np.array(a_star * 0.5)) > s
        assert pol.regen_cost_per_month(np.array(a_star * 2.0)) < s

    def test_view_rate_decays_monotonically(self):
        pol = RegenPolicy()
        ages = np.linspace(0.1, 60.0, 50)
        rates = pol.view_rate_per_month(ages)
        assert np.all(np.diff(rates) < 0)

    def test_cheaper_gpus_demote_earlier(self):
        assert RegenPolicy(p_gpu_hr=0.10).demotion_age_months() < \
            RegenPolicy().demotion_age_months()


class TestDemotionSweep:
    def test_sweep_respects_idle_cutoff(self):
        store = RegenTierStore()
        for oid in range(4):
            store.put(oid, 1e5, now_mo=0.0,
                      recipe=Recipe(seed=oid, height=8, width=8))
        cutoff = store.policy.demotion_age_months()
        store.fetch(0, now_mo=cutoff + 5.0)  # object 0 stays warm
        n = store.run_demotion(now_mo=cutoff + 10.0)
        assert n == 3
        assert not store.is_demoted(0)
        assert all(store.is_demoted(o) for o in (1, 2, 3))

    def test_age_override_for_tradeoff_curves(self):
        store = RegenTierStore()
        store.put(1, 1e5, now_mo=0.0)
        assert store.run_demotion(now_mo=1.0, age_override_mo=0.5) == 1
        assert store.is_demoted(1)

    def test_readmit_restores_latent_class(self):
        store = RegenTierStore()
        store.put(1, 1e5, now_mo=0.0)
        store.demote(1)
        _, needs_regen = store.fetch(1, now_mo=5.0)
        assert needs_regen and store.n_regens == 1
        store.readmit(1, 1e5, now_mo=5.0)
        _, needs_regen = store.fetch(1, now_mo=5.1)
        assert not needs_regen


class TestTraceDrivenRegen:
    """Demoted-cold objects regenerate through the tier walk and come back
    warm — on a real (synthetic) trace, through the public facade only."""

    @pytest.fixture(scope="class")
    def trace(self):
        return generate_trace(TraceConfig(n_objects=40, n_requests=2_000,
                                          span_days=10, seed=5))

    def test_cold_objects_regen_then_warm(self, trace):
        box = LatentBox.simulated(StoreConfig(
            n_nodes=2, cache_bytes_per_node=3e4, image_bytes=3e3,
            latent_bytes=6e2, tuner=TunerConfig(window=10**9)))
        ids = trace.object_ids[:600].tolist()
        for oid in set(ids):
            box.put(oid, recipe=Recipe(seed=oid, height=8, width=8))
        # first half of the trace warms the store
        half = len(ids) // 2
        box.get_many(ids[:half])
        # demote everything that went cold (never requested in window 1)
        seen = set(ids[:half])
        cold = [oid for oid in set(ids) if oid not in seen]
        assert cold, "trace slice should leave some objects cold"
        demoted = [oid for oid in cold if box.demote(oid)]
        assert demoted
        # replay the second half: every demoted object's first appearance
        # must classify as a regen miss, and later reads must NOT
        results = box.get_many(ids[half:])
        first_seen = {}
        for oid, r in zip(ids[half:], results):
            if oid not in first_seen:
                first_seen[oid] = r.hit_class
            if oid in demoted and oid in first_seen \
                    and first_seen[oid] != r.hit_class:
                # a later read of a regenerated object is warm again
                assert r.hit_class != REGEN_MISS
        for oid in demoted:
            if oid in first_seen:
                assert first_seen[oid] == REGEN_MISS
        # non-demoted objects never regen
        for oid, r in zip(ids[half:], results):
            if oid not in demoted:
                assert r.hit_class != REGEN_MISS
        s = box.summary()
        assert s[REGEN_MISS] == sum(
            1 for r in results if r.hit_class == REGEN_MISS)

    def test_regen_readmits_to_durable(self, trace):
        box = LatentBox.simulated(StoreConfig(
            n_nodes=1, cache_bytes_per_node=64.0,   # cache fits ~nothing
            image_bytes=3e3, latent_bytes=6e2,
            tuner=TunerConfig(window=10**9)))
        box.put(1, recipe=Recipe(seed=1, height=8, width=8))
        box.demote(1)
        assert box.get(1).hit_class == REGEN_MISS
        # durable again: the next uncached read is a plain fetch
        assert box.get(1).hit_class == FULL_MISS

    def test_engine_regen_is_bit_exact(self):
        """The regenerated latent decodes to the exact pre-demotion pixels
        (the property that makes recipes a durability class at all)."""
        from repro.vae.model import VAE, VAEConfig
        vae = VAE(VAEConfig(name="tiny", latent_channels=4,
                            block_out_channels=(16, 32), layers_per_block=1,
                            groups=4), seed=0)
        box = LatentBox.engine(vae=vae, config=StoreConfig(
            n_nodes=1, cache_bytes_per_node=1e4, image_bytes=3e3,
            latent_bytes=6e2, tuner=TunerConfig(window=10**9)))
        rec = Recipe(seed=21, height=16, width=16, scale=0.5)
        box.put(9, recipe=rec)
        before = box.get(9)
        assert before.hit_class == FULL_MISS
        box.demote(9)
        after = box.get(9)
        assert after.hit_class == REGEN_MISS and after.regenerated
        np.testing.assert_array_equal(before.payload, after.payload)
