import os

# Tests must see the real 1-CPU world (the dry-run sets its own flags in a
# separate process).  Keep any accidental device-count override out.
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
