import os

# Tests must see the real 1-CPU world (the dry-run sets its own flags in a
# separate process).  Keep any accidental device-count override out.
os.environ.pop("XLA_FLAGS", None)

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: full differential matrices (shard x backend x scenario); "
        "run by the scheduled CI job, excluded from push CI via -m 'not slow'")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# shared helpers of the shard-conformance harness (tests/test_shard_*.py)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def tiny_vae():
    """One tiny VAE for every engine-backend test in the session: all
    backends sharing this instance share its jitted decode, so each batch
    bucket compiles once for the whole run."""
    from repro.vae.model import VAE, VAEConfig
    return VAE(VAEConfig(name="tiny", latent_channels=4,
                         block_out_channels=(16, 32),
                         layers_per_block=1, groups=4), seed=0)


def conformance_config(n_nodes: int, **kw):
    """StoreConfig for differential runs: real capacity pressure (caches
    evict), but the marginal-hit tuner's window never fires — alpha stays
    put, so classification depends only on the per-node request
    subsequences, which the global node namespace makes shard-invariant.
    """
    from repro.core.tuner import TunerConfig
    from repro.store import StoreConfig
    # image_bytes = uint8 nbytes of a decoded 16x16x3 image: the engine
    # backend charges real stored-array bytes, so every cell of the
    # differential matrix must estimate the same truth
    base = dict(n_nodes=n_nodes, cache_bytes_per_node=2e4, image_bytes=768.0,
                latent_bytes=6e2, promote_threshold=2,
                tuner=TunerConfig(window=10**9))
    base.update(kw)
    return StoreConfig(**base)


def make_box(kind: str, shards: int, total_nodes: int, vae=None, **cfg_kw):
    """Build a LatentBox cell of the differential matrix: ``total_nodes``
    is the global fleet size, split evenly across ``shards``."""
    from repro.store import LatentBox
    assert total_nodes % shards == 0
    cfg = conformance_config(total_nodes // shards, **cfg_kw)
    if kind == "engine":
        return LatentBox.engine(vae=vae, config=cfg, shards=shards)
    if kind == "sim":
        return LatentBox.simulated(cfg, shards=shards)
    raise ValueError(kind)


def fill_and_demote(box, n_objects: int, demote=(3, 7, 11), res: int = 16):
    """Identical starting state for every cell: recipe-backed puts, a few
    objects demoted to recipe-only durability (regen coverage)."""
    from repro.core.regen_tier import Recipe
    for oid in range(n_objects):
        box.put(oid, recipe=Recipe(seed=1000 + oid, height=res, width=res))
    for oid in demote:
        if oid < n_objects:
            assert box.demote(oid)


def classify(box, object_ids, window: int = 8):
    """Replay a trace through the facade in fixed windows; returns the
    differential signature: per-request (hit_class, owner node)."""
    out = []
    ids = [int(i) for i in object_ids]
    for s in range(0, len(ids), window):
        out += [(r.hit_class, r.node) for r in box.get_many(ids[s:s + window])]
    return out
