"""Synthetic trace statistics + distribution helpers (sharding specs,
collectives, analytic costs vs XLA)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.trace.synth import TraceConfig, generate_trace


class TestTrace:
    @pytest.fixture(scope="class")
    def trace(self):
        return generate_trace(TraceConfig(n_objects=20_000,
                                          n_requests=400_000,
                                          span_days=60, seed=5))

    def test_sorted_and_bounded(self, trace):
        assert np.all(np.diff(trace.timestamps) >= 0)
        assert trace.timestamps[0] >= 0
        assert trace.timestamps[-1] <= trace.config.span_days * 86_400 + 1
        assert trace.object_ids.max() < trace.config.n_objects

    def test_zipf_skew(self, trace):
        s = trace.characterize()
        assert s["top1_share"] > 0.15          # heavy head
        assert s["top10_share"] > s["top1_share"]
        assert s["frac_lt10_views"] > 0.4      # long tail

    def test_reaccess_concentration(self, trace):
        s = trace.characterize()
        assert s["reaccess_1h"] > 0.15
        assert s["reaccess_1d"] > s["reaccess_1h"]

    def test_post_birth_decay(self, trace):
        ages = trace.timestamps - trace.birth_time[trace.object_ids]
        frac_week1 = float(np.mean(ages < 7 * 86_400))
        assert frac_week1 > 0.5                # most views close to birth

    def test_deterministic(self):
        cfg = TraceConfig(n_objects=500, n_requests=5_000, seed=9)
        a, b = generate_trace(cfg), generate_trace(cfg)
        np.testing.assert_array_equal(a.object_ids, b.object_ids)

    def test_window_and_downsample(self, trace):
        w = trace.window(0, 86_400.0)
        assert w.n_requests < trace.n_requests
        assert np.all(w.timestamps <= 86_400.0)
        d = trace.downsample_objects(1_000, seed=1)
        assert len(np.unique(d.object_ids)) <= 1_000

    def test_save_load_roundtrip(self, trace, tmp_path):
        p = str(tmp_path / "t.npz")
        trace.save(p)
        from repro.trace.synth import SyntheticTrace
        t2 = SyntheticTrace.load(p)
        np.testing.assert_array_equal(trace.object_ids, t2.object_ids)


class TestShardingHelpers:
    def test_constrain_noop_without_mesh(self):
        from repro.dist.sharding import constrain, set_constraint_mesh
        set_constraint_mesh(None)
        x = jnp.ones((4, 4))
        assert constrain(x, "data", None) is x

    def test_zero1_skips_fsdp_leaves(self):
        from repro.dist.sharding import opt_state_pspecs
        specs = {"w": P(None, "data", "model"), "b": P(None, "model")}
        o = opt_state_pspecs(specs, zero1=True)
        assert o.m["w"] == P(None, "data", "model")     # untouched
        assert o.m["b"] == P("data", "model")           # first free dim

    def test_retarget_pspec_multipod(self):
        import jax as _jax
        from repro.dist.sharding import retarget_pspec
        mesh = _jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
        assert retarget_pspec(P("data", None), mesh) == \
            P(("pod", "data"), None)


class TestAnalyticCosts:
    def test_model_flops_6nd_dense(self):
        import repro.configs as RC
        from repro.configs.shapes import LM_SHAPES
        from repro.launch.costs import cell_cost
        cfg = RC.get_config("granite-8b")
        c = cell_cost(cfg, LM_SHAPES["train_4k"])
        tokens = 256 * 4096
        assert c.model_flops == pytest.approx(
            6 * cfg.param_count() * tokens, rel=1e-6)
        # compiled-equivalent flops exceed 6ND (remat) but < 3x
        assert 1.0 < c.flops / c.model_flops < 3.0

    def test_decode_memory_dominated_by_kv_or_params(self):
        import repro.configs as RC
        from repro.configs.shapes import LM_SHAPES
        from repro.launch.costs import cell_cost
        cfg = RC.get_config("qwen2-7b")
        c = cell_cost(cfg, LM_SHAPES["decode_32k"])
        # decode flops tiny vs train
        t = cell_cost(cfg, LM_SHAPES["train_4k"])
        assert c.flops < t.flops / 1e3

    def test_vae_decoder_flops_scale(self):
        from repro.vae.serve import decoder_flops_per_image
        f512 = decoder_flops_per_image(resolution=512)
        f1024 = decoder_flops_per_image(resolution=1024)
        assert 3.5 < f1024 / f512 < 4.5        # ~quadratic in resolution

    def test_analytic_matches_xla_at_smoke_scale(self):
        """Calibration: cost_analysis on an unrolled 1-device compile of a
        reduced dense model agrees with the analytic forward FLOPs within
        ~35% (XLA counts some fusions differently)."""
        import dataclasses
        import repro.configs as RC
        from repro.launch.costs import fwd_flops_per_token, _logits_flops
        cfg = dataclasses.replace(RC.reduced_config(RC.get_config(
            "granite-8b")), scan_unroll=True, remat=False)
        model = RC.build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        b, s = 2, 64
        toks = jnp.zeros((b, s), jnp.int32)

        def fwd(p, t):
            return model.logits(p, model.hidden(p, t, remat=False))

        compiled = jax.jit(fwd).lower(params, toks).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):     # jax < 0.5 per-device list
            ca = ca[0]
        got = ca["flops"]
        want = (fwd_flops_per_token(cfg, s / 2) * b * s
                + _logits_flops(cfg, b * s))
        assert 0.5 < got / want < 1.5
