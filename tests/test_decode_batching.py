"""Microbatching decode scheduler: bucketed batch-N decode is bit-identical
to batch-1 per image (the paper's determinism claim survives batching),
duplicate in-flight oids single-flight into one decode, and node-name
parsing is strict."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.core.latent_store import LatentStore
from repro.core.tuner import TunerConfig
from repro.serve.engine import (DecodeBatcher, EngineConfig, ServingEngine,
                                _node_index)
from repro.vae.model import VAE, VAEConfig

TINY = VAEConfig(name="tiny", latent_channels=4, block_out_channels=(16, 32),
                 layers_per_block=1, groups=4)
N_OBJECTS = 12


@pytest.fixture(scope="module")
def vae():
    return VAE(TINY, seed=0)


@pytest.fixture(scope="module")
def store(vae):
    rng = np.random.default_rng(7)
    st = LatentStore(seed=1)
    for oid in range(N_OBJECTS):
        img = jnp.asarray(rng.standard_normal((1, 16, 16, 3)), jnp.float32)
        z = np.asarray(vae.encode_mean(img)).astype(np.float16)[0]
        st.put(oid, compress_latent(z))
    return st


def make_engine(vae, store, **kw):
    cfg = EngineConfig(n_nodes=2, cache_bytes_per_node=1e5,
                       tuner=TunerConfig(window=50, step=0.02), **kw)
    # image_bytes = real uint8 nbytes of a 16x16x3 decode (the engine
    # corrects the charge to the stored array's nbytes anyway)
    return ServingEngine(vae, store, cfg, image_bytes=768.0, latent_bytes=6e2)


class TestBitIdenticalBatching:
    def test_batched_equals_batch1_per_image(self, vae, store):
        """get_many over N cold misses (one bucketed decode) returns the
        same bits as N separate get calls on a fresh engine."""
        oids = list(range(8))
        batched = make_engine(vae, store).get_many(oids)
        sequential_eng = make_engine(vae, store)
        for oid, (img_b, _) in zip(oids, batched):
            img_1, _ = sequential_eng.get(oid)
            np.testing.assert_array_equal(img_b, img_1)

    def test_padded_bucket_equals_batch1(self, vae, store):
        """3 misses pad to the 4-bucket; padding must not perturb outputs."""
        eng = make_engine(vae, store)
        res = eng.get_many([0, 1, 2])
        assert eng.batcher.stats["padded_slots"] == 1
        for oid, (img, _) in zip([0, 1, 2], res):
            z = decompress_latent(store.get(oid))
            direct = np.asarray(vae.decode_u8(
                jnp.asarray(z, jnp.float32)[None]))[0]
            np.testing.assert_array_equal(img, direct)

    def test_batched_results_match_direct_decode(self, vae, store):
        eng = make_engine(vae, store)
        res = eng.get_many(list(range(N_OBJECTS)))   # > max bucket: 2 batches
        assert eng.batcher.stats["batches"] == 2
        for oid, (img, _) in zip(range(N_OBJECTS), res):
            z = decompress_latent(store.get(oid))
            direct = np.asarray(vae.decode_u8(
                jnp.asarray(z, jnp.float32)[None]))[0]
            np.testing.assert_array_equal(img, direct)


class TestSingleFlight:
    def test_duplicate_oids_decode_once(self, vae, store):
        eng = make_engine(vae, store)
        res = eng.get_many([5, 5, 5, 5])
        assert eng.batcher.stats["decodes"] == 1
        assert eng.batcher.stats["coalesced"] == 3
        ref = res[0][0]
        for img, _ in res[1:]:
            np.testing.assert_array_equal(img, ref)

    def test_mixed_duplicates_and_uniques(self, vae, store):
        eng = make_engine(vae, store)
        res = eng.get_many([1, 2, 1, 3, 2, 1])
        assert eng.batcher.stats["decodes"] == 3
        assert eng.batcher.stats["coalesced"] == 3
        assert len(res) == 6
        s = eng.summary()
        assert s["total"] == 6 and s["coalesced_decodes"] == 3

    def test_tuner_sees_per_image_ms(self, vae, store):
        eng = make_engine(vae, store)
        eng.get_many([0, 1, 2, 3])
        assert any(n.tuner.t_decode._initialized for n in eng.nodes)


class TestBucketing:
    def test_bucket_for(self, vae):
        b = DecodeBatcher(vae, buckets=(1, 2, 4, 8))
        assert [b.bucket_for(n) for n in (1, 2, 3, 4, 5, 8)] == \
            [1, 2, 4, 4, 8, 8]

    def test_flush_chunks_at_max_bucket(self, vae, store):
        eng = make_engine(vae, store, decode_buckets=(1, 2))
        eng.get_many(list(range(5)))                 # 2 + 2 + 1
        assert eng.batcher.stats["batches"] == 3
        assert eng.batcher.stats["padded_slots"] == 0

    def test_bad_buckets_rejected(self, vae):
        with pytest.raises(ValueError):
            DecodeBatcher(vae, buckets=())
        with pytest.raises(ValueError):
            DecodeBatcher(vae, buckets=(0, 2))


class TestNodeIndex:
    def test_parses(self):
        assert _node_index("node0") == 0
        assert _node_index("node17") == 17

    @pytest.mark.parametrize("bad", ["node", "peer3", "nodex", "3"])
    def test_rejects_malformed(self, bad):
        with pytest.raises(ValueError):
            _node_index(bad)


class TestAbortedWindow:
    def test_unknown_oid_does_not_leak_pending_decodes(self, vae, store):
        """A KeyError mid-window must not leave queued decodes or queue
        depth behind for the next window."""
        eng = make_engine(vae, store)
        with pytest.raises(KeyError):
            eng.get_many([0, 1, N_OBJECTS + 99])
        assert len(eng.batcher) == 0
        assert all(n.queue_depth == 0 for n in eng.nodes)
        decodes_before = eng.batcher.stats["decodes"]
        res = eng.get_many([2, 3])
        assert eng.batcher.stats["decodes"] == decodes_before + 2
        for oid, (img, _) in zip([2, 3], res):
            z = decompress_latent(store.get(oid))
            direct = np.asarray(vae.decode_u8(
                jnp.asarray(z, jnp.float32)[None]))[0]
            np.testing.assert_array_equal(img, direct)


class TestEngineStillServes:
    def test_hit_composition_improves(self, vae, store):
        """Repeated zipf traffic through the batched path still builds
        image hits (regression guard on the rewritten read path)."""
        rng = np.random.default_rng(0)
        eng = make_engine(vae, store)
        ids = rng.zipf(1.4, 300) % N_OBJECTS
        outcomes = []
        for start in range(0, len(ids), 8):          # 8-request windows
            outcomes += [o for _, o in
                         eng.get_many([int(i) for i in
                                       ids[start:start + 8]])]
        s = eng.summary()
        assert s["total"] == 300
        assert s["image_hit"] > 0
        assert sum(o != "full_miss" for o in outcomes[-100:]) > 50
