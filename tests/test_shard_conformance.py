"""Differential conformance harness for the sharded LatentBox cluster.

Every scenario of the workload suite replays through {1-shard, 4-shard} x
{SimBackend, EngineBackend} cells built over the SAME global node fleet
(8 nodes: 1x8 vs 4x2).  Because a shard's tier walk runs over its slice of
one global node namespace, consistent hashing guarantees sharding never
changes an object's owner node — so every cell must produce the identical
per-request (hit class, owner node) signature.  On top of the differential
matrix the harness locks down zero cross-shard key leakage, bounded key
remap on elastic reshard (<= 2/N for a single-shard add), and that the
cluster-level ``summary`` equals the sum of per-shard stats.

The full 4-cell x all-scenarios matrix is ``@pytest.mark.slow`` (scheduled
CI); push CI runs the sim matrix plus one engine smoke cell.
"""

import numpy as np
import pytest

from conftest import (classify, conformance_config, fill_and_demote,
                      make_box)
from repro.store import (FULL_MISS, IMAGE_HIT, LATENT_HIT, REGEN_MISS,
                         LatentBox, ShardedLatentBox)
from repro.trace.synth import list_scenarios, make_trace

N_OBJECTS = 24
N_REQUESTS = 240
TOTAL_NODES = 8
SHARD_COUNTS = (1, 4)
COUNTER_KEYS = (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS,
                "spilled", "total")


def scenario_ids(name: str):
    tr = make_trace(name, n_objects=N_OBJECTS, n_requests=N_REQUESTS,
                    span_days=2.0, seed=7)
    return tr.object_ids, tr.timestamps * 1e3


def run_cell(kind: str, shards: int, ids, vae=None):
    box = make_box(kind, shards, TOTAL_NODES, vae=vae)
    fill_and_demote(box, N_OBJECTS)
    return classify(box, ids), box


@pytest.mark.parametrize("scenario", list_scenarios())
class TestSimShardingInvariance:
    """Fast half of the matrix: {1,4} shards on the simulator backend."""

    def test_classification_and_owner_identical(self, scenario):
        ids, _ = scenario_ids(scenario)
        sig1, _ = run_cell("sim", 1, ids)
        sig4, _ = run_cell("sim", 4, ids)
        assert sig1 == sig4

    def test_open_loop_replay_identical(self, scenario):
        """Same property under timestamped (open-loop) replay."""
        ids, ts = scenario_ids(scenario)
        out = []
        for shards in SHARD_COUNTS:
            box = make_box("sim", shards, TOTAL_NODES)
            fill_and_demote(box, N_OBJECTS)
            rs = box.get_many([int(i) for i in ids],
                              timestamps_ms=ts.tolist())
            out.append([(r.hit_class, r.node) for r in rs])
        assert out[0] == out[1]

    def test_aggregate_stat_is_sum_of_shards(self, scenario):
        ids, _ = scenario_ids(scenario)
        _, box = run_cell("sim", 4, ids)
        agg = box.summary()
        per = box.backend.shard_summaries()
        assert len(per) == 4
        for key in COUNTER_KEYS:
            assert agg[key] == sum(s[key] for s in per.values()), key
        assert agg["cache_resident_bytes"] == pytest.approx(
            sum(s["cache_resident_bytes"] for s in per.values()))
        assert agg["durable_bytes"] == pytest.approx(
            sum(s["durable_bytes"] for s in per.values()))
        assert len(agg["alpha"]) == TOTAL_NODES

    def test_no_cross_shard_key_leakage(self, scenario):
        ids, _ = scenario_ids(scenario)
        _, box = run_cell("sim", 4, ids)
        cluster: ShardedLatentBox = box.backend
        for oid in range(N_OBJECTS):
            holders = cluster.residency_shards(oid)
            assert holders == [cluster.shard_of(oid)], \
                f"object {oid} leaked to shards {holders}"


class TestEngineShardingSmoke:
    """One engine cell on every push: the 4-cell matrix on one scenario."""

    def test_four_cells_agree(self, tiny_vae):
        ids, _ = scenario_ids("flash_crowd")
        ref, _ = run_cell("sim", 1, ids)
        for kind, shards in (("sim", 4), ("engine", 1), ("engine", 4)):
            sig, _ = run_cell(kind, shards, ids, vae=tiny_vae)
            assert sig == ref, f"{kind}@{shards} diverged"


@pytest.mark.slow
@pytest.mark.parametrize("scenario", list_scenarios())
class TestFullDifferentialMatrix:
    """The acceptance matrix: {1,4} shards x {sim, engine} x all scenarios
    must agree on every request's (hit class, owner node) — and on the
    aggregate hit-class accounting."""

    def test_matrix(self, scenario, tiny_vae):
        ids, _ = scenario_ids(scenario)
        cells = {}
        for kind in ("sim", "engine"):
            for shards in SHARD_COUNTS:
                cells[(kind, shards)] = run_cell(kind, shards, ids,
                                                 vae=tiny_vae)
        ref_sig, ref_box = cells[("sim", 1)]
        ref_sum = ref_box.summary()
        for key, (sig, box) in cells.items():
            assert sig == ref_sig, f"{key} diverged from sim@1"
            s = box.summary()
            for cls in (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS):
                assert s[cls] == ref_sum[cls], (key, cls)


class TestElasticResharding:
    def _loaded_cluster(self, n_keys=2000, shards=4):
        box = make_box("sim", shards, TOTAL_NODES)
        for oid in range(n_keys):
            box.put(oid)
        return box, box.backend

    def test_single_shard_add_moves_bounded_fraction(self):
        box, cluster = self._loaded_cluster()
        before = {oid: cluster.shard_of(oid) for oid in range(2000)}
        rep = cluster.add_shard()
        assert rep.n_keys == 2000 and rep.n_shards == 5
        # consistent hashing: ~1/N of keys remap; 2/N is the hard bound
        assert 0 < rep.moved_fraction <= 2 / rep.n_shards
        # every moved key landed on the new shard; nothing else moved
        for oid in range(2000):
            now = cluster.shard_of(oid)
            if now != before[oid]:
                assert now == rep.shard_id
        assert rep.n_moved == sum(
            1 for oid in range(2000) if cluster.shard_of(oid) != before[oid])

    def test_reshard_keeps_every_key_readable_and_leak_free(self):
        box, cluster = self._loaded_cluster(n_keys=300)
        box.get_many(list(range(300)))            # warm some cache state
        rep = cluster.add_shard()
        rs = box.get_many(list(range(300)))
        assert len(rs) == 300
        assert all(r.hit_class in (IMAGE_HIT, LATENT_HIT, FULL_MISS)
                   for r in rs)
        for oid in range(300):
            assert cluster.residency_shards(oid) == [cluster.shard_of(oid)]
        assert rep.n_moved > 0

    def test_remove_shard_drains_exactly_its_keys(self):
        box, cluster = self._loaded_cluster(n_keys=1000)
        victim = cluster.shard_ids[-1]
        owned = [oid for oid in range(1000) if cluster.shard_of(oid) == victim]
        rep = cluster.remove_shard(victim)
        assert rep.n_moved == len(owned) and rep.n_shards == 3
        assert victim not in cluster.shard_ids
        rs = box.get_many(list(range(1000)))
        assert len(rs) == 1000
        for oid in owned[:50]:
            assert cluster.residency_shards(oid) == [cluster.shard_of(oid)]

    def test_remove_last_shard_refuses(self):
        cluster = ShardedLatentBox.simulated(1, conformance_config(2))
        with pytest.raises(ValueError, match="last shard"):
            cluster.remove_shard(cluster.shard_ids[0])

    def test_migration_preserves_demotion_and_recipes(self):
        from repro.core.regen_tier import Recipe
        box, cluster = self._loaded_cluster(n_keys=0)
        n = 80
        for oid in range(n):
            box.put(oid, recipe=Recipe(seed=oid, height=16, width=16))
            assert box.demote(oid)
        before = {oid: cluster.shard_of(oid) for oid in range(n)}
        rep = cluster.add_shard()
        moved = [oid for oid in range(n) if cluster.shard_of(oid) != before[oid]]
        assert moved and rep.n_moved == len(moved)
        for oid in moved:
            st = box.stat(oid)
            assert st.demoted and st.residency == ["recipe"]
            assert st.recipe_bytes > 0
        # a read regenerates on the new shard, exactly like before the move
        r = box.get(moved[0])
        assert r.hit_class == REGEN_MISS and r.regenerated

    def test_migration_preserves_last_access_time(self):
        """A migrated object must not look maximally idle to the demotion
        sweep on its new shard."""
        from repro.core.regen_tier import Recipe
        box, cluster = self._loaded_cluster(n_keys=0)
        n = 60
        for oid in range(n):
            box.put(oid, recipe=Recipe(seed=oid, height=16, width=16))
        # stamp a recent access on every shard's regen tier
        for sid in cluster.shard_ids:
            regen = cluster.shards[sid].backend.regen
            for oid in range(n):
                if oid in regen:
                    regen._last_access_mo[oid] = 11.0
        before = {oid: cluster.shard_of(oid) for oid in range(n)}
        cluster.add_shard()
        moved = [oid for oid in range(n)
                 if cluster.shard_of(oid) != before[oid]]
        assert moved
        new_shard = cluster.shards[cluster.shard_of(moved[0])].backend
        for oid in moved:
            assert new_shard.regen.last_access_mo_of(oid) == 11.0
        # demotion sweep 1 month later: nothing migrated is 6-months idle
        assert new_shard.regen.run_demotion(12.0, age_override_mo=6.0) == 0

    def test_engine_payloads_survive_migration(self, tiny_vae):
        """Real pixel bit-identity across a reshard: the durable blob moves
        with the key, so the new shard decodes the exact same image."""
        from repro.core.regen_tier import Recipe
        box = make_box("engine", 2, 4, vae=tiny_vae)
        cluster = box.backend
        n = 24
        for oid in range(n):
            box.put(oid, recipe=Recipe(seed=500 + oid, height=16, width=16))
        baseline = {oid: box.get(oid).payload for oid in range(n)}
        before = {oid: cluster.shard_of(oid) for oid in range(n)}
        cluster.add_shard()
        moved = [oid for oid in range(n) if cluster.shard_of(oid) != before[oid]]
        assert moved, "no key moved — enlarge n"
        for oid in moved:
            r = box.get(oid)
            assert r.hit_class == FULL_MISS      # cold on the new shard
            np.testing.assert_array_equal(r.payload, baseline[oid])


class TestPersistentMigration:
    """Segment-shipping resharding on the log-structured durable store:
    ``add_shard``/``remove_shard`` move whole sealed segments, and must
    preserve demotion flags, recipes, pixel bit-identity — and on-disk
    byte accounting within one segment of slack."""

    def _persistent_cluster(self, tmp_path, n=80, shards=4):
        box = make_box("sim", shards, TOTAL_NODES,
                       data_dir=str(tmp_path / "cluster"))
        from repro.core.regen_tier import Recipe
        for oid in range(n):
            box.put(oid, recipe=Recipe(seed=oid, height=16, width=16))
        for oid in range(0, n, 5):
            assert box.demote(oid)
        return box, box.backend

    def test_migration_ships_segments_and_preserves_state(self, tmp_path):
        n = 80
        box, cluster = self._persistent_cluster(tmp_path, n=n)
        before = {oid: cluster.shard_of(oid) for oid in range(n)}
        demoted = {oid for oid in range(n) if box.stat(oid).demoted}
        rep = cluster.add_shard()
        moved = [oid for oid in range(n)
                 if cluster.shard_of(oid) != before[oid]]
        assert moved and rep.n_moved == len(moved)
        for oid in moved:
            st = box.stat(oid)
            assert st is not None
            assert st.demoted == (oid in demoted)
            assert st.recipe_bytes > 0           # the recipe shipped too
            assert cluster.residency_shards(oid) == [cluster.shard_of(oid)]
        # a migrated batch lands as ONE fresh sealed segment per dst shard
        dst = cluster.shards[rep.shard_id].backend
        assert dst.durable_log is not None
        assert sorted(dst.durable_log.object_oids()) == sorted(
            o for o in moved if o not in demoted)
        box.close()

    def test_on_disk_bytes_conserved_within_one_segment(self, tmp_path):
        """After migration + a full compaction sweep of every shard, the
        cluster's on-disk bytes must equal its live bytes within one
        segment of slack per shard (the partially-filled active heads)."""
        from repro.store.durable import Compactor
        n = 80
        box, cluster = self._persistent_cluster(tmp_path, n=n)
        box.flush()
        live_before = sum(
            cluster.shards[sid].backend.durable_log.live_bytes
            for sid in cluster.shard_ids)
        cluster.add_shard()
        for sid in cluster.shard_ids:
            log = cluster.shards[sid].backend.durable_log
            Compactor(log, live_frac_threshold=1.0).compact_all()
        live_after = sum(
            cluster.shards[sid].backend.durable_log.live_bytes
            for sid in cluster.shard_ids)
        disk_after = sum(
            cluster.shards[sid].backend.durable_log.on_disk_bytes
            for sid in cluster.shard_ids)
        seg = conformance_config(2).segment_bytes
        # live state is conserved by the move (tombstones add O(record))
        assert abs(live_after - live_before) <= seg
        # and the disk holds nothing beyond live data + bounded slack
        assert disk_after - live_after <= seg
        box.close()

    def test_engine_pixels_bit_identical_after_shipped_migration(
            self, tmp_path, tiny_vae):
        from repro.core.regen_tier import Recipe
        box = make_box("engine", 2, 4, vae=tiny_vae,
                       data_dir=str(tmp_path / "ecluster"))
        cluster = box.backend
        n = 24
        for oid in range(n):
            box.put(oid, recipe=Recipe(seed=900 + oid, height=16, width=16))
        baseline = {oid: box.get(oid).payload for oid in range(n)}
        before = {oid: cluster.shard_of(oid) for oid in range(n)}
        cluster.add_shard()
        moved = [oid for oid in range(n)
                 if cluster.shard_of(oid) != before[oid]]
        assert moved, "no key moved — enlarge n"
        for oid in moved:
            r = box.get(oid)
            assert r.hit_class == FULL_MISS      # cold on the new shard
            np.testing.assert_array_equal(r.payload, baseline[oid])
        box.close()


class TestShardedFacadeSurface:
    """The facade surface works transparently over shards."""

    def test_lifecycle_over_shards(self):
        from repro.core.regen_tier import Recipe
        box = LatentBox.simulated(conformance_config(2), shards=3)
        fill_and_demote(box, 12, demote=(5,))
        assert box.stat(5).demoted
        assert box.promote(5) and not box.stat(5).demoted
        assert box.delete(4)
        assert box.stat(4) is None and 4 not in box
        with pytest.raises(KeyError):
            box.get(4)
        box.put(4, recipe=Recipe(seed=9, height=16, width=16))
        assert box.get(4).hit_class == FULL_MISS
        s = box.summary()
        assert s["n_shards"] == 3 and s["n_nodes"] == 6

    def test_residency_uses_global_node_names(self):
        box = make_box("sim", 4, TOTAL_NODES)
        fill_and_demote(box, N_OBJECTS, demote=())
        box.get_many(list(range(N_OBJECTS)))
        names = set()
        for oid in range(N_OBJECTS):
            for r in box.stat(oid).residency:
                if "@" in r:
                    names.add(r.split("@")[1])
        # cache residency reports global node ids spread across shards
        assert len(names) > 2
        assert all(n.startswith("node") and int(n[4:]) < TOTAL_NODES
                   for n in names)
