"""Persistent Pallas kernel autotuner: cache roundtrip + versioned
invalidation, deterministic winner selection under an injected timer,
dispatch-side tuned-shape lookup (numerically invariant), the bounded
tune-on-first-miss driver, and a reopened engine honoring the cache."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune as at
from repro.kernels import ops
from repro.store import LatentBox, StoreConfig
from repro.vae.model import DEMO_VAE

LATENT_HWC = (8, 8, 4)


def entry(rows=16, block_cout=64, **kw):
    e = {"rows": rows, "block_cout": block_cout, "us": 10.0,
         "default_us": 20.0, "candidates": 3, "impl": "pallas_interpret",
         "weight_dtype": "float32"}
    e.update(kw)
    return e


class ScriptedTimer:
    """Replays a fixed sequence of clock readings (2 per timed rep)."""

    def __init__(self, durations, reps=1):
        self.reads = []
        for d in durations:
            for _ in range(reps):
                self.reads += [0.0, d]
        self.i = 0

    def __call__(self):
        v = self.reads[self.i]
        self.i += 1
        return v


# ---------------------------------------------------------------------------
# the persistent cache
# ---------------------------------------------------------------------------

class TestTuningCache:
    def test_roundtrip(self, tmp_path):
        path = str(tmp_path / "tuning_cache.json")
        cache = at.TuningCache(path)
        key = at.cache_key("conv3x3", 2, 8, 8, 4, 32, "float32")
        cache.put(key, entry())
        cache.save()
        loaded = at.TuningCache.load(path)
        assert len(loaded) == 1 and key in loaded
        assert loaded.get(key) == entry()
        assert not (tmp_path / "tuning_cache.json.tmp").exists()

    def test_missing_file_is_empty(self, tmp_path):
        cache = at.TuningCache.load(str(tmp_path / "nope.json"))
        assert len(cache) == 0

    def test_pathless_cache_never_writes(self):
        cache = at.TuningCache(None)
        cache.put("k", entry())
        cache.save()                      # no-op, must not raise
        assert "k" in cache

    def test_schema_version_bump_invalidates(self, tmp_path):
        path = str(tmp_path / "tuning_cache.json")
        with open(path, "w") as f:
            json.dump({"schema_version": at.SCHEMA_VERSION + 1,
                       "entries": {"k": entry()}}, f)
        assert len(at.TuningCache.load(path)) == 0

    @pytest.mark.parametrize("blob", [b"{not json", b"", b"[1, 2, 3]",
                                      b'{"entries": "nope"}'])
    def test_corrupt_file_falls_back_clean(self, tmp_path, blob):
        path = str(tmp_path / "tuning_cache.json")
        with open(path, "wb") as f:
            f.write(blob)
        assert len(at.TuningCache.load(path)) == 0


# ---------------------------------------------------------------------------
# dispatch-side lookup
# ---------------------------------------------------------------------------

class TestTunedParams:
    def test_no_active_cache_means_defaults(self):
        assert at.get_active_cache() is None
        assert at.tuned_params("conv3x3", (1, 8, 8, 4), 32, "float32") == {}

    def test_hit_and_miss(self):
        cache = at.TuningCache(None)
        cache.put(at.cache_key("conv3x3", 1, 8, 8, 4, 32, "float32"),
                  entry(rows=8, block_cout=32))
        with at.active_cache(cache):
            assert at.tuned_params("conv3x3", (1, 8, 8, 4), 32,
                                   "float32") == {"rows": 8, "block_cout": 32}
            assert at.tuned_params("conv3x3", (2, 8, 8, 4), 32,
                                   "float32") == {}          # other bucket
            assert at.tuned_params("conv3x3", (1, 8, 8, 4), 32,
                                   "bfloat16") == {}         # other dtype
        assert at.get_active_cache() is None                 # scope restored

    @pytest.mark.parametrize("bad", [{"rows": 8}, {"rows": 8.5,
                                                   "block_cout": 32},
                                     {"rows": 0, "block_cout": 32}, {}])
    def test_malformed_entry_means_defaults(self, bad):
        cache = at.TuningCache(None)
        cache.put(at.cache_key("conv3x3", 1, 8, 8, 4, 32, "float32"), bad)
        with at.active_cache(cache):
            assert at.tuned_params("conv3x3", (1, 8, 8, 4), 32,
                                   "float32") == {}

    def test_dispatch_numerically_invariant(self, rng):
        """A tuned blocking must change only the schedule, not the math."""
        x = jnp.asarray(rng.standard_normal((1, 8, 8, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((3, 3, 8, 16)) / 8, jnp.float32)
        b = jnp.asarray(rng.standard_normal((16,)) * 0.01, jnp.float32)
        base = np.asarray(ops.conv3x3(x, w, b, impl="pallas_interpret"))
        cache = at.TuningCache(None)
        cache.put(at.cache_key("conv3x3", 1, 8, 8, 8, 16, "float32"),
                  entry(rows=4, block_cout=8))
        with at.active_cache(cache):
            tuned = np.asarray(ops.conv3x3(x, w, b, impl="pallas_interpret"))
        np.testing.assert_allclose(tuned, base, atol=1e-6)


# ---------------------------------------------------------------------------
# shape derivation + candidate grids
# ---------------------------------------------------------------------------

class TestDecodeShapes:
    def test_demo_decoder_shape_set(self):
        shapes = at.decode_shapes(DEMO_VAE, LATENT_HWC, bucket=2)
        sigs = {(s["kernel"], s["h"], s["w"], s["cin"], s["cout"])
                for s in shapes}
        assert sigs == {
            ("conv3x3", 8, 8, 4, 32),            # conv_in
            ("gn_silu_conv3x3", 8, 8, 32, 32),   # mid + top level
            ("upsample_conv3x3", 8, 8, 32, 32),
            ("gn_silu_conv3x3", 16, 16, 32, 16),
            ("gn_silu_conv3x3", 16, 16, 16, 16),
            ("output_epilogue", 16, 16, 16, 3),  # fused epilogue @ 2x
        }
        assert all(s["n"] == 2 and s["groups"] == 4 for s in shapes)

    def test_candidates_default_first_and_deduped(self):
        spec = {"kernel": "conv3x3", "n": 1, "h": 32, "w": 32,
                "cin": 64, "cout": 64, "groups": 4}
        cands = at.candidates("conv3x3", spec)
        assert cands[0] == at.DEFAULTS["conv3x3"]
        effs = [at._effective("conv3x3", spec, c["rows"], c["block_cout"])
                for c in cands]
        assert len(set(effs)) == len(effs)       # no duplicate blockings
        assert len(cands) > 1                    # this shape has real choices


# ---------------------------------------------------------------------------
# the timed sweep (injected timer => fully deterministic)
# ---------------------------------------------------------------------------

SWEEP_SPEC = {"kernel": "conv3x3", "n": 1, "h": 32, "w": 32,
              "cin": 64, "cout": 64, "groups": 4}
SWEEP_GRIDS = dict(rows_grid=(8, 32), block_cout_grid=(32, 64))


class TestTuneDeterminism:
    def test_injected_timer_picks_scripted_winner(self):
        cands = at.candidates("conv3x3", SWEEP_SPEC, **SWEEP_GRIDS)
        assert len(cands) >= 3
        durations = [10.0] * len(cands)
        durations[2] = 1.0                       # candidate 2 is fastest
        e = at.tune(SWEEP_SPEC, reps=1, timer=ScriptedTimer(durations),
                    **SWEEP_GRIDS)
        assert {"rows": e["rows"], "block_cout": e["block_cout"]} == cands[2]
        assert e["us"] == pytest.approx(1e6)     # 1.0 s -> us
        assert e["default_us"] == pytest.approx(10e6)
        assert e["candidates"] == len(cands)

    def test_tie_keeps_the_default(self):
        cands = at.candidates("conv3x3", SWEEP_SPEC, **SWEEP_GRIDS)
        e = at.tune(SWEEP_SPEC, reps=1,
                    timer=ScriptedTimer([5.0] * len(cands)), **SWEEP_GRIDS)
        assert {"rows": e["rows"],
                "block_cout": e["block_cout"]} == at.DEFAULTS["conv3x3"]
        assert e["us"] == e["default_us"]

    def test_winner_never_worse_than_default(self):
        cands = at.candidates("conv3x3", SWEEP_SPEC, **SWEEP_GRIDS)
        rng = np.random.default_rng(0)
        for _ in range(3):
            durations = list(rng.uniform(1.0, 10.0, len(cands)))
            e = at.tune(SWEEP_SPEC, reps=1, timer=ScriptedTimer(durations),
                        **SWEEP_GRIDS)
            assert e["us"] <= e["default_us"]


# ---------------------------------------------------------------------------
# tune-on-first-miss driver
# ---------------------------------------------------------------------------

class TestKernelAutotuner:
    def make_tuner(self, tmp_path):
        cache = at.TuningCache(str(tmp_path / at.CACHE_FILENAME))
        return at.KernelAutotuner(
            cache, DEMO_VAE, impl="pallas_interpret", reps=1,
            timer=ScriptedTimer([1.0] * 4096),
            rows_grid=(8,), block_cout_grid=(32,))

    def test_note_bucket_queues_only_missing(self, tmp_path):
        tuner = self.make_tuner(tmp_path)
        n = tuner.note_bucket(1, LATENT_HWC)
        assert n == tuner.pending == 6           # the demo shape set
        assert tuner.note_bucket(1, LATENT_HWC) == 0     # already queued
        assert tuner.note_bucket(2, LATENT_HWC) == 6     # new bucket = new keys

    def test_step_is_bounded_and_persists(self, tmp_path):
        tuner = self.make_tuner(tmp_path)
        tuner.note_bucket(1, LATENT_HWC)
        keys = tuner.step(2)
        assert len(keys) == 2 and tuner.pending == 4
        assert all(k in tuner.cache for k in keys)
        # each step persists: a fresh load already sees the first wins
        assert set(at.TuningCache.load(tuner.cache.path).entries) == set(keys)
        while tuner.pending:
            tuner.step(4)
        assert len(tuner.cache) == 6
        assert tuner.step(1) == []               # drained queue is a no-op
        # tuned keys are exactly what dispatch will look up
        assert at.tuned_params("conv3x3", (1,) + LATENT_HWC, 32,
                               "float32") == {}  # no active cache yet
        with at.active_cache(tuner.cache):
            got = at.tuned_params("conv3x3", (1,) + LATENT_HWC, 32,
                                  "float32")
            assert set(got) == {"rows", "block_cout"}

    def test_engine_restart_honors_cache(self, tmp_path, rng):
        cfg = StoreConfig(n_nodes=1, cache_bytes_per_node=1e5,
                          adaptive=False, autotune=True,
                          decode_buckets=(1, 2))
        with LatentBox.open(tmp_path / "box", config=cfg) as box:
            eng = box.backend.engine
            assert at.get_active_cache() is eng.tuning_cache
            for oid in range(4):
                box.put(oid, latent=rng.standard_normal(LATENT_HWC)
                        .astype(np.float16))
            for _ in range(30):                  # maintenance drains the queue
                box.get_many([0, 1, 2, 3])
                if eng.autotuner.pending == 0 and len(eng.tuning_cache):
                    break
            assert len(eng.tuning_cache) > 0
            tuned_before = dict(eng.tuning_cache.entries)
            pixels = [np.asarray(r.payload).copy()
                      for r in box.get_many([0, 1])]
        with LatentBox.open(tmp_path / "box", config=cfg) as box:
            eng = box.backend.engine
            assert eng.tuning_cache.entries == tuned_before   # survived
            assert at.get_active_cache() is eng.tuning_cache  # and honored
            s = box.summary()
            assert s["tuned_kernel_keys"] == len(tuned_before)
            again = [np.asarray(r.payload) for r in box.get_many([0, 1])]
            for a, b in zip(pixels, again):
                np.testing.assert_array_equal(a, b)
