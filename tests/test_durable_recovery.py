"""Crash-recovery properties of the durable store.

The contract under test (the ``LatentBox.open`` reopen guarantee): after a
hard process kill at ANY point — mid-write, mid-compaction, with or
without a manifest — reopening the directory serves every *acknowledged*
put bit-exact and cleanly ignores every unacknowledged tail record.

Crash states are modeled two ways:

* **disk-state enumeration** — truncate the tail segment at every byte
  offset past the acknowledged prefix (the exhaustive sweep is the
  nightly ``slow`` recovery matrix; push CI runs a stride), delete the
  manifest, or stop a compaction between its durable copy and its unlink;
* **a real ``os._exit`` kill** — a subprocess acknowledges some puts,
  then dies mid-stream; the parent reopens whatever hit the disk.

Property tests use hypothesis when available (same dev-only guard as
``test_store_api.py``) with deterministic fallbacks exercising the same
check helper.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.store import LatentBox, StoreConfig
from repro.store.durable import Compactor, SegmentLog
from repro.store.durable.log import MANIFEST

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def blob_of(oid: int) -> bytes:
    rng = np.random.default_rng(oid)
    return rng.bytes(40 + (oid * 13) % 64)


def acked_prefix_log(path: str, n_acked: int, n_unacked: int) -> int:
    """Write ``n_acked`` flushed puts then ``n_unacked`` unflushed ones;
    returns the acknowledged byte length of the final (active) segment."""
    log = SegmentLog(path, segment_bytes=10**9, checkpoint_every=10**9)
    for oid in range(n_acked):
        log.put_blob(oid, blob_of(oid))
    log.flush()
    acked_len = log._seg_len[log._active_id]
    for oid in range(n_acked, n_acked + n_unacked):
        log.put_blob(oid, blob_of(oid))
    log.flush()          # the bytes exist on disk; the CRASH is modeled
    #                      by truncating anywhere past the acked prefix
    # abandon without close(): no seal, no manifest — a hard kill
    log._active_f.close()
    return acked_len


def check_recovery(path: str, n_acked: int) -> None:
    """Every acknowledged put must be served bit-exact; nothing may raise."""
    log = SegmentLog(path)
    for oid in range(n_acked):
        assert log.get_blob(oid) == blob_of(oid), f"oid {oid} corrupted"
    log.close()


def crash_at(path: str, cut: int) -> None:
    """Model the kill: the tail segment retains only ``cut`` bytes."""
    segs = sorted(f for f in os.listdir(path) if f.startswith("seg-"))
    with open(os.path.join(path, segs[-1]), "r+b") as f:
        f.truncate(cut)


N_ACKED, N_UNACKED = 6, 3


class TestMidWriteCrash:
    def test_every_cut_point_smoke(self, tmp_path):
        """Push-CI stride over the crash matrix: truncate the tail at a
        spread of offsets past the acked prefix; acked puts always
        recover bit-exact, the torn record is ignored and truncated."""
        base = str(tmp_path / "log")
        acked_len = acked_prefix_log(base, N_ACKED, N_UNACKED)
        total = os.path.getsize(os.path.join(
            base, sorted(os.listdir(base))[-1]))
        cuts = sorted({acked_len, acked_len + 1, acked_len + 28,
                       acked_len + 29, (acked_len + total) // 2,
                       total - 1})
        for cut in cuts:
            work = str(tmp_path / f"cut{cut}")
            subprocess.run(["cp", "-r", base, work], check=True)
            crash_at(work, cut)
            check_recovery(work, N_ACKED)

    @pytest.mark.slow
    def test_recovery_matrix_every_byte(self, tmp_path):
        """The nightly recovery matrix: EVERY truncation offset from the
        acked prefix to the full file."""
        base = str(tmp_path / "log")
        acked_len = acked_prefix_log(base, N_ACKED, N_UNACKED)
        seg = sorted(f for f in os.listdir(base) if f.startswith("seg-"))[-1]
        total = os.path.getsize(os.path.join(base, seg))
        for cut in range(acked_len, total + 1):
            work = str(tmp_path / "work")
            subprocess.run(["rm", "-rf", work], check=True)
            subprocess.run(["cp", "-r", base, work], check=True)
            crash_at(work, cut)
            check_recovery(work, N_ACKED)

    def test_missing_manifest_full_scan(self, tmp_path):
        path = str(tmp_path / "log")
        log = SegmentLog(path)
        for oid in range(5):
            log.put_blob(oid, blob_of(oid))
        log.close()
        os.remove(os.path.join(path, MANIFEST))
        check_recovery(path, 5)

    def test_corrupt_manifest_full_scan(self, tmp_path):
        path = str(tmp_path / "log")
        log = SegmentLog(path)
        for oid in range(5):
            log.put_blob(oid, blob_of(oid))
        log.close()
        with open(os.path.join(path, MANIFEST), "w") as f:
            f.write("{not json")
        check_recovery(path, 5)

    if HAVE_HYPOTHESIS:
        @given(n_acked=st.integers(0, 8), n_unacked=st.integers(0, 4),
               frac=st.floats(0.0, 1.0))
        @settings(max_examples=25, deadline=None)
        def test_property_random_crash_point(self, tmp_path_factory,
                                             n_acked, n_unacked, frac):
            tmp = tmp_path_factory.mktemp("crash")
            path = str(tmp / "log")
            acked_len = acked_prefix_log(path, n_acked, n_unacked)
            seg = sorted(f for f in os.listdir(path)
                         if f.startswith("seg-"))[-1]
            total = os.path.getsize(os.path.join(path, seg))
            cut = acked_len + int(frac * (total - acked_len))
            crash_at(path, cut)
            check_recovery(path, n_acked)


class _Crash(RuntimeError):
    pass


class TestMidCompactionCrash:
    def _crashed_compaction(self, path: str) -> str:
        """Build a churned log whose live records all sit in ONE sealed
        segment, then crash a compaction of it between the durable copy
        and the unlink.  Returns the copy-bearing segment's filename (the
        victim file still exists on disk)."""
        log = SegmentLog(path, segment_bytes=10**9, checkpoint_every=10**9)
        for _ in range(4):
            for oid in range(6):
                log.put_blob(oid, blob_of(oid) + bytes([0]))
        for oid in range(6):
            log.put_blob(oid, blob_of(oid))      # final live versions
        log.flush()
        log._seal_active()        # every acked byte is in sealed seg 1
        victim = min(log.sealed_segments())
        with pytest.raises(_Crash):
            log.compact_segment(victim, crash_hook=self._boom)
        copy_seg = f"seg-{log._active_id:08d}.lbx"
        log._active_f.close()                    # die: no manifest
        assert os.path.exists(os.path.join(path, f"seg-{victim:08d}.lbx"))
        return copy_seg

    @staticmethod
    def _boom():
        raise _Crash()

    def test_kill_between_copy_and_unlink(self, tmp_path):
        """The compaction crash window: live records are durably copied,
        the victim file still exists.  Recovery must dedupe (same lsn)
        and serve exactly the live versions."""
        path = str(tmp_path / "log")
        self._crashed_compaction(path)
        log2 = SegmentLog(path)
        # no manifest survived the kill: this recovery re-scanned the
        # duplicate copies and collapsed them — one live slot per oid
        assert log2.recovery_stats["scanned_records"] > 0
        assert sorted(log2.object_oids()) == list(range(6))
        log2.close()
        check_recovery(path, 6)

    def test_kill_during_copy_write(self, tmp_path):
        """Crash with the compaction copies only partially on disk: the
        torn copy tail is discarded; the victim segment still serves."""
        path = str(tmp_path / "log")
        copy_seg = self._crashed_compaction(path)
        sz = os.path.getsize(os.path.join(path, copy_seg))
        with open(os.path.join(path, copy_seg), "r+b") as f:
            f.truncate(max(0, sz - 11))
        check_recovery(path, 6)

    @pytest.mark.slow
    def test_recovery_matrix_compaction_cuts(self, tmp_path):
        """Nightly matrix: sweep truncation points across the copy-bearing
        segment after a mid-compaction kill — every prefix of the copies
        (including none at all) must recover from the surviving victim."""
        path = str(tmp_path / "base")
        copy_seg = self._crashed_compaction(path)
        total = os.path.getsize(os.path.join(path, copy_seg))
        for cut in range(0, total + 1, 7):
            work = str(tmp_path / "work")
            subprocess.run(["rm", "-rf", work], check=True)
            subprocess.run(["cp", "-r", path, work], check=True)
            with open(os.path.join(work, copy_seg), "r+b") as f:
                f.truncate(cut)
            check_recovery(work, 6)


_CHILD = r"""
import os, sys
sys.path.insert(0, {src!r})
from repro.store.durable import SegmentLog
import numpy as np

def blob_of(oid):
    rng = np.random.default_rng(oid)
    return rng.bytes(40 + (oid * 13) % 64)

log = SegmentLog({path!r}, segment_bytes=10**9)
for oid in range(6):
    log.put_blob(oid, blob_of(oid))
log.flush()
print("ACKED", flush=True)
for oid in range(6, 400):
    log.put_blob(oid, blob_of(oid))
os._exit(9)        # hard kill mid-stream: no flush, no close, no manifest
"""


class TestProcessKill:
    def test_os_exit_mid_stream(self, tmp_path):
        """A REAL process death: whatever the OS kept of the unflushed
        tail must never corrupt the acknowledged prefix."""
        path = str(tmp_path / "log")
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        code = _CHILD.format(src=os.path.abspath(src), path=path)
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True)
        assert "ACKED" in proc.stdout and proc.returncode == 9
        check_recovery(path, 6)


class TestFacadeReopen:
    """The documented ``LatentBox.open`` guarantee, end to end."""

    def test_sim_box_reopen_serves_acked_state(self, tmp_path):
        cfg = StoreConfig(n_nodes=2)
        with LatentBox.open(str(tmp_path / "box"), mode="sim",
                            config=cfg) as box:
            from repro.core.regen_tier import Recipe
            for oid in range(8):
                r = box.put(oid, recipe=Recipe(seed=oid, height=16,
                                               width=16))
                assert r.durable
            assert box.demote(3)
            box.delete(7)
        box2 = LatentBox.open(str(tmp_path / "box"), mode="sim", config=cfg)
        assert box2.stat(3).demoted and box2.stat(3).residency == ["recipe"]
        assert box2.stat(7) is None
        assert box2.get(3).hit_class == "regen_miss"
        assert box2.get(0).hit_class == "full_miss"
        box2.close()

    def test_engine_box_hard_kill_reopen_bit_exact(self, tmp_path, tiny_vae):
        """Kill (no close, manifest deleted, garbage appended) — every
        acknowledged object decodes to bit-identical pixels on reopen."""
        from repro.core.regen_tier import Recipe
        path = str(tmp_path / "box")
        box = LatentBox.open(path, mode="engine", vae=tiny_vae)
        for oid in range(6):
            box.put(oid, recipe=Recipe(seed=700 + oid, height=16, width=16))
        baseline = {oid: box.get(oid).payload for oid in range(6)}
        # hard kill: no close; simulate a torn in-flight append + lost
        # manifest
        ddir = box.backend.durable_log.path
        seg = sorted(f for f in os.listdir(ddir)
                     if f.startswith("seg-"))[-1]
        with open(os.path.join(ddir, seg), "ab") as f:
            f.write(b"LBS1" + b"\x99" * 17)
        man = os.path.join(ddir, MANIFEST)
        if os.path.exists(man):
            os.remove(man)
        del box

        box2 = LatentBox.open(path, mode="engine", vae=tiny_vae)
        assert box2.backend.durable_log.recovery_stats[
            "torn_tail_bytes"] == 21
        for oid in range(6):
            r = box2.get(oid)
            assert r.hit_class == "full_miss"     # cold, but bit-exact
            np.testing.assert_array_equal(r.payload, baseline[oid])
        box2.close()

    def test_write_behind_unacked_put_may_vanish_acked_survive(
            self, tmp_path):
        """write_behind: puts before the last flush() survive any kill;
        the unflushed tail is allowed to vanish and must do so cleanly."""
        from repro.core.regen_tier import Recipe
        path = str(tmp_path / "box")
        cfg = StoreConfig(n_nodes=2, write_behind=True)
        box = LatentBox.open(path, mode="sim", config=cfg)
        for oid in range(4):
            r = box.put(oid, recipe=Recipe(seed=oid, height=16, width=16))
            assert not r.durable                   # not acked yet
        box.flush()                                # ack 0..3
        log = box.backend.durable_log
        acked_len = log._seg_len[log._active_id]
        box.put(99, recipe=Recipe(seed=99, height=16, width=16))
        # hard kill: the unflushed tail (oid 99) never reaches the disk
        log._active_f.flush()                      # make it visible first,
        crash_at(log.path, acked_len)              # then model its loss
        del box
        box2 = LatentBox.open(path, mode="sim", config=cfg)
        for oid in range(4):
            assert box2.stat(oid) is not None
        assert box2.stat(99) is None               # cleanly ignored
        box2.close()
