"""Scenario workload suite (`make_trace`): structural invariants of every
named scenario plus the shape properties that make each one a distinct
stressor — diurnal intensity modulation, flash-crowd cold-before-spike,
drift's popularity flip, scan's full coverage, multi-tenant skew."""

import numpy as np
import pytest

from repro.trace.synth import (DAY_S, SCENARIOS, TraceConfig, list_scenarios,
                               make_trace)

SMALL = dict(n_objects=400, n_requests=8_000, span_days=4.0, seed=3)


@pytest.mark.parametrize("name", list_scenarios())
class TestEveryScenario:
    def test_structural_invariants(self, name):
        tr = make_trace(name, **SMALL)
        assert len(tr.timestamps) == len(tr.object_ids)
        assert np.all(np.diff(tr.timestamps) >= 0)          # sorted
        assert tr.timestamps[0] >= 0.0
        assert tr.timestamps[-1] <= SMALL["span_days"] * DAY_S
        assert tr.object_ids.min() >= 0
        assert tr.object_ids.max() < SMALL["n_objects"]
        assert len(tr.birth_time) == SMALL["n_objects"]
        assert len(tr.model_ids) == SMALL["n_objects"]

    def test_deterministic_per_seed(self, name):
        a = make_trace(name, **SMALL)
        b = make_trace(name, **SMALL)
        np.testing.assert_array_equal(a.object_ids, b.object_ids)
        np.testing.assert_array_equal(a.timestamps, b.timestamps)
        c = make_trace(name, **{**SMALL, "seed": 4})
        if name != "scan":                      # scan is seed-independent
            assert not np.array_equal(a.object_ids, c.object_ids) or \
                not np.array_equal(a.timestamps, c.timestamps)


class TestScenarioShapes:
    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            make_trace("nope")

    def test_registry_matches_listing(self):
        assert sorted(SCENARIOS) == list_scenarios()

    def test_config_passthrough_and_overrides(self):
        base = TraceConfig(n_objects=123, n_requests=500, span_days=2.0)
        tr = make_trace("diurnal", config=base, n_requests=900)
        assert tr.config.n_objects == 123 and tr.config.n_requests == 900

    def test_diurnal_modulates_intensity(self):
        tr = make_trace("diurnal", **{**SMALL, "n_requests": 40_000},
                        amplitude=0.9)
        hour = ((tr.timestamps % DAY_S) // 3600).astype(int)
        per_hour = np.bincount(hour, minlength=24)
        assert per_hour.max() > 3 * per_hour.min()
        flat = make_trace("diurnal", **{**SMALL, "n_requests": 40_000},
                          amplitude=0.0)
        per_hour = np.bincount(
            ((flat.timestamps % DAY_S) // 3600).astype(int), minlength=24)
        assert per_hour.max() < 1.3 * per_hour.min()

    def test_flash_crowd_viral_objects_cold_before_spike(self):
        tr = make_trace("flash_crowd", **SMALL, n_viral=6, spike_frac=0.3,
                        spike_start_frac=0.5)
        viral = np.arange(SMALL["n_objects"] - 6, SMALL["n_objects"])
        mask = np.isin(tr.object_ids, viral)
        assert mask.mean() == pytest.approx(0.3, abs=0.02)
        # no viral access before the spike start; birth pinned to the spike
        assert tr.timestamps[mask].min() >= 0.5 * SMALL["span_days"] * DAY_S
        assert np.all(tr.birth_time[viral] == 0.5 * SMALL["span_days"] * DAY_S)

    def test_flash_crowd_tiny_object_space(self):
        # n_viral clamps below n_objects so background mass never zeroes
        tr = make_trace("flash_crowd", n_objects=3, n_requests=200,
                        span_days=1.0, seed=0)
        assert tr.object_ids.max() < 3
        with pytest.raises(ValueError, match=">= 2 objects"):
            make_trace("flash_crowd", n_objects=1, n_requests=10,
                       span_days=1.0, seed=0)

    def test_zipf_drift_flips_popularity(self):
        tr = make_trace("zipf_drift", **{**SMALL, "n_requests": 40_000})
        h = len(tr.object_ids) // 2
        n = SMALL["n_objects"]

        def top(ids, k=20):
            return set(np.argsort(np.bincount(ids, minlength=n))[-k:])

        assert len(top(tr.object_ids[:h]) & top(tr.object_ids[h:])) <= 2

    def test_scan_covers_every_object_sequentially(self):
        tr = make_trace("scan", **SMALL)
        n = SMALL["n_objects"]
        np.testing.assert_array_equal(tr.object_ids[:n],
                                      np.arange(n, dtype=np.int64))
        assert set(np.unique(tr.object_ids)) == set(range(n))

    def test_scan_honors_exact_request_count(self):
        # non-multiple n_requests: exactly n_requests, last pass partial
        tr = make_trace("scan", n_objects=1000, n_requests=1400,
                        span_days=1.0, seed=0)
        assert len(tr.object_ids) == 1400
        assert tr.object_ids[-1] == 399
        # explicit passes win over n_requests
        tr = make_trace("scan", n_objects=100, n_requests=1400,
                        span_days=1.0, seed=0, passes=2)
        assert len(tr.object_ids) == 200

    def test_multi_tenant_shares_are_skewed_and_pools_disjoint(self):
        tr = make_trace("multi_tenant", **{**SMALL, "n_requests": 20_000},
                        n_tenants=4)
        tenant_of_req = tr.model_ids[tr.object_ids]
        shares = np.bincount(tenant_of_req, minlength=4) / len(tenant_of_req)
        assert np.all(np.diff(shares) < 0)       # Zipf over tenants
        for t in range(4):
            pool = np.nonzero(tr.model_ids == t)[0]
            assert len(pool) > 0
        assert len(np.unique(tr.model_ids)) == 4


class TestScenarioConsumers:
    def test_cache_replay_consumes_scenarios(self):
        from repro.core.replay import ReplayConfig, replay_scenario
        res = replay_scenario(
            "scan", ReplayConfig(cache_bytes=50 * 1.4e6, adaptive=False),
            n_objects=200, n_requests=1_000, span_days=1.0, seed=0)
        assert res.n == 1_000
        # a scan over 200 objects with a 50-object cache can't image-hit
        assert res.image_hit_frac == 0.0

    def test_cluster_sim_consumes_scenarios(self):
        from repro.core.cluster import ClusterConfig, replay_scenario
        log, sim = replay_scenario(
            ClusterConfig(n_nodes=2, cache_bytes_per_node=20 * 1.4e6,
                          adaptive=False),
            "flash_crowd", n_objects=150, n_requests=800, span_days=0.2,
            seed=1)
        s = log.summarize()
        assert s["n"] == 800 and s["mean_ms"] > 0

    def test_request_log_accounts_regen_misses(self):
        """Hit-class fractions in RequestLog.summarize partition to 1.0
        with regen_miss included, and regens never count as hits."""
        from repro.core.metrics import RequestLog
        log = RequestLog()
        log.add(0.0, 1.0, "image_hit", queue_ms=1.0)
        log.add(1.0, 2.0, "latent_hit", queue_ms=2.0)
        log.add(2.0, 150.0, "full_miss", queue_ms=30.0)
        log.add(3.0, 4000.0, "regen_miss", queue_ms=40.0)
        s = log.summarize()
        assert s["regen_miss_frac"] == 0.25
        assert (s["image_hit_frac"] + s["latent_hit_frac"]
                + s["full_miss_frac"] + s["regen_miss_frac"]) == 1.0
        assert s["hit.queue_ms"] == 1.5          # regen queue excluded
