"""Consistent-hash router (paper §4.4): ownership stability, coalescing,
spillover decisions, and elastic node churn."""

import numpy as np
import pytest

from repro.core.router import ConsistentHashRing, Router


class TestRing:
    def test_deterministic_ownership(self):
        r1 = ConsistentHashRing(["a", "b", "c"])
        r2 = ConsistentHashRing(["a", "b", "c"])
        for oid in range(200):
            assert r1.owner(oid) == r2.owner(oid)

    def test_balanced(self):
        r = ConsistentHashRing([f"n{i}" for i in range(4)], vnodes=256)
        owners = [r.owner(i) for i in range(20_000)]
        _, counts = np.unique(owners, return_counts=True)
        assert counts.min() > 0.15 * 20_000          # no starved node

    def test_minimal_churn_on_node_add(self):
        """Elastic scaling property: adding a node remaps ~1/(n+1)."""
        r = ConsistentHashRing(["a", "b", "c"], vnodes=256)
        before = {i: r.owner(i) for i in range(10_000)}
        r.add_node("d")
        moved = sum(before[i] != r.owner(i) for i in range(10_000))
        assert moved / 10_000 < 0.45                  # ~0.25 expected
        # and everything that moved went to the new node
        for i in range(10_000):
            if before[i] != r.owner(i):
                assert r.owner(i) == "d"

    def test_remove_node(self):
        r = ConsistentHashRing(["a", "b"], vnodes=64)
        r.remove_node("a")
        assert all(r.owner(i) == "b" for i in range(100))


class TestRouterCoalescing:
    def test_coalesce_parks_waiters(self):
        r = Router(["n0", "n1"])
        assert not r.try_coalesce(7, "w1")            # nothing in flight
        r.begin_inflight(7)
        assert r.try_coalesce(7, "w2")
        assert r.try_coalesce(7, "w3")
        assert r.finish_inflight(7) == ["w2", "w3"]
        assert not r.try_coalesce(7, "w4")            # cleared


class TestSpillover:
    def test_dispatch_prefers_owner_under_threshold(self):
        r = Router(["n0", "n1"], theta=4)
        owner = r.ring.owner(42)
        r.report_depth(owner, 3)
        o, e, spilled = r.dispatch(42)
        assert o == e == owner and not spilled

    def test_dispatch_spills_when_overloaded(self):
        r = Router(["n0", "n1"], theta=2)
        owner = r.ring.owner(42)
        other = "n1" if owner == "n0" else "n0"
        r.report_depth(owner, 10)
        r.report_depth(other, 0)
        o, e, spilled = r.dispatch(42)
        assert o == owner and e == other and spilled  # cache pinned at owner

    def test_no_spill_when_everyone_loaded(self):
        r = Router(["n0", "n1"], theta=2)
        owner = r.ring.owner(42)
        for n in ("n0", "n1"):
            r.report_depth(n, 10)
        _, e, spilled = r.dispatch(42)
        assert e == owner and not spilled

    def test_single_node_cluster(self):
        r = Router(["n0"], theta=0)
        r.report_depth("n0", 99)
        o, e, spilled = r.dispatch(1)
        assert o == e == "n0" and not spilled
