"""Runtime substrate: optimizer math, checkpoint restart/reshard, data
determinism, gradient compression, collectives, cost model."""

import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.cost_model import CostParams, normalized_horizons, project
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.train import grad_compress as GC
from repro.train.optim import (AdamW, AdamWConfig, clip_by_global_norm,
                               schedule_lr)


class TestAdamW:
    def test_matches_reference_step(self):
        cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8,
                          weight_decay=0.0, clip_norm=None,
                          warmup_steps=0, schedule="constant")
        opt = AdamW(cfg)
        p = {"w": jnp.array([1.0, -2.0])}
        g = {"w": jnp.array([0.5, 0.5])}
        state = opt.init(p)
        p2, state, _ = opt.update(g, state, p)
        m = 0.1 * 0.5
        v = 0.001 * 0.25
        mhat = m / (1 - 0.9)
        vhat = v / (1 - 0.999)
        want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
        assert float(p2["w"][0]) == pytest.approx(want, rel=1e-5)

    def test_weight_decay_decoupled(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=None,
                          warmup_steps=0, schedule="constant")
        opt = AdamW(cfg)
        p = {"w": jnp.array([2.0])}
        g = {"w": jnp.array([0.0])}
        p2, _, _ = opt.update(g, opt.init(p), p)
        assert float(p2["w"][0]) == pytest.approx(2.0 - 0.1 * 0.5 * 2.0)

    def test_schedule_warmup_and_cosine(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
        assert float(schedule_lr(cfg, jnp.int32(0))) == pytest.approx(0.1)
        assert float(schedule_lr(cfg, jnp.int32(9))) == pytest.approx(1.0)
        assert float(schedule_lr(cfg, jnp.int32(110))) < 1e-6

    def test_clip(self):
        tree = {"a": jnp.array([3.0, 4.0])}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(5.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)

    def test_bf16_moments(self):
        opt = AdamW(AdamWConfig(moment_dtype="bfloat16"))
        st = opt.init({"w": jnp.zeros((4,))})
        assert st.m["w"].dtype == jnp.bfloat16


class TestCheckpoint:
    def test_roundtrip_and_resume(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
        mgr.save(5, tree)
        mgr.save(10, jax.tree.map(lambda x: x * 2, tree))
        assert mgr.all_steps() == [5, 10]
        restored, step = mgr.restore(tree)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]) * 2)
        assert restored["nested"]["b"].dtype == jnp.bfloat16

    def test_atomicity_ignores_uncommitted(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.zeros((2,))}
        mgr.save(1, tree)
        # simulate a torn write: directory without the commit marker
        os.makedirs(tmp_path / "step_000000002")
        assert mgr.latest_step() == 1

    def test_prune_keeps_last(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"a": jnp.zeros(1)})
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, {"a": jnp.ones((8, 8))}, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_shape_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"a": jnp.zeros((2,))})
        with pytest.raises(ValueError):
            mgr.restore({"a": jnp.zeros((3,))})

    def test_clock_injection(self, tmp_path):
        """Regression: save() used to stamp bare ``time.time()`` into the
        manifest and commit marker; an injected clock (the
        ``StoreConfig.clock`` convention) must flow to both."""
        import json
        t = [1_234.5]
        mgr = CheckpointManager(str(tmp_path), clock=lambda: t[0])
        mgr.save(3, {"a": jnp.zeros((2,))})
        t[0] = 9_999.0
        mgr.save(4, {"a": jnp.ones((2,))})
        for step, want in ((3, 1_234.5), (4, 9_999.0)):
            d = tmp_path / f"step_{step:09d}"
            with open(d / "manifest.json") as f:
                assert json.load(f)["created"] == want
            assert float((d / "_COMMITTED").read_text()) == want

    def test_default_clock_is_wall_clock(self, tmp_path):
        import json
        import time
        mgr = CheckpointManager(str(tmp_path))
        before = time.time()
        mgr.save(1, {"a": jnp.zeros((1,))})
        after = time.time()
        with open(tmp_path / "step_000000001" / "manifest.json") as f:
            created = json.load(f)["created"]
        assert before <= created <= after


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=3)
        d1, d2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
        b1, b2 = d1.batch(17), d2.batch(17)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_shards_partition_batch(self):
        base = dict(vocab_size=100, seq_len=8, global_batch=4, seed=0)
        s0 = SyntheticTokens(DataConfig(**base, shard_index=0, num_shards=2))
        s1 = SyntheticTokens(DataConfig(**base, shard_index=1, num_shards=2))
        b0, b1 = s0.batch(0), s1.batch(0)
        assert b0["tokens"].shape == (2, 8)
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_labels_shifted(self):
        d = SyntheticTokens(DataConfig(vocab_size=50, seq_len=16,
                                       global_batch=1))
        b = d.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self, rng):
        g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        q, s = GC.quantize_int8(g)
        err = np.abs(np.asarray(GC.dequantize_int8(q, s) - g))
        assert err.max() <= float(s) * 0.5 + 1e-7

    def test_error_feedback_unbiased_over_steps(self, rng):
        """EF: the accumulated applied update converges to the true sum."""
        g = {"w": jnp.asarray(rng.standard_normal(256) * 1e-3, jnp.float32)}
        err = None
        applied = np.zeros(256)
        for _ in range(50):
            (q, s), err = GC.compress_tree(g, err)
            applied += np.asarray(GC.decompress_tree(q, s)["w"])
        true = np.asarray(g["w"]) * 50
        assert np.abs(applied - true).max() <= float(s["w"]) + 1e-6


class TestCostModel:
    def test_imgstore_linear_anchor(self):
        curves = project(CostParams())
        norm = normalized_horizons(curves)
        assert norm["imgstore"][2026.25] == pytest.approx(1.0, abs=0.05)
        # paper: ImgStore ~164x by 2050, LB-5090 ~49x (constant prices);
        # our ramp model anchors slightly differently — same order
        assert 80 <= norm["imgstore"][2050.0] <= 260
        assert norm["lb_5090"][2050.0] < 0.55 * norm["imgstore"][2050.0]

    def test_glacier_between(self):
        norm = normalized_horizons(project(CostParams()))
        assert norm["lb_5090"][2050.0] < norm["imgstore_glacier"][2050.0] \
            < norm["imgstore"][2050.0]


class TestElasticRescale:
    def test_restore_onto_different_mesh(self, tmp_path):
        """Elastic path: checkpoint saved unsharded restores onto a mesh
        with a different layout via the shardings argument."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        mgr.save(1, tree)
        mesh = jax.make_mesh((1,), ("model",))
        sh = {"w": NamedSharding(mesh, P("model", None))}
        restored, step = mgr.restore(tree, shardings=sh)
        assert step == 1
        assert restored["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))

    def test_trainer_resume_after_data_reshard(self, tmp_path):
        """Rescale story: same global batch, different shard count — the
        stateless data pipeline regenerates the identical global stream."""
        base = dict(vocab_size=64, seq_len=8, global_batch=4, seed=11)
        whole = SyntheticTokens(DataConfig(**base))
        halves = [SyntheticTokens(DataConfig(**base, shard_index=i,
                                             num_shards=2))
                  for i in range(2)]
        b = whole.batch(3)
        b2 = np.concatenate([h.batch(3)["tokens"] for h in halves])
        np.testing.assert_array_equal(b["tokens"], b2)
