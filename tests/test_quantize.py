"""Quantized decoder storage behind the ±1-LSB uint8 serving gate:
bf16 passes on a calibrated decoder (every bucket, padded slots
included), grid-snapped int8 round-trips to 0 LSB, an out-of-tolerance
quantizer is rejected at engine open, and quantized pixels survive a
flush + reopen bit-identical."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops
from repro.store import LatentBox, StoreConfig
from repro.vae import quantize as Q
from repro.vae.model import VAE, DEMO_VAE, demo_vae

LATENT_HWC = (8, 8, 4)
BUCKETS = (1, 2, 4, 8)


@pytest.fixture(scope="module")
def vae_bf16():
    return demo_vae(seed=0, weight_dtype="bfloat16")


@pytest.fixture(scope="module")
def vae_int8_snapped():
    vae = demo_vae(seed=0)
    Q.snap_to_grid(vae)
    vae.set_weight_dtype("int8")
    return vae


def store_config(**kw):
    base = dict(n_nodes=1, cache_bytes_per_node=1e5, adaptive=False,
                decode_buckets=BUCKETS)
    base.update(kw)
    return StoreConfig(**base)


# ---------------------------------------------------------------------------
# array-level quantizers
# ---------------------------------------------------------------------------

class TestQuantizeInt8:
    def test_per_channel_scale_shape_and_range(self, rng):
        w = jnp.asarray(rng.standard_normal((3, 3, 8, 16)), jnp.float32)
        qw = Q.quantize_int8(w)
        assert qw.q.dtype == jnp.int8 and qw.q.shape == w.shape
        assert qw.scale.shape == (16,) and qw.scale.dtype == jnp.float32
        assert int(jnp.max(jnp.abs(qw.q.astype(jnp.int32)))) <= 127
        # per-channel: each channel's max |q| saturates at exactly 127
        assert int(jnp.min(jnp.max(jnp.abs(qw.q.astype(jnp.int32)),
                                   axis=(0, 1, 2)))) == 127

    def test_grid_snap_roundtrips_exactly(self, rng):
        w = jnp.asarray(rng.standard_normal((3, 3, 4, 8)), jnp.float32)
        snapped = Q.quantize_int8(w).dequant(jnp.float32)
        again = Q.quantize_int8(snapped).dequant(jnp.float32)
        np.testing.assert_array_equal(np.asarray(snapped), np.asarray(again))

    def test_zero_channel_gets_unit_scale(self):
        w = jnp.zeros((3, 3, 2, 2), jnp.float32)
        qw = Q.quantize_int8(w)
        np.testing.assert_array_equal(np.asarray(qw.scale), 1.0)
        np.testing.assert_array_equal(np.asarray(qw.q), 0)

    def test_unknown_weight_dtype_rejected(self):
        with pytest.raises(ValueError, match="weight_dtype"):
            Q.quantize_decoder({}, "int4")


class TestDecoderStorage:
    def test_bytes_per_param_ladder(self):
        vae = VAE(DEMO_VAE, seed=0, with_encoder=False)
        f32 = Q.decoder_storage(vae.decoder)
        bf16 = Q.decoder_storage(Q.quantize_decoder(vae.decoder, "bfloat16"))
        int8 = Q.decoder_storage(Q.quantize_decoder(vae.decoder, "int8"))
        assert f32["bytes_per_param"] == pytest.approx(4.0)
        assert 1.9 < bf16["bytes_per_param"] < 2.2       # 1-D affine stays f32
        assert 1.0 < int8["bytes_per_param"] < 1.3       # denses stay bf16
        assert f32["params"] == bf16["params"] == int8["params"]

    def test_float32_is_identity(self):
        vae = VAE(DEMO_VAE, seed=0, with_encoder=False)
        assert Q.quantize_decoder(vae.decoder, "float32") is vae.decoder


# ---------------------------------------------------------------------------
# the ±1-LSB gate
# ---------------------------------------------------------------------------

class TestGate:
    def test_bf16_within_one_lsb_every_bucket(self, vae_bf16):
        lsb = Q.check_u8_gate(vae_bf16, BUCKETS, LATENT_HWC)
        assert set(lsb) == set(BUCKETS)
        assert max(lsb.values()) <= 1

    def test_snapped_int8_is_exact(self, vae_int8_snapped):
        lsb = Q.check_u8_gate(vae_int8_snapped, BUCKETS, LATENT_HWC)
        assert max(lsb.values()) == 0

    def test_raw_int8_random_decoder_rejected(self):
        """Unsnapped int8 on this decoder drifts past 1 LSB — the gate's
        whole point is that it, not a promise, decides admissibility."""
        vae = demo_vae(seed=0)
        vae.set_weight_dtype("int8")
        with pytest.raises(Q.QuantizationGateError, match="int8"):
            Q.check_u8_gate(vae, (1, 2), LATENT_HWC)

    def test_float32_override_is_the_oracle(self, vae_bf16):
        """precision='float32' must bypass quantized weights entirely."""
        z = Q.probe_latents(LATENT_HWC, 2, seed=3)
        oracle = VAE(DEMO_VAE, seed=0, with_encoder=False)
        oracle.decoder = vae_bf16.decoder
        oracle.set_weight_dtype("float32")
        ref = np.asarray(oracle.decode_u8(jnp.asarray(z)))
        got = np.asarray(vae_bf16.decode_u8(jnp.asarray(z),
                                            precision="float32"))
        np.testing.assert_array_equal(ref, got)


# ---------------------------------------------------------------------------
# engine integration: open-time gate + padded slots + persistence
# ---------------------------------------------------------------------------

def _put_latents(box, n, rng):
    for oid in range(n):
        box.put(oid, latent=rng.standard_normal(LATENT_HWC)
                .astype(np.float16))


class TestEngineGate:
    def test_open_accepts_bf16_and_reports_gate(self, vae_bf16, rng):
        box = LatentBox.engine(vae=vae_bf16,
                               config=store_config(weight_dtype="bfloat16"))
        _put_latents(box, 3, rng)
        assert all(r.payload.dtype == np.uint8 for r in box.get_many([0, 1]))
        s = box.summary()
        assert s["weight_dtype"] == "bfloat16"
        assert max(s["quantize_gate_lsb"].values()) <= 1

    @pytest.mark.parametrize("n", [3, 5])
    def test_padded_windows_match_oracle(self, vae_bf16, rng, n):
        """Windows of 3 and 5 pad buckets 4 and 8: quantized serving must
        stay within ±1 LSB of the f32 oracle on the *real* slots."""
        box = LatentBox.engine(vae=vae_bf16,
                               config=store_config(weight_dtype="bfloat16"))
        lat = [rng.standard_normal(LATENT_HWC).astype(np.float16)
               for _ in range(n)]
        for oid, z in enumerate(lat):
            box.put(oid, latent=z)
        got = box.get_many(list(range(n)))
        for r, z in zip(got, lat):
            zb = jnp.asarray(np.asarray(z, np.float32)[None])
            ref = np.asarray(vae_bf16.decode_u8(zb, precision="float32"))[0]
            err = np.abs(ref.astype(np.int16)
                         - r.payload.astype(np.int16)).max()
            assert err <= 1

    def test_out_of_tolerance_quantizer_rejected(self, vae_bf16,
                                                 monkeypatch):
        """The gate is the admission contract: a quantizer whose output
        drifts (here: weights zeroed) must fail the open, loudly."""
        monkeypatch.setitem(
            Q.QUANTIZERS, "bfloat16",
            lambda params: Q._map_weights(
                params, lambda p: (p * 0 if getattr(p, "ndim", 0) >= 2
                                   else p)))
        vae = demo_vae(seed=0)
        with pytest.raises(Q.QuantizationGateError):
            LatentBox.engine(vae=vae,
                             config=store_config(weight_dtype="bfloat16",
                                                 decode_buckets=(1, 2)))

    def test_raw_int8_rejected_at_open(self):
        vae = demo_vae(seed=0)
        with pytest.raises(Q.QuantizationGateError):
            LatentBox.engine(vae=vae,
                             config=store_config(weight_dtype="int8",
                                                 decode_buckets=(1, 2)))

    def test_quantization_requires_uint8_pixels(self, vae_bf16):
        with pytest.raises(ValueError, match="uint8 fast path"):
            LatentBox.engine(vae=vae_bf16,
                             config=store_config(weight_dtype="bfloat16",
                                                 pixel_format="float32",
                                                 image_bytes=64e3))


class TestQuantizedPersistence:
    def test_pixels_identical_across_flush_and_reopen(self, tmp_path, rng):
        cfg = store_config(weight_dtype="bfloat16", decode_buckets=(1, 2))
        with LatentBox.open(tmp_path / "box", config=cfg) as box:
            _put_latents(box, 4, rng)
            box.flush()
            before = [np.asarray(r.payload).copy()
                      for r in box.get_many([0, 1, 2, 3])]
        with LatentBox.open(tmp_path / "box", config=cfg) as box:
            after = [np.asarray(r.payload)
                     for r in box.get_many([0, 1, 2, 3])]
        for a, b in zip(before, after):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# kernel-level int8 parity (differential, interpret vs xla)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestInt8KernelParity:
    """The Pallas in-kernel dequant must match dequant-then-XLA — the
    scale fold into the f32 accumulator is exact per output channel."""

    def test_conv3x3(self, rng):
        x = jnp.asarray(rng.standard_normal((1, 8, 8, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((3, 3, 8, 16)) / 8, jnp.float32)
        b = jnp.asarray(rng.standard_normal((16,)) * 0.01, jnp.float32)
        qw = Q.quantize_int8(w)
        got = ops.conv3x3(x, qw, b, impl="pallas_interpret")
        ref = ops.conv3x3(x, qw.dequant(jnp.float32), b, impl="xla")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)

    def test_upsample_conv3x3(self, rng):
        x = jnp.asarray(rng.standard_normal((1, 8, 8, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) / 8, jnp.float32)
        b = jnp.asarray(rng.standard_normal((8,)) * 0.01, jnp.float32)
        qw = Q.quantize_int8(w)
        got = ops.upsample_conv3x3(x, qw, b, impl="pallas_interpret")
        ref = ops.upsample_conv3x3(x, qw.dequant(jnp.float32), b, impl="xla")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)
