"""Dual-format cache invariants (paper §4.2) — unit + hypothesis property
tests: single residency, capacity bounds, promotion-at-h, tail-hit
semantics, alpha resizing."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")   # dev-only dep, see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dual_cache import (DualFormatCache, SegmentedLRU, FULL_MISS,
                                   IMAGE_HIT, LATENT_HIT)

IMG, LAT = 100.0, 20.0


def make(capacity=1000.0, alpha=0.5, tau=0.1, h=3):
    return DualFormatCache(capacity, alpha=alpha, tau=tau,
                           promote_threshold=h,
                           image_size_fn=lambda _: IMG,
                           latent_size_fn=lambda _: LAT)


class TestSegmentedLRU:
    def test_basic_lru_order(self):
        c = SegmentedLRU(3.0, tau=0.0)
        for i in range(3):
            c.insert(i, 1.0)
        c.lookup(0)                       # refresh 0
        c.insert(3, 1.0)                  # evicts 1 (LRU)
        assert 0 in c and 2 in c and 3 in c and 1 not in c

    def test_tail_demotion_and_tail_hit(self):
        c = SegmentedLRU(10.0, tau=0.2)   # main 8, tail 2
        for i in range(10):
            c.insert(i, 1.0)
        # oldest entries demoted into tail
        assert c.lookup(8) == "tail" or c.lookup(8) == "main"
        c.check_invariants()

    def test_oversize_object_rejected(self):
        c = SegmentedLRU(10.0)
        evicted = c.insert(1, 50.0)
        assert (1, 50.0) in evicted and 1 not in c

    def test_capacity_shrink_evicts(self):
        c = SegmentedLRU(10.0)
        for i in range(10):
            c.insert(i, 1.0)
        c.set_capacity(4.0)
        assert c.resident_bytes <= 4.0
        c.check_invariants()


class TestDualFormatCache:
    def test_lookup_cascade(self):
        c = make()
        r = c.lookup(1)
        assert r.outcome == FULL_MISS
        c.admit_latent(1)
        assert c.lookup(1).outcome == LATENT_HIT

    def test_promotion_at_threshold(self):
        c = make(h=3)
        c.admit_latent(1)
        assert c.lookup(1).outcome == LATENT_HIT        # count 1
        assert c.lookup(1).outcome == LATENT_HIT        # count 2
        r = c.lookup(1)                                  # count 3 -> promote
        assert r.outcome == LATENT_HIT and r.promoted
        assert c.contains(1) == "image"
        assert c.lookup(1).outcome == IMAGE_HIT

    def test_single_residency(self):
        c = make(h=1)
        c.admit_latent(1)
        c.lookup(1)                                      # promote at h=1
        assert 1 in c.image_tier and 1 not in c.latent_tier
        c.check_invariants()

    def test_no_promotion_into_zero_image_tier(self):
        c = make(alpha=0.0, h=1)
        c.admit_latent(1)
        r = c.lookup(1)
        assert r.outcome == LATENT_HIT and not r.promoted
        assert c.contains(1) == "latent"                 # object kept

    def test_alpha_one_drops_latent_admission(self):
        c = make(alpha=1.0)
        c.admit_latent(1)
        assert c.contains(1) is None
        c.insert_image(1)
        assert c.contains(1) == "image"

    def test_window_stats(self):
        c = make(h=2)
        c.lookup(1)
        c.admit_latent(1)
        c.lookup(1)
        c.lookup(1)                                      # promotes
        c.lookup(1)                                      # image hit
        s = c.end_window()
        assert s.total_requests == 4
        assert s.full_misses == 1
        assert s.latent_hits == 2
        assert s.image_hits == 1
        assert s.promotions == 1
        assert c.stats.total_requests == 0               # reset

    def test_set_alpha_rebalances(self):
        c = make(alpha=0.5)
        for i in range(40):
            c.admit_latent(i)
        before = c.latent_tier.resident_bytes
        c.set_alpha(0.9)
        assert c.latent_tier.capacity == pytest.approx(100.0)
        assert c.latent_tier.resident_bytes <= 100.0
        assert c.latent_tier.resident_bytes < before
        c.check_invariants()


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 30), st.sampled_from(["get"])),
                min_size=1, max_size=300),
       st.floats(0.0, 1.0), st.floats(0.0, 0.4),
       st.integers(1, 6))
def test_property_invariants(ops, alpha, tau, h):
    """Any access sequence preserves: capacity bounds, single residency,
    non-negative counters, and the outcome algebra."""
    c = DualFormatCache(500.0, alpha=alpha, tau=tau, promote_threshold=h,
                        image_size_fn=lambda _: IMG,
                        latent_size_fn=lambda _: LAT)
    for oid, _ in ops:
        r = c.lookup(oid)
        if r.outcome == FULL_MISS:
            c.admit_latent(oid)
        c.check_invariants()
    s = c.stats
    assert s.image_hits + s.image_misses == s.total_requests
    assert s.latent_hits + s.full_misses == s.image_misses
    assert s.image_tail_hits <= s.image_hits
    assert s.latent_tail_hits <= s.latent_hits


class TestRegenTier:
    """Beyond-paper recipe tier (core/regen_tier.py)."""

    def test_breakeven_age_positive_and_finite(self):
        from repro.core.regen_tier import RegenPolicy
        a = RegenPolicy().demotion_age_months()
        assert 0.1 < a < 240.0

    def test_demotion_and_regen_flow(self):
        from repro.core.regen_tier import RegenPolicy, RegenTierStore
        pol = RegenPolicy()
        st = RegenTierStore(pol)
        st.put(1, 290e3, now_mo=0.0)
        st.put(2, 290e3, now_mo=0.0)
        _, r = st.fetch(2, now_mo=0.5)        # keep 2 warm
        assert not r
        st.run_demotion(now_mo=pol.demotion_age_months() + 1.0)
        _, needs1 = st.fetch(1, now_mo=pol.demotion_age_months() + 1.1)
        assert needs1                          # 1 was demoted to recipe
        st.readmit(1, 290e3, now_mo=pol.demotion_age_months() + 1.1)
        _, needs1b = st.fetch(1, now_mo=pol.demotion_age_months() + 1.2)
        assert not needs1b                     # warm again after regen

    def test_cheaper_gpu_lowers_breakeven_age(self):
        from repro.core.regen_tier import RegenPolicy
        import dataclasses
        a_expensive = RegenPolicy(p_gpu_hr=2.5).demotion_age_months()
        a_cheap = RegenPolicy(p_gpu_hr=0.3).demotion_age_months()
        assert a_cheap < a_expensive
