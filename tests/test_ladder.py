"""Rate-distortion ladder: quality-tiered durable latents.

Three layers under test:

* **Log mechanics** — ``RUNG`` intent records in the segment log: pending
  until the compactor rewrites the blob's segment, invalidated by fresh
  puts, surviving reopen (manifest and full-scan recovery), and dropped
  when unsatisfiable so ladder victim selection terminates.
* **Compaction piggyback** — re-encoding rides along with segment
  rewrites (no standalone re-encode I/O pass): blob payloads transcode
  down the ladder, size-only registrations rescale, accounting counters
  move.
* **Store semantics** — ``LatentBox.demote(oid, rung=...)`` end to end:
  eager application on memory backends, deferred-to-compaction on
  persistent boxes, rung-by-rung cooling down to recipe-only regen with
  every rung meeting its fidelity floor, identical hit classification
  across the {1,4}-shard x {sim,engine} matrix, and rung state surviving
  shard migration on both the memory and segment-shipped paths.
"""

import numpy as np
import pytest

from conftest import classify, fill_and_demote, make_box
from repro.compression.ladder import (LOSSLESS_RUNG, RECIPE_RUNG, RUNGS,
                                      LadderPolicy, encode_at, resolve_rung,
                                      scaled_nbytes)
from repro.compression.latentcodec import blob_rung, compress_latent
from repro.compression.metrics import psnr, ssim
from repro.core.regen_tier import Recipe
from repro.store import FULL_MISS, LatentBox, REGEN_MISS, StoreConfig
from repro.store.durable.compact import Compactor
from repro.store.durable.log import MANIFEST, SegmentLog


def _latent(rng, shape=(8, 8, 4)):
    base = np.cumsum(rng.standard_normal(shape), axis=0)
    return (base / max(1.0, float(np.max(np.abs(base))))).astype(np.float16)


class TestRungResolution:
    def test_lookup_forms(self):
        assert resolve_rung(2).name == "mid"
        assert resolve_rung("low").index == 3
        assert resolve_rung(RUNGS[1]) is RUNGS[1]
        assert resolve_rung(None).is_recipe     # pre-ladder demote() meaning

    def test_invalid(self):
        with pytest.raises(ValueError):
            resolve_rung("shiny")
        with pytest.raises(ValueError):
            resolve_rung(17)

    def test_ladder_shape(self):
        assert RUNGS[LOSSLESS_RUNG].bits is None
        assert RUNGS[RECIPE_RUNG].is_recipe
        scales = [r.scale for r in RUNGS]
        assert scales == sorted(scales, reverse=True)
        bits = [r.bits for r in RUNGS if r.lossy]
        assert bits == sorted(bits, reverse=True)

    def test_scaled_nbytes(self):
        assert scaled_nbytes(1000.0, 0, 2) == pytest.approx(500.0)
        assert scaled_nbytes(500.0, 2, 3) == pytest.approx(380.0)
        assert scaled_nbytes(0.0, 0, 3) == 0.0

    def test_policy_picks_coldest_crossed_trigger(self):
        pol = LadderPolicy()
        assert pol.rung_for_idle(0.5) is None          # nothing crossed
        assert pol.rung_for_idle(1.5) == 1
        assert pol.rung_for_idle(7.0) == 3
        assert pol.rung_for_idle(20.0) == RECIPE_RUNG
        assert pol.rung_for_idle(7.0, cur=3) is None   # never re-inflate
        assert LadderPolicy(enabled=False).rung_for_idle(99.0) is None


class TestLogLadderMechanics:
    def _log(self, tmp_path, **kw):
        kw.setdefault("segment_bytes", 400)
        return SegmentLog(str(tmp_path / "log"), **kw)

    def test_intent_pending_then_applied_by_compaction(self, tmp_path, rng):
        log = self._log(tmp_path)
        z = _latent(rng)
        blobs = {oid: encode_at(z + oid / 10, 0) for oid in (1, 2, 3)}
        for oid, b in blobs.items():
            log.put_blob(oid, b)
        assert log.rung_of(1) == 0 and log.target_rung_of(1) is None
        log.set_target_rung(1, 2)
        assert log.target_rung_of(1) == 2
        assert log.pending_rungs() == {1: 2}
        log.put_blob(9, bytes(500))         # roll: seal the pending segment
        before = len(log.get_blob(1))
        assert Compactor(log, live_frac_threshold=1.0).compact_all() > 0
        assert log.target_rung_of(1) is None            # intent consumed
        assert log.rung_of(1) == 2
        assert blob_rung(log.get_blob(1)) == 2
        assert len(log.get_blob(1)) < before
        assert log.reencoded_records >= 1
        assert log.reencode_bytes_saved > 0
        # untouched neighbors stay lossless and bit-identical
        assert log.get_blob(2) == blobs[2] and log.rung_of(2) == 0

    def test_fresh_put_invalidates_intent(self, tmp_path, rng):
        log = self._log(tmp_path)
        log.put_blob(1, encode_at(_latent(rng), 0))
        log.set_target_rung(1, 3)
        log.put_blob(1, encode_at(_latent(rng) * 2, 0))  # re-put: hot again
        assert log.target_rung_of(1) is None
        log.put_blob(9, bytes(500))
        Compactor(log, live_frac_threshold=1.0).compact_all()
        assert log.rung_of(1) == 0                       # never demoted

    def test_size_records_rescale(self, tmp_path):
        log = self._log(tmp_path)
        log.put_size(1, 10_000.0)
        log.set_target_rung(1, 2)
        log.put_blob(9, bytes(500))          # overflow the active segment...
        log.put_blob(10, b"x")               # ...and roll it sealed
        Compactor(log, live_frac_threshold=1.0).compact_all()
        assert log.rung_of(1) == 2
        assert log.size_of(1) == pytest.approx(5_000.0)

    @pytest.mark.parametrize("drop_manifest", [False, True])
    def test_rung_and_intent_survive_reopen(self, tmp_path, rng,
                                            drop_manifest):
        log = self._log(tmp_path)
        log.put_blob(1, encode_at(_latent(rng), 0))
        log.put_size(2, 8_000.0)
        log.set_target_rung(1, 1)
        log.set_target_rung(2, 3)
        log.put_blob(9, bytes(500))
        Compactor(log, live_frac_threshold=1.0).compact_all()
        log.set_target_rung(1, 3)            # fresh, still-pending intent
        log.close()
        if drop_manifest:                    # force full-scan recovery
            (tmp_path / "log" / MANIFEST).unlink()
        log2 = SegmentLog(str(tmp_path / "log"))
        assert log2.rung_of(1) == 1 and log2.target_rung_of(1) == 3
        assert log2.rung_of(2) == 3 and log2.target_rung_of(2) is None
        assert log2.size_of(2) == pytest.approx(8_000.0 * 0.38)

    def test_unsatisfiable_intent_dropped_and_terminates(self, tmp_path):
        log = self._log(tmp_path)
        log.put_blob(1, b"\x00opaque-not-a-codec-payload" * 8)
        log.set_target_rung(1, 2)
        log.put_blob(9, bytes(500))
        log.put_blob(10, b"x")               # roll the pending segment sealed
        comp = Compactor(log, live_frac_threshold=1.0)
        comp.compact_all()                   # must terminate
        assert log.target_rung_of(1) is None  # intent dropped, not retried
        assert comp.step() == 0              # steady state: no ladder victim

    def test_ladder_victim_earns_rewrite_without_dead_bytes(self, tmp_path,
                                                           rng):
        log = self._log(tmp_path, segment_bytes=10_000)
        for oid in range(4):
            log.put_blob(oid, encode_at(_latent(rng) + oid, 0))
        log.set_target_rung(2, 3)
        log._seal_active()
        comp = Compactor(log, live_frac_threshold=0.6)
        assert comp._victim() is None        # 100% live: no dead-byte case
        assert comp.step() == 1              # pending bytes earn the rewrite
        assert log.rung_of(2) == 3 and log.target_rung_of(2) is None

    def test_export_ingest_preserves_pending_intent(self, tmp_path, rng):
        src = SegmentLog(str(tmp_path / "src"), segment_bytes=400)
        dst = SegmentLog(str(tmp_path / "dst"), segment_bytes=400)
        src.put_blob(1, encode_at(_latent(rng), 0))
        src.put_size(2, 6_000.0, rung=1)
        src.set_target_rung(1, 2)
        applied = dst.ingest_segment(src.export_records([1, 2]))
        assert applied["rungs"] == {1: 2}
        assert dst.target_rung_of(1) == 2    # still pending at the new home
        assert dst.rung_of(2) == 1           # applied rung travels in SIZE
        dst.put_blob(9, bytes(500))
        Compactor(dst, live_frac_threshold=1.0).compact_all()
        assert dst.rung_of(1) == 2


class TestMemoryEagerLadder:
    """Memory backends have no compactor to piggyback on: demotion
    applies eagerly and ``target_rung`` never reads as pending."""

    def test_sim_box_rescales_bytes_eagerly(self):
        box = LatentBox.simulated(StoreConfig(n_nodes=1))
        box.put(1, nbytes=10_000.0, recipe=Recipe(seed=1, height=16,
                                                  width=16))
        assert box.demote(1, "mid")
        st = box.stat(1)
        assert st.rung == 2 and st.rung_name == "mid"
        assert st.target_rung is None
        assert st.durable_bytes == pytest.approx(5_000.0)
        assert box.demote(1, 3)
        assert box.stat(1).durable_bytes == pytest.approx(3_800.0)

    def test_refuses_uphill_and_noop_demotes(self):
        box = LatentBox.simulated(StoreConfig(n_nodes=1))
        box.put(1, nbytes=1_000.0, recipe=Recipe(seed=1, height=16,
                                                 width=16))
        assert box.demote(1, "low")
        assert not box.demote(1, "high")     # ladder only descends
        assert not box.demote(1, "low")      # not strictly colder
        assert not box.demote(1, 0)          # "demote to lossless" is a no-op
        assert not box.demote(999, "mid")    # unknown object

    def test_classification_unchanged_by_lossy_rungs(self):
        box = LatentBox.simulated(StoreConfig(n_nodes=1))
        box.put(1, nbytes=1_000.0, recipe=Recipe(seed=1, height=16,
                                                 width=16))
        box.get(1)
        assert box.demote(1, "low")
        # durable fetch before and after: lossy rungs never change the walk
        box2 = LatentBox.simulated(StoreConfig(n_nodes=1))
        box2.put(1, nbytes=1_000.0, recipe=Recipe(seed=1, height=16,
                                                  width=16))
        box2.get(1)
        for a, b in zip(box.get_many([1, 1, 1]), box2.get_many([1, 1, 1])):
            assert a.hit_class == b.hit_class
        assert box.demote(1)                 # ...only the recipe rung does
        assert box.get(1).hit_class == REGEN_MISS


class TestCoolingTraceEndToEnd:
    """A persistent engine box cools objects rung-by-rung: every demotion
    piggybacks on compaction, every rung meets its fidelity floor, the
    coldest rung serves recipe-only regeneration, and the whole ladder
    state survives reopen."""

    RES = 16

    def _open(self, path, vae):
        return LatentBox.open(path, mode="engine", vae=vae,
                              config=StoreConfig(n_nodes=1,
                                                 segment_bytes=1_500,
                                                 compact_live_frac=0.6))

    def _settle(self, box, oid):
        """Roll the active segment, then compact until the intent applies
        (bounded: unsatisfied intents would fail the assert below)."""
        for filler in range(900, 904):
            box.put(filler, latent=np.zeros((8, 8, 4), np.float16)
                    + filler / 1e3)
        for _ in range(12):
            if box.stat(oid).target_rung is None:
                break
            box.backend.store.maybe_compact()
        assert box.stat(oid).target_rung is None

    def test_descend_ladder_and_regen(self, tmp_path, tiny_vae):
        path = tmp_path / "box"
        oid = 42
        with self._open(path, tiny_vae) as box:
            box.put(oid, recipe=Recipe(seed=7, height=self.RES,
                                       width=self.RES))
            ref = box.get(oid).payload.copy()
            sizes = [box.stat(oid).durable_bytes]
        for rung in ("high", "mid", "low"):
            with self._open(path, tiny_vae) as box:
                assert box.demote(oid, rung)
                st = box.stat(oid)
                assert st.target_rung == resolve_rung(rung).index
                self._settle(box, oid)
                st = box.stat(oid)
                assert st.rung == resolve_rung(rung).index
                assert st.rung_name == rung
                sizes.append(st.durable_bytes)
            # reopen cold: the read decodes the demoted durable bytes
            with self._open(path, tiny_vae) as box:
                r = box.get(oid)
                assert r.hit_class == FULL_MISS
                floor = resolve_rung(rung)
                assert psnr(ref, r.payload) >= floor.psnr_floor_db
                assert ssim(ref, r.payload) >= floor.ssim_floor
        assert sizes == sorted(sizes, reverse=True), sizes
        # final rung: recipe-only — near-zero bytes, full regen on read
        with self._open(path, tiny_vae) as box:
            assert box.demote(oid)
            st = box.stat(oid)
            assert st.demoted and st.durable_bytes == 0.0
            assert st.rung == RECIPE_RUNG
        with self._open(path, tiny_vae) as box:
            r = box.get(oid)
            assert r.hit_class == REGEN_MISS and r.regenerated
            np.testing.assert_array_equal(r.payload, ref)


TOTAL_NODES = 8
N_OBJECTS = 24

#: window index -> [(oid, rung), ...] applied before that window is served
LADDER_PLAN = {2: [(1, "high"), (5, "high")],
               4: [(1, "mid"), (9, "low")],
               6: [(5, "low"), (13, "mid")]}


def _classify_with_ladder(kind, shards, ids, vae=None, window=8):
    box = make_box(kind, shards, TOTAL_NODES, vae=vae)
    fill_and_demote(box, N_OBJECTS)
    sig, demoted = [], []
    ids = [int(i) for i in ids]
    for w, s in enumerate(range(0, len(ids), window)):
        for oid, rung in LADDER_PLAN.get(w, ()):
            demoted.append(box.demote(oid, rung))
        sig += [(r.hit_class, r.node) for r in box.get_many(ids[s:s + window])]
    assert all(demoted)
    return sig, box


class TestShardConformanceWithLadder:
    """Interleaved lossy-rung demotes must not perturb the {1,4}-shard x
    {sim,engine} classification identity."""

    def _ids(self):
        rng = np.random.default_rng(3)
        return rng.integers(0, N_OBJECTS, 96)

    def test_sim_1v4_identical(self):
        ids = self._ids()
        sig1, _ = _classify_with_ladder("sim", 1, ids)
        sig4, box4 = _classify_with_ladder("sim", 4, ids)
        assert sig1 == sig4
        assert box4.stat(1).rung == resolve_rung("mid").index

    @pytest.mark.slow
    def test_engine_matches_sim(self, tiny_vae):
        ids = self._ids()
        sim_sig, _ = _classify_with_ladder("sim", 1, ids)
        eng_sig, ebox = _classify_with_ladder("engine", 1, ids,
                                              vae=tiny_vae)
        assert sim_sig == eng_sig
        assert ebox.stat(9).rung == resolve_rung("low").index


class TestMigrationCarriesRungs:
    def test_memory_path_carries_applied_rung(self):
        box = LatentBox.simulated(StoreConfig(n_nodes=4), shards=2)
        for oid in range(16):
            box.put(oid, nbytes=1_000.0,
                    recipe=Recipe(seed=oid, height=16, width=16))
            assert box.demote(oid, "mid")
        rep = box.backend.add_shard()
        assert rep.n_moved > 0
        for oid in range(16):
            st = box.stat(oid)
            assert st.rung == 2 and st.durable_bytes == pytest.approx(500.0)

    def test_log_path_ships_pending_intents(self, tmp_path):
        box = LatentBox.open(tmp_path / "cluster", mode="sim",
                             config=StoreConfig(n_nodes=4,
                                                segment_bytes=2_000,
                                                compact_live_frac=0.0),
                             shards=2)
        try:
            for oid in range(16):
                box.put(oid, nbytes=1_000.0,
                        recipe=Recipe(seed=oid, height=16, width=16))
                assert box.demote(oid, "low")   # pending: compaction is off
            assert all(box.stat(oid).target_rung == 3 for oid in range(16))
            rep = box.backend.add_shard()
            assert rep.n_moved > 0
            # intents survived the segment-shipped migration...
            assert all(box.stat(oid).target_rung == 3 for oid in range(16))
            # ...and still apply at the new home when its compactor runs
            cluster = box.backend
            for sid in cluster.shard_ids:
                log = cluster.shards[sid].backend.durable_log
                log._seal_active()           # stragglers still in the head
                Compactor(log, live_frac_threshold=1.0).compact_all()
            for oid in range(16):
                st = box.stat(oid)
                assert st.rung == 3 and st.target_rung is None
                assert st.durable_bytes == pytest.approx(380.0)
        finally:
            box.close()


class TestDeleteSemantics:
    """Satellite regression: ``LatentBox.delete`` must not drop metadata
    before the backend acknowledges the delete."""

    def test_delete_missing_keeps_nothing_and_returns_false(self):
        box = LatentBox.simulated(StoreConfig(n_nodes=1))
        assert box.delete(123) is False

    def test_raising_backend_preserves_metadata(self):
        box = LatentBox.simulated(StoreConfig(n_nodes=1))
        box.put(1, nbytes=100.0, recipe=Recipe(seed=1, height=16, width=16),
                meta={"tag": "keep-me"})

        class Boom(Exception):
            pass

        orig = box.backend.delete
        def exploding_delete(oid):
            raise Boom()
        box.backend.delete = exploding_delete
        with pytest.raises(Boom):
            box.delete(1)
        box.backend.delete = orig
        assert box.stat(1).meta == {"tag": "keep-me"}   # nothing lost
        assert box.delete(1) is True
        assert box.stat(1) is None
