"""Fault-tolerance suite: replication, failure injection, hedged reads.

The contract under test (PR 6 tentpole): on an R>=2 cluster no single
shard death loses an acknowledged object or changes a single request's
classification — the differential signature of a one-shard-dead cluster
is IDENTICAL to the healthy cluster's, and engine pixels stay
bit-identical through failover.  Kill-then-restart recovers the revived
shard from its own log plus delta catch-up from its peers, converging to
``under_replicated_objects() == 0``.  Hedged reads cut the slow-replica
tail without ever touching classification, cache state, or decode
counts.

Fast cases run per push; the full {kill, stall, partition} x {sim,
engine} matrix is ``slow``-marked for the nightly job.
"""

import json
import os

import numpy as np
import pytest

from conftest import classify, conformance_config, fill_and_demote

from repro.core.regen_tier import Recipe
from repro.store import FaultEvent, FaultPlan, HedgeConfig, LatentBox


def _trace(n_objects, length, seed=7):
    rng = np.random.default_rng(seed)
    # zipf-flavoured: a hot head (exercises image tier + hedging) plus a
    # uniform tail (exercises durable + regen paths)
    hot = rng.choice(max(1, n_objects // 4), size=length // 2)
    cold = rng.choice(n_objects, size=length - len(hot))
    seq = np.concatenate([hot, cold])
    rng.shuffle(seq)
    return [int(x) for x in seq]


def _replicated_box(kind, shards, vae=None, replication=2, hedge=None,
                    fault_plan=None, total_nodes=None, **cfg_kw):
    total = total_nodes if total_nodes is not None else 2 * shards
    assert total % shards == 0
    cfg = conformance_config(total // shards, **cfg_kw)
    if kind == "engine":
        return LatentBox.engine(vae=vae, config=cfg, shards=shards,
                                replication=replication, hedge=hedge,
                                fault_plan=fault_plan)
    return LatentBox.simulated(cfg, shards=shards, replication=replication,
                               hedge=hedge, fault_plan=fault_plan)


N_OBJECTS = 20
TRACE_LEN = 160


# ---------------------------------------------------------------------------
# replication is classification-invariant while healthy
# ---------------------------------------------------------------------------

class TestHealthyReplication:
    @pytest.mark.parametrize("kind", ["sim", "engine"])
    def test_r2_matches_r1_classification(self, kind, tiny_vae):
        trace = _trace(N_OBJECTS, TRACE_LEN)
        vae = tiny_vae if kind == "engine" else None
        base = _replicated_box(kind, 4, vae=vae, replication=1)
        repl = _replicated_box(kind, 4, vae=vae, replication=2)
        for box in (base, repl):
            fill_and_demote(box, N_OBJECTS)
        assert classify(base, trace) == classify(repl, trace)
        s = repl.summary()
        assert s["replication"] == 2
        assert s["under_replicated_objects"] == 0
        assert s["failovers"] == 0

    def test_replica_placement_distinct_shards(self):
        box = _replicated_box("sim", 4, replication=3)
        cluster = box.backend
        fill_and_demote(box, N_OBJECTS)
        for oid in range(N_OBJECTS):
            reps = cluster.replica_shards(oid)
            assert len(reps) == 3
            assert len(set(reps)) == 3
            assert reps[0] == cluster.shard_of(oid)

    def test_replication_capped_by_shard_count(self):
        box = _replicated_box("sim", 2, replication=4)
        cluster = box.backend
        fill_and_demote(box, 6)
        for oid in range(6):
            assert len(cluster.replica_shards(oid)) == 2
        assert cluster.under_replicated_objects() == 0


# ---------------------------------------------------------------------------
# the acid test: one dead shard is classification-invisible
# ---------------------------------------------------------------------------

class TestDeadShardConformance:
    @pytest.mark.parametrize("kind", ["sim", "engine"])
    def test_kill_mid_trace_identical_classes(self, kind, tiny_vae):
        trace = _trace(N_OBJECTS, TRACE_LEN)
        vae = tiny_vae if kind == "engine" else None
        healthy = _replicated_box(kind, 4, vae=vae, replication=2)
        hurt = _replicated_box(kind, 4, vae=vae, replication=2,
                               fault_plan=FaultPlan.kill(1, TRACE_LEN // 3))
        for box in (healthy, hurt):
            fill_and_demote(box, N_OBJECTS)
        sig_h = classify(healthy, trace)
        sig_d = classify(hurt, trace)
        assert sig_h == sig_d
        s = hurt.summary()
        assert s["dead_shards"] == [1]
        assert s["failovers"] > 0
        # every request answered; no read ever failed
        assert len(sig_d) == TRACE_LEN

    def test_engine_failover_pixels_bit_identical(self, tiny_vae):
        trace = _trace(N_OBJECTS, 96)
        healthy = _replicated_box("engine", 4, vae=tiny_vae, replication=2)
        hurt = _replicated_box("engine", 4, vae=tiny_vae, replication=2,
                               fault_plan=FaultPlan.kill(1, 32))
        for box in (healthy, hurt):
            fill_and_demote(box, N_OBJECTS)
        for s in range(0, len(trace), 8):
            win = trace[s:s + 8]
            for rh, rd in zip(healthy.get_many(win), hurt.get_many(win)):
                assert rh.hit_class == rd.hit_class
                np.testing.assert_array_equal(rh.payload, rd.payload)
        assert hurt.summary()["failovers"] > 0

    def test_failover_reads_are_flagged(self):
        plan = FaultPlan.kill(0, 0)
        box = _replicated_box("sim", 3, replication=2, fault_plan=plan)
        fill_and_demote(box, 9, demote=())
        cluster = box.backend
        owned = [oid for oid in range(9) if cluster.shard_of(oid) == 0]
        assert owned, "need at least one object on the killed shard"
        res = box.get_many(owned)
        assert all(r.failover for r in res)
        assert all(r.hit_class for r in res)

    def test_unreplicated_dead_shard_raises(self):
        box = _replicated_box("sim", 3, replication=1,
                              fault_plan=FaultPlan.kill(0, 0))
        fill_and_demote(box, 9, demote=())
        cluster = box.backend
        owned = [oid for oid in range(9) if cluster.shard_of(oid) == 0]
        with pytest.raises(RuntimeError, match="no replicas"):
            box.get_many(owned)


# ---------------------------------------------------------------------------
# kill -> restart: recovery and re-replication
# ---------------------------------------------------------------------------

class TestKillRestart:
    def test_restart_recovers_full_replication(self):
        plan = FaultPlan.kill_restart(2, 40, 120)
        box = _replicated_box("sim", 4, replication=2, fault_plan=plan)
        fill_and_demote(box, N_OBJECTS)
        trace = _trace(N_OBJECTS, TRACE_LEN)
        classify(box, trace)
        s = box.summary()
        assert s["restarts"] == 1
        assert s["dead_shards"] == []
        assert s["under_replicated_objects"] == 0
        # the revived shard serves its own keys again (cache-cold but whole)
        cluster = box.backend
        owned = [oid for oid in range(N_OBJECTS)
                 if cluster.shard_of(oid) == 2]
        for r in box.get_many(owned):
            assert r.hit_class
            assert not r.failover

    def test_writes_during_outage_reach_revived_shard(self):
        plan = FaultPlan.kill_restart(1, 8, 16)
        box = _replicated_box("sim", 4, replication=2, fault_plan=plan)
        for oid in range(8):
            box.put(oid, recipe=Recipe(seed=oid, height=16, width=16),
                    nbytes=600.0)
        box.get_many(list(range(8)))          # crosses the kill boundary
        cluster = box.backend
        new_ids = [oid for oid in range(8, 40)
                   if cluster.shard_of(oid) == 1][:4]
        assert new_ids, "need fresh objects owned by the dead shard"
        for oid in new_ids:
            box.put(oid, recipe=Recipe(seed=oid, height=16, width=16),
                    nbytes=600.0)             # acked by a replica
        box.get_many(list(range(8)) * 2)      # crosses the restart boundary
        assert box.summary()["under_replicated_objects"] == 0
        for r in box.get_many(new_ids):
            assert r.hit_class
            assert not r.failover             # the owner serves again

    def test_persistent_restart_ships_delta_and_conserves_bytes(self,
                                                                tmp_path):
        cfg_kw = dict(write_behind=True, segment_bytes=4096.0)
        plan = FaultPlan.kill_restart(2, 40, 120)
        box = LatentBox.open(tmp_path, mode="sim",
                             config=conformance_config(1, **cfg_kw),
                             shards=4, replication=2, fault_plan=plan)
        fill_and_demote(box, N_OBJECTS)
        trace = _trace(N_OBJECTS, TRACE_LEN)
        classify(box, trace)
        cluster = box.backend
        assert cluster.under_replicated_objects() == 0
        # catch-up was delta-shipped: every holder's high-water mark sits
        # at its source's current position, so the next sync ships nothing
        for (f, src), holder in cluster._holders.items():
            assert holder.hwm <= cluster._source_position(src)
            assert not cluster._export_from(
                src, holder.hwm, cluster._designated.get((f, src), set()))
        box.flush()
        # on-disk replica bytes stay within one segment of slack of the
        # primaries' live bytes (no unbounded re-ship amplification)
        live = sum(sh.backend.summary()["durable_live_bytes"]
                   for sh in cluster.shards.values())
        replica = box.summary()["replica_disk_bytes"]
        n_holders = len(cluster._holders)
        assert replica <= live + 4096.0 * max(1, n_holders)
        box.close()

    def test_partition_heal_converges(self):
        plan = FaultPlan(events=(
            FaultEvent(kind="partition", shard_id=1, at_request=30),
            FaultEvent(kind="restart", shard_id=1, at_request=90),
        ))
        healthy = _replicated_box("sim", 4, replication=2)
        parted = _replicated_box("sim", 4, replication=2, fault_plan=plan)
        for box in (healthy, parted):
            fill_and_demote(box, N_OBJECTS)
        trace = _trace(N_OBJECTS, 120)
        sig_h = classify(healthy, trace[:90])
        sig_p = classify(parted, trace[:90])
        assert sig_h == sig_p                  # partition == kill for reads
        classify(parted, trace[90:])
        s = parted.summary()
        assert s["dead_shards"] == []
        assert s["under_replicated_objects"] == 0


# ---------------------------------------------------------------------------
# hedged reads
# ---------------------------------------------------------------------------

class TestHedgedReads:
    def _run(self, hedge):
        plan = FaultPlan.stall(0, 24, 400.0)
        box = _replicated_box("sim", 4, replication=2, hedge=hedge,
                              fault_plan=plan)
        # no demotions: a 3.9 s regen miss would own the p99 and hedging
        # (rightly) never races the regen pipeline
        fill_and_demote(box, N_OBJECTS, demote=())
        trace = _trace(N_OBJECTS, TRACE_LEN)
        res = []
        for s in range(0, len(trace), 8):
            res += box.get_many(trace[s:s + 8])
        return box, res

    def test_hedging_cuts_slow_replica_tail(self):
        off_box, off = self._run(HedgeConfig(enabled=False))
        on_box, on = self._run(HedgeConfig(quantile=0.9, min_samples=8))
        # classification is untouchable: hedging only re-times requests
        assert ([(r.hit_class, r.node) for r in off]
                == [(r.hit_class, r.node) for r in on])
        p99_off = float(np.percentile([r.total_ms for r in off], 99))
        p99_on = float(np.percentile([r.total_ms for r in on], 99))
        assert on_box.summary()["hedges_fired"] > 0
        assert on_box.summary()["hedge_wins"] > 0
        assert p99_on < p99_off

    def test_won_hedges_do_not_double_decode(self, tiny_vae):
        plan = FaultPlan.stall(0, 24, 400.0)
        hedged = _replicated_box("engine", 4, vae=tiny_vae, replication=2,
                                 hedge=HedgeConfig(quantile=0.9,
                                                   min_samples=8),
                                 fault_plan=plan)
        plain = _replicated_box("engine", 4, vae=tiny_vae, replication=2,
                                fault_plan=FaultPlan.stall(0, 24, 400.0),
                                hedge=HedgeConfig(enabled=False))
        for box in (hedged, plain):
            fill_and_demote(box, N_OBJECTS)
        trace = _trace(N_OBJECTS, TRACE_LEN)
        for s in range(0, len(trace), 8):
            a = hedged.get_many(trace[s:s + 8])
            b = plain.get_many(trace[s:s + 8])
            for ra, rb in zip(a, b):
                assert ra.hit_class == rb.hit_class
                np.testing.assert_array_equal(ra.payload, rb.payload)

        # the single-flight guarantee: a won hedge re-times the read, it
        # never runs a second decode
        assert hedged.summary()["decodes"] == plain.summary()["decodes"]

    def test_hedge_flag_and_latency_rewrite(self):
        on_box, on = self._run(HedgeConfig(quantile=0.9, min_samples=8))
        wins = [r for r in on if r.hedged]
        assert len(wins) == on_box.summary()["hedge_wins"]
        for r in wins:
            assert r.latency_ms["total"] < r.latency_ms["unhedged_total"]
            assert "hedge_fetch" in r.latency_ms


# ---------------------------------------------------------------------------
# satellites: crash-safe meta, corrupt segment ingest, reshard edge cases
# ---------------------------------------------------------------------------

class TestClusterMetaDurability:
    def test_truncated_meta_raises_cleanly(self, tmp_path):
        box = LatentBox.open(tmp_path, mode="sim",
                             config=conformance_config(1), shards=2)
        box.close()
        meta = os.path.join(tmp_path, "CLUSTER.json")
        raw = open(meta, "rb").read()
        with open(meta, "wb") as f:
            f.write(raw[:len(raw) // 2])       # torn write
        with pytest.raises(ValueError, match="corrupt cluster meta"):
            LatentBox.open(tmp_path, mode="sim",
                           config=conformance_config(1), shards=2)

    def test_meta_write_leaves_no_tmp_and_survives_stale_tmp(self, tmp_path):
        box = LatentBox.open(tmp_path, mode="sim",
                             config=conformance_config(1), shards=2,
                             replication=2)
        box.close()
        meta = os.path.join(tmp_path, "CLUSTER.json")
        assert not os.path.exists(meta + ".tmp")
        with open(meta + ".tmp", "w") as f:
            f.write("{garbage")                # crashed mid-replace
        box2 = LatentBox.open(tmp_path, mode="sim",
                              config=conformance_config(1), shards=2)
        assert box2.backend.replication == 2   # inherited from meta
        assert not os.path.exists(meta + ".tmp")
        box2.close()

    def test_replication_mismatch_on_reopen_errors(self, tmp_path):
        box = LatentBox.open(tmp_path, mode="sim",
                             config=conformance_config(1), shards=2,
                             replication=2)
        box.close()
        with pytest.raises(ValueError, match="replication"):
            LatentBox.open(tmp_path, mode="sim",
                           config=conformance_config(1), shards=2,
                           replication=3)


class TestCorruptSegmentIngest:
    def test_bit_flip_rejected_without_partial_state(self, tmp_path):
        from repro.store.durable.log import SegmentLog
        src = SegmentLog(os.path.join(tmp_path, "src"))
        for oid in range(8):
            src.put_blob(oid, bytes([oid]) * 64)
        src.flush()
        raw = bytearray(src.export_delta(0))
        dst = SegmentLog(os.path.join(tmp_path, "dst"))
        flipped = bytearray(raw)
        flipped[len(flipped) // 2] ^= 0x40
        before = sorted(dst.object_oids())
        with pytest.raises(ValueError):
            dst.ingest_segment(bytes(flipped))
        assert sorted(dst.object_oids()) == before   # nothing applied
        # the pristine copy still ingests fine afterwards
        applied = dst.ingest_segment(bytes(raw))
        assert len(applied["objects"]) == 8
        src.close(); dst.close()

    def test_empty_ingest_is_noop(self, tmp_path):
        from repro.store.durable.log import SegmentLog
        log = SegmentLog(os.path.join(tmp_path, "log"))
        applied = log.ingest_segment(b"")
        assert applied["objects"] == []
        assert applied["segment"] is None
        log.close()


class TestReshardEdgeCases:
    @pytest.mark.parametrize("replication", [1, 2])
    def test_remove_down_to_one_shard(self, replication):
        box = _replicated_box("sim", 4, replication=replication)
        fill_and_demote(box, N_OBJECTS)
        cluster = box.backend
        baseline = classify(box, list(range(N_OBJECTS)))
        while cluster.n_shards > 1:
            victim = max(cluster.shard_ids)
            cluster.remove_shard(victim)
            res = box.get_many(list(range(N_OBJECTS)))
            assert all(r.hit_class for r in res)
        assert cluster.n_shards == 1
        assert cluster.under_replicated_objects() == 0
        assert len(baseline) == N_OBJECTS

    @pytest.mark.parametrize("replication", [1, 2])
    def test_remove_zero_object_shard(self, replication):
        box = _replicated_box("sim", 3, replication=replication)
        cluster = box.backend
        # place objects only on shards != victim
        victim = 2
        oids = [oid for oid in range(200)
                if cluster.shard_of(oid) != victim][:10]
        for oid in oids:
            box.put(oid, recipe=Recipe(seed=oid, height=16, width=16),
                    nbytes=600.0)
        report = cluster.remove_shard(victim)
        assert report.n_moved == 0
        for r in box.get_many(oids):
            assert r.hit_class
        assert cluster.under_replicated_objects() == 0

    def test_reshard_refused_while_shard_down(self):
        box = _replicated_box("sim", 4, replication=2,
                              fault_plan=FaultPlan.kill(1, 0))
        fill_and_demote(box, 8, demote=())
        box.get_many(list(range(8)))          # fires the kill
        with pytest.raises(RuntimeError, match="down"):
            box.backend.remove_shard(2)
        with pytest.raises(RuntimeError, match="down"):
            box.backend.add_shard()


# ---------------------------------------------------------------------------
# summary surface
# ---------------------------------------------------------------------------

class TestSummarySurface:
    def test_fault_counters_serializable(self):
        plan = FaultPlan.kill(1, 40)
        box = _replicated_box("sim", 4, replication=2, fault_plan=plan)
        fill_and_demote(box, N_OBJECTS)
        classify(box, _trace(N_OBJECTS, 120))
        s = box.summary()
        for key in ("replication", "failovers", "hedges_fired", "hedge_wins",
                    "under_replicated_objects", "dead_shards", "restarts"):
            assert key in s, key
        json.dumps(s)                          # bench/CI consume this


# ---------------------------------------------------------------------------
# nightly matrix: every fault kind on both backends
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestFaultMatrix:
    KIND_PLANS = {
        "kill": lambda: FaultPlan.kill(1, TRACE_LEN // 3),
        "stall": lambda: FaultPlan.stall(1, TRACE_LEN // 3, 300.0),
        "partition": lambda: FaultPlan(events=(
            FaultEvent(kind="partition", shard_id=1,
                       at_request=TRACE_LEN // 3),)),
    }

    @pytest.mark.parametrize("fault", sorted(KIND_PLANS))
    @pytest.mark.parametrize("kind", ["sim", "engine"])
    def test_fault_is_classification_invisible(self, kind, fault, tiny_vae):
        trace = _trace(N_OBJECTS, TRACE_LEN)
        vae = tiny_vae if kind == "engine" else None
        healthy = _replicated_box(kind, 4, vae=vae, replication=2)
        hurt = _replicated_box(kind, 4, vae=vae, replication=2,
                               fault_plan=self.KIND_PLANS[fault]())
        for box in (healthy, hurt):
            fill_and_demote(box, N_OBJECTS)
        assert classify(healthy, trace) == classify(hurt, trace)
        s = hurt.summary()
        if fault in ("kill", "partition"):
            assert s["dead_shards"] == [1]
            assert s["failovers"] > 0
