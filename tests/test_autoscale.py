"""Cost-model-driven elastic autoscaler (``repro.core.autoscale``):
controller law (hysteresis, cooldown, cost ranking, SLO feasibility,
scale-down safety), the GPU-queue and cache actuation primitives, the
derived cache-entry cost model, and the end-to-end guarantees — a
disabled box builds no controller at all, and an enabled box never loses
a request across a resize."""

import numpy as np
import pytest

from repro.core.autoscale import (AutoscaleConfig, AutoscaleController,
                                  PlantState, WindowObs)
from repro.core.cluster import GpuQueue
from repro.core.cost_model import (CostParams, dollars_per_million_requests,
                                   params_for_store, pixel_cache_entry_mb)
from repro.core.dual_cache import DualFormatCache
from repro.core.regen_tier import Recipe
from repro.core.tuner import TunerConfig
from repro.store import LatentBox, StoreConfig
from repro.store.api import HIT_CLASSES
from repro.trace.synth import make_trace

IMG, LAT = 100.0, 20.0


def obs(util: float, gpus: int, span: float = 1000.0, queue: float = 0.0,
        decode_frac: float = 1.0, requests: int = 100) -> WindowObs:
    """A window whose measured utilization at ``gpus`` total GPUs is
    exactly ``util``."""
    return WindowObs(requests=requests, span_ms=span,
                     busy_ms=util * span * gpus, decode_frac=decode_frac,
                     queue_p99_ms=queue)


def controller(gpus_per_node=1, n_nodes=1, cache=1e9, n_shards=1,
               guard=None, **cfg_kw) -> AutoscaleController:
    cfg_kw.setdefault("cooldown_windows", 0)
    return AutoscaleController(
        PlantState(gpus_per_node, n_nodes, cache, n_shards=n_shards),
        AutoscaleConfig(**cfg_kw), shard_guard=guard)


class TestControllerLaw:
    def test_scale_up_on_high_util(self):
        c = controller(cache_knob=False)
        ev = c.step(obs(1.2, 1))
        assert ev is not None and ev.action == "gpu_up"
        assert c.state.gpus_per_node == 2 and c.scale_ups == 1

    def test_scale_down_on_low_util(self):
        c = controller(gpus_per_node=2, n_nodes=2, cache_knob=False)
        ev = c.step(obs(0.1, 4))
        assert ev is not None and ev.action == "gpu_down"
        assert c.state.gpus_per_node == 1 and c.scale_downs == 1

    def test_hold_inside_hysteresis_band(self):
        c = controller(gpus_per_node=2)
        assert c.step(obs(0.5, 2)) is None
        assert c.state.gpus_per_node == 2 and not c.events

    def test_scale_down_must_clear_band_midpoint(self):
        # util 0.29 at 2 GPUs would become 0.58 at 1 GPU — above the
        # (0.30 + 0.80)/2 midpoint, so shrinking would re-trigger a
        # scale-up next window.  The controller must hold instead.
        c = controller(gpus_per_node=2, cache_knob=False)
        assert c.step(obs(0.29, 2)) is None
        assert c.state.gpus_per_node == 2

    def test_cooldown_blocks_consecutive_actions(self):
        c = controller(cache_knob=False, cooldown_windows=2)
        assert c.step(obs(1.2, 1)) is not None
        assert c.step(obs(1.2, 2)) is None      # cooldown 2 -> 1
        assert c.step(obs(1.2, 2)) is None      # cooldown 1 -> 0
        assert c.step(obs(1.2, 2)) is not None  # acts again
        assert c.state.gpus_per_node == 3

    def test_never_beyond_gpu_bounds(self):
        c = controller(gpus_per_node=2, cache_knob=False,
                       max_gpus_per_node=2)
        assert c.step(obs(2.0, 2)) is None      # no candidate above max
        c2 = controller(gpus_per_node=1, cache_knob=False)
        assert c2.step(obs(0.01, 1)) is None    # no candidate below min

    def test_cache_bounded_by_config_fractions(self):
        c = controller(cache=1e6, gpu_knob=False, cache_step=2.0,
                       max_cache_frac=2.0, min_cache_frac=0.5)
        assert c.step(obs(0.9, 1)) is not None  # 1e6 -> 2e6 (at max)
        assert c.step(obs(0.9, 1)) is None      # 4e6 would breach max
        assert c.state.cache_bytes_per_node == pytest.approx(2e6)
        down = controller(cache=1e6, gpu_knob=False, cache_step=2.0,
                          min_cache_frac=0.5)
        assert down.step(obs(0.05, 1)) is not None   # 1e6 -> 5e5 (at min)
        assert down.step(obs(0.05, 1)) is None       # 2.5e5 would breach
        assert down.state.cache_bytes_per_node == pytest.approx(5e5)

    def test_queue_breach_triggers_scale_up_at_moderate_util(self):
        c = controller(cache_knob=False, queue_slo_ms=250.0)
        ev = c.step(obs(0.5, 1, queue=400.0))
        assert ev is not None and ev.action == "gpu_up"
        assert "SLO" in ev.reason

    def test_queue_pressure_vetoes_scale_down(self):
        c = controller(gpus_per_node=2, cache_knob=False,
                       queue_slo_ms=250.0)
        # util says shrink, but the queue tail is already at half the
        # SLO: the down-trigger requires BOTH signals quiet
        assert c.step(obs(0.1, 2, queue=200.0)) is None

    def test_cost_ranks_cache_step_over_gpu_when_both_feasible(self):
        # 4 nodes: a GPU step adds 4 x $2.50/hr, a cache doubling adds
        # fractions of a cent — the controller must pick the cheap knob
        # when its predicted utilization is feasible
        c = controller(n_nodes=4, cache=1e9)
        ev = c.step(obs(0.5, 4, queue=400.0))
        assert ev is not None and ev.action == "cache_up"
        assert c.state.cache_bytes_per_node == pytest.approx(2e9)

    def test_gpu_step_chosen_when_cache_cannot_absorb(self):
        # util 1.2: a cache doubling predicts 1.2*(1-0.25) = 0.90 (still
        # over the band) but a second GPU predicts 0.60 — feasibility,
        # not raw price, must decide
        c = controller()
        ev = c.step(obs(1.2, 1))
        assert ev is not None and ev.action == "gpu_up"

    def test_shard_guard_blocks_shard_down(self):
        vetoed = controller(n_shards=3, gpu_knob=False, cache_knob=False,
                            shard_knob=True, guard=lambda: False)
        assert vetoed.step(obs(0.05, 3)) is None
        assert vetoed.state.n_shards == 3 and vetoed.scale_downs == 0
        allowed = controller(n_shards=3, gpu_knob=False, cache_knob=False,
                             shard_knob=True, guard=lambda: True)
        ev = allowed.step(obs(0.05, 3))
        assert ev is not None and ev.action == "shard_down"
        assert allowed.state.n_shards == 2

    def test_min_shards_respects_replication_floor(self):
        c = controller(n_shards=2, gpu_knob=False, cache_knob=False,
                       shard_knob=True, min_shards=2, guard=lambda: True)
        assert c.step(obs(0.05, 2)) is None
        assert c.state.n_shards == 2

    def test_empty_window_holds(self):
        c = controller()
        assert c.step(obs(1.5, 1, requests=0)) is None
        assert c.step(WindowObs(requests=10, span_ms=0.0,
                                busy_ms=100.0)) is None

    def test_summary_keys(self):
        c = controller(n_nodes=2)
        c.step(obs(1.2, 2))
        s = c.summary()
        assert s["scale_up_events"] == 1
        assert s["autoscale_windows"] == 1
        assert s["autoscale_gpus_per_node"] == c.state.gpus_per_node
        assert s["autoscale_cost_per_hr"] > 0.0


class TestCostModel:
    def test_pixel_cache_entry_derived_from_format(self):
        assert pixel_cache_entry_mb("uint8") == pytest.approx(3.145728)
        assert pixel_cache_entry_mb("float32") == pytest.approx(12.582912)
        assert pixel_cache_entry_mb("uint8", height=16, width=16) == \
            pytest.approx(16 * 16 * 3 / 1e6)
        with pytest.raises(ValueError):
            pixel_cache_entry_mb("bfloat16")

    def test_default_params_match_derivation(self):
        # the Table-5 constant is no longer hard-coded lore: the dataclass
        # default must equal the uint8 derivation exactly
        assert CostParams().s_px_cache_mb == pixel_cache_entry_mb("uint8")

    def test_params_for_store_follows_pixel_format(self):
        p8 = params_for_store(StoreConfig(pixel_format="uint8"))
        p32 = params_for_store(StoreConfig(pixel_format="float32"))
        assert p8.s_px_cache_mb == pytest.approx(3.145728)
        assert p32.s_px_cache_mb == pytest.approx(12.582912)
        # everything else untouched
        assert p32.p_s3_gb_mo == CostParams().p_s3_gb_mo

    def test_dollars_per_million_requests(self):
        # one GPU held for one hour serving 1M requests at $2.50/hr
        summ = {"provisioned_gpu_ms": 3.6e6, "decode_gpus": 1}
        assert dollars_per_million_requests(summ, 1_000_000) == \
            pytest.approx(2.50)
        # cache bytes: 1 GB held for one hour at $0.023/GB-month
        summ = {"provisioned_cache_byte_ms": 1e9 * 3.6e6}
        assert dollars_per_million_requests(summ, 1_000_000) == \
            pytest.approx(0.023 / 730.0)
        assert dollars_per_million_requests({}, 0) == 0.0


class TestGpuQueueElasticity:
    def test_busy_ms_accumulates(self):
        q = GpuQueue(2)
        for _ in range(3):
            q.start(0.0, 10.0)
        assert q.busy_ms == pytest.approx(30.0)

    def test_resize_grow_adds_idle_gpus(self):
        q = GpuQueue(2)
        q.start(0.0, 10.0)
        q.resize(4)
        assert q.n_gpus == 4
        assert q.free_at[2] == 0.0 and q.outstanding[3] == 0

    def test_resize_shrink_keeps_every_inflight_decode(self):
        q = GpuQueue(3)
        for k in range(7):
            q.start(float(k), 10.0)
        before = sum(q.outstanding)
        worst_free = max(q.free_at)
        q.resize(1)
        assert q.n_gpus == 1
        assert sum(q.outstanding) == before          # nothing dropped
        assert q._done[0] == sorted(q._done[0])      # release() order holds
        assert q.free_at[0] >= worst_free            # no capacity invented
        q.release(1e9)
        assert sum(q.outstanding) == 0               # all drain normally

    def test_new_work_after_shrink_waits_for_merged_backlog(self):
        q = GpuQueue(2)
        q.start(0.0, 50.0)
        q.start(0.0, 50.0)
        q.resize(1)
        _, start = q.start(0.0, 10.0)
        assert start >= 50.0                         # behind the survivors

    def test_resize_to_zero_rejected(self):
        with pytest.raises(ValueError):
            GpuQueue(2).resize(0)


class TestCacheCapacityHandoff:
    def make(self, capacity=1000.0, alpha=0.5):
        return DualFormatCache(capacity, alpha=alpha, tau=0.1,
                               promote_threshold=3,
                               image_size_fn=lambda _: IMG,
                               latent_size_fn=lambda _: LAT)

    def test_alpha_preserved_across_resize(self):
        c = self.make(alpha=0.7)
        c.set_capacity(500.0)
        assert c.alpha == pytest.approx(0.7)
        assert c.image_tier.capacity == pytest.approx(350.0)
        assert c.latent_tier.capacity == pytest.approx(150.0)

    def test_shrink_evicts_to_fit(self):
        c = self.make()
        for i in range(25):
            c.admit_latent(i)
        c.set_capacity(100.0)
        assert c.latent_tier.resident_bytes <= c.latent_tier.capacity
        c.check_invariants()

    def test_grow_keeps_contents(self):
        c = self.make()
        for i in range(10):
            c.admit_latent(i)
        before = c.latent_tier.resident_bytes
        c.set_capacity(4000.0)
        assert c.latent_tier.resident_bytes == pytest.approx(before)
        c.check_invariants()

    def test_negative_capacity_rejected(self):
        with pytest.raises(ValueError):
            self.make().set_capacity(-1.0)


def _sim_cfg(**kw) -> StoreConfig:
    base = dict(n_nodes=2, cache_bytes_per_node=2e4, image_bytes=768.0,
                latent_bytes=6e2, promote_threshold=10**6,
                tuner=TunerConfig(window=10**9))
    base.update(kw)
    return StoreConfig(**base)


def _fill(box, n_objects):
    for oid in range(n_objects):
        box.put(oid, recipe=Recipe(seed=1000 + oid, height=16, width=16),
                nbytes=600.0)


class TestDisabledIsNoop:
    def test_sim_backend_builds_no_controller(self):
        box = LatentBox.simulated(_sim_cfg())
        assert box.backend.autoscaler is None
        _fill(box, 8)
        box.get_many(list(range(8)))
        s = box.summary()
        # observability is always on ...
        for key in ("gpu_seconds", "decode_gpus", "decode_util",
                    "provisioned_gpu_ms", "provisioned_cache_byte_ms"):
            assert key in s
        # ... but no controller state leaks into a disabled summary
        assert "scale_up_events" not in s

    def test_sharded_cluster_builds_no_controller(self):
        box = LatentBox.simulated(_sim_cfg(), shards=2)
        assert box.backend.autoscaler is None
        for shard in box.backend.shards.values():
            assert shard.backend.autoscaler is None


class TestNoRequestLostAcrossResizes:
    """The tentpole acceptance property: with autoscaling ON, a diurnal
    replay that forces scale-ups AND scale-downs serves every request
    (none lost, every hit class valid) and every object survives."""

    def test_diurnal_replay_full_accounting(self):
        n_objects, n_requests = 24, 1_600
        cfg = _sim_cfg(
            n_nodes=2, autoscale=True,
            autoscale_cfg=AutoscaleConfig(window=32, cooldown_windows=0,
                                          util_high=0.6, util_low=0.2,
                                          max_gpus_per_node=4))
        span_days = n_requests / (50.0 * 86_400.0)
        tr = make_trace("diurnal", n_objects=n_objects,
                        n_requests=n_requests, span_days=span_days, seed=5,
                        period_days=span_days)
        box = LatentBox.simulated(cfg)
        _fill(box, n_objects)
        ts_ms = tr.timestamps * 1e3
        ids = tr.object_ids
        results = []
        for s in range(0, len(ids), 8):
            results += box.get_many(ids[s:s + 8],
                                    timestamps_ms=ts_ms[s:s + 8])
        assert len(results) == n_requests
        assert all(r.hit_class in HIT_CLASSES for r in results)
        assert len(box.backend.log.latency_ms) == n_requests
        for oid in range(n_objects):
            assert box.stat(oid) is not None
        s = box.summary()
        assert s["scale_up_events"] >= 1, "load peak never scaled up"
        assert s["scale_down_events"] >= 1, "trough never scaled down"
        # the live plant is what the controller thinks it is
        assert s["decode_gpus"] == \
            cfg.n_nodes * s["autoscale_gpus_per_node"]

    def test_conformance_with_disabled_twin(self):
        """autoscale=False must be bit-identical to the pre-feature path:
        same classification stream as a config that never heard of the
        controller."""
        ids = make_trace("flash_crowd", n_objects=16, n_requests=320,
                         seed=3).object_ids
        sigs = []
        for enabled in (False, True):
            cfg = _sim_cfg(promote_threshold=2)
            cfg.autoscale = enabled
            if enabled:
                # a controller that can never act: observation plumbing
                # alone must not perturb classification
                cfg.autoscale_cfg = AutoscaleConfig(window=10**9)
            box = LatentBox.simulated(cfg)
            _fill(box, 16)
            sig = []
            for s in range(0, len(ids), 8):
                sig += [(r.hit_class, r.node)
                        for r in box.get_many(ids[s:s + 8])]
            sigs.append(sig)
        assert sigs[0] == sigs[1]


class TestShardKnob:
    def test_controller_drives_add_and_remove_shard(self):
        cfg = _sim_cfg(
            autoscale=True,
            autoscale_cfg=AutoscaleConfig(window=16, cooldown_windows=0,
                                          util_high=0.6, util_low=0.2,
                                          gpu_knob=False, cache_knob=False,
                                          max_shards=4))
        box = LatentBox.simulated(cfg, shards=2)
        cluster = box.backend
        assert cluster.autoscaler is not None
        assert cluster.autoscaler.cfg.shard_knob
        _fill(box, 24)
        rng = np.random.default_rng(0)

        def drive(n, dt_ms, t0):
            t = t0
            for s in range(0, n, 8):
                ids = rng.integers(0, 24, size=8)
                ts = [t + k * dt_ms for k in range(8)]
                box.get_many(ids, timestamps_ms=ts)
                t = ts[-1] + dt_ms
            return t

        # overload: arrivals every 1 ms against 31 ms decodes
        t = drive(160, 1.0, 1.0)
        assert cluster.n_shards > 2, "overload never added a shard"
        assert cluster.autoscaler.scale_ups >= 1
        # idle: arrivals every 2 s -> utilization collapses
        drive(160, 2_000.0, t + 1e6)
        assert cluster.autoscaler.scale_downs >= 1, \
            "idle cluster never removed a shard"
        assert cluster.n_shards < 4 or cluster.autoscaler.scale_ups > 2
        # no object lost across the reshards
        for oid in range(24):
            assert box.stat(oid) is not None
        s = box.summary()
        assert s["autoscale_shards"] == cluster.n_shards

    def test_scale_down_safety_gates(self):
        cfg = _sim_cfg(autoscale=True)
        box = LatentBox.simulated(cfg, shards=3, replication=2)
        cluster = box.backend
        # min_shards pinned to the replication factor
        assert cluster.autoscaler.cfg.min_shards == 2
        assert cluster._scale_down_safe()
        cluster._resharding = True
        assert not cluster._scale_down_safe()
        cluster._resharding = False
        cluster._dead[1] = object()
        assert not cluster._scale_down_safe()


class TestEngineAutoscale:
    def test_engine_controller_scales_on_decode_occupancy(self, tiny_vae):
        clock = [1_000.0]
        cfg = _sim_cfg(
            promote_threshold=10**6, clock=lambda: clock[0],
            autoscale=True,
            autoscale_cfg=AutoscaleConfig(window=8, cooldown_windows=0,
                                          util_high=0.5,
                                          max_gpus_per_node=4))
        box = LatentBox.engine(vae=tiny_vae, config=cfg)
        eng = box.backend.engine
        assert eng.autoscaler is not None
        _fill(box, 8)
        # real decode wall-time against a barely advancing wall clock:
        # utilization saturates, the controller must grow the virtual
        # fleet
        for _ in range(6):
            clock[0] += 1e-3
            box.get_many(list(range(8)))
        s = box.summary()
        assert s["scale_up_events"] >= 1
        assert s["autoscale_gpus_per_node"] > 1
        assert eng.gpus_per_node == s["autoscale_gpus_per_node"]
        assert s["provisioned_gpu_ms"] > 0.0

    def test_engine_disabled_builds_no_controller(self, tiny_vae):
        box = LatentBox.engine(vae=tiny_vae, config=_sim_cfg())
        assert box.backend.engine.autoscaler is None
        s = box.summary()
        assert "decode_util" in s and "scale_up_events" not in s


@pytest.mark.slow
class TestCostHeadline:
    """The benchmark's certified property, locked in as a (slow) test:
    on a diurnal cycle the autoscaled plant is strictly cheaper per
    million requests than a static peak-provisioned plant at equal SLO
    attainment."""

    def test_autoscaled_cheaper_than_static_peak_at_slo(self):
        from repro.trace.synth import TraceConfig
        n_objects, n_requests, slo_ms = 64, 4_800, 250.0
        span_days = n_requests / (80.0 * 86_400.0)
        tcfg = TraceConfig(n_objects=n_objects, n_requests=n_requests,
                           span_days=span_days, zipf_alpha=0.3, seed=11)
        tr = make_trace("diurnal", config=tcfg, period_days=span_days)
        ts_ms = tr.timestamps * 1e3

        def replay(gpus, autoscale):
            cfg = _sim_cfg(
                n_nodes=4, gpus_per_node=gpus, autoscale=autoscale,
                autoscale_cfg=AutoscaleConfig(
                    window=48, cooldown_windows=1, util_high=0.70,
                    cache_gain=0.05, max_gpus_per_node=4)
                if autoscale else None)
            box = LatentBox.simulated(cfg)
            _fill(box, n_objects)
            for s in range(0, len(tr.object_ids), 8):
                box.get_many(tr.object_ids[s:s + 8],
                             timestamps_ms=ts_ms[s:s + 8])
            lat = np.asarray(box.backend.log.latency_ms)
            assert len(lat) == n_requests
            dpm = dollars_per_million_requests(
                box.summary(), n_requests, params=params_for_store(cfg))
            return dpm, float(np.mean(lat <= slo_ms))

        auto_dpm, auto_att = replay(1, True)
        peak_dpm, peak_att = replay(2, False)
        assert auto_dpm < peak_dpm
        assert auto_att >= peak_att - 0.02
