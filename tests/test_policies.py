"""Replacement policies: LRU/S3-FIFO/Belady semantics + the ordering
invariant Belady <= best-online (Fig. 4c's sanity condition)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")   # dev-only dep, see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.policies import (BeladyCache, LRUCache, MixedFormatLRU,
                                 S3FIFOCache, miss_ratio)


def test_lru_classic_sequence():
    c = LRUCache(2)
    assert not c.access(1)
    assert not c.access(2)
    assert c.access(1)
    assert not c.access(3)        # evicts 2
    assert not c.access(2)


def test_s3fifo_one_hit_wonders_dont_pollute_main():
    c = S3FIFOCache(100)
    for i in range(1000):          # scan of one-hit wonders
        c.access(i)
    for i in range(5):             # small working set
        for _ in range(5):
            c.access(10_000 + i)
    hits = sum(c.access(10_000 + i) for i in range(5))
    assert hits == 5


def test_belady_is_lower_bound(rng):
    ids = rng.zipf(1.2, 20_000) % 500
    for cap in (10, 50, 150):
        mr_belady = miss_ratio(BeladyCache(cap), ids)
        mr_lru = miss_ratio(LRUCache(cap), ids)
        mr_s3 = miss_ratio(S3FIFOCache(cap), ids)
        assert mr_belady <= mr_lru + 1e-9
        assert mr_belady <= mr_s3 + 1e-9


def test_belady_optimal_on_known_pattern():
    # cyclic scan of 3 items with capacity 2: LRU thrashes (0 hits),
    # Belady keeps one item resident
    ids = [0, 1, 2] * 50
    assert miss_ratio(LRUCache(2), ids) == 1.0
    assert miss_ratio(BeladyCache(2), list(ids)) < 0.7


def test_mixed_lru_formats():
    m = MixedFormatLRU(1000.0, image_size=100.0, latent_size=20.0,
                       promote_threshold=2)
    m.access(1)
    assert m.format_of(1) == "latent"
    m.access(1)
    m.access(1)                     # second hit -> promote
    assert m.format_of(1) == "image"


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=500),
       st.integers(1, 30))
def test_property_miss_ratio_bounds(ids, cap):
    for pol in (LRUCache(cap), S3FIFOCache(cap)):
        mr = miss_ratio(pol, ids)
        uniq = len(set(ids))
        assert uniq / len(ids) <= mr <= 1.0 + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 20), min_size=5, max_size=300),
       st.integers(2, 10))
def test_property_belady_dominates(ids, cap):
    mr_b = miss_ratio(BeladyCache(cap), list(ids))
    mr_l = miss_ratio(LRUCache(cap), ids)
    assert mr_b <= mr_l + 1e-9
