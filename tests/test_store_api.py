"""LatentBox object-store API: put/get round-trip bit-identity, tier-walk
hit-class accounting, engine-vs-simulator classification parity on a shared
trace, lifecycle ops (delete/stat/demote/promote), the deprecated
``EngineConfig.theta`` alias, the latent store's reorder-stable per-call
latency seeding (incl. the delete->re-put epoch), and hypothesis property
tests of the TierWalk invariants."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.latent_store import LatentStore
from repro.core.regen_tier import Recipe, synthesize_image
from repro.core.tuner import TunerConfig
from repro.store import (FULL_MISS, HIT_CLASSES, IMAGE_HIT, LATENT_HIT,
                         REGEN_MISS, LatentBox, StoreConfig)

# Same dev-only guard class as the PR-1 importorskip pattern, but partial:
# only the property-test class needs hypothesis, so a bare try/except keeps
# the rest of this module running when it is absent (deterministic
# fallbacks below exercise the same check helpers either way).
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_OBJECTS = 12


def small_cfg(**kw):
    # image_bytes is the uint8 nbytes of a decoded 16x16x3 test image:
    # the engine charges the stored array's REAL bytes, so engine/sim
    # parity requires the config estimate to match the truth
    base = dict(n_nodes=2, cache_bytes_per_node=2e4, image_bytes=768.0,
                latent_bytes=6e2, promote_threshold=2,
                tuner=TunerConfig(window=10**9))
    base.update(kw)
    return StoreConfig(**base)


@pytest.fixture(scope="module")
def vae(tiny_vae):
    # alias of conftest's session VAE (identical config): every engine test
    # in the run shares one jitted decode per batch bucket
    return tiny_vae


def fill(box, n=N_OBJECTS, res=16):
    for oid in range(n):
        box.put(oid, recipe=Recipe(seed=1000 + oid, height=res, width=res))


class TestRoundTrip:
    def test_put_get_bit_identical_to_direct_decode(self, vae):
        """put(image) -> get() returns exactly decode(encode(image))
        through the whole facade (compress/store/fetch/batch included)."""
        box = LatentBox.engine(vae=vae, config=small_cfg())
        img = synthesize_image(Recipe(seed=3, height=16, width=16))
        box.put(7, image=img)
        z = np.asarray(vae.encode_mean(jnp.asarray(img)))[0].astype(np.float16)
        direct = np.asarray(vae.decode_u8(jnp.asarray(z, jnp.float32)[None]))[0]
        got = box.get(7)
        assert got.hit_class == FULL_MISS
        np.testing.assert_array_equal(got.payload, direct)
        # repeated reads serve the same bits from warmer tiers
        again = box.get(7)
        assert again.hit_class in (LATENT_HIT, IMAGE_HIT)
        np.testing.assert_array_equal(again.payload, got.payload)

    def test_recipe_only_put_synthesizes(self, vae):
        box = LatentBox.engine(vae=vae, config=small_cfg())
        rec = Recipe(seed=11, height=16, width=16)
        box.put(1, recipe=rec)
        manual = LatentBox.engine(vae=vae, config=small_cfg())
        manual.put(1, image=synthesize_image(rec))
        np.testing.assert_array_equal(box.get(1).payload,
                                      manual.get(1).payload)

    def test_prewarm_makes_first_read_an_image_hit(self, vae):
        box = LatentBox.engine(vae=vae, config=small_cfg())
        box.put(2, recipe=Recipe(seed=5, height=16, width=16), prewarm=True)
        assert box.get(2).hit_class == IMAGE_HIT


class TestHitClassAccounting:
    def test_tier_walk_progression(self, vae):
        """cold -> full miss; warm -> latent hits; past h -> image hit."""
        box = LatentBox.engine(vae=vae, config=small_cfg(promote_threshold=2))
        fill(box, n=1)
        classes = [box.get(0).hit_class for _ in range(4)]
        assert classes[0] == FULL_MISS
        assert classes[1] == LATENT_HIT
        # promotion fired on the h-th latent hit; later reads hit pixels
        assert classes[-1] == IMAGE_HIT

    def test_summary_counts_match_results(self, vae):
        box = LatentBox.engine(vae=vae, config=small_cfg())
        fill(box)
        rng = np.random.default_rng(1)
        ids = (rng.zipf(1.4, 120) % N_OBJECTS).tolist()
        results = []
        for s in range(0, len(ids), 8):
            results += box.get_many(ids[s:s + 8])
        s = box.summary()
        for cls in (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS):
            assert s[cls] == sum(1 for r in results if r.hit_class == cls)
        assert s["total"] == len(ids)

    def test_latency_breakdown_populated(self, vae):
        box = LatentBox.engine(vae=vae, config=small_cfg())
        fill(box, n=1)
        r = box.get(0)
        assert r.latency_ms["fetch"] > 0 and r.latency_ms["decode"] > 0
        assert r.total_ms >= r.latency_ms["decode"]


class TestBackendParity:
    def test_engine_and_sim_classify_identically(self, vae):
        """The acceptance property: both backends of the facade report the
        same hit/miss classification for every request of a shared
        synthetic trace."""
        cfg = small_cfg()
        eng = LatentBox.engine(vae=vae, config=cfg)
        sim = LatentBox.simulated(small_cfg())
        for oid in range(N_OBJECTS):
            rec = Recipe(seed=1000 + oid, height=16, width=16)
            eng.put(oid, recipe=rec)
            sim.put(oid, recipe=rec)
        rng = np.random.default_rng(0)
        ids = (rng.zipf(1.3, 300) % N_OBJECTS).tolist()
        eng_cls, sim_cls = [], []
        for s in range(0, len(ids), 8):
            w = ids[s:s + 8]
            eng_cls += [r.hit_class for r in eng.get_many(w)]
            sim_cls += [r.hit_class for r in sim.get_many(w)]
        assert eng_cls == sim_cls
        # and the aggregate accounting agrees
        es, ss = eng.summary(), sim.summary()
        for cls in (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS):
            assert es[cls] == ss[cls]

    def test_parity_survives_demotion(self, vae):
        cfg = small_cfg()
        eng = LatentBox.engine(vae=vae, config=cfg)
        sim = LatentBox.simulated(small_cfg())
        for box in (eng, sim):
            fill(box, n=4)
            for oid in range(4):
                box.get(oid)
            assert box.demote(2)
        ids = [2, 0, 2, 1, 3, 2]
        ecls = [r.hit_class for r in eng.get_many(ids)]
        scls = [r.hit_class for r in sim.get_many(ids)]
        assert ecls == scls
        assert REGEN_MISS in ecls

    def test_engine_honors_adaptive_false(self, vae):
        """StoreConfig.adaptive=False must disable the tuner on BOTH
        backends (a tuner running on only one side would drift alpha and
        break classification parity)."""
        eng = LatentBox.engine(vae=vae, config=small_cfg(adaptive=False))
        sim = LatentBox.simulated(small_cfg(adaptive=False))
        assert all(t.tuner is None for t in eng.backend.walk.caches)
        assert all(t.tuner is None for t in sim.backend.walk.caches)
        fill(eng, n=2)
        eng.get_many([0, 1, 0, 1])            # no tuner crash on the path
        assert eng.summary()["alpha"] == [0.5, 0.5]

    def test_sim_closed_loop_latencies_are_deterministic(self):
        def replay():
            sim = LatentBox.simulated(small_cfg(
                store_latency=LatentStore().latency))
            fill(sim, n=6)
            rng = np.random.default_rng(3)
            ids = (rng.integers(0, 6, 60)).tolist()
            return [r.total_ms for r in sim.get_many(ids)]
        assert replay() == replay()


class TestLifecycle:
    def test_delete_purges_every_tier(self, vae):
        box = LatentBox.engine(vae=vae, config=small_cfg())
        fill(box, n=2)
        box.get(0), box.get(0)
        assert box.stat(0) is not None
        assert box.delete(0)
        assert box.stat(0) is None and 0 not in box
        with pytest.raises(KeyError):
            box.get(0)

    def test_stat_residency_and_meta(self, vae):
        box = LatentBox.engine(vae=vae, config=small_cfg())
        box.put(5, recipe=Recipe(seed=9, height=16, width=16),
                meta={"model": "demo"})
        st = box.stat(5)
        assert st.residency == ["durable", "recipe"]
        assert st.meta == {"model": "demo"}
        box.get(5)
        assert any(r.startswith("latent@") for r in box.stat(5).residency)

    def test_demote_then_promote_restores_durability(self, vae):
        box = LatentBox.engine(vae=vae, config=small_cfg())
        fill(box, n=1)
        before = box.get(0).payload
        assert box.demote(0)
        assert box.stat(0).demoted
        assert box.promote(0)
        st = box.stat(0)
        assert not st.demoted and "durable" in st.residency
        r = box.get(0)
        assert r.hit_class == FULL_MISS and not r.regenerated
        np.testing.assert_array_equal(r.payload, before)

    def test_demote_without_recipe_refuses(self, vae):
        box = LatentBox.engine(vae=vae, config=small_cfg())
        box.put(3, image=synthesize_image(Recipe(seed=2, height=16,
                                                 width=16)))
        assert not box.demote(3)        # nothing to regenerate from


class TestFailedFetchDoesNotPoison:
    def test_size_only_object_keeps_classifying_full_miss(self, vae):
        """A durable entry whose payload can't materialize (size-only
        registration) must not be admitted to the latent cache by the
        failed read — the next read must classify FULL_MISS again, not a
        phantom LATENT_HIT."""
        from repro.serve.engine import ServingEngine
        store = LatentStore()
        store.put_size(1, 640.0)                 # size, no payload
        eng = ServingEngine(vae, store, small_cfg())
        for _ in range(2):
            with pytest.raises(KeyError, match="durable payload"):
                eng.get(1)
        assert eng.summary()[FULL_MISS] == 2     # never a latent hit
        assert all(1 not in n.cache.latent_tier for n in eng.nodes)


class TestConfigDedup:
    def test_theta_alias_raises(self):
        from repro.serve.engine import EngineConfig
        with pytest.raises(TypeError, match="promote_threshold"):
            EngineConfig(theta=4)

    def test_promote_threshold_drives_spillover_bound(self):
        from repro.serve.engine import EngineConfig
        cfg = EngineConfig(promote_threshold=7)
        assert cfg.store_config(1e3, 1e2).promote_threshold == 7


# -- TierWalk invariants -----------------------------------------------------
# Check helpers shared by the hypothesis property tests and the
# deterministic fallbacks (which keep the invariants exercised in
# environments without the dev-only hypothesis dependency).

def _check_get_resolves_in_exactly_one_tier(requests, demotions):
    """Every get classifies into exactly one hit class, and that class is
    the FIRST tier (walk order) the object was resident in beforehand."""
    box = LatentBox.simulated(small_cfg())
    fill(box)
    for oid in demotions:
        box.demote(oid)
    for oid in requests:
        residency = box.stat(oid).residency       # stat never mutates
        r = box.get(oid)
        assert r.hit_class in HIT_CLASSES
        if any(x.startswith("image@") for x in residency):
            expect = IMAGE_HIT
        elif any(x.startswith("latent@") for x in residency):
            expect = LATENT_HIT
        elif "durable" in residency:
            expect = FULL_MISS
        else:
            assert residency == ["recipe"]
            expect = REGEN_MISS
        assert r.hit_class == expect, (oid, residency, r.hit_class)
    s = box.summary()
    assert s["total"] == len(requests)
    assert sum(s[c] for c in HIT_CLASSES) == s["total"]


def _check_demote_get_roundtrips_bit_exact(vae, oids):
    """demote -> get regenerates bit-exactly what the durable path served."""
    box = LatentBox.engine(vae=vae, config=small_cfg())
    fill(box, n=6)
    baseline = {oid: box.get(oid).payload for oid in oids}
    for oid in oids:
        assert box.demote(oid)
    for oid in oids:
        r = box.get(oid)
        assert r.hit_class == REGEN_MISS and r.regenerated
        np.testing.assert_array_equal(r.payload, baseline[oid])


def _check_delete_then_get_raises(victims, survivors):
    box = LatentBox.simulated(small_cfg())
    fill(box)
    for oid in victims:
        assert box.delete(oid)
        assert box.stat(oid) is None
        with pytest.raises(KeyError):
            box.get(oid)
    for oid in survivors:
        assert box.get(oid).hit_class in HIT_CLASSES


class TestTierWalkInvariantsDeterministic:
    """Fixed-example fallbacks for the property tests below."""

    def test_get_resolves_in_exactly_one_tier(self):
        _check_get_resolves_in_exactly_one_tier(
            requests=[0, 1, 0, 2, 0, 0, 3, 1, 5, 0, 11, 5, 5, 5],
            demotions=[3, 11])

    def test_demote_get_roundtrips_bit_exact(self, vae):
        _check_demote_get_roundtrips_bit_exact(vae, oids=[0, 4])

    def test_delete_then_get_raises(self):
        _check_delete_then_get_raises(victims=[2, 9], survivors=[0, 1, 3])


if HAVE_HYPOTHESIS:
    class TestTierWalkProperties:
        """Hypothesis property tests of the walk invariants (satellite:
        every get resolves in exactly one tier, demote->get round-trips
        bit-exactly, delete->get raises)."""

        @given(requests=st.lists(st.integers(0, N_OBJECTS - 1),
                                 min_size=1, max_size=50),
               demotions=st.lists(st.integers(0, N_OBJECTS - 1),
                                  unique=True, max_size=4))
        @settings(max_examples=25, deadline=None)
        def test_every_get_resolves_in_exactly_one_tier(self, requests,
                                                        demotions):
            _check_get_resolves_in_exactly_one_tier(requests, demotions)

        @given(oids=st.lists(st.integers(0, 5), unique=True,
                             min_size=1, max_size=3))
        @settings(max_examples=6, deadline=None,
                  suppress_health_check=[HealthCheck.function_scoped_fixture])
        def test_demote_get_roundtrips_bit_exact(self, vae, oids):
            _check_demote_get_roundtrips_bit_exact(vae, oids)

        @given(victims=st.lists(st.integers(0, N_OBJECTS - 1), unique=True,
                                min_size=1, max_size=5),
               extra=st.lists(st.integers(0, N_OBJECTS - 1), max_size=8))
        @settings(max_examples=25, deadline=None)
        def test_delete_then_get_raises(self, victims, extra):
            survivors = [o for o in extra if o not in victims]
            _check_delete_then_get_raises(victims, survivors)
else:
    @pytest.mark.skip(reason="hypothesis not installed (dev-only dep, see "
                             "requirements-dev.txt); deterministic "
                             "fallbacks above still ran")
    def test_tier_walk_property_suite_requires_hypothesis():
        pass


class TestClockInjection:
    """Satellite of the durable-store PR: the engine's store-latency
    warmth draws route through ONE injectable clock (StoreConfig.clock /
    EngineConfig.clock) instead of bare ``time.time()``, so latency
    behavior is deterministic under test."""

    def test_engine_fetch_uses_injected_clock(self, vae):
        t = [1_000.0]
        box = LatentBox.engine(vae=vae, config=small_cfg(clock=lambda: t[0]))
        fill(box, 2)
        assert box.get(0).hit_class == FULL_MISS   # durable fetch at t=1000
        assert box.backend.store.stat(0)["last_fetch_s"] == 1_000.0
        # purge cached copies so the next read is another durable fetch
        t[0] = 77_777.0
        for tier in box.backend.engine.walk.caches:
            tier.evict(0)
        assert box.get(0).hit_class == FULL_MISS
        assert box.backend.store.stat(0)["last_fetch_s"] == 77_777.0

    def test_warmth_window_follows_virtual_time(self, vae):
        """Advancing the injected clock past warm_window_s must flip the
        store's warmth classification — pure virtual time, no sleeping."""
        t = [0.0]
        box = LatentBox.engine(vae=vae, config=small_cfg(clock=lambda: t[0]))
        fill(box, 1)
        box.get(0)
        store = box.backend.store
        warm_window = store.latency.warm_window_s
        t[0] = warm_window - 1.0                   # still inside the window
        assert (t[0] - store.stat(0)["last_fetch_s"]) <= warm_window
        t[0] = 10 * warm_window                    # way past it: cold again
        assert (t[0] - store.stat(0)["last_fetch_s"]) > warm_window

    def test_engine_config_clock_passes_through(self):
        from repro.serve.engine import EngineConfig
        calls = []
        cfg = EngineConfig(clock=lambda: calls.append(1) or 42.0)
        sc = cfg.store_config(16e3, 13e3)
        assert sc.now_s() == 42.0 and calls
        assert StoreConfig().now_s() > 0           # default = wall clock


class TestStoreLatencySeeding:
    def test_per_call_seed_is_reorder_stable(self):
        a, b = LatentStore(seed=4), LatentStore(seed=4)
        a.put_size(1, 100), a.put_size(2, 100)
        b.put_size(1, 100), b.put_size(2, 100)
        # same (oid, seq) pairs, opposite global order -> same samples
        a1 = a.fetch_ms(1, 0.0, seq=10)
        a2 = a.fetch_ms(2, 0.0, seq=11)
        b2 = b.fetch_ms(2, 0.0, seq=11)
        b1 = b.fetch_ms(1, 0.0, seq=10)
        assert a1 == b1 and a2 == b2

    def test_shared_stream_is_order_sensitive(self):
        a, b = LatentStore(seed=4), LatentStore(seed=4)
        for st in (a, b):
            st.put_size(1, 100), st.put_size(2, 100)
        x = [a.fetch_ms(1, 0.0), a.fetch_ms(2, 0.0)]
        y = [b.fetch_ms(2, 0.0), b.fetch_ms(1, 0.0)]
        assert x[0] != y[1] or x[1] != y[0]   # shared RNG: order leaks in

    def test_delete_clears_warmth(self):
        st = LatentStore(seed=0)
        st.put(1, b"x" * 64)
        st.fetch_ms(1, 100.0)
        assert st.stat(1)["last_fetch_s"] == 100.0
        st.delete(1)
        assert st.stat(1) is None
        st.put(1, b"x" * 64)
        assert st.stat(1)["last_fetch_s"] == float("-inf")   # cold again

    def test_delete_resets_latency_seed_state(self):
        """A deleted-then-re-put object id is a NEW object: it must draw
        fresh per-call latencies, not replay the dead object's stream."""
        st = LatentStore(seed=4)
        st.put_size(1, 100)
        first_life = st.fetch_ms(1, 0.0, seq=10)
        assert st.stat(1)["epoch"] == 0
        st.delete(1)
        st.put_size(1, 100)
        assert st.stat(1)["epoch"] == 1
        second_life = st.fetch_ms(1, 0.0, seq=10)
        assert second_life != first_life          # fresh epoch stream
        # deleting something else must not perturb object 1's stream
        st.put_size(2, 100)
        st.delete(2)
        assert st.stat(1)["epoch"] == 1

    def test_reorder_stability_survives_reput(self):
        """The reorder-stability contract holds WITHIN each life: two
        stores replaying the same delete/re-put history draw identical
        samples for the same (oid, seq), in either request order."""
        def life(order):
            st = LatentStore(seed=4)
            st.put_size(1, 100), st.put_size(2, 100)
            st.fetch_ms(1, 0.0, seq=0)
            st.delete(1)
            st.put_size(1, 100)                   # second life of oid 1
            out = {}
            for oid, seq in order:
                out[(oid, seq)] = st.fetch_ms(oid, 0.0, seq=seq)
            return out

        a = life([(1, 10), (2, 11)])
        b = life([(2, 11), (1, 10)])              # opposite order
        assert a == b
