"""Tests of the concurrent serving runtime (``repro.serve.runtime``).

Three layers:

* unit — event loop determinism, token buckets, start-time-fair queueing,
  deadline-forced dispatch, admission decisions;
* drain-mode conformance — with QoS/admission off and a drain schedule,
  ``serve_stream`` must classify every request identically to the legacy
  ``serve_window`` path on every scenario x {sim, engine}, engine pixels
  bit-exact (the runtime extension of ``test_shard_conformance.py``);
* QoS/SLO behavior — flash-crowd overload sheds only batch-class work
  while interactive p99 stays within its SLO, weighted-fair dequeue
  protects a trickle tenant from a flooding one.
"""

import math

import numpy as np
import pytest

from conftest import classify, conformance_config, fill_and_demote, make_box
from repro.core.metrics import RequestLog
from repro.serve.runtime import (AdmissionConfig, EventLoop, FairQueue,
                                 Request, RuntimeConfig, ServingRuntime,
                                 SLO_BATCH, SLO_INTERACTIVE, TokenBucket,
                                 requests_from_trace)
from repro.store import LatentBox
from repro.trace.synth import list_scenarios, make_trace

N_OBJECTS = 24
N_REQUESTS = 240
TOTAL_NODES = 8


def scenario_ids(name: str):
    tr = make_trace(name, n_objects=N_OBJECTS, n_requests=N_REQUESTS,
                    span_days=2.0, seed=7)
    return tr.object_ids, tr.timestamps * 1e3


def drain_requests(ids):
    return [Request(oid=int(o), arrival_ms=0.0, seq=k)
            for k, o in enumerate(ids)]


# ---------------------------------------------------------------------------
# unit: event loop
# ---------------------------------------------------------------------------

class TestEventLoop:
    def test_fires_in_time_then_insertion_order(self):
        loop, out = EventLoop(), []
        loop.at(5.0, lambda: out.append("b"))
        loop.at(1.0, lambda: out.append("a"))
        loop.at(5.0, lambda: out.append("c"))      # same instant: FIFO
        assert loop.run() == 5.0
        assert out == ["a", "b", "c"]

    def test_past_events_clamp_to_now(self):
        loop, out = EventLoop(), []

        def schedule_stale():
            loop.at(0.0, lambda: out.append(loop.now))   # in the past

        loop.at(10.0, schedule_stale)
        loop.run()
        assert out == [10.0]                             # never rewinds

    def test_callbacks_can_chain(self):
        loop, out = EventLoop(), []
        loop.at(1.0, lambda: loop.after(2.0, lambda: out.append(loop.now)))
        assert loop.run() == 3.0 and out == [3.0]


# ---------------------------------------------------------------------------
# unit: token bucket + fair queue
# ---------------------------------------------------------------------------

class TestTokenBucket:
    def test_burst_then_rate_limited(self):
        tb = TokenBucket(rate_per_s=10.0, burst=2.0)
        assert tb.try_take(0.0) and tb.try_take(0.0)
        assert not tb.try_take(0.0)                 # burst exhausted
        assert not tb.try_take(50.0)                # 0.5 tokens refilled
        assert tb.try_take(100.0)                   # 1 token at +100ms

    def test_refill_caps_at_burst(self):
        tb = TokenBucket(rate_per_s=1000.0, burst=3.0)
        for _ in range(3):
            assert tb.try_take(0.0)
        assert tb.available(10_000.0) == 3.0

    def test_rejects_nonpositive_params(self):
        with pytest.raises(ValueError):
            TokenBucket(0.0, 1.0)
        with pytest.raises(ValueError):
            TokenBucket(1.0, 0.0)


def _req(seq, tenant=0, slo=SLO_INTERACTIVE, deadline=None):
    return Request(oid=seq, arrival_ms=0.0, seq=seq, tenant=tenant, slo=slo,
                   deadline_ms=deadline)


class TestFairQueue:
    def test_qos_off_is_global_fifo(self):
        q = FairQueue(qos=False)
        for k in range(6):
            q.push(_req(k, tenant=k % 3, slo=(SLO_BATCH, SLO_INTERACTIVE)[k % 2]),
                   now_ms=0.0)
        assert [q.pop().seq for _ in range(6)] == list(range(6))

    def test_sfq_alternates_between_backlogged_tenants(self):
        """10:1 push imbalance, equal weights: dequeue alternates 1:1."""
        q = FairQueue(qos=True)
        seq = 0
        for _ in range(10):
            q.push(_req(seq, tenant=0), 0.0)
            seq += 1
        q.push(_req(100, tenant=1), 0.0)
        q.push(_req(101, tenant=1), 0.0)
        order = [q.pop().tenant for _ in range(4)]
        assert order == [0, 1, 0, 1]

    def test_weights_bias_dequeue_share(self):
        q = FairQueue(qos=True, weights={0: 3.0, 1: 1.0})
        for k in range(12):
            q.push(_req(k, tenant=0), 0.0)
            q.push(_req(100 + k, tenant=1), 0.0)
        first8 = [q.pop().tenant for _ in range(8)]
        assert first8.count(0) == 6 and first8.count(1) == 2

    def test_interactive_band_jumps_batch(self):
        q = FairQueue(qos=True)
        q.push(_req(0, slo=SLO_BATCH), 0.0)
        q.push(_req(1, slo=SLO_BATCH), 0.0)
        q.push(_req(2, slo=SLO_INTERACTIVE), 0.0)
        assert q.pop().seq == 2                     # queue-jump
        assert q.n_queued(SLO_BATCH) == 2

    def test_over_rate_requests_demote_within_band(self):
        q = FairQueue(qos=True, rate_rps=10.0, burst=1.0)
        q.push(_req(0, tenant=0), 0.0)              # takes the burst token
        q.push(_req(1, tenant=0), 0.0)              # over-rate
        q.push(_req(2, tenant=1), 0.0)              # own bucket: conforming
        assert q.n_over_rate == 1
        assert [q.pop().seq for _ in range(3)] == [0, 2, 1]

    def test_earliest_deadline_tracks_queued_only(self):
        q = FairQueue(qos=True)
        q.push(_req(0, deadline=500.0), 0.0)
        q.push(_req(1, deadline=200.0), 0.0)
        assert q.earliest_deadline() == 200.0
        popped = {q.pop().seq, q.pop().seq}
        assert popped == {0, 1}
        assert q.earliest_deadline() == math.inf


# ---------------------------------------------------------------------------
# metrics: RequestLog extensions
# ---------------------------------------------------------------------------

class TestRequestLogSLO:
    def test_legacy_add_signature_still_works(self):
        log = RequestLog()
        log.add(0.0, 12.0, "image_hit", 1.0, 2.0, 3.0, 4.0, False, False, 2)
        s = log.summarize()
        assert s["n"] == 1 and s["p50_ms"] == 12.0
        assert "shed_frac" not in s

    def test_shed_excluded_from_latency_percentiles(self):
        log = RequestLog()
        log.add(0.0, 100.0, "latent_hit", slo="interactive")
        log.add(0.0, 0.0, "shed", slo="batch", deadline_met=False)
        s = log.summarize()
        assert s["p50_ms"] == 100.0                 # shed row masked out
        assert s["shed_frac"] == 0.5

    def test_slo_summary_per_class_and_tenant(self):
        log = RequestLog()
        log.add(0.0, 50.0, "image_hit", slo="interactive", tenant=0,
                queue_delay_ms=5.0, deadline_met=True)
        log.add(0.0, 900.0, "latent_hit", slo="batch", tenant=1,
                queue_delay_ms=700.0, deadline_met=False)
        log.add(0.0, 0.0, "shed", slo="batch", tenant=1, deadline_met=False)
        s = log.slo_summary()
        assert s["interactive.slo_attainment"] == 1.0
        assert s["batch.slo_attainment"] == 0.0
        assert s["batch.shed_frac"] == 0.5
        assert s["tenant1.n"] == 2.0
        assert s["interactive.queue_delay_p99_ms"] == 5.0


# ---------------------------------------------------------------------------
# scheduler behavior (sim backend, virtual clock)
# ---------------------------------------------------------------------------

def _sim_box(**kw):
    box = LatentBox.simulated(conformance_config(TOTAL_NODES, **kw))
    fill_and_demote(box, N_OBJECTS)
    return box


class TestSchedulerDispatch:
    def test_deadline_forces_partial_batch(self):
        """Two early requests + one far-future arrival: the bucket never
        fills, so the earliest deadline must force a partial dispatch long
        before the third request arrives."""
        box = _sim_box()
        reqs = [Request(oid=0, arrival_ms=0.0, seq=0),
                Request(oid=1, arrival_ms=1.0, seq=1),
                Request(oid=2, arrival_ms=60_000.0, seq=2)]
        cfg = RuntimeConfig(qos=True, admission=AdmissionConfig(enabled=False))
        rep = box.serve_stream(reqs, runtime_cfg=cfg)
        assert rep.counters["forced_dispatches"] >= 1
        arr = rep.log.arrays()
        # the two early requests completed within their interactive budget,
        # i.e. dispatched by deadline slack, not by the t=60s arrival
        early = arr["arrival_ms"] < 1000.0
        assert early.sum() == 2
        assert bool(arr["deadline_met"][early].all())
        assert (arr["arrival_ms"] + arr["latency_ms"])[early].max() < 1000.0

    def test_full_bucket_dispatches_without_waiting(self):
        box = _sim_box()
        reqs = [Request(oid=k % N_OBJECTS, arrival_ms=0.0, seq=k)
                for k in range(16)]
        rep = box.serve_stream(
            reqs, runtime_cfg=RuntimeConfig(
                admission=AdmissionConfig(enabled=False)))
        assert rep.counters["full_dispatches"] >= 1
        assert rep.counters["served"] == 16

    def test_stream_makespan_tracks_arrivals(self):
        """Underload: the makespan is set by the last arrival, not by a
        serialized closed-loop replay."""
        box = _sim_box()
        reqs = [Request(oid=k % N_OBJECTS, arrival_ms=400.0 * k, seq=k)
                for k in range(40)]
        rep = box.serve_stream(
            reqs, runtime_cfg=RuntimeConfig(
                admission=AdmissionConfig(enabled=False)))
        assert rep.counters["served"] == 40
        assert rep.makespan_ms < 400.0 * 40 + 10_000.0


def _crowd_box():
    """Overload fixture: promotion disabled so every request keeps paying
    a decode (the plant saturates) and nothing is demoted (no 3.9 s regens
    that would block the server regardless of scheduling)."""
    box = LatentBox.simulated(
        conformance_config(TOTAL_NODES, promote_threshold=10**6))
    fill_and_demote(box, N_OBJECTS, demote=())
    return box


class TestAdmissionAndQoS:
    def _crowd(self, spacing_ms=2.0, n=600, interactive_every=8):
        """Flash-crowd-style overload stream: arrivals ~5x above decode
        capacity, 1-in-``interactive_every`` requests interactive."""
        ids, _ = scenario_ids("flash_crowd")
        reqs = []
        for k in range(n):
            slo = SLO_INTERACTIVE if k % interactive_every == 0 else SLO_BATCH
            reqs.append(Request(oid=int(ids[k % len(ids)]),
                                arrival_ms=spacing_ms * k, seq=k,
                                tenant=k % 3, slo=slo))
        return reqs

    def test_flash_crowd_shedding_confines_damage_to_batch(self):
        box = _crowd_box()
        cfg = RuntimeConfig(qos=True, admission=AdmissionConfig(
            enabled=True, policy="shed"))
        reqs = self._crowd()
        rep = box.serve_stream(reqs, runtime_cfg=cfg)
        s = rep.summary()
        assert rep.counters["shed"] > 0
        # shed/degraded outcomes only ever land on batch-class requests
        for r in reqs:
            outcome = rep.outcomes[r.seq][0]
            if outcome in ("shed", "degraded"):
                assert r.slo == SLO_BATCH
        # interactive tail holds its SLO under overload
        assert s["interactive.p99_ms"] <= cfg.interactive_deadline_ms
        assert s["interactive.slo_attainment"] >= 0.95

    def test_runtime_off_lets_interactive_tail_collapse(self):
        """A-B of the whole stack under the same overload: QoS + shed
        admission vs plain FIFO with admission disabled."""
        on = _crowd_box().serve_stream(
            self._crowd(), runtime_cfg=RuntimeConfig(
                qos=True,
                admission=AdmissionConfig(enabled=True, policy="shed")))
        off = _crowd_box().serve_stream(
            self._crowd(), runtime_cfg=RuntimeConfig(
                qos=False, admission=AdmissionConfig(enabled=False)))
        assert off.counters["shed"] == 0
        s_on, s_off = on.summary(), off.summary()
        assert s_on["interactive.slo_attainment"] >= 0.95
        assert s_off["interactive.slo_attainment"] < 0.5
        assert s_off["interactive.p99_ms"] > 5 * s_on["interactive.p99_ms"]

    def test_shedding_cuts_the_tail_even_under_fifo(self):
        """Admission's direct effect, isolated from QoS queue-jumping:
        with a FIFO queue, shedding batch work still halves the backlog
        every class waits in."""
        shed = _crowd_box().serve_stream(
            self._crowd(), runtime_cfg=RuntimeConfig(
                qos=False,
                admission=AdmissionConfig(enabled=True, policy="shed")))
        noshed = _crowd_box().serve_stream(
            self._crowd(), runtime_cfg=RuntimeConfig(
                qos=False, admission=AdmissionConfig(enabled=False)))
        assert shed.counters["shed"] > 0
        assert shed.summary()["interactive.p99_ms"] < \
            noshed.summary()["interactive.p99_ms"]

    def test_degrade_serves_stale_pixels_from_cache(self):
        """Under overload, batch requests for pixel-resident objects
        degrade (immediate stale answer, deadline met) instead of
        shedding."""
        from repro.core.regen_tier import Recipe
        box = LatentBox.simulated(
            conformance_config(TOTAL_NODES, promote_threshold=10**6))
        for oid in range(N_OBJECTS):
            # the batch-class half of the id space is pixel-resident
            box.put(oid, recipe=Recipe(seed=1000 + oid, height=16, width=16),
                    prewarm=oid < 12)
        assert box.pixels_resident(0) and not box.pixels_resident(12)
        # interactive flood on never-promoted ids saturates the plant;
        # batch requests target the prewarmed half
        reqs = []
        for k in range(600):
            if k % 2 == 0:
                reqs.append(Request(oid=12 + (k // 2) % 12,
                                    arrival_ms=2.0 * k, seq=k,
                                    slo=SLO_INTERACTIVE))
            else:
                reqs.append(Request(oid=(k // 2) % 12, arrival_ms=2.0 * k,
                                    seq=k, slo=SLO_BATCH))
        rep = box.serve_stream(reqs, runtime_cfg=RuntimeConfig(
            qos=True,
            admission=AdmissionConfig(enabled=True, policy="degrade")))
        assert rep.counters["degraded"] > 0
        assert rep.counters["shed"] == 0        # every candidate resident
        arr = rep.log.arrays()
        degraded = arr["outcome"] == 5
        assert bool(arr["deadline_met"][degraded].all())

    def test_defer_parks_batch_work_but_loses_nothing(self):
        box = _crowd_box()
        rep = box.serve_stream(
            self._crowd(), runtime_cfg=RuntimeConfig(
                qos=True,
                admission=AdmissionConfig(enabled=True, policy="defer")))
        assert rep.counters["deferred"] > 0
        assert rep.counters["shed"] == 0
        # every request eventually served with a real hit class
        assert rep.counters["served"] == 600
        assert all(o[0] not in ("shed", "degraded", "") for o in rep.outcomes)

    def test_fair_queue_protects_trickle_tenant(self):
        """Tenant 0 floods, tenant 1 trickles: with QoS the trickle
        tenant's p99 must improve vs the FIFO baseline."""
        def stream():
            reqs, seq = [], 0
            for k in range(400):                # flood: every 2ms
                reqs.append(Request(oid=k % N_OBJECTS, arrival_ms=2.0 * k,
                                    seq=seq, tenant=0))
                seq += 1
            for k in range(20):                 # trickle: every 40ms
                reqs.append(Request(oid=(k * 7) % N_OBJECTS,
                                    arrival_ms=40.0 * k, seq=seq, tenant=1))
                seq += 1
            return reqs

        adm = AdmissionConfig(enabled=False)
        rep_qos = _sim_box().serve_stream(
            stream(), runtime_cfg=RuntimeConfig(qos=True, admission=adm))
        rep_fifo = _sim_box().serve_stream(
            stream(), runtime_cfg=RuntimeConfig(qos=False, admission=adm))
        p99_qos = rep_qos.summary()["tenant1.p99_ms"]
        p99_fifo = rep_fifo.summary()["tenant1.p99_ms"]
        assert p99_qos < p99_fifo

    def test_requests_from_trace_carries_tenants_and_slos(self):
        tr = make_trace("multi_tenant", n_objects=N_OBJECTS,
                        n_requests=N_REQUESTS, span_days=2.0, seed=7)
        reqs = requests_from_trace(tr)
        assert {r.tenant for r in reqs} == set(
            int(t) for t in np.unique(tr.model_ids))
        for r in reqs:
            want = SLO_BATCH if tr.slo_class[r.oid] else SLO_INTERACTIVE
            assert r.slo == want
            assert r.tenant == int(tr.model_ids[r.oid])


# ---------------------------------------------------------------------------
# drain-mode conformance: serve_stream == serve_window, all scenarios
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", list_scenarios())
class TestDrainConformanceSim:
    def test_stream_classifies_like_window(self, scenario):
        ids, _ = scenario_ids(scenario)
        legacy_box = make_box("sim", 1, TOTAL_NODES)
        fill_and_demote(legacy_box, N_OBJECTS)
        legacy = classify(legacy_box, ids, window=8)

        stream_box = make_box("sim", 1, TOTAL_NODES)
        fill_and_demote(stream_box, N_OBJECTS)
        rep = stream_box.serve_stream(drain_requests(ids),
                                      runtime_cfg=RuntimeConfig.conformance())
        assert rep.outcomes == legacy
        assert rep.counters["shed"] == 0 and rep.counters["degraded"] == 0


def _engine_legacy(ids, vae):
    """Legacy window path on the engine: signature + per-request pixels."""
    box = make_box("engine", 1, TOTAL_NODES, vae=vae)
    fill_and_demote(box, N_OBJECTS)
    sig, pixels = [], []
    oids = [int(i) for i in ids]
    for s in range(0, len(oids), 8):
        for r in box.get_many(oids[s:s + 8]):
            sig.append((r.hit_class, r.node))
            pixels.append(r.payload)
    return sig, pixels


def _engine_stream(ids, vae):
    box = make_box("engine", 1, TOTAL_NODES, vae=vae)
    fill_and_demote(box, N_OBJECTS)
    rep = box.serve_stream(
        drain_requests(ids),
        runtime_cfg=RuntimeConfig.conformance(keep_payloads=True))
    return rep


class TestDrainConformanceEngineSmoke:
    """Push-CI engine cell: one scenario, classification + bit-exact pixels."""

    def test_stream_matches_window_bit_exact(self, tiny_vae):
        ids, _ = scenario_ids("flash_crowd")
        legacy_sig, legacy_px = _engine_legacy(ids, tiny_vae)
        rep = _engine_stream(ids, tiny_vae)
        assert rep.outcomes == legacy_sig
        assert len(rep.payloads) == len(ids)
        for k, px in enumerate(legacy_px):
            np.testing.assert_array_equal(rep.payloads[k], px)


@pytest.mark.slow
@pytest.mark.parametrize("scenario", list_scenarios())
class TestDrainConformanceEngineFull:
    """Scheduled-CI matrix: every scenario on the engine, pixels bit-exact."""

    def test_stream_matches_window_bit_exact(self, scenario, tiny_vae):
        ids, _ = scenario_ids(scenario)
        legacy_sig, legacy_px = _engine_legacy(ids, tiny_vae)
        rep = _engine_stream(ids, tiny_vae)
        assert rep.outcomes == legacy_sig
        for k, px in enumerate(legacy_px):
            np.testing.assert_array_equal(rep.payloads[k], px)


# ---------------------------------------------------------------------------
# facade / sharded surface
# ---------------------------------------------------------------------------

class TestStreamSurface:
    def test_sharded_drain_conformance(self):
        ids, _ = scenario_ids("multi_tenant")
        ref_box = make_box("sim", 1, TOTAL_NODES)
        fill_and_demote(ref_box, N_OBJECTS)
        ref = classify(ref_box, ids, window=8)

        sharded = make_box("sim", 4, TOTAL_NODES)
        fill_and_demote(sharded, N_OBJECTS)
        rep = sharded.serve_stream(drain_requests(ids),
                                   runtime_cfg=RuntimeConfig.conformance())
        assert rep.outcomes == ref

    def test_serve_stream_accepts_a_trace(self):
        box = _sim_box()
        tr = make_trace("multi_tenant", n_objects=N_OBJECTS,
                        n_requests=80, span_days=2.0, seed=7,
                        load_factor=1e6)       # compress 2 days into ~0.2s
        rep = box.serve_stream(tr)
        assert len(rep.outcomes) == 80
        assert rep.counters["served"] + rep.counters["shed"] \
            + rep.counters["degraded"] == 80

    def test_engine_paced_stream_serves_real_pixels(self, tiny_vae):
        box = make_box("engine", 1, TOTAL_NODES, vae=tiny_vae)
        fill_and_demote(box, N_OBJECTS)
        reqs = [Request(oid=k % N_OBJECTS, arrival_ms=30.0 * k, seq=k)
                for k in range(40)]
        rep = box.serve_stream(
            reqs, runtime_cfg=RuntimeConfig(
                keep_payloads=True,
                admission=AdmissionConfig(enabled=False)))
        assert rep.counters["served"] == 40
        assert all(rep.payloads[k].shape[-1] == 3 for k in rep.payloads)
