"""Marginal-hit tuner (paper §4.3): gradient sign algebra, EWMA feedback,
convergence toward the better tier on synthetic workloads, and
re-convergence after the Zipf-drift scenario's popularity flip."""

import numpy as np
import pytest

from repro.core.dual_cache import DualFormatCache, WindowStats
from repro.core.replay import ReplayConfig, replay, replay_scenario
from repro.core.tuner import Ewma, MarginalHitTuner, TunerConfig


def stats(total=1000, img_miss=400, full_miss=100, img_tail=20, lat_tail=10):
    s = WindowStats()
    s.total_requests = total
    s.image_misses = img_miss
    s.image_hits = total - img_miss
    s.full_misses = full_miss
    s.latent_hits = img_miss - full_miss
    s.image_tail_hits = img_tail
    s.latent_tail_hits = lat_tail
    return s


class TestGradient:
    def test_eq2_value(self):
        s = stats()
        d = MarginalHitTuner.gradient(s, t_decode=40.0, t_fetch=140.0)
        mr_lat = 100 / 400
        expect = -(20 / 1000) * (40 + 140 * mr_lat) + 140 * (400 / 1000) \
            * (10 / 400)
        assert d == pytest.approx(expect)

    def test_sign_moves_alpha_toward_image_tier(self):
        cache = DualFormatCache(1000.0, alpha=0.5)
        tuner = MarginalHitTuner(cache, TunerConfig(window=10, step=0.05))
        # image tail hits dominate -> D < 0 -> alpha up
        cache.stats = stats(img_tail=100, lat_tail=0)
        rec = tuner.end_window()
        assert rec.gradient < 0 and cache.alpha == pytest.approx(0.55)

    def test_sign_moves_alpha_toward_latent_tier(self):
        cache = DualFormatCache(1000.0, alpha=0.5)
        tuner = MarginalHitTuner(cache, TunerConfig(window=10, step=0.05))
        cache.stats = stats(img_tail=0, lat_tail=200)
        rec = tuner.end_window()
        assert rec.gradient > 0 and cache.alpha == pytest.approx(0.45)

    def test_alpha_clamped(self):
        cache = DualFormatCache(1000.0, alpha=0.99)
        tuner = MarginalHitTuner(cache, TunerConfig(window=10, step=0.05,
                                                    alpha_max=1.0))
        cache.stats = stats(img_tail=100, lat_tail=0)
        tuner.end_window()
        assert cache.alpha <= 1.0

    def test_expected_latency_eq1(self):
        s = stats()
        e = MarginalHitTuner.expected_latency_ms(s, 40.0, 140.0)
        mr_i, mr_l = 0.4, 0.25
        assert e == pytest.approx(mr_i * ((1 - mr_l) * 40 + mr_l * 180))


class TestEwma:
    def test_cold_start_then_tracks(self):
        e = Ewma(40.0, beta=0.5)
        assert e.value == 40.0
        e.update(100.0)
        assert e.value == 100.0            # first sample replaces default
        e.update(0.0)
        assert e.value == 50.0

    def test_feedback_loop_raises_alpha_when_decode_expensive(self):
        """Paper Fig. 6: GPU overload -> T_decode up -> alpha pushed up."""
        cache = DualFormatCache(1000.0, alpha=0.5)
        tuner = MarginalHitTuner(cache, TunerConfig(window=10, step=0.01))
        cache.stats = stats(img_tail=30, lat_tail=30)
        for _ in range(50):
            tuner.observe_decode_ms(500.0)     # overloaded GPU
        rec = tuner.end_window()
        assert rec.gradient < 0                # image tier favored


class TestZipfDriftReconvergence:
    """Regression: under the drift scenario's mid-trace popularity flip
    (phase-2 hot set = phase-1 cold set) the tuner must absorb the
    perturbation and return alpha to its pre-flip operating point —
    a tuner that latches onto stale per-object state would diverge."""

    N_OBJ = 1_500
    KNOBS = dict(n_objects=N_OBJ, n_requests=400_000, span_days=10, seed=0)

    def _drift_cfg(self, **kw):
        base = dict(cache_bytes=self.N_OBJ * 1.4e6 * 0.3,
                    image_bytes=1.4e6, latent_bytes=0.28e6, adaptive=True,
                    tuner=TunerConfig(window=4_000, step=0.03))
        base.update(kw)
        return ReplayConfig(**base)

    def test_alpha_reconverges_after_flip(self):
        res = replay_scenario("zipf_drift", self._drift_cfg(), **self.KNOBS)
        wa, wm = res.window_alpha, res.window_mean_ms
        half = len(wa) // 2                       # the flip window
        pre_alpha = wa[half - 10:half].mean()
        pre_ms = wm[half - 5:half].mean()
        # the flip visibly perturbs the plant (miss spike on the new hot set)
        assert wm[half:half + 3].max() > 1.2 * pre_ms
        # ... and the tuner walks alpha back to the same operating point
        post_alpha = wa[-10:].mean()
        assert post_alpha == pytest.approx(pre_alpha, abs=0.06)
        # ... restoring the pre-flip latency level
        assert wm[-5:].mean() <= 1.1 * pre_ms
        # the equilibrium is interior, not a clamp artifact
        assert 0.1 < post_alpha < 0.9

    def test_adaptive_tracks_drift_better_than_worst_static(self):
        ad = replay_scenario("zipf_drift", self._drift_cfg(), **self.KNOBS)
        worst = max(
            replay_scenario("zipf_drift",
                            self._drift_cfg(alpha0=a, adaptive=False),
                            **self.KNOBS).mean_ms
            for a in (0.1, 0.9))
        assert ad.mean_ms <= worst * 1.05


class TestExternalCapacityResize:
    """The autoscaler's capacity-handoff contract (``set_capacity``): the
    controller owns the cache's TOTAL bytes, the marginal-hit tuner keeps
    sole ownership of the alpha split — so an external resize must
    preserve alpha, evict through the normal tail path, and leave the
    tuner's gradient walk fully functional."""

    IMG, LAT = 100.0, 20.0

    def make(self, capacity=4000.0, alpha=0.5):
        return DualFormatCache(capacity, alpha=alpha, tau=0.1,
                               promote_threshold=3,
                               image_size_fn=lambda _: self.IMG,
                               latent_size_fn=lambda _: self.LAT)

    def test_alpha_preserved_and_split_rescaled(self):
        c = self.make(alpha=0.7)
        c.set_capacity(1000.0)
        assert c.alpha == pytest.approx(0.7)
        assert c.image_tier.capacity == pytest.approx(700.0)
        assert c.latent_tier.capacity == pytest.approx(300.0)

    def test_shrink_evicts_with_invariants(self):
        c = self.make()
        for i in range(100):
            c.admit_latent(i)
        c.set_capacity(400.0)
        assert c.latent_tier.resident_bytes <= c.latent_tier.capacity
        assert c.image_tier.resident_bytes <= c.image_tier.capacity
        c.check_invariants()

    def test_tuner_keeps_stepping_after_resize(self):
        c = self.make(alpha=0.5)
        tuner = MarginalHitTuner(c, TunerConfig(window=10, step=0.05))
        c.stats = stats(img_tail=100, lat_tail=0)
        tuner.end_window()
        assert c.alpha == pytest.approx(0.55)
        c.set_capacity(1000.0)                    # external shrink
        assert c.alpha == pytest.approx(0.55)     # alpha untouched
        c.stats = stats(img_tail=100, lat_tail=0)
        rec = tuner.end_window()
        # the gradient walk continues from the preserved operating point
        assert rec.gradient < 0 and c.alpha == pytest.approx(0.60)
        assert c.image_tier.capacity == pytest.approx(600.0)
        c.check_invariants()

    def test_alpha_stays_clamped_after_resize(self):
        c = self.make(alpha=0.98)
        tuner = MarginalHitTuner(c, TunerConfig(window=10, step=0.05,
                                                alpha_max=1.0))
        c.set_capacity(500.0)
        c.stats = stats(img_tail=100, lat_tail=0)
        tuner.end_window()
        assert 0.0 <= c.alpha <= 1.0

    def test_reconverges_after_capacity_step(self):
        """A mid-run halving of total bytes must not strand alpha: under
        an unchanged latent-favoring signal the tuner walks back to the
        same clamp-free equilibrium side it held before the resize."""
        c = self.make(alpha=0.5)
        tuner = MarginalHitTuner(c, TunerConfig(window=10, step=0.05,
                                                alpha_min=0.1))
        for _ in range(6):
            c.stats = stats(img_tail=0, lat_tail=200)
            tuner.end_window()
        pre = c.alpha
        c.set_capacity(2000.0)
        for _ in range(6):
            c.stats = stats(img_tail=0, lat_tail=200)
            tuner.end_window()
        assert c.alpha <= pre                     # kept moving latent-ward
        assert c.alpha >= 0.1 - 1e-9              # ... inside the clamp
        c.check_invariants()


class TestEndToEndAdaptation:
    def test_adaptive_beats_or_matches_worst_static(self):
        rng = np.random.default_rng(0)
        ids = rng.zipf(1.3, 60_000) % 2_000
        base = dict(cache_bytes=2_000 * 1.4e6 * 0.05, image_bytes=1.4e6,
                    latent_bytes=0.28e6)
        ad = replay(ids, ReplayConfig(**base, adaptive=True,
                                      tuner=TunerConfig(window=5_000,
                                                        step=0.02)))
        worst = max(
            replay(ids, ReplayConfig(**base, alpha0=a, adaptive=False)
                   ).mean_ms
            for a in (0.1, 0.9))
        assert ad.mean_ms <= worst * 1.05
