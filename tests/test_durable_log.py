"""Unit tests of the log-structured durable store mechanics.

Covers the record format (checksums, torn-tail scan), the SegmentLog
(index, roll/seal, manifest checkpointing, lsn-preserving compaction,
tombstone persistence, recipe-state journaling, segment shipping) and the
Compactor policy.  Crash/recovery *properties* — kill mid-write and
mid-compaction, then reopen — live in ``test_durable_recovery.py``.
"""

import os

import pytest

from repro.store.durable import (BLOB, Compactor, MemoryBackend, SegmentLog,
                                 SegmentLogBackend, SIZE, TOMB, pack_record,
                                 scan_records)
from repro.store.durable.log import NS_OBJECT


def make_log(tmp_path, **kw):
    kw.setdefault("segment_bytes", 512)       # tiny segments: force rolls
    kw.setdefault("checkpoint_every", 10**9)  # manifests only when asked
    return SegmentLog(str(tmp_path / "log"), **kw)


class TestRecordFormat:
    def test_roundtrip(self):
        raw = pack_record(7, BLOB, 42, b"payload-bytes")
        recs, end = scan_records(raw)
        assert end == len(raw)
        (r,) = recs
        assert (r.lsn, r.kind, r.oid, r.payload) == (7, BLOB, 42,
                                                     b"payload-bytes")

    def test_scan_stops_at_corrupt_record(self):
        a = pack_record(1, BLOB, 1, b"aaaa")
        b = bytearray(pack_record(2, BLOB, 2, b"bbbb"))
        b[-1] ^= 0xFF                        # flip one payload byte
        recs, end = scan_records(bytes(a + b))
        assert [r.lsn for r in recs] == [1]
        assert end == len(a)

    def test_scan_stops_at_truncated_tail(self):
        a = pack_record(1, SIZE, 1, b"12345678")
        b = pack_record(2, BLOB, 2, b"x" * 100)
        for cut in (1, 10, len(b) - 1):
            recs, end = scan_records((a + b)[:len(a) + cut])
            assert [r.lsn for r in recs] == [1]
            assert end == len(a)

    def test_scan_rejects_wrong_magic(self):
        recs, end = scan_records(b"NOPE" + b"\0" * 60)
        assert recs == [] and end == 0


class TestSegmentLog:
    def test_blob_roundtrip_and_index(self, tmp_path):
        log = make_log(tmp_path)
        log.put_blob(1, b"one")
        log.put_size(2, 999.0)
        assert log.get_blob(1) == b"one"
        assert log.get_blob(2) is None       # size-only: no payload
        assert log.size_of(1) == 3.0 and log.size_of(2) == 999.0
        assert sorted(log.object_oids()) == [1, 2]
        log.close()

    def test_overwrite_supersedes_by_lsn(self, tmp_path):
        log = make_log(tmp_path)
        log.put_blob(1, b"old")
        log.put_blob(1, b"new")
        assert log.get_blob(1) == b"new"
        assert log.live_bytes < log.on_disk_bytes   # dead record counted
        log.close()

    def test_tombstone_hides_and_survives(self, tmp_path):
        log = make_log(tmp_path)
        log.put_blob(1, b"x")
        log.tombstone(1)
        assert not log.contains_object(1)
        log.close()
        log2 = SegmentLog(str(tmp_path / "log"))
        assert not log2.contains_object(1)
        log2.close()

    def test_segments_roll_and_seal(self, tmp_path):
        log = make_log(tmp_path, segment_bytes=256)
        for oid in range(20):
            log.put_blob(oid, bytes(64))
        assert len(log._seg_len) > 1
        for oid in range(20):
            assert log.get_blob(oid) == bytes(64)
        log.close()

    def test_reopen_without_manifest_full_scan(self, tmp_path):
        log = make_log(tmp_path)
        for oid in range(8):
            log.put_blob(oid, bytes([oid]) * 10)
        log.close()
        os.remove(os.path.join(log.path, "MANIFEST.json"))
        log2 = SegmentLog(log.path)
        assert not log2.recovery_stats["from_manifest"]
        for oid in range(8):
            assert log2.get_blob(oid) == bytes([oid]) * 10
        log2.close()

    def test_reopen_with_manifest_scans_nothing(self, tmp_path):
        log = make_log(tmp_path)
        for oid in range(8):
            log.put_blob(oid, b"v")
        log.close()
        log2 = SegmentLog(log.path)
        st = log2.recovery_stats
        assert st["from_manifest"] and st["scanned_records"] == 0
        log2.close()

    def test_stale_manifest_discarded(self, tmp_path):
        """A manifest referencing a compacted-away segment must be
        ignored in favor of a full scan."""
        log = make_log(tmp_path, segment_bytes=128)
        for oid in range(10):
            log.put_blob(oid, bytes(40))
        log.write_manifest()
        # supersede everything, then compact the cold segments
        for oid in range(10):
            log.put_blob(oid, bytes([oid]) * 40)
        log.flush()
        Compactor(log, live_frac_threshold=1.0).compact_all()
        # roll back to the pre-compaction manifest
        stale = os.path.join(log.path, "MANIFEST.json")
        log.close()
        manifest_now = open(stale).read()
        log2 = SegmentLog(log.path)
        for oid in range(10):
            assert log2.get_blob(oid) == bytes([oid]) * 40
        log2.close()
        assert manifest_now       # sanity: manifest existed through it all

    def test_compaction_preserves_lsn_order(self, tmp_path):
        """A compacted copy of an OLD record must never shadow a NEWER
        record living in another segment (replay is by lsn, not file
        order)."""
        log = make_log(tmp_path, segment_bytes=128)
        log.put_blob(1, b"a" * 60)           # seg A
        log.put_blob(2, b"filler" * 12)      # forces roll eventually
        log.put_blob(1, b"b" * 60)           # newer version, later seg
        log.flush()
        sealed = [s for s in log.sealed_segments()]
        for sid in sealed:
            log.compact_segment(sid)
        assert log.get_blob(1) == b"b" * 60
        log.close()
        log2 = SegmentLog(log.path)
        assert log2.get_blob(1) == b"b" * 60
        log2.close()

    def test_compaction_reclaims_dead_bytes(self, tmp_path):
        """A sealed segment holding both live and superseded records:
        compaction must drop the dead one, carry the live ones (rewrite
        bytes show up in write amplification), and shrink the disk."""
        log = make_log(tmp_path, segment_bytes=256)
        for oid in (0, 1, 2):
            log.put_blob(oid, bytes(50))
        log.put_blob(0, bytes(51))           # supersedes 0 within the seg
        log.put_blob(9, bytes(50))           # rolls: first seg seals
        log.flush()
        assert log.sealed_segments()
        before = log.on_disk_bytes
        n = Compactor(log, live_frac_threshold=1.0).compact_all()
        assert n >= 1
        assert log.on_disk_bytes < before
        assert log.get_blob(0) == bytes(51)
        for oid in (1, 2, 9):
            assert log.get_blob(oid) == bytes(50)
        assert log.write_amplification > 1.0
        log.close()

    def test_recipe_state_journal(self, tmp_path):
        log = make_log(tmp_path)
        log.put_recipe_state(5, {"recipe": {"seed": 5}, "recipe_nbytes": 44.0,
                                 "latent_bytes": None,
                                 "last_access_mo": 2.0})
        log.close()
        log2 = SegmentLog(log.path)
        states = log2.recipe_states()
        assert states[5]["latent_bytes"] is None
        assert states[5]["recipe"]["seed"] == 5
        log2.delete_recipe(5)
        log2.close()
        log3 = SegmentLog(log.path)
        assert log3.recipe_states() == {}
        log3.close()

    def test_export_ingest_ships_raw_records(self, tmp_path):
        src = SegmentLog(str(tmp_path / "src"))
        dst = SegmentLog(str(tmp_path / "dst"))
        src.put_blob(1, b"blob-one")
        src.put_size(2, 123.0)
        src.put_recipe_state(1, {"recipe": None, "recipe_nbytes": 9.0,
                                 "latent_bytes": 8.0, "last_access_mo": 0.0})
        n_segs_before = len(dst._seg_len)
        applied = dst.ingest_segment(src.export_records([1, 2]))
        assert sorted(applied["objects"]) == [1, 2]
        assert applied["recipes"][1]["recipe_nbytes"] == 9.0
        # one fresh sealed segment, not per-key appends into the active
        assert len(dst._seg_len) == n_segs_before + 1
        assert dst.get_blob(1) == b"blob-one"
        assert dst.size_of(2) == 123.0
        src.close(), dst.close()

    def test_ingest_rejects_torn_batch(self, tmp_path):
        dst = SegmentLog(str(tmp_path / "dst"))
        raw = pack_record(1, BLOB, 1, b"ok") + b"LBS1garbage"
        with pytest.raises(ValueError, match="nothing applied"):
            dst.ingest_segment(raw)
        # validate-before-apply: the good leading record must NOT land
        assert not dst.contains_object(1)
        dst.close()

    def test_read_handles_closed_segment_compacted(self, tmp_path):
        log = make_log(tmp_path, segment_bytes=64)
        log.put_blob(1, bytes(40))
        assert log.get_blob(1) == bytes(40)   # opens a read handle
        log.put_blob(1, bytes(41))            # rolls; old seg now dead
        log.flush()
        for sid in list(log.sealed_segments()):
            log.compact_segment(sid)
        assert log.get_blob(1) == bytes(41)
        log.close()


class TestBackends:
    def test_memory_backend_matches_old_semantics(self):
        b = MemoryBackend()
        b.put_blob(1, b"abc")
        b.put_size(2, 10.0)
        assert b.contains(1) and b.contains(2)
        assert b.total_bytes == 13.0
        assert b.delete(1) and not b.delete(1)
        assert b.maybe_compact() == 0
        b.flush(), b.close()                  # durability hooks are no-ops

    def test_segment_backend_ack_contract(self, tmp_path):
        """flush_each_put=True: a put is on disk (readable by a cold
        reopen of the same directory) the moment it returns."""
        b = SegmentLogBackend.open(str(tmp_path / "d"), flush_each_put=True)
        b.put_blob(1, b"abc")
        b.put_size(2, 55.0)
        b.delete(2)
        # reopen the directory cold, as a crashed-and-restarted process
        # would (read-only view; the writer is still live, test-only)
        reopened = SegmentLog(str(tmp_path / "d"))
        assert reopened.get_blob(1) == b"abc"
        assert not reopened.contains_object(2)
        reopened.close()
        b.close()

    def test_segment_backend_write_behind_defers_ack(self, tmp_path):
        """flush_each_put=False: puts buffer until flush() — a cold
        reopen before the flush may not see the tail, after it must."""
        b = SegmentLogBackend.open(str(tmp_path / "wb"),
                                   flush_each_put=False)
        b.put_blob(1, b"unacked")
        b.flush()                              # the acknowledgement point
        reopened = SegmentLog(str(tmp_path / "wb"))
        assert reopened.get_blob(1) == b"unacked"
        reopened.close()
        b.close()

    def test_compactor_threshold_and_victim_choice(self, tmp_path):
        log = make_log(tmp_path, segment_bytes=256)
        for _ in range(5):
            for oid in range(4):
                log.put_blob(oid, bytes(50))
        log.flush()
        comp = Compactor(log, live_frac_threshold=0.0)   # disabled
        assert comp.step() == 0
        comp = Compactor(log, live_frac_threshold=0.9)
        segs = log.sealed_segments()
        coldest = min((sid for sid, (n, l) in segs.items() if n),
                      key=lambda s: segs[s][1] / segs[s][0])
        assert comp.step() == 1
        assert coldest not in log.sealed_segments()
        log.close()

    def test_slot_accounting_object_namespace(self, tmp_path):
        log = make_log(tmp_path)
        log.put_blob(3, b"xyz")
        s = log.slots[(NS_OBJECT, 3)]
        assert s.kind == BLOB and s.size == 3.0
        log.tombstone(3)
        assert log.slots[(NS_OBJECT, 3)].kind == TOMB
        assert log.payload_bytes == 0.0
        log.close()
