"""The uint8 regeneration fast path: fused-epilogue decode within +-1 LSB
of the f32 reference on every bucket (padded slots included), pipelined
flush bit-identical to the sequential flush, decompression memoized (a
coalesced or repeated oid never pays host DEFLATE twice), and the pixel
tier charged the stored array's real uint8 bytes."""

import types

import numpy as np
import pytest

import jax.numpy as jnp

from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.core.dual_cache import DualFormatCache
from repro.core.regen_tier import Recipe
from repro.core.tuner import TunerConfig
from repro.kernels.ref import quantize_u8_ref
from repro.serve.engine import DecodeBatcher, EngineConfig, ServingEngine
from repro.core.latent_store import LatentStore
from repro.store import LatentBox, StoreConfig
from repro.vae.model import VAE, VAEConfig

TINY = VAEConfig(name="tiny", latent_channels=4, block_out_channels=(16, 32),
                 layers_per_block=1, groups=4)
N_OBJECTS = 12
LATENT_HWC = (8, 8, 4)          # 16x16x3 images (768 uint8 bytes)


@pytest.fixture(scope="module")
def vae():
    return VAE(TINY, seed=0)


@pytest.fixture(scope="module")
def store(vae):
    rng = np.random.default_rng(7)
    st = LatentStore(seed=1)
    for oid in range(N_OBJECTS):
        img = jnp.asarray(rng.standard_normal((1, 16, 16, 3)), jnp.float32)
        z = np.asarray(vae.encode_mean(img)).astype(np.float16)[0]
        st.put(oid, compress_latent(z))
    return st


def make_engine(vae, store, **kw):
    base = dict(n_nodes=2, cache_bytes_per_node=1e5,
                tuner=TunerConfig(window=50, step=0.02))
    base.update(kw)
    return ServingEngine(vae, store, EngineConfig(**base), image_bytes=768.0,
                         latent_bytes=6e2)


class TestUint8WithinOneLsb:
    @pytest.mark.parametrize("n", [1, 2, 3, 4, 5, 8])
    def test_every_bucket_padded_slots_included(self, vae, store, n):
        """Window sizes covering every bucket (3 and 5 pad): the uint8
        fast path stays within +-1 LSB of quantizing the f32 reference
        decode for every slot."""
        eng = make_engine(vae, store)
        res = eng.get_many(list(range(n)))
        for oid, (img, _) in zip(range(n), res):
            assert img.dtype == np.uint8
            z = decompress_latent(store.get(oid))
            f32 = np.asarray(vae.decode(jnp.asarray(z, jnp.float32)[None]))[0]
            want = np.asarray(quantize_u8_ref(f32))
            lsb = np.abs(img.astype(np.int16) - want.astype(np.int16))
            assert lsb.max() <= 1

    def test_float32_mode_still_served(self, vae, store):
        """pixel_format='float32' keeps the legacy float pixels."""
        eng = make_engine(vae, store, pixel_format="float32")
        img, _ = eng.get(0)
        assert img.dtype == np.float32
        z = decompress_latent(store.get(0))
        direct = np.asarray(vae.decode(jnp.asarray(z, jnp.float32)[None]))[0]
        np.testing.assert_array_equal(img, direct)


class TestPipelinedFlush:
    def test_pipelined_bit_identical_to_sequential(self, vae, store):
        """Async-dispatch pipelining is a scheduling change only: the
        decoded bytes match the sequential flush exactly."""
        node = types.SimpleNamespace(tuner=None)
        results = {}
        for pipeline in (False, True):
            b = DecodeBatcher(vae, (1, 2, 4, 8), pipeline=pipeline)
            for oid in range(N_OBJECTS):       # 12 oids -> 8 + 4 chunks
                b.submit(oid, store.get(oid), node)
            results[pipeline] = b.flush()
        assert results[False].keys() == results[True].keys()
        for oid in results[False]:
            np.testing.assert_array_equal(results[False][oid],
                                          results[True][oid])

    def test_prewarm_compiles_all_buckets(self, vae, store):
        b = DecodeBatcher(vae, (1, 2, 4, 8))
        b.prewarm(LATENT_HWC)
        assert b._warm == {1, 2, 4, 8}
        eng = make_engine(vae, store)
        eng.prewarm_decode(LATENT_HWC)
        assert eng.batcher._warm == {1, 2, 4, 8}


class TestDecompressionMemo:
    def test_coalesced_oid_never_decompresses_twice(self, vae, store):
        """Single-flight duplicates within a window and repeats across
        windows both hit the memo: one DEFLATE per distinct blob."""
        eng = make_engine(vae, store)
        eng.get_many([5, 5, 5, 5])
        assert eng.batcher.stats["decompressions"] == 1
        assert eng.batcher.stats["coalesced"] == 3
        # the pixel tier may now serve 5 from cache; force decodes via
        # fresh oids plus the repeat to exercise the cross-window memo
        eng.get_many([5, 6, 7])
        assert eng.batcher.stats["decompressions"] <= 3
        counts = eng.batcher.stats
        assert counts["memo_hits"] + counts["decompressions"] >= 3

    def test_repeat_windows_hit_memo(self, vae, store):
        """An object decoding once per window (latent-hit traffic) pays
        host DEFLATE only on its first window."""
        # pixel tier too small for these images -> every read re-decodes
        eng = make_engine(vae, store, cache_bytes_per_node=2e3, alpha0=0.1)
        for _ in range(4):
            eng.get_many([1])
        assert eng.batcher.stats["decodes"] == 4
        assert eng.batcher.stats["decompressions"] == 1
        assert eng.batcher.stats["memo_hits"] == 3

    def test_memo_invalidated_on_reput(self, vae, store):
        """delete + re-put with different pixels must not serve the stale
        memoized latent."""
        rng = np.random.default_rng(3)
        st = LatentStore(seed=1)
        vae_local = vae
        img_a = jnp.asarray(rng.standard_normal((1, 16, 16, 3)), jnp.float32)
        z_a = np.asarray(vae_local.encode_mean(img_a)).astype(np.float16)[0]
        st.put(0, compress_latent(z_a))
        eng = make_engine(vae_local, st, cache_bytes_per_node=2e3, alpha0=0.1)
        first, _ = eng.get(0)
        eng.delete(0)
        img_b = jnp.asarray(rng.standard_normal((1, 16, 16, 3)), jnp.float32)
        eng.put(0, image=img_b)
        second, _ = eng.get(0)
        want = eng.batcher.decode_single(np.asarray(
            decompress_latent(st.get(0)), np.float32))
        np.testing.assert_array_equal(second, want)
        assert not np.array_equal(first, second)

    def test_overwrite_put_purges_cached_copies(self, vae):
        """Re-putting an oid WITHOUT deleting first must not serve stale
        pixels from any cache tier (pixel payload, latent blob, or memo)."""
        rng = np.random.default_rng(11)
        st = LatentStore(seed=1)
        img_a = jnp.asarray(rng.standard_normal((1, 16, 16, 3)), jnp.float32)
        z_a = np.asarray(vae.encode_mean(img_a)).astype(np.float16)[0]
        st.put(0, compress_latent(z_a))
        eng = make_engine(vae, st, promote_threshold=1)
        for _ in range(3):              # miss -> promote -> pixel hit
            stale, _ = eng.get(0)
        img_b = jnp.asarray(rng.standard_normal((1, 16, 16, 3)), jnp.float32)
        eng.put(0, image=img_b)         # overwrite, no delete
        fresh, _ = eng.get(0)
        want = eng.batcher.decode_single(np.asarray(
            decompress_latent(st.get(0)), np.float32))
        np.testing.assert_array_equal(fresh, want)
        assert not np.array_equal(stale, fresh)

    def test_memo_disabled(self, vae, store):
        eng = make_engine(vae, store, cache_bytes_per_node=2e3, alpha0=0.1)
        eng.batcher.memo_entries = 0
        for _ in range(3):
            eng.get_many([2])
        assert eng.batcher.stats["decompressions"] == 3


class TestRealPixelBytes:
    def test_dual_cache_resize_in_place(self):
        """set_image_nbytes corrects the charge without LRU reorder."""
        c = DualFormatCache(10_000, alpha=1.0, image_size_fn=lambda _: 3072)
        for oid in (1, 2):
            c.insert_image(oid)
        assert c.image_tier.resident_bytes == 6144
        assert c.set_image_nbytes(1, 768)
        assert c.image_tier.size_of(1) == 768
        assert c.image_tier.resident_bytes == 768 + 3072
        # LRU order unchanged: 1 is still the eviction candidate
        evicted = {oid for oid, _ in c.image_tier.insert(3, 8000)}
        assert 1 in evicted
        assert not c.set_image_nbytes(99, 10)     # absent -> no-op

    def test_insert_with_real_nbytes(self):
        c = DualFormatCache(10_000, alpha=0.5)
        c.insert_image(7, nbytes=768)
        assert c.image_tier.size_of(7) == 768
        c.admit_latent(8, nbytes=100)
        assert c.latent_tier.size_of(8) == 100

    def test_engine_charges_real_uint8_bytes(self, vae, store):
        """Promoted pixels are charged 768 bytes (16x16x3 uint8), not the
        float32 3072 — and stat()/summary() surface it."""
        cfg = StoreConfig(n_nodes=2, cache_bytes_per_node=1e5,
                          image_bytes=768.0, latent_bytes=6e2,
                          promote_threshold=1,
                          tuner=TunerConfig(window=10**9))
        box = LatentBox.engine(vae=vae, config=cfg)
        box.put(0, recipe=Recipe(seed=1, height=16, width=16))
        for _ in range(3):                 # miss -> latent hit -> promote
            box.get(0)
        st = box.stat(0)
        assert any(r.startswith("image@") for r in st.residency)
        assert st.pixel_bytes == 768.0
        s = box.summary()
        assert s["pixel_bytes_per_object"] == 768.0
        assert s["pixel_cached_objects"] == 1

    def test_prewarm_charges_real_bytes(self, vae):
        cfg = StoreConfig(n_nodes=1, cache_bytes_per_node=1e5,
                          image_bytes=3072.0, latent_bytes=6e2)
        box = LatentBox.engine(vae=vae, config=cfg)
        box.put(4, recipe=Recipe(seed=4, height=16, width=16), prewarm=True)
        assert box.stat(4).pixel_bytes == 768.0


class TestUint8PutRoundTrip:
    def test_put_accepts_uint8_pixels(self, vae):
        """Pixels served by a get() (uint8) can be put back directly."""
        cfg = StoreConfig(n_nodes=1, cache_bytes_per_node=1e5,
                          image_bytes=768.0, latent_bytes=6e2)
        box = LatentBox.engine(vae=vae, config=cfg)
        box.put(1, recipe=Recipe(seed=9, height=16, width=16))
        img = box.get(1).payload
        assert img.dtype == np.uint8
        box.put(2, image=img)
        again = box.get(2).payload
        lsb = np.abs(again.astype(np.int16) - img.astype(np.int16))
        # encode -> decode round trip is lossy; just sanity-bound it
        assert again.dtype == np.uint8 and lsb.mean() < 64
