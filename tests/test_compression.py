"""Latent codec: bit-exact roundtrip (hypothesis when available, plus
deterministic fallbacks), lossy-ladder rate/fidelity properties, ratio
sanity, PNG proxy, lossy pixel codec quality ordering + odd-shape
padding, PSNR/SSIM metric properties."""

import numpy as np
import pytest

try:                                # dev-only dep, see requirements-dev.txt
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.compression.ladder import (RECIPE_RUNG, RUNGS, encode_at,
                                      resolve_rung, transcode_blob)
from repro.compression.latentcodec import (blob_rung, compress_latent,
                                           compress_latent_lossy,
                                           compression_ratio,
                                           decompress_latent)
from repro.compression.lossy import jpeg_like
from repro.compression.metrics import psnr, ssim
from repro.compression.png_proxy import png_like_size

#: The lossy rungs of the ladder, hottest first (indices 1..3).
LOSSY_RUNGS = [r for r in RUNGS if r.lossy]


def _smooth_latent(rng, shape=(4, 24, 24), dtype=np.float16):
    """A latent-like tensor with spatial structure (not pure noise), so
    quantization error is the dominant, well-ordered distortion."""
    base = np.cumsum(rng.standard_normal(shape), axis=-1)
    return (base / max(1.0, float(np.max(np.abs(base))))).astype(dtype)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.sampled_from([np.float16, np.float32, np.int16, np.uint16,
                            np.int32]).flatmap(
        lambda dt: hnp.arrays(dtype=dt,
                              shape=hnp.array_shapes(min_dims=1, max_dims=3,
                                                     min_side=1,
                                                     max_side=24))))
    def test_roundtrip_bit_exact(arr):
        out = decompress_latent(compress_latent(arr))
        assert out.dtype == arr.dtype and out.shape == arr.shape
        assert np.array_equal(arr, out, equal_nan=True)

    @settings(max_examples=40, deadline=None)
    @given(st.sampled_from([np.float16, np.float32]).flatmap(
        lambda dt: hnp.arrays(
            dtype=dt,
            shape=hnp.array_shapes(min_dims=1, max_dims=3, min_side=1,
                                   max_side=16),
            elements=st.floats(-100, 100, width=16))),
        st.sampled_from([r.index for r in RUNGS if r.lossy]))
    def test_lossy_roundtrip_shape_dtype(arr, rung):
        blob = encode_at(arr, rung)
        out = decompress_latent(blob)
        assert out.dtype == arr.dtype and out.shape == arr.shape
        assert blob_rung(blob) == rung


def test_roundtrip_bit_exact_deterministic(rng):
    """Hypothesis-free floor: the property above on a fixed grid."""
    for dt in (np.float16, np.float32, np.int16, np.uint16, np.int32):
        for shape in ((1,), (7,), (5, 3), (3, 17, 2), (16, 8, 8)):
            arr = (rng.standard_normal(shape) * 50).astype(dt)
            out = decompress_latent(compress_latent(arr))
            assert out.dtype == arr.dtype and out.shape == arr.shape
            assert np.array_equal(arr, out, equal_nan=True)


def test_special_values_roundtrip():
    sp = np.array([np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-40, -1e-40,
                   np.finfo(np.float32).max], np.float32)
    out = decompress_latent(compress_latent(sp))
    assert np.array_equal(sp, out, equal_nan=True)
    assert np.array_equal(np.signbit(sp), np.signbit(out))


def test_smooth_latents_compress_better_than_noise(rng):
    noise = rng.standard_normal((16, 64, 64)).astype(np.float16)
    x = np.linspace(0, 8 * np.pi, 64 * 64, dtype=np.float32)
    smooth = np.broadcast_to(np.sin(x).reshape(64, 64), (16, 64, 64))
    smooth = smooth.astype(np.float16)
    _, _, r_noise = compression_ratio(noise)
    _, _, r_smooth = compression_ratio(np.ascontiguousarray(smooth))
    assert r_smooth > 1.5 * r_noise


def test_constant_array_compresses_heavily():
    a = np.full((16, 32, 32), 1.25, np.float16)
    raw, comp, ratio = compression_ratio(a)
    assert ratio > 20


def test_png_proxy_smooth_vs_noise(rng):
    smooth = np.tile(np.linspace(0, 255, 64, dtype=np.uint8)[None, :, None],
                     (64, 1, 3))
    noise = rng.integers(0, 256, (64, 64, 3)).astype(np.uint8)
    assert png_like_size(smooth) < png_like_size(noise) / 3


class TestLossyLatentLadder:
    """Rate-distortion properties of the quantized byte-plane codec that
    backs durable rungs 1-3 (``repro.compression.ladder``)."""

    def test_decode_shape_dtype_preserved(self, rng):
        for dt in (np.float16, np.float32, np.float64):
            for shape in ((3,), (5, 7), (4, 11, 13)):
                arr = _smooth_latent(rng, shape, dt)
                for r in LOSSY_RUNGS:
                    out = decompress_latent(encode_at(arr, r))
                    assert out.dtype == arr.dtype
                    assert out.shape == arr.shape

    def test_bytes_monotone_non_increasing_down_ladder(self, rng):
        arr = _smooth_latent(rng, (8, 32, 32))
        sizes = [len(compress_latent(arr))] + \
            [len(encode_at(arr, r)) for r in LOSSY_RUNGS]
        for hotter, colder in zip(sizes, sizes[1:]):
            assert colder <= hotter, sizes

    def test_psnr_monotone_non_increasing_down_ladder(self, rng):
        arr = _smooth_latent(rng, (8, 32, 32), np.float32)
        span = float(np.ptp(arr)) or 1.0
        psnrs = [psnr(arr, decompress_latent(encode_at(arr, r)),
                      data_range=span) for r in LOSSY_RUNGS]
        for hotter, colder in zip(psnrs, psnrs[1:]):
            assert colder <= hotter + 1e-9, psnrs

    def test_rung_tag_travels_in_blob(self, rng):
        arr = _smooth_latent(rng)
        assert blob_rung(compress_latent(arr)) == 0
        for r in LOSSY_RUNGS:
            assert blob_rung(encode_at(arr, r)) == r.index

    def test_transcode_only_descends(self, rng):
        arr = _smooth_latent(rng)
        mid = encode_at(arr, "mid")
        # colder target: re-encodes (strictly smaller-or-equal, new tag)
        low = transcode_blob(mid, "low")
        assert blob_rung(low) == resolve_rung("low").index
        assert len(low) <= len(mid)
        # hotter (or equal) target: identity — the ladder never re-inflates
        assert transcode_blob(mid, "high") is mid
        assert transcode_blob(mid, "mid") is mid

    def test_degenerate_inputs(self):
        const = np.full((4, 6), 0.75, np.float32)
        out = decompress_latent(encode_at(const, "low"))
        assert np.allclose(out, const, atol=1e-6)
        weird = np.array([np.nan, np.inf, -np.inf, 0.5], np.float32)
        out = decompress_latent(encode_at(weird, "mid"))
        assert out.shape == weird.shape and np.all(np.isfinite(out))

    def test_non_float_rejected(self):
        with pytest.raises(TypeError):
            compress_latent_lossy(np.arange(8, dtype=np.int32), 8)

    def test_recipe_rung_stores_no_bytes(self, rng):
        with pytest.raises(ValueError):
            encode_at(_smooth_latent(rng), RECIPE_RUNG)


class TestLossy:
    def test_quality_ordering(self, rng):
        img = (np.clip(np.cumsum(rng.standard_normal((64, 64, 3)), axis=0)
                       * 10 + 128, 0, 255)).astype(np.uint8)
        s95, r95 = jpeg_like(img, 95)
        s50, r50 = jpeg_like(img, 50)
        assert s50 < s95
        assert psnr(img, r95) > psnr(img, r50)
        assert ssim(img, r95) > ssim(img, r50)

    def test_odd_shapes_pad_and_crop(self, rng):
        """Regression: jpeg_like used to hard-assert 8-aligned H/W; it
        now replicate-pads internally and crops the reconstruction."""
        for shape in ((100, 100, 3), (7, 13, 3), (65, 8, 3), (8, 9, 3)):
            img = (np.clip(np.cumsum(rng.standard_normal(shape), axis=0)
                           * 10 + 128, 0, 255)).astype(np.uint8)
            size, rec = jpeg_like(img, 90)
            assert rec.shape == img.shape and rec.dtype == np.uint8
            assert size > 0
            assert psnr(img, rec) > 25.0

    def test_aligned_shapes_unchanged_by_padding_path(self, rng):
        img = (np.clip(np.cumsum(rng.standard_normal((64, 64, 3)), axis=0)
                       * 10 + 128, 0, 255)).astype(np.uint8)
        s1, r1 = jpeg_like(img, 80)
        s2, r2 = jpeg_like(img, 80)
        assert s1 == s2 and np.array_equal(r1, r2)


class TestMetrics:
    def test_psnr_identity_inf(self, rng):
        img = rng.integers(0, 256, (32, 32, 3)).astype(np.uint8)
        assert psnr(img, img) == float("inf")
        assert ssim(img, img) == pytest.approx(1.0, abs=1e-6)

    def test_psnr_known_value(self):
        a = np.zeros((16, 16))
        b = np.full((16, 16), 16.0)
        assert psnr(a, b) == pytest.approx(10 * np.log10(255 ** 2 / 256.0))

    def test_ssim_degrades_with_noise(self, rng):
        img = (np.clip(np.cumsum(rng.standard_normal((64, 64)), axis=0)
                       * 10 + 128, 0, 255))
        noisy1 = img + rng.normal(0, 5, img.shape)
        noisy2 = img + rng.normal(0, 25, img.shape)
        assert ssim(img, noisy1) > ssim(img, noisy2)
