"""Latent codec: bit-exact roundtrip (hypothesis), ratio sanity, PNG proxy,
lossy codec quality ordering, PSNR/SSIM metric properties."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")   # dev-only dep, see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.compression.latentcodec import (compress_latent, compression_ratio,
                                           decompress_latent)
from repro.compression.lossy import jpeg_like
from repro.compression.metrics import psnr, ssim
from repro.compression.png_proxy import png_like_size


@settings(max_examples=60, deadline=None)
@given(st.sampled_from([np.float16, np.float32, np.int16, np.uint16,
                        np.int32]).flatmap(
    lambda dt: hnp.arrays(dtype=dt,
                          shape=hnp.array_shapes(min_dims=1, max_dims=3,
                                                 min_side=1, max_side=24))))
def test_roundtrip_bit_exact(arr):
    out = decompress_latent(compress_latent(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape
    assert np.array_equal(arr, out, equal_nan=True)


def test_special_values_roundtrip():
    sp = np.array([np.nan, np.inf, -np.inf, 0.0, -0.0, 1e-40, -1e-40,
                   np.finfo(np.float32).max], np.float32)
    out = decompress_latent(compress_latent(sp))
    assert np.array_equal(sp, out, equal_nan=True)
    assert np.array_equal(np.signbit(sp), np.signbit(out))


def test_smooth_latents_compress_better_than_noise(rng):
    noise = rng.standard_normal((16, 64, 64)).astype(np.float16)
    x = np.linspace(0, 8 * np.pi, 64 * 64, dtype=np.float32)
    smooth = np.broadcast_to(np.sin(x).reshape(64, 64), (16, 64, 64))
    smooth = smooth.astype(np.float16)
    _, _, r_noise = compression_ratio(noise)
    _, _, r_smooth = compression_ratio(np.ascontiguousarray(smooth))
    assert r_smooth > 1.5 * r_noise


def test_constant_array_compresses_heavily():
    a = np.full((16, 32, 32), 1.25, np.float16)
    raw, comp, ratio = compression_ratio(a)
    assert ratio > 20


def test_png_proxy_smooth_vs_noise(rng):
    smooth = np.tile(np.linspace(0, 255, 64, dtype=np.uint8)[None, :, None],
                     (64, 1, 3))
    noise = rng.integers(0, 256, (64, 64, 3)).astype(np.uint8)
    assert png_like_size(smooth) < png_like_size(noise) / 3


class TestLossy:
    def test_quality_ordering(self, rng):
        img = (np.clip(np.cumsum(rng.standard_normal((64, 64, 3)), axis=0)
                       * 10 + 128, 0, 255)).astype(np.uint8)
        s95, r95 = jpeg_like(img, 95)
        s50, r50 = jpeg_like(img, 50)
        assert s50 < s95
        assert psnr(img, r95) > psnr(img, r50)
        assert ssim(img, r95) > ssim(img, r50)


class TestMetrics:
    def test_psnr_identity_inf(self, rng):
        img = rng.integers(0, 256, (32, 32, 3)).astype(np.uint8)
        assert psnr(img, img) == float("inf")
        assert ssim(img, img) == pytest.approx(1.0, abs=1e-6)

    def test_psnr_known_value(self):
        a = np.zeros((16, 16))
        b = np.full((16, 16), 16.0)
        assert psnr(a, b) == pytest.approx(10 * np.log10(255 ** 2 / 256.0))

    def test_ssim_degrades_with_noise(self, rng):
        img = (np.clip(np.cumsum(rng.standard_normal((64, 64)), axis=0)
                       * 10 + 128, 0, 255))
        noisy1 = img + rng.normal(0, 5, img.shape)
        noisy2 = img + rng.normal(0, 25, img.shape)
        assert ssim(img, noisy1) > ssim(img, noisy2)
