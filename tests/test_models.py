"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch at reduced scale — one forward/train step on CPU, shape + finiteness
asserts, plus prefill/decode == full-forward consistency."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as RC
from repro.models.common import cross_entropy_loss

R = np.random.default_rng(0)


def make_batch(cfg, b=2, s=24):
    toks = jnp.asarray(R.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            R.standard_normal((b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    elif cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            R.standard_normal((b, 8, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", RC.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = RC.reduced_config(RC.get_config(arch))
    model = RC.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", RC.ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = RC.reduced_config(RC.get_config(arch))
    model = RC.build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 20
    batch = make_batch(cfg, b, s)
    toks = batch["tokens"]

    if cfg.family == "encdec":
        enc = model.encode(params, batch["frames"])
        full = model._dec_forward(params, toks, enc) @ params["embed"].T
        pl, cache = model.prefill(params, toks[:, :s - 1], batch["frames"],
                                  max_len=s + 2)
    elif cfg.family == "vlm":
        h = model.hidden(params, toks, batch["vision_embeds"])
        full = model.logits(params, h)[:, 8:]
        pl, cache = model.prefill(params, toks[:, :s - 1],
                                  batch["vision_embeds"], max_len=s + 10)
    else:
        full = model.logits(params, model.hidden(params, toks))
        pl, cache = model.prefill(params, toks[:, :s - 1], max_len=s + 2)

    np.testing.assert_allclose(np.asarray(pl), np.asarray(full[:, s - 2]),
                               atol=5e-3)
    dl, cache = model.decode_step(params, cache, toks[:, s - 1])
    np.testing.assert_allclose(np.asarray(dl), np.asarray(full[:, s - 1]),
                               atol=5e-3)
    assert int(cache["pos"][0]) == (s if cfg.family != "vlm" else s + 8)


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x7b", "rwkv6-7b",
                                  "zamba2-2.7b"])
def test_two_train_steps_reduce_loss_direction(arch):
    """A couple of AdamW steps on a fixed batch must reduce the loss."""
    from repro.train.optim import AdamW, AdamWConfig
    from repro.train.train_step import make_train_step
    cfg = RC.reduced_config(RC.get_config(arch))
    model = RC.build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = make_batch(cfg, b=4, s=16)
    opt = AdamW(AdamWConfig(lr=3e-3, warmup_steps=1))
    step = jax.jit(make_train_step(model, opt, microbatches=2))
    state = opt.init(params)
    losses = []
    ef = None
    for _ in range(3):
        params, state, ef, metrics = step(params, state, ef, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_param_counts_match_configs():
    """Analytic parameter counts are in the advertised ballpark."""
    expected = {"granite-8b": (7, 9.5), "qwen3-14b": (13, 16),
                "qwen2-7b": (6.5, 8.5), "phi4-mini-3.8b": (3.3, 4.5),
                "mixtral-8x7b": (44, 49), "kimi-k2-1t-a32b": (950, 1100),
                "rwkv6-7b": (6.5, 8.5), "qwen2-vl-72b": (65, 80),
                "zamba2-2.7b": (2.2, 3.3)}
    for arch, (lo, hi) in expected.items():
        n = RC.get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"


def test_active_params_moe():
    kimi = RC.get_config("kimi-k2-1t-a32b")
    active = kimi.active_param_count() / 1e9
    assert 25 <= active <= 40          # "a32b"
    mix = RC.get_config("mixtral-8x7b")
    assert 11 <= mix.active_param_count() / 1e9 <= 15


def test_cross_entropy_ignore_mask():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.array([[1, 2, -1, -1]])
    loss = cross_entropy_loss(logits, labels)
    assert float(loss) == pytest.approx(np.log(8), rel=1e-5)


def test_swa_ring_buffer_long_decode():
    """Sliding-window decode far past the window stays consistent with a
    full forward on the visible window."""
    cfg = dataclasses.replace(RC.reduced_config(RC.get_config("mixtral-8x7b")),
                              sliding_window=8)
    model = RC.build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    b, s = 1, 30
    toks = jnp.asarray(R.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    full = model.logits(params, model.hidden(params, toks))
    pl, cache = model.prefill(params, toks[:, :s - 1], max_len=s + 4)
    dl, _ = model.decode_step(params, cache, toks[:, s - 1])
    np.testing.assert_allclose(np.asarray(dl), np.asarray(full[:, s - 1]),
                               atol=5e-3)
