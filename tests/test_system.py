"""End-to-end behaviour of the paper's system (deliverable c, integration):

1. latent-first storage roundtrip: encode -> compress -> store -> fetch ->
   decode is bit-exact through the storage layer and the decoded image
   matches a direct decode;
2. the serving engine (real VAE + router + dual cache + tuner) improves
   hit composition as traffic repeats, coalesces, and pins cache entries
   at hash owners;
3. the cluster simulator reproduces the paper's qualitative results:
   LB-Adaptive beats ImgStore on misses; spillover reduces queue tails;
4. trainer fault tolerance: kill mid-run, resume, identical loss path.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.core.cluster import ClusterConfig, replay_cluster
from repro.core.latent_store import LatentStore
from repro.core.tuner import TunerConfig
from repro.trace.synth import TraceConfig, generate_trace
from repro.vae.model import VAE, VAEConfig

TINY = VAEConfig(name="tiny", latent_channels=4, block_out_channels=(16, 32),
                 layers_per_block=1, groups=4)


@pytest.fixture(scope="module")
def vae():
    return VAE(TINY, seed=0)


@pytest.fixture(scope="module")
def trace():
    return generate_trace(TraceConfig(n_objects=3000, n_requests=60_000,
                                      span_days=20, seed=2))


class TestLatentFirstRoundtrip:
    def test_store_roundtrip_bit_exact(self, vae, rng):
        img = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
        z = np.asarray(vae.encode_mean(img)).astype(np.float16)
        store = LatentStore()
        store.put(123, compress_latent(z))
        z2 = decompress_latent(store.get(123))
        assert np.array_equal(z, z2)
        direct = np.asarray(vae.decode(jnp.asarray(z, jnp.float32)))
        via_store = np.asarray(vae.decode(jnp.asarray(z2, jnp.float32)))
        np.testing.assert_array_equal(direct, via_store)   # determinism

    def test_fetch_latency_model_warm_vs_cold(self):
        store = LatentStore(seed=0)
        store.put_size(1, 0.28e6)
        cold = np.mean([store.fetch_ms(1, t * 10_000.0)
                        for t in range(1, 20, 2)])
        warm = np.mean([store.fetch_ms(1, 1e6 + t) for t in range(20)])
        assert warm < cold


class TestServingEngine:
    def test_engine_end_to_end(self, vae, rng):
        from repro.serve.engine import EngineConfig, ServingEngine
        store = LatentStore(seed=1)
        for oid in range(30):
            img = jnp.asarray(rng.standard_normal((1, 16, 16, 3)),
                              jnp.float32)
            z = np.asarray(vae.encode_mean(img)).astype(np.float16)[0]
            store.put(oid, compress_latent(z))
        eng = ServingEngine(vae, store, EngineConfig(
            n_nodes=2, cache_bytes_per_node=2e5,
            tuner=TunerConfig(window=50, step=0.02)),
            image_bytes=3e3, latent_bytes=6e2)
        ids = rng.zipf(1.4, 600) % 30
        outcomes = [eng.get(int(oid))[1] for oid in ids]
        s = eng.summary()
        assert s["total"] == 600
        assert s["image_hit"] > 0 and s["latent_hit"] > 0
        tail = outcomes[-100:]
        assert sum(o != "full_miss" for o in tail) > 60
        # decoded pixels identical to a direct decode (cache correctness;
        # the engine serves the uint8 fast path)
        oid = int(ids[-1])
        img1, _ = eng.get(oid)
        z = decompress_latent(store.get(oid))
        img2 = np.asarray(vae.decode_u8(jnp.asarray(z, jnp.float32)[None]))[0]
        np.testing.assert_array_equal(img1, img2)


class TestClusterSim:
    def test_paper_qualitative_ordering(self, trace):
        ts, ids = trace.timestamps[:30_000], trace.object_ids[:30_000]
        wss = len(np.unique(trace.object_ids)) * 1.4e6
        base = dict(n_nodes=3, cache_bytes_per_node=0.02 * wss / 3,
                    tuner=TunerConfig(window=5_000), seed=0)
        res = {}
        for mode, kw in (("decode_all", {}),
                         ("imgstore", {}),
                         ("lb", dict(alpha0=0.5, adaptive=True))):
            cfg = ClusterConfig(mode=mode, **base, **kw)
            log, _ = replay_cluster(cfg, ts, ids, speedup=10.0)
            res[mode] = log.summarize()
        assert res["lb"]["mean_ms"] < res["decode_all"]["mean_ms"]
        assert res["lb"]["full_miss_frac"] < res["imgstore"]["full_miss_frac"]

    def test_coalescing_reduces_decodes(self, trace):
        ts = np.zeros(500)                      # burst of identical requests
        ids = np.full(500, 7)
        cfg = ClusterConfig(mode="lb", n_nodes=1, cache_bytes_per_node=1e9,
                            coalescing=True, adaptive=False)
        log, sim = replay_cluster(cfg, ts, ids, speedup=1.0)
        assert sim.router.n_coalesced >= 499

    def test_spillover_reduces_tail_under_load(self, trace):
        ts, ids = trace.timestamps[:20_000], trace.object_ids[:20_000]
        wss = len(np.unique(trace.object_ids)) * 1.4e6
        base = dict(mode="lb", n_nodes=4, cache_bytes_per_node=0.01 * wss / 4,
                    tuner=TunerConfig(window=5_000), theta=2, seed=0)
        p99 = {}
        for name, sp in (("on", True), ("off", False)):
            cfg = ClusterConfig(spillover=sp, **base)
            log, _ = replay_cluster(cfg, ts, ids, speedup=2000.0)
            p99[name] = float(np.percentile(log.queue_ms, 99))
        assert p99["on"] <= p99["off"]


class TestTrainerFaultTolerance:
    def test_kill_resume_same_losses(self, tmp_path):
        import repro.configs as RC
        from repro.data.synthetic import DataConfig, SyntheticTokens
        from repro.train.optim import AdamW, AdamWConfig
        from repro.train.trainer import Trainer, TrainerConfig

        cfg = RC.reduced_config(RC.get_config("granite-8b"))
        model = RC.build_model(cfg)
        data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=16, global_batch=4))
        opt = AdamW(AdamWConfig(lr=1e-3, warmup_steps=1))

        def make(steps):
            return Trainer(model, opt, data, TrainerConfig(
                steps=steps, ckpt_every=3, ckpt_dir=str(tmp_path),
                log_every=100))

        params0 = model.init(jax.random.PRNGKey(0))
        t_full = make(6)
        t_full.run(params0, resume=False)
        full_losses = [h["loss"] for h in t_full.history]

        import shutil
        shutil.rmtree(tmp_path)
        t_a = make(3)
        t_a.run(params0, resume=False)
        t_b = make(6)
        t_b.run(params0, resume=True)          # resumes from step 3
        resumed_losses = [h["loss"] for h in t_a.history] + \
            [h["loss"] for h in t_b.history]
        np.testing.assert_allclose(resumed_losses, full_losses, rtol=1e-4)
