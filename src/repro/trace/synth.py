"""Synthetic CompanyX-like access trace (paper §3.1).

The paper's 35-month / 2.07 B-request production trace is proprietary; this
module generates a statistically matched stand-in reproducing the four
observations that drive LatentBox's design:

  O1  Zipf-like popularity (alpha ~ 1.11): top 1% of images ~ 39% of views,
      top 10% ~ 71%, most images nearly never re-accessed.
  O2  rapid post-birth decay: per-image access rate drops >100x within a
      year for every popularity tier (hot is a phase, not a property).
  O3  a persistent miss residual at practical cache sizes.
  O4  heavy-tailed re-access intervals: ~38% within an hour, ~68% within a
      day, a long tail beyond 30 days.

Construction: objects are born over the trace window with slowly growing
intensity; each object gets a Zipf lifetime weight and its accesses are
placed at post-birth ages drawn from a truncated Lomax (power-law) decay.
Everything is vectorized numpy; ~5 M requests generate in a few seconds.

Beyond the stationary baseline, :func:`make_trace` exposes a suite of
named scenarios (diurnal load cycle, flash-crowd spike, Zipf-popularity
drift, sequential scan, multi-tenant mix) that stress the cache/tuner in
ways a single Zipf stream cannot — see :data:`SCENARIOS`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

DAY_S = 86_400.0
HOUR_S = 3_600.0


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_objects: int = 200_000
    n_requests: int = 4_000_000
    span_days: float = 90.0
    zipf_alpha: float = 1.11        # view-count ~ rank^{-alpha}
    decay_a0_days: float = 1.0      # Lomax scale (post-birth half-life knob)
    decay_beta: float = 1.8         # Lomax shape (>1; larger = faster decay)
    birth_growth: float = 1.0       # births/day grows by this factor over span
    burst_frac: float = 0.35        # fraction of re-accesses in a short burst
    burst_scale_s: float = 40 * 60  # mean burst re-access interval (40 min)
    n_models: int = 1500            # distinct generator models (Table 1 style)
    seed: int = 0


@dataclasses.dataclass
class SyntheticTrace:
    """``timestamps`` seconds from trace start (sorted), parallel arrays.

    Timestamps are *open-loop* arrival times: every scenario places its
    requests by an arrival process (Poisson given the request count for
    the stochastic scenarios, evenly spaced for the deterministic scan),
    independent of how fast the store serves them — which is what lets
    the serving runtime measure queueing delay at all.  ``slo_class``
    (when present) carries a per-object SLO class: 0 = ``interactive``,
    1 = ``batch``; the ``multi_tenant`` scenario fills it per tenant.
    """

    timestamps: np.ndarray          # float64 [R]
    object_ids: np.ndarray          # int64   [R]
    birth_time: np.ndarray          # float64 [N] per-object birth
    model_ids: np.ndarray           # int32   [N] per-object generator model
    config: TraceConfig
    slo_class: Optional[np.ndarray] = None   # int8 [N], 0=interactive 1=batch

    @property
    def n_requests(self) -> int:
        return len(self.timestamps)

    @property
    def n_objects(self) -> int:
        return len(self.birth_time)

    def save(self, path: str) -> None:
        extra = {}
        if self.slo_class is not None:
            extra["slo_class"] = self.slo_class
        np.savez_compressed(
            path, timestamps=self.timestamps, object_ids=self.object_ids,
            birth_time=self.birth_time, model_ids=self.model_ids,
            config=np.array([repr(dataclasses.asdict(self.config))]), **extra)

    @staticmethod
    def load(path: str) -> "SyntheticTrace":
        z = np.load(path, allow_pickle=False)
        cfg = TraceConfig(**eval(str(z["config"][0])))  # trusted local artifact
        return SyntheticTrace(z["timestamps"], z["object_ids"],
                              z["birth_time"], z["model_ids"], cfg,
                              slo_class=(z["slo_class"]
                                         if "slo_class" in z.files else None))

    # -- derived views --------------------------------------------------------
    def window(self, t0_s: float, t1_s: float) -> "SyntheticTrace":
        lo, hi = np.searchsorted(self.timestamps, [t0_s, t1_s])
        return SyntheticTrace(self.timestamps[lo:hi], self.object_ids[lo:hi],
                              self.birth_time, self.model_ids, self.config,
                              slo_class=self.slo_class)

    def downsample_objects(self, n_keep: int, seed: int = 0) -> "SyntheticTrace":
        """Paper §6.1: sample object IDs, keep ALL accesses to the sample."""
        rng = np.random.default_rng(seed)
        uniq = np.unique(self.object_ids)
        keep = rng.choice(uniq, size=min(n_keep, len(uniq)), replace=False)
        mask = np.isin(self.object_ids, keep)
        return SyntheticTrace(self.timestamps[mask], self.object_ids[mask],
                              self.birth_time, self.model_ids, self.config,
                              slo_class=self.slo_class)

    def characterize(self) -> Dict[str, float]:
        """Observed O1/O4 statistics (compare against the paper's numbers)."""
        ids = self.object_ids
        counts = np.bincount(ids, minlength=self.n_objects)
        viewed = counts[counts > 0]
        order = np.sort(viewed)[::-1]
        csum = np.cumsum(order)
        total = csum[-1]
        n = len(order)
        top1 = csum[max(1, n // 100) - 1] / total
        top10 = csum[max(1, n // 10) - 1] / total
        lt10 = float(np.mean(viewed < 10))
        once = float(np.mean(viewed == 1))
        # re-access intervals
        ts_sorted_by_obj = np.lexsort((self.timestamps, ids))
        t = self.timestamps[ts_sorted_by_obj]
        o = ids[ts_sorted_by_obj]
        same = o[1:] == o[:-1]
        gaps = (t[1:] - t[:-1])[same]
        stats = {
            "top1_share": float(top1),
            "top10_share": float(top10),
            "frac_lt10_views": lt10,
            "frac_once": once,
            "reaccess_1h": float(np.mean(gaps <= HOUR_S)) if len(gaps) else 0.0,
            "reaccess_1d": float(np.mean(gaps <= DAY_S)) if len(gaps) else 0.0,
            "reaccess_gt30d": float(np.mean(gaps > 30 * DAY_S)) if len(gaps) else 0.0,
            "n_requests": float(self.n_requests),
            "n_viewed_objects": float(n),
        }
        return stats


def _zipf_weights(n: int, alpha: float, rng: np.random.Generator) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    rng.shuffle(w)                       # rank order decoupled from object id
    return w / w.sum()


def _sample_births(n: int, span_s: float, growth: float,
                   rng: np.random.Generator) -> np.ndarray:
    """Birth intensity grows linearly by ``growth`` over the span; sample via
    inverse CDF of f(t) ∝ 1 + growth*t/span."""
    u = rng.random(n)
    if growth <= 1e-9:
        return u * span_s
    g = growth
    # CDF(t) = (t + g t^2 / (2 span)) / (span (1 + g/2)); solve quadratic.
    a = g / (2.0 * span_s)
    c = -u * span_s * (1.0 + g / 2.0)
    t = (-1.0 + np.sqrt(1.0 - 4.0 * a * c)) / (2.0 * a)
    return np.clip(t, 0.0, span_s)


def _sample_lomax_trunc(a0_s: float, beta: float, max_age_s: np.ndarray,
                        rng: np.random.Generator) -> np.ndarray:
    """Ages from density ∝ (1 + a/a0)^(-beta) truncated to [0, max_age]."""
    # CDF(a) = 1 - (1 + a/a0)^(1-beta)  (beta > 1)
    fmax = 1.0 - (1.0 + np.maximum(max_age_s, 0.0) / a0_s) ** (1.0 - beta)
    u = rng.random(len(max_age_s)) * fmax
    a = a0_s * ((1.0 - u) ** (1.0 / (1.0 - beta)) - 1.0)
    return np.clip(a, 0.0, max_age_s)


def _finalize(timestamps: np.ndarray, object_ids: np.ndarray,
              n_objects: int, model_ids: Optional[np.ndarray],
              birth_time: Optional[np.ndarray],
              cfg: TraceConfig,
              slo_class: Optional[np.ndarray] = None) -> SyntheticTrace:
    """Sort a (timestamps, ids) pair into a SyntheticTrace, filling the
    per-object arrays scenarios don't model (births at t=0, one model)."""
    order = np.argsort(timestamps, kind="stable")
    if birth_time is None:
        birth_time = np.zeros(n_objects, dtype=np.float64)
    if model_ids is None:
        model_ids = np.zeros(n_objects, dtype=np.int32)
    return SyntheticTrace(np.asarray(timestamps, np.float64)[order],
                          np.asarray(object_ids, np.int64)[order],
                          birth_time, model_ids, cfg, slo_class=slo_class)


def _zipf_choice(n_objects: int, n_requests: int, alpha: float,
                 rng: np.random.Generator,
                 weights: Optional[np.ndarray] = None) -> np.ndarray:
    w = _zipf_weights(n_objects, alpha, rng) if weights is None else weights
    return rng.choice(n_objects, size=n_requests, p=w).astype(np.int64)


# ---------------------------------------------------------------------------
# named scenarios — the workload suite beyond the stationary CompanyX trace
# ---------------------------------------------------------------------------

def _scenario_companyx(cfg: TraceConfig, rng: np.random.Generator,
                       **_kw) -> SyntheticTrace:
    """The paper-calibrated stationary baseline (O1-O4)."""
    return generate_trace(cfg)


def _scenario_diurnal(cfg: TraceConfig, rng: np.random.Generator,
                      amplitude: float = 0.8, period_days: float = 1.0,
                      **_kw) -> SyntheticTrace:
    """Daily load cycle: arrival intensity lambda(t) = 1 + A sin(2 pi t/P)
    over Zipf-popular objects.  Sampled by inverting the cumulative
    intensity on a dense grid (exact up to grid resolution)."""
    span_s = cfg.span_days * DAY_S
    period_s = period_days * DAY_S
    a = float(np.clip(amplitude, 0.0, 1.0))
    grid = np.linspace(0.0, span_s, 8192)
    cum = grid + a * (period_s / (2 * np.pi)) * (
        1.0 - np.cos(2 * np.pi * grid / period_s))
    u = np.sort(rng.random(cfg.n_requests)) * cum[-1]
    ts = np.interp(u, cum, grid)
    ids = _zipf_choice(cfg.n_objects, cfg.n_requests, cfg.zipf_alpha, rng)
    return _finalize(ts, ids, cfg.n_objects, None, None, cfg)


def _scenario_flash_crowd(cfg: TraceConfig, rng: np.random.Generator,
                          spike_start_frac: float = 0.5,
                          spike_dur_frac: float = 0.05,
                          spike_frac: float = 0.3,
                          n_viral: int = 8, **_kw) -> SyntheticTrace:
    """Steady Zipf background plus a short spike in which ``spike_frac`` of
    all requests hammer ``n_viral`` previously-cold objects (a post going
    viral).  The viral objects are born at the spike start."""
    if cfg.n_objects < 2:
        raise ValueError("flash_crowd needs >= 2 objects (a viral set and "
                         "a background population)")
    span_s = cfg.span_days * DAY_S
    n_spike = int(cfg.n_requests * spike_frac)
    n_base = cfg.n_requests - n_spike
    n_viral = min(n_viral, cfg.n_objects - 1)   # keep background mass > 0
    # background avoids the viral ids so they are genuinely cold pre-spike
    w = _zipf_weights(cfg.n_objects, cfg.zipf_alpha, rng)
    viral = np.arange(cfg.n_objects - n_viral, cfg.n_objects, dtype=np.int64)
    w[viral] = 0.0
    w /= w.sum()
    base_ids = _zipf_choice(cfg.n_objects, n_base, cfg.zipf_alpha, rng,
                            weights=w)
    base_ts = rng.random(n_base) * span_s
    t0 = spike_start_frac * span_s
    dur = max(spike_dur_frac * span_s, 1.0)
    spike_ids = viral[rng.integers(0, n_viral, size=n_spike)]
    spike_ts = t0 + rng.random(n_spike) * dur
    ts = np.concatenate([base_ts, spike_ts])
    ids = np.concatenate([base_ids, spike_ids])
    births = np.zeros(cfg.n_objects)
    births[viral] = t0
    return _finalize(ts, ids, cfg.n_objects, None, births, cfg)


def _scenario_zipf_drift(cfg: TraceConfig, rng: np.random.Generator,
                         n_phases: int = 2, **_kw) -> SyntheticTrace:
    """Popularity drift: the span splits into ``n_phases`` equal phases and
    the Zipf rank order flips between consecutive phases (phase 1's hottest
    objects become phase 2's coldest).  The marginal-hit tuner must
    re-converge after each flip — ``tests/test_tuner.py`` locks that in."""
    span_s = cfg.span_days * DAY_S
    ranks = np.arange(1, cfg.n_objects + 1, dtype=np.float64)
    w = ranks ** (-cfg.zipf_alpha)
    perm = rng.permutation(cfg.n_objects)       # id -> rank decoupling
    per_phase = np.array_split(np.arange(cfg.n_requests), n_phases)
    ts_parts, id_parts = [], []
    for p, idx in enumerate(per_phase):
        wp = w if p % 2 == 0 else w[::-1]       # the popularity flip
        weights = np.empty(cfg.n_objects)
        weights[perm] = wp / wp.sum()
        id_parts.append(_zipf_choice(cfg.n_objects, len(idx),
                                     cfg.zipf_alpha, rng, weights=weights))
        lo, hi = p / n_phases, (p + 1) / n_phases
        ts_parts.append((lo + rng.random(len(idx)) * (hi - lo)) * span_s)
    return _finalize(np.concatenate(ts_parts), np.concatenate(id_parts),
                     cfg.n_objects, None, None, cfg)


def _scenario_scan(cfg: TraceConfig, rng: np.random.Generator,
                   passes: Optional[int] = None,
                   poisson: bool = False, **_kw) -> SyntheticTrace:
    """Sequential sweep over the whole object space (batch re-encode /
    integrity audit): the cache-adversarial workload — every request is
    maximally far from its previous access.  Default: exactly
    ``n_requests`` requests (the last pass may be partial); with an
    explicit ``passes`` the trace is exactly ``passes * n_objects``.

    Arrivals are evenly spaced (a scan is a paced batch job, and the
    default trace must stay seed-independent); ``poisson=True`` swaps in
    Poisson arrival times at the same mean rate while keeping the
    sequential id order, for open-loop runtime studies."""
    if passes is None:
        n_total = cfg.n_requests
    else:
        n_total = int(passes) * cfg.n_objects
    n_passes = -(-n_total // cfg.n_objects)          # ceil
    ids = np.tile(np.arange(cfg.n_objects, dtype=np.int64),
                  n_passes)[:n_total]
    if poisson:
        # order statistics of U(0, span) = Poisson arrivals given the count
        ts = np.sort(rng.random(len(ids))) * cfg.span_days * DAY_S
    else:
        ts = np.linspace(0.0, cfg.span_days * DAY_S, len(ids), endpoint=False)
    return _finalize(ts, ids, cfg.n_objects, None, None, cfg)


def _scenario_multi_tenant(cfg: TraceConfig, rng: np.random.Generator,
                           n_tenants: int = 4,
                           tenant_alphas: Optional[Sequence[float]] = None,
                           tenant_share_alpha: float = 1.0,
                           tenant_slos: Optional[Sequence[str]] = None,
                           **_kw) -> SyntheticTrace:
    """T tenants with disjoint object pools: tenant traffic shares follow a
    Zipf over tenants, and each tenant has its own per-pool skew (some
    tenants serve one viral asset, others a flat archive).  ``model_ids``
    carries the owning tenant of every object, and ``slo_class`` the
    tenant's SLO class (``tenant_slos``, one of ``interactive``/``batch``
    per tenant; default alternates, starting interactive) — together the
    keys the serving runtime's QoS and admission layers act on."""
    n_tenants = max(1, min(n_tenants, cfg.n_objects))
    if tenant_alphas is None:
        # spread skews from heavy (first tenant) to near-uniform (last)
        tenant_alphas = np.linspace(cfg.zipf_alpha + 0.3, 0.2, n_tenants)
    if tenant_slos is None:
        tenant_slos = ["interactive" if t % 2 == 0 else "batch"
                       for t in range(n_tenants)]
    if len(tenant_slos) != n_tenants or \
            any(s not in ("interactive", "batch") for s in tenant_slos):
        raise ValueError("tenant_slos needs one 'interactive'/'batch' entry "
                         f"per tenant ({n_tenants}): {tenant_slos!r}")
    pools = np.array_split(np.arange(cfg.n_objects, dtype=np.int64),
                           n_tenants)
    shares = np.arange(1, n_tenants + 1, dtype=np.float64) \
        ** (-tenant_share_alpha)
    shares /= shares.sum()
    tenant_of_req = rng.choice(n_tenants, size=cfg.n_requests, p=shares)
    ids = np.empty(cfg.n_requests, dtype=np.int64)
    for t in range(n_tenants):
        mask = tenant_of_req == t
        pool = pools[t]
        local = _zipf_choice(len(pool), int(mask.sum()),
                             float(tenant_alphas[t]), rng)
        ids[mask] = pool[local]
    ts = rng.random(cfg.n_requests) * cfg.span_days * DAY_S
    model_ids = np.empty(cfg.n_objects, dtype=np.int32)
    slo_class = np.empty(cfg.n_objects, dtype=np.int8)
    for t, pool in enumerate(pools):
        model_ids[pool] = t
        slo_class[pool] = 0 if tenant_slos[t] == "interactive" else 1
    return _finalize(ts, ids, cfg.n_objects, model_ids, None, cfg,
                     slo_class=slo_class)


#: Named workloads of the scenario suite.  Every generator takes
#: ``(TraceConfig, rng, **knobs)`` and returns a :class:`SyntheticTrace`;
#: ``make_trace`` is the one public entry point.
SCENARIOS = {
    "companyx": _scenario_companyx,
    "diurnal": _scenario_diurnal,
    "flash_crowd": _scenario_flash_crowd,
    "zipf_drift": _scenario_zipf_drift,
    "scan": _scenario_scan,
    "multi_tenant": _scenario_multi_tenant,
}


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def make_trace(scenario: str = "companyx",
               config: Optional[TraceConfig] = None,
               n_objects: Optional[int] = None,
               n_requests: Optional[int] = None,
               span_days: Optional[float] = None,
               seed: Optional[int] = None,
               load_factor: float = 1.0,
               **knobs) -> SyntheticTrace:
    """Generate a named workload: ``make_trace("flash_crowd", n_objects=...)``.

    The common size knobs override ``config`` fields; scenario-specific
    knobs (``amplitude``, ``spike_frac``, ``n_phases``, ``passes``,
    ``n_tenants``, ``tenant_slos``, ...) pass through to the generator.
    ``load_factor`` scales the open-loop arrival *rate* of any scenario:
    timestamps divide by it (2.0 = the same requests arrive twice as
    fast), which is how the runtime benchmarks sweep a scenario from
    underload into overload without changing its access pattern.
    Consumed by ``core/replay.py``, ``core/cluster.py``,
    ``benchmarks/bench_trace.py`` and the conformance harnesses.
    """
    if scenario not in SCENARIOS:
        raise KeyError(f"unknown scenario {scenario!r}; "
                       f"pick one of {list_scenarios()}")
    if load_factor <= 0:
        raise ValueError(f"load_factor must be > 0: {load_factor!r}")
    cfg = config or TraceConfig()
    overrides = {k: v for k, v in (("n_objects", n_objects),
                                   ("n_requests", n_requests),
                                   ("span_days", span_days),
                                   ("seed", seed)) if v is not None}
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    rng = np.random.default_rng(cfg.seed)
    trace = SCENARIOS[scenario](cfg, rng, **knobs)
    if load_factor != 1.0:
        trace = dataclasses.replace(
            trace, timestamps=trace.timestamps / float(load_factor))
    return trace


def generate_trace(config: Optional[TraceConfig] = None) -> SyntheticTrace:
    cfg = config or TraceConfig()
    rng = np.random.default_rng(cfg.seed)
    span_s = cfg.span_days * DAY_S

    births = _sample_births(cfg.n_objects, span_s, cfg.birth_growth, rng)
    weights = _zipf_weights(cfg.n_objects, cfg.zipf_alpha, rng)

    # Discount each object's weight by its remaining lifetime mass so that
    # late-born objects don't get impossible request budgets.
    frac_life = 1.0 - (1.0 + (span_s - births) / (cfg.decay_a0_days * DAY_S)) ** (
        1.0 - cfg.decay_beta)
    eff = weights * frac_life
    lam = cfg.n_requests * eff / eff.sum()
    counts = rng.poisson(lam)

    total = int(counts.sum())
    oid = np.repeat(np.arange(cfg.n_objects, dtype=np.int64), counts)
    birth_of = np.repeat(births, counts)
    max_age = span_s - birth_of
    ages = _sample_lomax_trunc(cfg.decay_a0_days * DAY_S, cfg.decay_beta,
                               max_age, rng)

    ts = birth_of + ages

    # O4's short-interval mass: a fraction of each object's re-accesses are
    # bursty follow-ups to the previous access rather than independent draws
    # from the decay profile.  Implement by snapping a random subset of
    # accesses to (previous access of same object) + Exp(burst_scale).
    order = np.lexsort((ts, oid))
    ts_o = ts[order]
    oid_o = oid[order]
    same_prev = np.zeros(total, dtype=bool)
    same_prev[1:] = oid_o[1:] == oid_o[:-1]
    burst = same_prev & (rng.random(total) < cfg.burst_frac)
    # Sequential dependency (burst chains) — resolve with a forward pass on
    # the object-sorted arrays; numpy-friendly since chains share the base.
    delta = rng.exponential(cfg.burst_scale_s, size=total)
    ts_new = ts_o.copy()
    idx = np.nonzero(burst)[0]
    ts_new[idx] = ts_o[idx - 1] + delta[idx]
    ts_new = np.minimum(ts_new, span_s)

    final_order = np.argsort(ts_new, kind="stable")
    timestamps = ts_new[final_order]
    object_ids = oid_o[final_order]

    model_ids = rng.integers(0, cfg.n_models, size=cfg.n_objects).astype(np.int32)
    return SyntheticTrace(timestamps, object_ids, births, model_ids, cfg)
