"""Synthetic CompanyX-like access trace (paper §3.1).

The paper's 35-month / 2.07 B-request production trace is proprietary; this
module generates a statistically matched stand-in reproducing the four
observations that drive LatentBox's design:

  O1  Zipf-like popularity (alpha ~ 1.11): top 1% of images ~ 39% of views,
      top 10% ~ 71%, most images nearly never re-accessed.
  O2  rapid post-birth decay: per-image access rate drops >100x within a
      year for every popularity tier (hot is a phase, not a property).
  O3  a persistent miss residual at practical cache sizes.
  O4  heavy-tailed re-access intervals: ~38% within an hour, ~68% within a
      day, a long tail beyond 30 days.

Construction: objects are born over the trace window with slowly growing
intensity; each object gets a Zipf lifetime weight and its accesses are
placed at post-birth ages drawn from a truncated Lomax (power-law) decay.
Everything is vectorized numpy; ~5 M requests generate in a few seconds.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

DAY_S = 86_400.0
HOUR_S = 3_600.0


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_objects: int = 200_000
    n_requests: int = 4_000_000
    span_days: float = 90.0
    zipf_alpha: float = 1.11        # view-count ~ rank^{-alpha}
    decay_a0_days: float = 1.0      # Lomax scale (post-birth half-life knob)
    decay_beta: float = 1.8         # Lomax shape (>1; larger = faster decay)
    birth_growth: float = 1.0       # births/day grows by this factor over span
    burst_frac: float = 0.35        # fraction of re-accesses in a short burst
    burst_scale_s: float = 40 * 60  # mean burst re-access interval (40 min)
    n_models: int = 1500            # distinct generator models (Table 1 style)
    seed: int = 0


@dataclasses.dataclass
class SyntheticTrace:
    """``timestamps`` seconds from trace start (sorted), parallel arrays."""

    timestamps: np.ndarray          # float64 [R]
    object_ids: np.ndarray          # int64   [R]
    birth_time: np.ndarray          # float64 [N] per-object birth
    model_ids: np.ndarray           # int32   [N] per-object generator model
    config: TraceConfig

    @property
    def n_requests(self) -> int:
        return len(self.timestamps)

    @property
    def n_objects(self) -> int:
        return len(self.birth_time)

    def save(self, path: str) -> None:
        np.savez_compressed(
            path, timestamps=self.timestamps, object_ids=self.object_ids,
            birth_time=self.birth_time, model_ids=self.model_ids,
            config=np.array([repr(dataclasses.asdict(self.config))]))

    @staticmethod
    def load(path: str) -> "SyntheticTrace":
        z = np.load(path, allow_pickle=False)
        cfg = TraceConfig(**eval(str(z["config"][0])))  # trusted local artifact
        return SyntheticTrace(z["timestamps"], z["object_ids"],
                              z["birth_time"], z["model_ids"], cfg)

    # -- derived views --------------------------------------------------------
    def window(self, t0_s: float, t1_s: float) -> "SyntheticTrace":
        lo, hi = np.searchsorted(self.timestamps, [t0_s, t1_s])
        return SyntheticTrace(self.timestamps[lo:hi], self.object_ids[lo:hi],
                              self.birth_time, self.model_ids, self.config)

    def downsample_objects(self, n_keep: int, seed: int = 0) -> "SyntheticTrace":
        """Paper §6.1: sample object IDs, keep ALL accesses to the sample."""
        rng = np.random.default_rng(seed)
        uniq = np.unique(self.object_ids)
        keep = rng.choice(uniq, size=min(n_keep, len(uniq)), replace=False)
        mask = np.isin(self.object_ids, keep)
        return SyntheticTrace(self.timestamps[mask], self.object_ids[mask],
                              self.birth_time, self.model_ids, self.config)

    def characterize(self) -> Dict[str, float]:
        """Observed O1/O4 statistics (compare against the paper's numbers)."""
        ids = self.object_ids
        counts = np.bincount(ids, minlength=self.n_objects)
        viewed = counts[counts > 0]
        order = np.sort(viewed)[::-1]
        csum = np.cumsum(order)
        total = csum[-1]
        n = len(order)
        top1 = csum[max(1, n // 100) - 1] / total
        top10 = csum[max(1, n // 10) - 1] / total
        lt10 = float(np.mean(viewed < 10))
        once = float(np.mean(viewed == 1))
        # re-access intervals
        ts_sorted_by_obj = np.lexsort((self.timestamps, ids))
        t = self.timestamps[ts_sorted_by_obj]
        o = ids[ts_sorted_by_obj]
        same = o[1:] == o[:-1]
        gaps = (t[1:] - t[:-1])[same]
        stats = {
            "top1_share": float(top1),
            "top10_share": float(top10),
            "frac_lt10_views": lt10,
            "frac_once": once,
            "reaccess_1h": float(np.mean(gaps <= HOUR_S)) if len(gaps) else 0.0,
            "reaccess_1d": float(np.mean(gaps <= DAY_S)) if len(gaps) else 0.0,
            "reaccess_gt30d": float(np.mean(gaps > 30 * DAY_S)) if len(gaps) else 0.0,
            "n_requests": float(self.n_requests),
            "n_viewed_objects": float(n),
        }
        return stats


def _zipf_weights(n: int, alpha: float, rng: np.random.Generator) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    rng.shuffle(w)                       # rank order decoupled from object id
    return w / w.sum()


def _sample_births(n: int, span_s: float, growth: float,
                   rng: np.random.Generator) -> np.ndarray:
    """Birth intensity grows linearly by ``growth`` over the span; sample via
    inverse CDF of f(t) ∝ 1 + growth*t/span."""
    u = rng.random(n)
    if growth <= 1e-9:
        return u * span_s
    g = growth
    # CDF(t) = (t + g t^2 / (2 span)) / (span (1 + g/2)); solve quadratic.
    a = g / (2.0 * span_s)
    c = -u * span_s * (1.0 + g / 2.0)
    t = (-1.0 + np.sqrt(1.0 - 4.0 * a * c)) / (2.0 * a)
    return np.clip(t, 0.0, span_s)


def _sample_lomax_trunc(a0_s: float, beta: float, max_age_s: np.ndarray,
                        rng: np.random.Generator) -> np.ndarray:
    """Ages from density ∝ (1 + a/a0)^(-beta) truncated to [0, max_age]."""
    # CDF(a) = 1 - (1 + a/a0)^(1-beta)  (beta > 1)
    fmax = 1.0 - (1.0 + np.maximum(max_age_s, 0.0) / a0_s) ** (1.0 - beta)
    u = rng.random(len(max_age_s)) * fmax
    a = a0_s * ((1.0 - u) ** (1.0 / (1.0 - beta)) - 1.0)
    return np.clip(a, 0.0, max_age_s)


def generate_trace(config: Optional[TraceConfig] = None) -> SyntheticTrace:
    cfg = config or TraceConfig()
    rng = np.random.default_rng(cfg.seed)
    span_s = cfg.span_days * DAY_S

    births = _sample_births(cfg.n_objects, span_s, cfg.birth_growth, rng)
    weights = _zipf_weights(cfg.n_objects, cfg.zipf_alpha, rng)

    # Discount each object's weight by its remaining lifetime mass so that
    # late-born objects don't get impossible request budgets.
    frac_life = 1.0 - (1.0 + (span_s - births) / (cfg.decay_a0_days * DAY_S)) ** (
        1.0 - cfg.decay_beta)
    eff = weights * frac_life
    lam = cfg.n_requests * eff / eff.sum()
    counts = rng.poisson(lam)

    total = int(counts.sum())
    oid = np.repeat(np.arange(cfg.n_objects, dtype=np.int64), counts)
    birth_of = np.repeat(births, counts)
    max_age = span_s - birth_of
    ages = _sample_lomax_trunc(cfg.decay_a0_days * DAY_S, cfg.decay_beta,
                               max_age, rng)

    ts = birth_of + ages

    # O4's short-interval mass: a fraction of each object's re-accesses are
    # bursty follow-ups to the previous access rather than independent draws
    # from the decay profile.  Implement by snapping a random subset of
    # accesses to (previous access of same object) + Exp(burst_scale).
    order = np.lexsort((ts, oid))
    ts_o = ts[order]
    oid_o = oid[order]
    same_prev = np.zeros(total, dtype=bool)
    same_prev[1:] = oid_o[1:] == oid_o[:-1]
    burst = same_prev & (rng.random(total) < cfg.burst_frac)
    # Sequential dependency (burst chains) — resolve with a forward pass on
    # the object-sorted arrays; numpy-friendly since chains share the base.
    delta = rng.exponential(cfg.burst_scale_s, size=total)
    ts_new = ts_o.copy()
    idx = np.nonzero(burst)[0]
    ts_new[idx] = ts_o[idx - 1] + delta[idx]
    ts_new = np.minimum(ts_new, span_s)

    final_order = np.argsort(ts_new, kind="stable")
    timestamps = ts_new[final_order]
    object_ids = oid_o[final_order]

    model_ids = rng.integers(0, cfg.n_models, size=cfg.n_objects).astype(np.int32)
    return SyntheticTrace(timestamps, object_ids, births, model_ids, cfg)
