from repro.trace.synth import (SCENARIOS, SyntheticTrace, TraceConfig,
                               generate_trace, list_scenarios, make_trace)

__all__ = ["SCENARIOS", "SyntheticTrace", "TraceConfig", "generate_trace",
           "list_scenarios", "make_trace"]
