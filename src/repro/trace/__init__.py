from repro.trace.synth import SyntheticTrace, TraceConfig, generate_trace

__all__ = ["SyntheticTrace", "TraceConfig", "generate_trace"]
