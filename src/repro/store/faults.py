"""Failure injection for the sharded store.

A :class:`FaultPlan` is a deterministic script of shard-level faults keyed
by *global request index* — the cluster counts every request it has ever
served and, at each window boundary the plan splits, applies the events
that have come due.  Driving faults off the request clock (not wall time)
keeps every injected run exactly reproducible, which is what lets the
conformance suite demand bit-identical classification from a degraded
cluster.

Event kinds:

``kill``
    The shard process dies mid-trace.  Persistent shards lose their
    unflushed write-behind tail (``SegmentLog.abandon``), memory shards
    lose everything.  Reads fail over to replica holders.
``restart``
    A previously killed shard comes back: it recovers from its own log,
    then catches up from its peers' replica holders via delta segment
    shipping.
``stall``
    The shard answers, but ``stall_ms`` slower — the one-slow-replica
    scenario hedged reads exist for.  A second ``stall`` event with
    ``stall_ms=0`` clears it.
``partition``
    The shard is unreachable but intact (no data loss); reads fail over
    exactly as for ``kill``.
``heal``
    The partition ends; the shard catches up on the writes it missed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

KINDS = ("kill", "restart", "stall", "partition", "heal")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str
    shard_id: int
    at_request: int             # fires before serving this global request
    stall_ms: float = 0.0       # only meaningful for kind="stall"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(want one of {KINDS})")
        if self.at_request < 0:
            raise ValueError("at_request must be >= 0")


class FaultPlan:
    """An ordered script of :class:`FaultEvent`; the cluster pops events
    as their request index comes due."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self._pending: List[FaultEvent] = sorted(
            events, key=lambda e: e.at_request)
        self.fired: List[FaultEvent] = []

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending(self) -> List[FaultEvent]:
        return list(self._pending)

    def next_boundary(self, after: int) -> Optional[int]:
        """First pending event index > ``after`` (None: no more events) —
        where the cluster must split its serving window."""
        for e in self._pending:
            if e.at_request > after:
                return e.at_request
        return None

    def pop_due(self, request_index: int) -> List[FaultEvent]:
        """Events with ``at_request <= request_index``, in firing order."""
        due = [e for e in self._pending if e.at_request <= request_index]
        if due:
            self._pending = [e for e in self._pending
                             if e.at_request > request_index]
            self.fired.extend(due)
        return due

    # -- convenience constructors ---------------------------------------------
    @staticmethod
    def kill(shard_id: int, at_request: int) -> "FaultPlan":
        return FaultPlan([FaultEvent("kill", shard_id, at_request)])

    @staticmethod
    def kill_restart(shard_id: int, kill_at: int,
                     restart_at: int) -> "FaultPlan":
        return FaultPlan([FaultEvent("kill", shard_id, kill_at),
                          FaultEvent("restart", shard_id, restart_at)])

    @staticmethod
    def stall(shard_id: int, at_request: int, stall_ms: float,
              until_request: Optional[int] = None) -> "FaultPlan":
        ev = [FaultEvent("stall", shard_id, at_request, stall_ms=stall_ms)]
        if until_request is not None:
            ev.append(FaultEvent("stall", shard_id, until_request))
        return FaultPlan(ev)

    @staticmethod
    def partition(shard_id: int, at_request: int,
                  heal_at: Optional[int] = None) -> "FaultPlan":
        ev = [FaultEvent("partition", shard_id, at_request)]
        if heal_at is not None:
            ev.append(FaultEvent("heal", shard_id, heal_at))
        return FaultPlan(ev)
