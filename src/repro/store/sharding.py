"""``ShardedLatentBox`` — a multi-node LatentBox cluster as one backend.

The paper's fleet serves billions of requests by consistent-hash-placing
objects across independent store nodes; this module scales the single
``LatentBox`` backend the same way.  A sharded box owns S *shard backends*
(each a full :class:`~repro.store.backends.SimBackend` or
:class:`~repro.store.backends.EngineBackend` with its own GPU plant, caches
and tuner state) and routes every facade call to the shard that owns the
object.

The load-bearing design decision is the **global node namespace**: the
cluster has one flat fleet of nodes ``node0 .. node{S*K-1}`` and one global
consistent-hash ring over all of them; shard ``s`` simply *hosts* nodes
``[s*K, (s+1)*K)``, and an object's shard is the shard hosting its
globally-hashed owner node.  Because the owner among any subset of a
consistent-hash ring equals the global owner whenever the global owner is
in that subset, each shard's internal :class:`~repro.store.walk.TierWalk`
(built over its slice of the namespace via ``StoreConfig.node_names``)
resolves every object to exactly the node the *unsharded* fleet would pick.
Two consequences, both locked down by
``tests/test_shard_conformance.py``:

* **conformance** — per-node request subsequences are identical for any
  shard count, so a 1-shard and a 4-shard cluster classify every request
  of every scenario identically (the differential property);
* **bounded resharding** — adding a shard adds K nodes to the global ring,
  so only ~K/(N+K) of keys remap (consistent hashing), far below naive
  mod-N rehashing.

Shard add/remove migrates exactly the remapped keys: durable payload (or
size registration), recipe payload/accounting, and the demoted flag move;
cache warmth intentionally does not (a migrated key restarts cold on its
new shard, as it would in production).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.regen_tier import Recipe
from repro.core.router import ConsistentHashRing, parse_node_index
from repro.store.api import GetResult, ObjectStat, PutResult, StoreConfig

#: vnode count shared with the walks' internal :class:`Router` rings — the
#: subset-owner property needs identical vnode hashing on every ring.
_VNODES = 128


@dataclasses.dataclass(frozen=True)
class ReshardReport:
    """Key-movement accounting of one shard add/remove."""

    n_keys: int                      # keys tracked before the reshard
    n_moved: int                     # keys whose owner shard changed
    n_shards: int                    # shard count AFTER the reshard
    shard_id: int                    # the added / removed shard

    @property
    def moved_fraction(self) -> float:
        return self.n_moved / self.n_keys if self.n_keys else 0.0


@dataclasses.dataclass
class _Shard:
    """One shard: a full backend hosting a slice of the node namespace."""

    shard_id: int
    backend: Any
    node_names: Tuple[str, ...]


_global_node_index = parse_node_index    # names are 'node<global idx>'


class ShardedLatentBox:
    """Consistent-hash placement of objects over N per-shard backends.

    Implements the full backend protocol of the :class:`LatentBox` facade
    (``put/get_many/delete/demote/promote/stat/summary``), so
    ``LatentBox.simulated(cfg, shards=4)`` / ``LatentBox.engine(shards=4)``
    is a drop-in multi-node cluster.  ``config.n_nodes`` is the node count
    *per shard*.
    """

    name = "sharded"

    #: topology checkpoint of a persistent cluster (under ``data_dir``):
    #: shard ids, their node slices, and the allocation counters — so a
    #: reopened cluster reconstructs the EXACT hash topology (shard ids
    #: are never reused; node ranges survive earlier removals).
    CLUSTER_META = "CLUSTER.json"

    def __init__(self, backend_factory: Callable[[StoreConfig], Any],
                 n_shards: int, config: Optional[StoreConfig] = None):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        self.cfg = config or StoreConfig()
        if self.cfg.node_names is not None:
            raise ValueError("the sharded box owns the node namespace; "
                             "leave StoreConfig.node_names unset")
        self._factory = backend_factory
        self._nodes_per_shard = self.cfg.n_nodes
        self._next_node = 0
        self._next_shard_id = 0
        self.shards: Dict[int, _Shard] = {}
        self._shard_of_node: Dict[str, int] = {}
        self.ring = ConsistentHashRing([], vnodes=_VNODES)
        self._keys: Dict[int, int] = {}          # oid -> owning shard id
        meta = self._load_meta()
        if meta is not None:
            if n_shards != len(meta["shards"]):
                raise ValueError(
                    f"{self.cfg.data_dir} holds a {len(meta['shards'])}-"
                    f"shard cluster; reopen with shards="
                    f"{len(meta['shards'])} (got {n_shards}) and use "
                    "add_shard/remove_shard to change the topology")
            self._next_node = int(meta["next_node"])
            self._next_shard_id = int(meta["next_shard_id"])
            for row in meta["shards"]:
                self._spawn_shard(sid=int(row["shard_id"]),
                                  names=tuple(row["node_names"]))
            self._recover_keys()
        else:
            for _ in range(n_shards):
                self._spawn_shard()
            self._write_meta()

    # -- persistent-topology plumbing ----------------------------------------
    def _meta_path(self) -> Optional[str]:
        if self.cfg.data_dir is None:
            return None
        return os.path.join(self.cfg.data_dir, self.CLUSTER_META)

    def _load_meta(self) -> Optional[Dict[str, Any]]:
        p = self._meta_path()
        if p is None or not os.path.exists(p):
            return None
        with open(p) as f:
            return json.load(f)

    def _write_meta(self) -> None:
        p = self._meta_path()
        if p is None:
            return
        os.makedirs(self.cfg.data_dir, exist_ok=True)
        meta = {"next_node": self._next_node,
                "next_shard_id": self._next_shard_id,
                "nodes_per_shard": self._nodes_per_shard,
                "shards": [{"shard_id": sid,
                            "node_names": list(s.node_names)}
                           for sid, s in sorted(self.shards.items())]}
        tmp = p + ".tmp"
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, p)

    def _recover_keys(self) -> None:
        """Rebuild the oid -> shard map from each shard's recovered log
        (objects AND recipe-only entries), so resharding after a reopen
        migrates exactly what the pre-crash cluster would have."""
        for sid, shard in self.shards.items():
            log = getattr(shard.backend, "durable_log", None)
            if log is None:
                continue
            for oid in log.object_oids():
                self._keys[int(oid)] = sid
            for oid in log.recipe_states():
                self._keys[int(oid)] = sid

    # -- constructors --------------------------------------------------------
    @classmethod
    def simulated(cls, n_shards: int,
                  config: Optional[StoreConfig] = None) -> "ShardedLatentBox":
        from repro.store.backends import SimBackend
        return cls(SimBackend, n_shards, config)

    @classmethod
    def engine(cls, vae, n_shards: int,
               config: Optional[StoreConfig] = None) -> "ShardedLatentBox":
        """All shards share one ``vae`` instance, so the jitted decode
        compiles once per batch-bucket shape for the whole cluster."""
        from repro.store.backends import EngineBackend
        return cls(lambda cfg: EngineBackend(vae, cfg), n_shards, config)

    # -- topology ------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def shard_ids(self) -> List[int]:
        return sorted(self.shards)

    @property
    def n_nodes(self) -> int:
        return sum(len(s.node_names) for s in self.shards.values())

    def shard_of(self, oid: int) -> int:
        """The shard hosting this object's globally-hashed owner node."""
        return self._shard_of_node[self.ring.owner(int(oid))]

    def _spawn_shard(self, sid: Optional[int] = None,
                     names: Optional[Tuple[str, ...]] = None) -> _Shard:
        """Create (or, with explicit ``sid``/``names`` from the topology
        checkpoint, re-attach) one shard backend."""
        if names is None:
            k = self._nodes_per_shard
            names = tuple(f"node{self._next_node + i}" for i in range(k))
            self._next_node += k
        if sid is None:
            sid = self._next_shard_id
            self._next_shard_id += 1
        # a persistent cluster gives each shard its own segment-log
        # directory under the cluster root (shard ids never reuse, so a
        # re-added shard never inherits a dead shard's segments)
        data_dir = (os.path.join(self.cfg.data_dir, f"shard{sid:03d}")
                    if self.cfg.data_dir is not None else None)
        cfg = dataclasses.replace(self.cfg, node_names=names,
                                  data_dir=data_dir)
        shard = _Shard(sid, self._factory(cfg), names)
        self.shards[sid] = shard
        for n in names:
            self.ring.add_node(n)
            self._shard_of_node[n] = sid
        return shard

    # -- elastic resharding --------------------------------------------------
    def add_shard(self) -> ReshardReport:
        """Grow the cluster by one shard (K fresh global nodes); migrates
        exactly the keys whose ring owner moved onto the new nodes."""
        shard = self._spawn_shard()
        moved = self._migrate_remapped()
        self._write_meta()
        return ReshardReport(n_keys=len(self._keys), n_moved=moved,
                             n_shards=self.n_shards, shard_id=shard.shard_id)

    def remove_shard(self, shard_id: int) -> ReshardReport:
        """Drain and drop one shard: its nodes leave the global ring,
        every key it owned migrates to the key's new owner shard, and
        (persistent clusters) its sealed-and-drained log directory is
        closed and deleted — the drained segments hold only tombstoned
        state, so keeping them would leak dead bytes forever."""
        if shard_id not in self.shards:
            raise KeyError(f"no shard {shard_id}")
        if self.n_shards == 1:
            raise ValueError("cannot remove the last shard")
        victim = self.shards[shard_id]
        for n in victim.node_names:
            self.ring.remove_node(n)
            del self._shard_of_node[n]
        moved = self._migrate_remapped()
        del self.shards[shard_id]
        close = getattr(victim.backend, "close", None)
        if close is not None:
            close()
        vlog = getattr(victim.backend, "durable_log", None)
        if vlog is not None:
            shutil.rmtree(vlog.path, ignore_errors=True)
        self._write_meta()
        return ReshardReport(n_keys=len(self._keys), n_moved=moved,
                             n_shards=self.n_shards, shard_id=shard_id)

    def _migrate_remapped(self) -> int:
        # group the remapped keys into per-(src, dst) migration batches so
        # persistent shards ship each batch as ONE sealed segment instead
        # of per-key copies
        batches: Dict[Tuple[int, int], List[int]] = {}
        for oid, old_sid in list(self._keys.items()):
            new_sid = self.shard_of(oid)
            if new_sid != old_sid:
                batches.setdefault((old_sid, new_sid), []).append(oid)
        moved = 0
        for (old_sid, new_sid), oids in batches.items():
            src = self.shards[old_sid].backend
            dst = self.shards[new_sid].backend
            self._move_batch(oids, src, dst)
            for oid in oids:
                self._keys[oid] = new_sid
            moved += len(oids)
        return moved

    def _move_batch(self, oids: Sequence[int], src, dst) -> None:
        """Move one migration batch between shard backends.

        When both sides are log-structured (persistent cluster), the
        source *seals* the batch — the current blob/size + recipe records
        of every moved key, raw bytes, original payloads — and the
        destination ingests it as one fresh sealed segment file: no
        per-key put path, no decompress/re-encode, one fsync.  The source
        then tombstones the moved keys (dead bytes the next compaction
        step reclaims).  Memory-backed shards keep the per-key move.
        """
        slog = getattr(src, "durable_log", None)
        dlog = getattr(dst, "durable_log", None)
        if slog is None or dlog is None:
            for oid in oids:
                self._move(oid, src, dst)
            return
        applied = dlog.ingest_segment(slog.export_records(oids))
        for oid, state in applied["recipes"].items():
            dst.regen.restore_state(oid, state)
        for oid in oids:
            src.delete(oid)                    # tombstones + cache purge
        src.flush()

    @staticmethod
    def _move(oid: int, src, dst) -> None:
        """Move one object's durable/recipe state between shard backends.

        Cache residency and store warmth do NOT move: the key restarts
        cold at its new home, exactly like a production reshard.
        """
        st = src.store.stat(oid)
        blob = src.store.get(oid)
        recipe: Optional[Recipe] = src.regen.recipe_of(oid)
        recipe_nbytes = src.regen.recipe_bytes_of(oid)
        last_access_mo = src.regen.last_access_mo_of(oid)
        demoted = src.regen.is_demoted(oid)
        nbytes = st["nbytes"] if st else 0.0
        src.delete(oid)
        if st is not None:
            if blob is not None:
                dst.store.put(oid, blob)
            else:
                dst.store.put_size(oid, nbytes)
        if recipe_nbytes is not None:
            dst.regen.put(oid, nbytes, recipe=recipe,
                          recipe_nbytes=recipe_nbytes,
                          now_mo=last_access_mo or 0.0)
            if demoted:
                dst.regen.demote(oid)

    # -- backend protocol ----------------------------------------------------
    def put(self, oid: int, image=None, latent=None,
            recipe: Optional[Recipe] = None, nbytes: Optional[float] = None,
            prewarm: bool = False) -> PutResult:
        sid = self.shard_of(oid)
        res = self.shards[sid].backend.put(
            int(oid), image=image, latent=latent, recipe=recipe,
            nbytes=nbytes, prewarm=prewarm)
        self._keys[int(oid)] = sid
        return res

    def get_many(self, oids: Sequence[int],
                 timestamps_ms: Optional[Sequence[float]] = None
                 ) -> List[GetResult]:
        """Scatter a request window to the owning shards (order preserved
        within each shard) and gather results back into request order,
        with node indices remapped into the global namespace."""
        groups: Dict[int, List[int]] = {}
        for k, oid in enumerate(oids):
            groups.setdefault(self.shard_of(oid), []).append(k)
        out: List[Optional[GetResult]] = [None] * len(oids)
        for sid, idxs in groups.items():
            shard = self.shards[sid]
            sub = [int(oids[k]) for k in idxs]
            ts = ([float(timestamps_ms[k]) for k in idxs]
                  if timestamps_ms is not None else None)
            for k, r in zip(idxs,
                            shard.backend.get_many(sub, timestamps_ms=ts)):
                r.node = _global_node_index(shard.node_names[r.node])
                if r.exec_node >= 0:
                    r.exec_node = _global_node_index(
                        shard.node_names[r.exec_node])
                out[k] = r
        return out  # type: ignore[return-value]

    def delete(self, oid: int) -> bool:
        self._keys.pop(int(oid), None)
        return self.shards[self.shard_of(oid)].backend.delete(int(oid))

    def demote(self, oid: int) -> bool:
        return self.shards[self.shard_of(oid)].backend.demote(int(oid))

    def promote(self, oid: int) -> bool:
        return self.shards[self.shard_of(oid)].backend.promote(int(oid))

    def stat(self, oid: int) -> Optional[ObjectStat]:
        return self.shards[self.shard_of(oid)].backend.stat(int(oid))

    def flush(self) -> None:
        for sid in self.shard_ids:
            flush = getattr(self.shards[sid].backend, "flush", None)
            if flush is not None:
                flush()

    def close(self) -> None:
        for sid in self.shard_ids:
            close = getattr(self.shards[sid].backend, "close", None)
            if close is not None:
                close()

    # -- introspection -------------------------------------------------------
    def residency_shards(self, oid: int) -> List[int]:
        """Every shard holding ANY residency for ``oid`` — the conformance
        harness asserts this is at most the one owning shard (no
        cross-shard key leakage)."""
        return [sid for sid in self.shard_ids
                if self.shards[sid].backend.stat(int(oid)) is not None]

    def shard_summaries(self) -> Dict[int, Dict[str, Any]]:
        return {sid: self.shards[sid].backend.summary()
                for sid in self.shard_ids}

    _SUMMED = ("image_hit", "latent_hit", "full_miss", "regen_miss",
               "spilled", "total", "cache_resident_bytes", "durable_bytes",
               "recipe_bytes", "decode_batches", "decodes",
               "coalesced_decodes", "decompressions",
               "decompress_memo_hits", "pixel_cached_objects",
               "pixel_cached_bytes",
               # persistent clusters: on-disk truth sums across shard logs
               "durable_disk_bytes", "durable_live_bytes",
               "durable_segments", "segments_compacted")

    def summary(self) -> Dict[str, Any]:
        """Cluster-level stats: additive counters sum across shards, alpha
        reports per node in global order, hit fractions recompute from the
        summed counts (``shard_summaries()`` keeps the per-shard view)."""
        per = [self.shards[sid].backend.summary() for sid in self.shard_ids]
        out: Dict[str, Any] = {"n_shards": self.n_shards,
                               "n_nodes": self.n_nodes}
        for key in self._SUMMED:
            vals = [s[key] for s in per if key in s]
            if vals:
                out[key] = type(vals[0])(sum(vals))
        out["alpha"] = [a for s in per for a in s.get("alpha", [])]
        if "sim_clock_ms" in per[0]:
            out["sim_clock_ms"] = max(s["sim_clock_ms"] for s in per)
        total = out.get("total", 0)
        if total:
            out["image_hit_frac"] = out["image_hit"] / total
            out["decode_frac"] = 1.0 - out["image_hit_frac"]
        # ratio recomputes from the summed counters (a mean of per-shard
        # ratios would weight empty shards wrong)
        if out.get("pixel_cached_objects"):
            out["pixel_bytes_per_object"] = (
                out["pixel_cached_bytes"] / out["pixel_cached_objects"])
        elif per and "pixel_bytes_per_object" in per[0]:
            out["pixel_bytes_per_object"] = per[0]["pixel_bytes_per_object"]
        # cluster write amplification recomputes from the summed byte
        # counters (a mean of per-shard ratios would weight idle shards
        # wrong, same argument as the hit fractions above)
        logs = [lg for sid in self.shard_ids
                if (lg := getattr(self.shards[sid].backend,
                                  "durable_log", None)) is not None]
        if logs:
            user = sum(lg.user_bytes_written for lg in logs)
            rewrite = sum(lg.rewrite_bytes_written for lg in logs)
            out["write_amplification"] = ((user + rewrite) / user
                                          if user else 1.0)
        out.update(self._latency_stats())
        return out

    def _latency_stats(self) -> Dict[str, float]:
        """Exact cluster-level latency stats from the union of the shard
        backends' request logs (percentiles cannot be aggregated from
        per-shard summaries).  Empty for backends without a log (engine)."""
        lats: List[float] = []
        for sid in self.shard_ids:
            log = getattr(self.shards[sid].backend, "log", None)
            if log is None:
                return {}
            lats.extend(log.latency_ms)
        if not lats:
            return {}
        arr = np.asarray(lats)
        return {"mean_ms": float(arr.mean()),
                "p50_ms": float(np.percentile(arr, 50)),
                "p95_ms": float(np.percentile(arr, 95)),
                "p99_ms": float(np.percentile(arr, 99))}
