"""``ShardedLatentBox`` — a multi-node LatentBox cluster as one backend.

The paper's fleet serves billions of requests by consistent-hash-placing
objects across independent store nodes; this module scales the single
``LatentBox`` backend the same way.  A sharded box owns S *shard backends*
(each a full :class:`~repro.store.backends.SimBackend` or
:class:`~repro.store.backends.EngineBackend` with its own GPU plant, caches
and tuner state) and routes every facade call to the shard that owns the
object.

The load-bearing design decision is the **global node namespace**: the
cluster has one flat fleet of nodes ``node0 .. node{S*K-1}`` and one global
consistent-hash ring over all of them; shard ``s`` simply *hosts* nodes
``[s*K, (s+1)*K)``, and an object's shard is the shard hosting its
globally-hashed owner node.  Because the owner among any subset of a
consistent-hash ring equals the global owner whenever the global owner is
in that subset, each shard's internal :class:`~repro.store.walk.TierWalk`
(built over its slice of the namespace via ``StoreConfig.node_names``)
resolves every object to exactly the node the *unsharded* fleet would pick.
Two consequences, both locked down by
``tests/test_shard_conformance.py``:

* **conformance** — per-node request subsequences are identical for any
  shard count, so a 1-shard and a 4-shard cluster classify every request
  of every scenario identically (the differential property);
* **bounded resharding** — adding a shard adds K nodes to the global ring,
  so only ~K/(N+K) of keys remap (consistent hashing), far below naive
  mod-N rehashing.

Shard add/remove migrates exactly the remapped keys: durable payload (or
size registration), recipe payload/accounting, and the demoted flag move;
cache warmth intentionally does not (a migrated key restarts cold on its
new shard, as it would in production).

**Replication and fault tolerance** (``replication=R``): each object is
additionally shipped to the next R-1 *distinct shards* along the global
ring (``ring.successors``), which host per-source *replica holders*
(:mod:`repro.store.replication`).  The primary acks as before; followers
are updated write-behind, per mutation.  A dead shard
(:class:`~repro.store.faults.FaultPlan` ``kill``/``partition``) fails its
reads over to a *proxy* backend rebuilt from the live holders plus a
replay of the shard's request journal — so a degraded cluster classifies
every request exactly as the healthy one would, just slower.  Reads whose
primary exceeds an adaptive peer-latency percentile fire a *hedged*
speculative replica fetch (first response wins, decode stays
single-flight).  Dead shards keep their ring nodes: a fault changes
availability, never placement, which is what keeps ``shard_of`` stable and
the differential property intact under failure.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from collections import deque
from typing import (Any, Callable, Dict, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np

from repro.core.dual_cache import FULL_MISS
from repro.core.regen_tier import Recipe
from repro.core.router import ConsistentHashRing, parse_node_index
from repro.store.api import (REGEN_MISS, GetResult, ObjectStat, PutResult,
                             StoreConfig)
from repro.compression.ladder import resolve_rung
from repro.store.durable.segment import (BLOB, RDEL, RSTATE, RUNG, SIZE,
                                         TOMB, scan_records,
                                         unpack_rung_payload,
                                         unpack_size_rung)
from repro.store.faults import FaultEvent, FaultPlan
from repro.store.replication import (HedgeConfig, LogReplicaHolder,
                                     MemoryReplica, pack_state_records)

#: vnode count shared with the walks' internal :class:`Router` rings — the
#: subset-owner property needs identical vnode hashing on every ring.
_VNODES = 128


@dataclasses.dataclass(frozen=True)
class ReshardReport:
    """Key-movement accounting of one shard add/remove."""

    n_keys: int                      # keys tracked before the reshard
    n_moved: int                     # keys whose owner shard changed
    n_shards: int                    # shard count AFTER the reshard
    shard_id: int                    # the added / removed shard

    @property
    def moved_fraction(self) -> float:
        return self.n_moved / self.n_keys if self.n_keys else 0.0


@dataclasses.dataclass
class _Shard:
    """One shard: a full backend hosting a slice of the node namespace."""

    shard_id: int
    backend: Any
    node_names: Tuple[str, ...]


@dataclasses.dataclass
class _Downed:
    """Bookkeeping for one down shard.

    ``frontier`` snapshots, per holder *for* this source, the holder-local
    lsn at the source's last durability barrier (kill) or at the moment of
    partition — restart catch-up ships exactly the records after it back
    to the revived primary.
    """

    kind: str                                    # 'kill' | 'partition'
    backend: Any                                 # intact backend (partition)
    frontier: Dict[Tuple[int, int], int]
    proxy: Any                                   # failover backend or None


_global_node_index = parse_node_index    # names are 'node<global idx>'


class ShardedLatentBox:
    """Consistent-hash placement of objects over N per-shard backends.

    Implements the full backend protocol of the :class:`LatentBox` facade
    (``put/get_many/delete/demote/promote/stat/summary``), so
    ``LatentBox.simulated(cfg, shards=4)`` / ``LatentBox.engine(shards=4)``
    is a drop-in multi-node cluster.  ``config.n_nodes`` is the node count
    *per shard*.

    ``replication=R`` keeps every object on R distinct shards and enables
    failover + hedged reads; ``fault_plan`` scripts deterministic fault
    injection by global request index; ``hedge`` tunes the hedging policy.
    """

    name = "sharded"

    #: topology checkpoint of a persistent cluster (under ``data_dir``):
    #: shard ids, their node slices, and the allocation counters — so a
    #: reopened cluster reconstructs the EXACT hash topology (shard ids
    #: are never reused; node ranges survive earlier removals).
    CLUSTER_META = "CLUSTER.json"

    def __init__(self, backend_factory: Callable[[StoreConfig], Any],
                 n_shards: int, config: Optional[StoreConfig] = None, *,
                 replication: Optional[int] = None,
                 hedge: Optional[HedgeConfig] = None,
                 fault_plan: Optional[FaultPlan] = None):
        if n_shards < 1:
            raise ValueError("need at least one shard")
        self.cfg = config or StoreConfig()
        if self.cfg.node_names is not None:
            raise ValueError("the sharded box owns the node namespace; "
                             "leave StoreConfig.node_names unset")
        self._factory = backend_factory
        self._nodes_per_shard = self.cfg.n_nodes
        self._next_node = 0
        self._next_shard_id = 0
        self.shards: Dict[int, _Shard] = {}
        self._shard_of_node: Dict[str, int] = {}
        self.ring = ConsistentHashRing([], vnodes=_VNODES)
        self._keys: Dict[int, int] = {}          # oid -> owning shard id
        # -- replication / fault state ---------------------------------------
        self.hedge = hedge or HedgeConfig()
        self.fault_plan = fault_plan or FaultPlan()
        self._holders: Dict[Tuple[int, int], Any] = {}   # (follower, src)
        self._designated: Dict[Tuple[int, int], Set[int]] = {}
        self._dead: Dict[int, _Downed] = {}
        self._stalled: Dict[int, float] = {}             # sid -> extra ms
        self._journal: Dict[int, List[tuple]] = {}       # sid -> cache ops
        self._fwd_seq: Dict[int, int] = {}       # memory-source fwd stream
        self._incarnation: Dict[int, int] = {}   # sid -> restart count
        self._lat_window: Dict[int, deque] = {}  # sid -> recent total_ms
        self._req_index = 0                      # global request counter
        self.failovers = 0
        self.hedges_fired = 0
        self.hedge_wins = 0
        self.restarts = 0
        meta = self._load_meta()
        if meta is not None:
            if n_shards != len(meta["shards"]):
                raise ValueError(
                    f"{self.cfg.data_dir} holds a {len(meta['shards'])}-"
                    f"shard cluster; reopen with shards="
                    f"{len(meta['shards'])} (got {n_shards}) and use "
                    "add_shard/remove_shard to change the topology")
            mrep = int(meta.get("replication", 1))
            if replication is None:
                replication = mrep                   # inherit on reopen
            elif int(replication) != mrep:
                raise ValueError(
                    f"{self.cfg.data_dir} holds a replication={mrep} "
                    f"cluster (got replication={replication})")
            self.replication = int(replication)
            self._next_node = int(meta["next_node"])
            self._next_shard_id = int(meta["next_shard_id"])
            for row in meta["shards"]:
                self._spawn_shard(sid=int(row["shard_id"]),
                                  names=tuple(row["node_names"]))
            self._recover_keys()
            self._reconcile_on_open()
        else:
            self.replication = 1 if replication is None else int(replication)
            for _ in range(n_shards):
                self._spawn_shard()
            self._write_meta()
        if self.replication < 1:
            raise ValueError("replication must be >= 1")
        self._mode = next(iter(self.shards.values())).backend.name
        self._decode_ewma = float(self.cfg.decode_ms)
        # -- elastic shard autoscaling (off by default) -----------------------
        # composition: each shard backend already runs its own gpu/cache
        # controller (same cfg.autoscale flag); the CLUSTER runs a second
        # controller owning ONLY the shard knob, so the two never fight
        # over a dimension.  Scale-down safety is the guard hook: never
        # mid-reshard, never while a shard is dead, never below
        # replication R.
        self._resharding = False
        self.autoscaler = None
        if self.cfg.autoscale:
            from repro.core.autoscale import (AutoscaleConfig,
                                              AutoscaleController, PlantState)
            from repro.core.cost_model import params_for_store
            base = self.cfg.autoscale_cfg or dataclasses.replace(
                AutoscaleConfig(), params=params_for_store(self.cfg))
            acfg = dataclasses.replace(
                base, shard_knob=True, gpu_knob=False, cache_knob=False,
                min_shards=max(base.min_shards, self.replication))
            self.autoscaler = AutoscaleController(
                PlantState(self.cfg.gpus_per_node, self._nodes_per_shard,
                           self.cfg.cache_bytes_per_node,
                           n_shards=self.n_shards),
                acfg, shard_guard=self._scale_down_safe)
            self._as_mark: Dict[str, Any] = {"reqs": 0, "clock": 0.0,
                                             "busy": 0.0, "logs": {}}

    # -- persistent-topology plumbing ----------------------------------------
    def _meta_path(self) -> Optional[str]:
        if self.cfg.data_dir is None:
            return None
        return os.path.join(self.cfg.data_dir, self.CLUSTER_META)

    def _load_meta(self) -> Optional[Dict[str, Any]]:
        p = self._meta_path()
        if p is None:
            return None
        if os.path.exists(p + ".tmp"):
            os.remove(p + ".tmp")     # torn writer; the rename never ran
        if not os.path.exists(p):
            return None
        try:
            with open(p) as f:
                return json.load(f)
        except ValueError as e:
            raise ValueError(
                f"corrupt cluster meta {p} ({e}); the meta is written "
                "atomically (fsync + rename), so this means external "
                "truncation/corruption — restore CLUSTER.json before "
                "reopening") from e

    def _write_meta(self) -> None:
        p = self._meta_path()
        if p is None:
            return
        os.makedirs(self.cfg.data_dir, exist_ok=True)
        meta = {"next_node": self._next_node,
                "next_shard_id": self._next_shard_id,
                "nodes_per_shard": self._nodes_per_shard,
                "replication": self.replication,
                "shards": [{"shard_id": sid,
                            "node_names": list(s.node_names)}
                           for sid, s in sorted(self.shards.items())]}
        tmp = p + ".tmp"
        # atomic + durable: a kill at ANY point leaves either the old or
        # the new meta, never a torn one (satellite of the resilience PR)
        with open(tmp, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, p)

    def _recover_keys(self) -> None:
        """Rebuild the oid -> shard map from each shard's recovered log
        (objects AND recipe-only entries), so resharding after a reopen
        migrates exactly what the pre-crash cluster would have."""
        for sid, shard in self.shards.items():
            log = getattr(shard.backend, "durable_log", None)
            if log is None:
                continue
            for oid in log.object_oids():
                self._keys[int(oid)] = sid
            for oid in log.recipe_states():
                self._keys[int(oid)] = sid

    # -- constructors --------------------------------------------------------
    @classmethod
    def simulated(cls, n_shards: int,
                  config: Optional[StoreConfig] = None, *,
                  replication: Optional[int] = None,
                  hedge: Optional[HedgeConfig] = None,
                  fault_plan: Optional[FaultPlan] = None
                  ) -> "ShardedLatentBox":
        from repro.store.backends import SimBackend
        return cls(SimBackend, n_shards, config, replication=replication,
                   hedge=hedge, fault_plan=fault_plan)

    @classmethod
    def engine(cls, vae, n_shards: int,
               config: Optional[StoreConfig] = None, *,
               replication: Optional[int] = None,
               hedge: Optional[HedgeConfig] = None,
               fault_plan: Optional[FaultPlan] = None
               ) -> "ShardedLatentBox":
        """All shards share one ``vae`` instance, so the jitted decode
        compiles once per batch-bucket shape for the whole cluster."""
        from repro.store.backends import EngineBackend
        return cls(lambda cfg: EngineBackend(vae, cfg), n_shards, config,
                   replication=replication, hedge=hedge,
                   fault_plan=fault_plan)

    # -- topology ------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def shard_ids(self) -> List[int]:
        return sorted(self.shards)

    @property
    def live_shard_ids(self) -> List[int]:
        return [sid for sid in self.shard_ids if sid not in self._dead]

    @property
    def n_nodes(self) -> int:
        return sum(len(s.node_names) for s in self.shards.values())

    def shard_of(self, oid: int) -> int:
        """The shard hosting this object's globally-hashed owner node.
        Down shards keep their ring nodes — faults change availability,
        never placement — so this is stable across kill/restart."""
        return self._shard_of_node[self.ring.owner(int(oid))]

    def replica_shards(self, oid: int) -> List[int]:
        """The R distinct shards holding this object, primary first — the
        shards hosting the first R distinct-shard nodes along the ring
        walk from the object's hash position."""
        want = min(self.replication, self.n_shards)
        out: List[int] = []
        for node in self.ring.successors(int(oid)):
            sid = self._shard_of_node[node]
            if sid not in out:
                out.append(sid)
                if len(out) >= want:
                    break
        return out

    def _shard_cfg(self, sid: int, names: Tuple[str, ...]) -> StoreConfig:
        # a persistent cluster gives each shard its own segment-log
        # directory under the cluster root (shard ids never reuse, so a
        # re-added shard never inherits a dead shard's segments)
        data_dir = (os.path.join(self.cfg.data_dir, f"shard{sid:03d}")
                    if self.cfg.data_dir is not None else None)
        return dataclasses.replace(self.cfg, node_names=names,
                                   data_dir=data_dir)

    def _spawn_shard(self, sid: Optional[int] = None,
                     names: Optional[Tuple[str, ...]] = None) -> _Shard:
        """Create (or, with explicit ``sid``/``names`` from the topology
        checkpoint, re-attach) one shard backend."""
        if names is None:
            k = self._nodes_per_shard
            names = tuple(f"node{self._next_node + i}" for i in range(k))
            self._next_node += k
        if sid is None:
            sid = self._next_shard_id
            self._next_shard_id += 1
        shard = _Shard(sid, self._factory(self._shard_cfg(sid, names)),
                       names)
        self.shards[sid] = shard
        for n in names:
            self.ring.add_node(n)
            self._shard_of_node[n] = sid
        return shard

    # -- replication plumbing ------------------------------------------------
    def _holder_path(self, follower: int, src: int) -> str:
        return os.path.join(self.cfg.data_dir, f"shard{follower:03d}",
                            f"replica-of-{src:03d}")

    def _holder_for(self, follower: int, src: int):
        key = (follower, src)
        h = self._holders.get(key)
        if h is None:
            if self.cfg.data_dir is not None:
                h = LogReplicaHolder(self._holder_path(follower, src),
                                     segment_bytes=self.cfg.segment_bytes,
                                     fsync=self.cfg.fsync)
            else:
                h = MemoryReplica()
            h.src_inc = self._incarnation.get(src, 0)
            self._holders[key] = h
        return h

    def _acting_backend(self, sid: int):
        """The backend serving this shard's requests right now: the real
        backend, or — while the shard is down — its failover proxy."""
        down = self._dead.get(sid)
        if down is None:
            return self.shards[sid].backend
        if down.proxy is None:
            raise RuntimeError(
                f"shard {sid} is down ({down.kind}) and the cluster has "
                f"no replicas to fail over to "
                f"(replication={self.replication})")
        return down.proxy

    def _acting_or_none(self, sid: int):
        down = self._dead.get(sid)
        if down is None:
            return self.shards[sid].backend
        return down.proxy

    def _source_position(self, sid: int) -> int:
        """Current position of this source's forwarding stream (source lsn
        for log backends, the cluster-kept sequence for memory ones)."""
        src = self._acting_backend(sid)
        slog = getattr(src, "durable_log", None)
        return slog.next_lsn - 1 if slog is not None \
            else self._fwd_seq.get(sid, 0)

    def _export_from(self, src_sid: int, since: int, oids) -> bytes:
        """Raw record image of the given oids' current state from a
        source: lsn-delta from its log when persistent, full state packs
        when memory-backed (``since`` is then ignored — memory sources
        cannot address their history)."""
        src = self._acting_backend(src_sid)
        slog = getattr(src, "durable_log", None)
        if slog is not None:
            return slog.export_delta(since, oids=oids)
        parts = []
        seq = self._fwd_seq.get(src_sid, 0)
        for oid in sorted(int(o) for o in oids):
            if src.store.stat(oid) is None \
                    and src.regen.state_of(oid) is None:
                continue
            parts.append(pack_state_records(oid, src.store, src.regen,
                                            seq + 1))
            seq += 2
        self._fwd_seq[src_sid] = seq
        return b"".join(parts)

    def _forward(self, oid: int, sid: int) -> None:
        """Ship one object's current durable state to its follower
        holders — called after every mutation (put/delete/demote/promote
        and read-path regeneration), so a holder always mirrors the last
        mutation the primary applied."""
        if self.replication <= 1:
            return
        oid = int(oid)
        raw = None
        pos = 0
        for f in self.replica_shards(oid)[1:]:
            if f in self._dead:
                continue              # missed updates re-ship at revival
            h = self._holder_for(f, sid)
            if raw is None:
                raw = self._export_from(sid, 0, {oid})
                pos = self._source_position(sid)
            if raw:
                h.apply_records(raw, pos)
            self._designated.setdefault((f, sid), set()).add(oid)
            if h.kind == "memory":
                h.checkpoint()

    def _checkpoint_source(self, sid: int) -> None:
        """Durability barrier for one source: flush it, then checkpoint
        every live holder that follows it (advancing their
        ``durable_frontier`` is only sound once the source records they
        mirror are on the source's own disk)."""
        src = self._acting_or_none(sid)
        if src is not None:
            flush = getattr(src, "flush", None)
            if flush is not None:
                flush()
        for (f, s2), h in self._holders.items():
            if s2 == sid and f not in self._dead:
                h.checkpoint()

    def _desired_designation(self) -> Dict[Tuple[int, int], Set[int]]:
        want: Dict[Tuple[int, int], Set[int]] = {}
        if self.replication <= 1:
            return want
        for oid, src in self._keys.items():
            for f in self.replica_shards(oid)[1:]:
                want.setdefault((f, src), set()).add(int(oid))
        return want

    def _sync_replicas(self) -> None:
        """Reconcile holders with the desired (follower, source) -> oids
        designation after a topology change: discard de-designated
        objects, drop unwanted holders, full-ship newly designated state.
        Resharding refuses to run while shards are down, so this only
        ever sees a fully live cluster."""
        if self.replication <= 1:
            return
        want = self._desired_designation()
        for key, cur in list(self._designated.items()):
            tgt = want.get(key, set())
            stale = cur - tgt
            h = self._holders.get(key)
            if h is not None:
                for oid in stale:
                    h.discard(oid)
            if stale:
                self._designated[key] = cur & tgt
        for key in [k for k in self._holders if k not in want]:
            h = self._holders.pop(key)
            self._designated.pop(key, None)
            h.close()
            if h.kind == "log":
                shutil.rmtree(h.path, ignore_errors=True)
        for key, tgt in want.items():
            f, src = key
            cur = self._designated.get(key, set())
            h = self._holder_for(f, src)
            new = tgt - cur
            if new:
                raw = self._export_from(src, 0, new)
                if raw:
                    h.apply_records(raw, self._source_position(src))
            h.set_hwm(self._source_position(src))
            h.src_inc = self._incarnation.get(src, 0)
            self._designated[key] = set(tgt)
            h.checkpoint()

    def _reconcile_on_open(self) -> None:
        """Process reopen of a replicated persistent cluster.

        A crash may have cost a primary its unflushed write-behind tail
        while a holder still has that state (forwards are per-mutation),
        and cost a holder records the primary kept.  Equalize both
        directions: ship each holder's post-checkpoint tail back to its
        primary, then each primary's post-hwm delta to the holder, and
        rebase the hwm (the primary's lsn space may have shifted down
        with the truncated tail)."""
        if self.replication <= 1:
            return
        want = self._desired_designation()
        for (f, src), _tgt in want.items():
            h = self._holder_for(f, src)
            raw = h.export_delta(h.durable_frontier, h.live_oids())
            if raw:
                primary = self.shards[src].backend
                for oid in self._apply_shipped(primary, raw):
                    if primary.store.stat(int(oid)) is not None \
                            or primary.regen.state_of(int(oid)) is not None:
                        self._keys[int(oid)] = src
        want = self._desired_designation()   # recovered keys may be new
        for (f, src), tgt in want.items():
            h = self._holder_for(f, src)
            pos = self._source_position(src)
            raw = self._export_from(src, h.hwm, tgt)
            if raw:
                h.apply_records(raw, pos)
            h.set_hwm(self._source_position(src))
            self._designated[(f, src)] = set(tgt)
            h.checkpoint()
        self._sync_replicas()

    def _apply_shipped(self, backend, raw: bytes) -> Set[int]:
        """Apply a shipped raw record image to a backend's durable state
        (no cache side effects); returns the affected oids.  Corrupt
        input raises before any state is applied."""
        affected: Set[int] = set()
        log = getattr(backend, "durable_log", None)
        if log is not None:
            applied = log.ingest_segment(raw)
            for oid, state in applied["recipes"].items():
                backend.regen.restore_state(oid, state)
            for oid in applied["removed_recipes"]:
                backend.regen.forget(int(oid))
            for k in ("objects", "removed_objects", "removed_recipes"):
                affected.update(int(o) for o in applied[k])
            affected.update(int(o) for o in applied["recipes"])
            return affected
        recs, valid_end = scan_records(raw, 0)
        if valid_end != len(raw):
            raise ValueError(
                f"shipped records are corrupt: checksum/framing failure "
                f"at byte {valid_end} of {len(raw)}; nothing applied")
        for r in recs:
            if r.kind == BLOB:
                backend.store.put(r.oid, r.payload)
            elif r.kind == SIZE:
                nbytes, rung = unpack_size_rung(r.payload)
                backend.store.put_size(r.oid, nbytes, rung=rung)
            elif r.kind == RUNG:
                # memory backends apply ladder intents eagerly; a target
                # at/above the current rung is already-applied state
                backend.store.set_target_rung(
                    r.oid, unpack_rung_payload(r.payload))
            elif r.kind == TOMB:
                backend.store.delete(r.oid)
            elif r.kind == RSTATE:
                backend.regen.restore_state(
                    r.oid, json.loads(r.payload.decode()))
            elif r.kind == RDEL:
                backend.regen.forget(r.oid)
            affected.add(int(r.oid))
        return affected

    def _purge_cached(self, backend, oid: int) -> None:
        """Drop every cached trace of one object from a backend (tiers,
        engine payloads, decode memo) — durable state is untouched."""
        for tier in backend.walk.caches:
            tier.evict(oid)
        eng = getattr(backend, "engine", None)
        if eng is not None:
            for node in eng.nodes:
                node.drop_payloads(oid)
            eng.batcher.forget(oid)

    # -- failure injection ---------------------------------------------------
    def _apply_event(self, e: FaultEvent) -> None:
        if e.kind == "kill":
            self.kill_shard(e.shard_id)
        elif e.kind == "partition":
            self.partition_shard(e.shard_id)
        elif e.kind in ("restart", "heal"):
            self.restart_shard(e.shard_id)
        elif e.kind == "stall":
            self.stall_shard(e.shard_id, e.stall_ms)

    def stall_shard(self, sid: int, stall_ms: float) -> None:
        """Inject ``stall_ms`` of extra latency into every answer from
        this shard (0 clears) — the one-slow-replica scenario."""
        if sid not in self.shards:
            raise KeyError(f"no shard {sid}")
        if stall_ms > 0:
            self._stalled[sid] = float(stall_ms)
        else:
            self._stalled.pop(sid, None)

    def kill_shard(self, sid: int) -> None:
        """The shard process dies: its unflushed write-behind tail is
        lost (``SegmentLog.abandon`` — memory shards lose everything),
        as are the replica holders it hosted.  Reads fail over to a
        proxy rebuilt from the surviving holders."""
        self._down(sid, "kill")

    def partition_shard(self, sid: int) -> None:
        """The shard is unreachable but intact: no data loss, but reads
        fail over exactly as for a kill until :meth:`restart_shard`."""
        self._down(sid, "partition")

    def _down(self, sid: int, kind: str) -> None:
        if sid not in self.shards:
            raise KeyError(f"no shard {sid}")
        if sid in self._dead:
            raise ValueError(f"shard {sid} is already down")
        backend = self.shards[sid].backend
        # snapshot, per holder following this source, the holder-local
        # frontier restart catch-up will ship back from: for a kill only
        # source-durable records survive on the source, so everything
        # after the durable frontier may be the lost tail; a partition
        # loses nothing, only the updates made while unreachable.
        frontier = {}
        for (f, src), h in self._holders.items():
            if src == sid and f not in self._dead:
                frontier[(f, src)] = (h.durable_frontier if kind == "kill"
                                      else h.frontier)
        if kind == "kill":
            log = getattr(backend, "durable_log", None)
            if log is not None:
                log.abandon()         # NOT close(): close would flush
            for key in [k for k in self._holders if k[0] == sid]:
                h = self._holders.pop(key)
                h.abandon()
                if h.kind == "memory":
                    self._designated.pop(key, None)
            kept = None
        else:
            kept = backend
        proxy = self._build_proxy(sid) if self.replication > 1 else None
        self._dead[sid] = _Downed(kind=kind, backend=kept,
                                  frontier=frontier, proxy=proxy)
        self._stalled.pop(sid, None)

    def restart_shard(self, sid: int) -> None:
        """Revive a down shard: recover from its own log (kill) or rejoin
        intact (partition/heal), catch up on missed state from its peers'
        holders via delta segment shipping, and rebuild the holders it
        hosted.  The revived shard is cache-cold, exactly like a real
        restarted process."""
        down = self._dead.get(sid)
        if down is None:
            raise ValueError(f"shard {sid} is not down")
        shard = self.shards[sid]
        self._incarnation[sid] = self._incarnation.get(sid, 0) + 1
        if down.kind == "partition":
            backend = down.backend
        else:
            backend = self._factory(self._shard_cfg(sid, shard.node_names))
        shard.backend = backend
        del self._dead[sid]
        self.restarts += 1
        persistent = getattr(backend, "durable_log", None) is not None
        full = down.kind == "kill" and not persistent
        self._catch_up_primary(sid, backend, down.frontier, full=full)
        # cache-cold on rejoin: a killed shard's caches are empty anyway;
        # a healed partition's are stale (the proxy evolved cache state
        # while it was fenced), so invalidate them wholesale
        for oid, src in self._keys.items():
            if src == sid:
                self._purge_cached(backend, int(oid))
        self._journal[sid] = []       # journal mirrors the fresh backend
        self._resync_after_revival(sid)

    def _catch_up_primary(self, sid: int, backend,
                          frontier: Dict[Tuple[int, int], int],
                          full: bool) -> None:
        """Ship each live holder's post-frontier designated records back
        to the revived primary — the write-behind tail a kill lost, or
        everything a partition missed (``full``: memory-mode kill, ship
        the complete designated state)."""
        for (f, src), h in self._holders.items():
            if src != sid or f in self._dead:
                continue
            desig = self._designated.get((f, src), set())
            if not desig:
                continue
            since = 0 if full else frontier.get((f, src), 0)
            raw = h.export_delta(since, desig)
            if not raw:
                continue
            for oid in self._apply_shipped(backend, raw):
                self._purge_cached(backend, int(oid))
                if backend.store.stat(int(oid)) is not None \
                        or backend.regen.state_of(int(oid)) is not None:
                    self._keys[int(oid)] = sid
        flush = getattr(backend, "flush", None)
        if flush is not None:
            flush()

    def _resync_after_revival(self, sid: int) -> None:
        """After a restart/heal: rebase the stream marks of holders that
        follow this (possibly lsn-shifted) source, and rebuild the
        holders this shard hosts for its peers."""
        if self.replication <= 1:
            return
        inc = self._incarnation.get(sid, 0)
        pos = self._source_position(sid)
        for (f, src), h in self._holders.items():
            if src == sid and f not in self._dead:
                h.set_hwm(pos)        # lsn space may have shifted DOWN
                h.src_inc = inc
                h.checkpoint()
        want = self._desired_designation()
        for (f, src), tgt in want.items():
            if f != sid or src in self._dead or not tgt:
                continue
            h = self._holder_for(f, src)
            cur = self._designated.get((f, src), set())
            src_inc = self._incarnation.get(src, 0)
            # hwm deltas are only meaningful against the same source
            # incarnation AND for continuously designated objects; ship
            # everything else as full current state
            cont = tgt & cur if h.src_inc == src_inc else set()
            spos = self._source_position(src)
            if cont:
                raw = self._export_from(src, h.hwm, cont)
                if raw:
                    h.apply_records(raw, spos)
            new = tgt - cont
            if new:
                raw = self._export_from(src, 0, new)
                if raw:
                    h.apply_records(raw, spos)
            h.set_hwm(self._source_position(src))
            h.src_inc = src_inc
            self._designated[(f, src)] = set(tgt)
            h.checkpoint()

    def _designated_holder_of(self, oid: int, sid: int):
        """The first live holder with this (dead) shard's object."""
        for f in self.replica_shards(oid)[1:]:
            if f in self._dead:
                continue
            h = self._holders.get((f, sid))
            if h is not None and h.contains_any(oid):
                return h
        return None

    def _build_proxy(self, sid: int):
        """Stand-in backend for a down shard: durable/recipe state from
        the live replica holders, cache state by replaying the shard's
        request journal — so failover reads classify exactly as the dead
        shard would have."""
        shard = self.shards[sid]
        cfg = dataclasses.replace(self.cfg, node_names=shard.node_names,
                                  data_dir=None)
        proxy = self._factory(cfg)
        for oid, src in self._keys.items():
            if src != sid:
                continue
            h = self._designated_holder_of(oid, sid)
            if h is None:
                continue
            oid = int(oid)
            blob = h.blob_of(oid)
            if blob is not None:
                proxy.store.put(oid, blob)
            else:
                sz = h.size_of(oid)
                if sz is not None:
                    proxy.store.put_size(oid, sz)
            st = h.recipe_state_of(oid)
            if st is not None:
                proxy.regen.restore_state(oid, st)
        self._replay_journal(proxy, sid)
        return proxy

    def _replay_journal(self, backend, sid: int) -> None:
        """Re-run the shard's cache-state history against a fresh proxy.

        Ops: ``("g", oid, hit_class, image_nbytes)`` per get, ``("x",
        oid)`` per put-overwrite/delete/demote, ``("pw", oid, nbytes)``
        per prewarm.  Cache transitions depend only on the op sequence
        and entry sizes, both of which the journal carries, so the proxy
        ends bit-identical in classification state."""
        walk = backend.walk
        eng = getattr(backend, "engine", None)
        for op in self._journal.get(sid, ()):
            tag, oid = op[0], int(op[1])
            if tag == "x":
                self._purge_cached(backend, oid)
            elif tag == "pw":
                nb = op[2]
                owner = walk._idx[walk.router.ring.owner(oid)]
                if eng is not None:
                    eng.nodes[owner].cache.insert_image(
                        oid, nbytes=(nb if nb is not None
                                     else self.cfg.image_bytes))
                else:
                    walk.caches[owner].store(oid, format="image")
            else:                     # "g"
                _, _, hit_class, nb = op
                owner = walk._idx[walk.router.ring.owner(oid)]
                tier = walk.caches[owner]
                tier.load(oid)
                if hit_class in (FULL_MISS, REGEN_MISS):
                    walk.admit_latent(owner, oid)
                if nb is not None and tier.cache.contains(oid) == "image":
                    tier.cache.set_image_nbytes(oid, nb)
                walk.counts[hit_class] = walk.counts.get(hit_class, 0) + 1

    # -- elastic resharding --------------------------------------------------
    def _check_reshardable(self) -> None:
        if self._dead:
            raise RuntimeError(
                f"cannot reshard while shards are down: "
                f"{sorted(self._dead)} (restart/heal them first)")

    def add_shard(self) -> ReshardReport:
        """Grow the cluster by one shard (K fresh global nodes); migrates
        exactly the keys whose ring owner moved onto the new nodes."""
        self._check_reshardable()
        self._resharding = True
        try:
            shard = self._spawn_shard()
            moved = self._migrate_remapped()
            self._write_meta()
            self._sync_replicas()
        finally:
            self._resharding = False
        return ReshardReport(n_keys=len(self._keys), n_moved=moved,
                             n_shards=self.n_shards, shard_id=shard.shard_id)

    def remove_shard(self, shard_id: int) -> ReshardReport:
        """Drain and drop one shard: its nodes leave the global ring,
        every key it owned migrates to the key's new owner shard, and
        (persistent clusters) its sealed-and-drained log directory is
        closed and deleted — the drained segments hold only tombstoned
        state, so keeping them would leak dead bytes forever."""
        if shard_id not in self.shards:
            raise KeyError(f"no shard {shard_id}")
        if self.n_shards == 1:
            raise ValueError("cannot remove the last shard")
        self._check_reshardable()
        self._resharding = True
        try:
            victim = self.shards[shard_id]
            for n in victim.node_names:
                self.ring.remove_node(n)
                del self._shard_of_node[n]
            moved = self._migrate_remapped()
            del self.shards[shard_id]
            # holders hosted on the victim close before its directory goes
            for key in [k for k in self._holders if k[0] == shard_id]:
                self._holders.pop(key).close()
                self._designated.pop(key, None)
            close = getattr(victim.backend, "close", None)
            if close is not None:
                close()
            vlog = getattr(victim.backend, "durable_log", None)
            if vlog is not None:
                shutil.rmtree(vlog.path, ignore_errors=True)
            self._stalled.pop(shard_id, None)
            self._journal.pop(shard_id, None)
            self._lat_window.pop(shard_id, None)
            self._write_meta()
            self._sync_replicas()     # drops holders FOR the victim too
        finally:
            self._resharding = False
        return ReshardReport(n_keys=len(self._keys), n_moved=moved,
                             n_shards=self.n_shards, shard_id=shard_id)

    def _migrate_remapped(self) -> int:
        # group the remapped keys into per-(src, dst) migration batches so
        # persistent shards ship each batch as ONE sealed segment instead
        # of per-key copies
        batches: Dict[Tuple[int, int], List[int]] = {}
        for oid, old_sid in list(self._keys.items()):
            new_sid = self.shard_of(oid)
            if new_sid != old_sid:
                batches.setdefault((old_sid, new_sid), []).append(oid)
        moved = 0
        for (old_sid, new_sid), oids in batches.items():
            src = self.shards[old_sid].backend
            dst = self.shards[new_sid].backend
            self._move_batch(oids, src, dst)
            for oid in oids:
                self._keys[oid] = new_sid
            moved += len(oids)
        return moved

    def _move_batch(self, oids: Sequence[int], src, dst) -> None:
        """Move one migration batch between shard backends.

        When both sides are log-structured (persistent cluster), the
        source *seals* the batch — the current blob/size + recipe records
        of every moved key, raw bytes, original payloads — and the
        destination ingests it as one fresh sealed segment file: no
        per-key put path, no decompress/re-encode, one fsync.  The source
        then tombstones the moved keys (dead bytes the next compaction
        step reclaims).  Memory-backed shards keep the per-key move.
        """
        slog = getattr(src, "durable_log", None)
        dlog = getattr(dst, "durable_log", None)
        if slog is None or dlog is None:
            for oid in oids:
                self._move(oid, src, dst)
            return
        applied = dlog.ingest_segment(slog.export_records(oids))
        for oid, state in applied["recipes"].items():
            dst.regen.restore_state(oid, state)
        for oid in oids:
            src.delete(oid)                    # tombstones + cache purge
        src.flush()

    @staticmethod
    def _move(oid: int, src, dst) -> None:
        """Move one object's durable/recipe state between shard backends.

        Cache residency and store warmth do NOT move: the key restarts
        cold at its new home, exactly like a production reshard.
        """
        st = src.store.stat(oid)
        blob = src.store.get(oid)
        recipe: Optional[Recipe] = src.regen.recipe_of(oid)
        recipe_nbytes = src.regen.recipe_bytes_of(oid)
        last_access_mo = src.regen.last_access_mo_of(oid)
        demoted = src.regen.is_demoted(oid)
        nbytes = st["nbytes"] if st else 0.0
        src.delete(oid)
        if st is not None:
            if blob is not None:
                dst.store.put(oid, blob)     # rung travels in the bytes
            else:
                dst.store.put_size(oid, nbytes,
                                   rung=st.get("rung") or 0)
        if recipe_nbytes is not None:
            dst.regen.put(oid, nbytes, recipe=recipe,
                          recipe_nbytes=recipe_nbytes,
                          now_mo=last_access_mo or 0.0)
            if demoted:
                dst.regen.demote(oid)

    # -- backend protocol ----------------------------------------------------
    def put(self, oid: int, image=None, latent=None,
            recipe: Optional[Recipe] = None, nbytes: Optional[float] = None,
            prewarm: bool = False) -> PutResult:
        oid = int(oid)
        sid = self.shard_of(oid)
        backend = self._acting_backend(sid)
        res = backend.put(oid, image=image, latent=latent, recipe=recipe,
                          nbytes=nbytes, prewarm=prewarm)
        self._keys[oid] = sid
        if self.replication > 1:
            jrnl = self._journal.setdefault(sid, [])
            jrnl.append(("x", oid))   # overwrite purge (no-op when fresh)
            if res.prewarmed:
                jrnl.append(("pw", oid,
                             backend.walk.pixel_bytes_of(oid) or None))
            self._forward(oid, sid)
            if res.durable:
                self._checkpoint_source(sid)
        return res

    def get_many(self, oids: Sequence[int],
                 timestamps_ms: Optional[Sequence[float]] = None
                 ) -> List[GetResult]:
        """Serve one request window, splitting it at every fault-plan
        boundary: scheduled events fire *before* the request index they
        name, so an injected run is exactly reproducible."""
        oids = [int(o) for o in oids]
        out: List[Optional[GetResult]] = [None] * len(oids)
        i = 0
        while i < len(oids):
            for e in self.fault_plan.pop_due(self._req_index):
                self._apply_event(e)
            n = len(oids) - i
            nxt = self.fault_plan.next_boundary(self._req_index)
            if nxt is not None:
                n = min(n, nxt - self._req_index)
            ts = (timestamps_ms[i:i + n]
                  if timestamps_ms is not None else None)
            for k, r in enumerate(self._serve_segment(oids[i:i + n], ts)):
                out[i + k] = r
            i += n
            self._req_index += n
        if self.autoscaler is not None:
            self._autoscale_step()
        return out  # type: ignore[return-value]

    # -- cluster-level elastic autoscaling (the shard knob) ------------------
    def _scale_down_safe(self) -> bool:
        """Scale-down safety hook handed to the controller: a shard may
        only be removed from a fully live, quiescent cluster with live
        shards to spare beyond the replication factor."""
        return (not self._dead and not self._resharding
                and self.n_shards > 1
                and len(self.live_shard_ids) > self.replication)

    def _cluster_busy_ms(self) -> float:
        busy = 0.0
        for sid in self.live_shard_ids:
            b = self.shards[sid].backend
            if hasattr(b, "gpus"):                       # sim backend
                busy += sum(q.busy_ms for q in b.gpus)
            else:                                        # engine backend
                busy += b.engine.batcher.busy_ms
        return busy

    def _cluster_clock_ms(self) -> float:
        clocks = [b.clock_ms for sid in self.live_shard_ids
                  if hasattr(b := self.shards[sid].backend, "clock_ms")]
        if clocks:
            return max(clocks)
        return self.cfg.now_s() * 1e3                    # engine: wall clock

    def _autoscale_step(self) -> None:
        from repro.core.autoscale import WindowObs
        mark = self._as_mark
        if self._req_index - mark["reqs"] < self.autoscaler.cfg.window:
            return
        if self._dead or self._resharding:
            return                     # observe only a quiescent cluster
        clock = self._cluster_clock_ms()
        busy = self._cluster_busy_ms()
        # queue-delay tail over the window: per-shard log tails since each
        # shard's last mark (engine shards have no plant log -> no signal)
        samples: List[float] = []
        log_marks: Dict[int, int] = {}
        for sid in self.live_shard_ids:
            log = getattr(self.shards[sid].backend, "log", None)
            if log is None:
                continue
            n = len(log.queue_ms)
            samples.extend(log.queue_ms[mark["logs"].get(sid, 0):n])
            log_marks[sid] = n
        obs = WindowObs(
            requests=self._req_index - mark["reqs"],
            span_ms=max(0.0, clock - mark["clock"]),
            # busy can regress when a shard (and its counters) was removed
            busy_ms=max(0.0, busy - mark["busy"]),
            decode_frac=1.0,
            queue_p99_ms=(float(np.percentile(np.asarray(samples), 99))
                          if samples else 0.0))
        self._as_mark = {"reqs": self._req_index, "clock": clock,
                         "busy": busy, "logs": log_marks}
        ev = self.autoscaler.step(obs)
        if ev is None:
            return
        if ev.action == "shard_up":
            self.add_shard()
        elif ev.action == "shard_down":
            self.remove_shard(max(self.live_shard_ids))
        # topology changed under the marks: restart the window cleanly
        self._as_mark = {"reqs": self._req_index,
                         "clock": self._cluster_clock_ms(),
                         "busy": self._cluster_busy_ms(), "logs": {}}
        # keep the controller's plant in lockstep with reality (an action
        # other than the shard knob cannot happen here, but be exact)
        if self.autoscaler.state.n_shards != self.n_shards:
            self.autoscaler.state = dataclasses.replace(
                self.autoscaler.state, n_shards=self.n_shards)

    def _serve_segment(self, oids: List[int],
                       timestamps_ms) -> List[GetResult]:
        """Scatter one fault-free stretch of requests to the acting shard
        backends (order preserved within each shard), gather back into
        request order with node indices remapped into the global
        namespace, then apply the resilience post-passes: stall latency,
        hedging, journaling, and regeneration forwarding."""
        replicated = self.replication > 1
        groups: Dict[int, List[int]] = {}
        for k, oid in enumerate(oids):
            groups.setdefault(self.shard_of(oid), []).append(k)
        out: List[Optional[GetResult]] = [None] * len(oids)
        for sid, idxs in groups.items():
            shard = self.shards[sid]
            down = self._dead.get(sid)
            backend = self._acting_backend(sid)
            sub = [oids[k] for k in idxs]
            ts = ([float(timestamps_ms[k]) for k in idxs]
                  if timestamps_ms is not None else None)
            stall = self._stalled.get(sid, 0.0)
            jrnl = self._journal.setdefault(sid, []) if replicated else None
            win = None
            if replicated:
                win = self._lat_window.setdefault(
                    sid, deque(maxlen=self.hedge.window))
            for k, oid, r in zip(idxs, sub,
                                 backend.get_many(sub, timestamps_ms=ts)):
                r.node = _global_node_index(shard.node_names[r.node])
                if r.exec_node >= 0:
                    r.exec_node = _global_node_index(
                        shard.node_names[r.exec_node])
                if down is not None:
                    r.failover = True
                    self.failovers += 1
                if stall:
                    r.latency_ms["stall"] = stall
                    r.latency_ms["total"] = r.total_ms + stall
                dec = r.latency_ms.get("decode", 0.0)
                if dec > 0.0:
                    self._decode_ewma = 0.9 * self._decode_ewma + 0.1 * dec
                self._maybe_hedge(sid, oid, r)
                if replicated:
                    jrnl.append(("g", oid, r.hit_class,
                                 float(r.payload.nbytes)
                                 if r.payload is not None else None))
                    win.append(r.total_ms)
                    if r.regenerated:
                        # read-path regeneration is a hidden durable
                        # mutation (readmitted latent) — replicate it
                        self._forward(oid, sid)
                out[k] = r
        if replicated:
            for sid in groups:
                self._checkpoint_source(sid)
        return out  # type: ignore[return-value]

    # -- hedged reads --------------------------------------------------------
    def _hedge_delay_ms(self, sid: int) -> Optional[float]:
        """Adaptive hedge delay for reads served by ``sid``: a percentile
        of the OTHER live shards' recent latencies — a stalling shard
        cannot talk the cluster out of hedging against it.  None until
        enough peer samples exist."""
        samples: List[float] = []
        for other, win in self._lat_window.items():
            if other != sid and other not in self._dead:
                samples.extend(win)
        if len(samples) < self.hedge.min_samples:
            return None
        return max(self.hedge.min_delay_ms,
                   float(np.percentile(np.asarray(samples),
                                       100.0 * self.hedge.quantile)))

    def _hedge_fetch_ms(self, oid: int, rep_sid: int, holder) -> float:
        """Cost of the speculative replica fetch leg.  Engine: measured
        wall clock of the actual holder read (the blob really is read —
        hedging is the fetch race).  Sim: a seeded cold-read draw from
        the cluster's store-latency model, deterministic per (oid,
        replica)."""
        if self._mode == "engine":
            t0 = time.perf_counter()
            holder.blob_of(oid)
            return (time.perf_counter() - t0) * 1e3
        m = self.cfg.store_latency
        rng = np.random.default_rng((self.cfg.seed, 0x48ED6E,
                                     int(oid) & 0xFFFFFFFF, rep_sid))
        base = max(float(rng.lognormal(np.log(m.cold_ms), m.sigma)),
                   m.first_byte_floor_ms)
        sz = holder.size_of(oid) or self.cfg.latent_bytes
        return base + sz / (m.bandwidth_mb_s * 1e6) * 1e3

    def _maybe_hedge(self, sid: int, oid: int, r: GetResult) -> None:
        """Post-hoc hedged-read accounting: when the primary's answer
        exceeded the hedge delay, a speculative fetch to the next live
        replica would have been in flight; if the modeled replica path
        beats the primary, the request's latency is the hedged one.
        Only latency changes — the primary still produced the (single)
        decode and all cache transitions, so hedging can never perturb
        classification, pixels, or decode counts."""
        hc = self.hedge
        if (not hc.enabled or self.replication <= 1 or r.failover
                or self.n_shards < 2):
            return
        delay = self._hedge_delay_ms(sid)
        if delay is None or r.total_ms <= delay:
            return
        target, holder = None, None
        for f in self.replica_shards(oid)[1:]:
            if f in self._dead:
                continue
            h = self._holders.get((f, sid))
            if h is not None and h.contains_any(oid):
                target, holder = f, h
                break
        if target is None:
            return
        self.hedges_fired += 1
        fetch = self._hedge_fetch_ms(oid, target, holder)
        decode = r.latency_ms.get("decode", 0.0)
        if decode <= 0.0:             # replica must decode even our hits
            decode = self._decode_ewma
        t_hedge = (delay + hc.net_hop_ms + fetch + decode
                   + r.latency_ms.get("regen", 0.0)
                   + r.latency_ms.get("net", 0.0)
                   + self._stalled.get(target, 0.0))
        if t_hedge < r.total_ms:
            self.hedge_wins += 1
            r.hedged = True
            r.latency_ms["unhedged_total"] = r.total_ms
            r.latency_ms["hedge_fetch"] = fetch
            r.latency_ms["total"] = t_hedge

    # -- remaining backend protocol ------------------------------------------
    def delete(self, oid: int) -> bool:
        oid = int(oid)
        sid = self.shard_of(oid)
        self._keys.pop(oid, None)
        found = self._acting_backend(sid).delete(oid)
        if self.replication > 1:
            self._journal.setdefault(sid, []).append(("x", oid))
            self._forward(oid, sid)   # ships the tombstones
            if not self.cfg.write_behind:
                self._checkpoint_source(sid)
        return found

    def demote(self, oid: int, rung=None) -> bool:
        oid = int(oid)
        sid = self.shard_of(oid)
        found = self._acting_backend(sid).demote(oid, rung)
        if found and self.replication > 1:
            if resolve_rung(rung).is_recipe:
                # recipe demotion drops cached copies cluster-wide; a
                # lossy-rung demotion leaves caches alone by design
                self._journal.setdefault(sid, []).append(("x", oid))
            self._forward(oid, sid)
            if not self.cfg.write_behind:
                self._checkpoint_source(sid)
        return found

    def promote(self, oid: int) -> bool:
        oid = int(oid)
        sid = self.shard_of(oid)
        found = self._acting_backend(sid).promote(oid)
        if found and self.replication > 1:
            self._forward(oid, sid)   # regenerated blob is durable again
            if not self.cfg.write_behind:
                self._checkpoint_source(sid)
        return found

    def stat(self, oid: int) -> Optional[ObjectStat]:
        return self._acting_backend(self.shard_of(oid)).stat(int(oid))

    def pixels_resident(self, oid: int) -> bool:
        """Pure peek: pixel-cache residency on the owning shard's acting
        backend (degrade-mode admission support)."""
        backend = self._acting_backend(self.shard_of(oid))
        probe = getattr(backend, "pixels_resident", None)
        return bool(probe(int(oid))) if probe is not None else False

    def flush(self) -> None:
        for sid in self.shard_ids:
            b = self._acting_or_none(sid)
            flush = getattr(b, "flush", None) if b is not None else None
            if flush is not None:
                flush()
        for (f, src), h in self._holders.items():
            if f not in self._dead and src not in self._dead:
                h.checkpoint()

    def close(self) -> None:
        self.flush()                  # sources durable before holders claim so
        for h in self._holders.values():
            h.close()
        self._holders.clear()
        for sid in self.shard_ids:
            down = self._dead.get(sid)
            if down is None:
                b = self.shards[sid].backend
            elif down.kind == "partition":
                b = down.backend      # intact: a clean close flushes it
            else:
                continue              # killed: its log is already abandoned
            close = getattr(b, "close", None)
            if close is not None:
                close()

    # -- introspection -------------------------------------------------------
    def residency_shards(self, oid: int) -> List[int]:
        """Every shard holding PRIMARY residency for ``oid`` — the
        conformance harness asserts this is at most the one owning shard
        (replica holders are not backend residency)."""
        out = []
        for sid in self.shard_ids:
            b = self._acting_or_none(sid)
            if b is not None and b.stat(int(oid)) is not None:
                out.append(sid)
        return out

    def under_replicated_objects(self) -> int:
        """Objects with fewer live copies (primary backend + designated
        live holders) than ``min(replication, live shards)`` — the
        catch-up acceptance gate: 0 again after every restart."""
        if self.replication <= 1:
            return 0
        n_live = len(self.live_shard_ids)
        n = 0
        for oid, src in self._keys.items():
            oid = int(oid)
            target = min(self.replication, n_live)
            copies = 0
            if src not in self._dead:
                b = self.shards[src].backend
                if b.store.stat(oid) is not None \
                        or b.regen.state_of(oid) is not None:
                    copies += 1
            for f in self.replica_shards(oid)[1:]:
                if f in self._dead:
                    continue
                h = self._holders.get((f, src))
                if h is not None and h.contains_any(oid):
                    copies += 1
            if copies < target:
                n += 1
        return n

    def shard_summaries(self) -> Dict[int, Dict[str, Any]]:
        return {sid: b.summary() for sid in self.shard_ids
                if (b := self._acting_or_none(sid)) is not None}

    _SUMMED = ("image_hit", "latent_hit", "full_miss", "regen_miss",
               "spilled", "total", "cache_resident_bytes", "durable_bytes",
               "recipe_bytes", "decode_batches", "decodes",
               "coalesced_decodes", "decompressions",
               "decompress_memo_hits", "pixel_cached_objects",
               "pixel_cached_bytes",
               # persistent clusters: on-disk truth sums across shard logs
               "durable_disk_bytes", "durable_live_bytes",
               "durable_segments", "segments_compacted",
               # decode-fleet observability + provisioned-cost integrals
               "gpu_seconds", "decode_gpus", "provisioned_gpu_ms",
               "provisioned_cache_byte_ms",
               # per-shard gpu/cache controllers' event counts
               "scale_up_events", "scale_down_events")

    def summary(self) -> Dict[str, Any]:
        """Cluster-level stats: additive counters sum across shards, alpha
        reports per node in global order, hit fractions recompute from the
        summed counts (``shard_summaries()`` keeps the per-shard view).
        Down shards report through their failover proxies (whose journal
        replay preserves the lifetime hit counts)."""
        per = [b.summary() for sid in self.shard_ids
               if (b := self._acting_or_none(sid)) is not None]
        out: Dict[str, Any] = {"n_shards": self.n_shards,
                               "n_nodes": self.n_nodes}
        for key in self._SUMMED:
            vals = [s[key] for s in per if key in s]
            if vals:
                out[key] = type(vals[0])(sum(vals))
        out["alpha"] = [a for s in per for a in s.get("alpha", [])]
        if per and "sim_clock_ms" in per[0]:
            out["sim_clock_ms"] = max(s["sim_clock_ms"] for s in per)
        total = out.get("total", 0)
        if total:
            out["image_hit_frac"] = out["image_hit"] / total
            out["decode_frac"] = 1.0 - out["image_hit_frac"]
        # ratio recomputes from the summed counters (a mean of per-shard
        # ratios would weight empty shards wrong)
        if out.get("pixel_cached_objects"):
            out["pixel_bytes_per_object"] = (
                out["pixel_cached_bytes"] / out["pixel_cached_objects"])
        elif per and "pixel_bytes_per_object" in per[0]:
            out["pixel_bytes_per_object"] = per[0]["pixel_bytes_per_object"]
        # cluster write amplification recomputes from the summed byte
        # counters (a mean of per-shard ratios would weight idle shards
        # wrong, same argument as the hit fractions above)
        logs = [lg for sid in self.shard_ids
                if (b := self._acting_or_none(sid)) is not None
                and (lg := getattr(b, "durable_log", None)) is not None]
        if logs:
            user = sum(lg.user_bytes_written for lg in logs)
            rewrite = sum(lg.rewrite_bytes_written for lg in logs)
            out["write_amplification"] = ((user + rewrite) / user
                                          if user else 1.0)
        # cluster decode utilization recomputes from the summed integrals
        # (time-weighted across resizes; a mean of per-shard utilizations
        # would weight idle shards wrong)
        if out.get("provisioned_gpu_ms"):
            out["decode_util"] = (out.get("gpu_seconds", 0.0) * 1e3
                                  / out["provisioned_gpu_ms"])
        if self.autoscaler is not None:
            # merge the cluster (shard-knob) controller's events into the
            # summed per-shard counters; topology keys come from reality
            cs = self.autoscaler.summary()
            out["scale_up_events"] = (out.get("scale_up_events", 0)
                                      + cs["scale_up_events"])
            out["scale_down_events"] = (out.get("scale_down_events", 0)
                                        + cs["scale_down_events"])
            out["autoscale_shards"] = self.n_shards
            out["autoscale_windows"] = cs["autoscale_windows"]
        out["replication"] = self.replication
        if self.replication > 1 or self._dead or self.fault_plan.fired:
            out["failovers"] = self.failovers
            out["hedges_fired"] = self.hedges_fired
            out["hedge_wins"] = self.hedge_wins
            out["restarts"] = self.restarts
            out["dead_shards"] = sorted(self._dead)
            out["under_replicated_objects"] = self.under_replicated_objects()
            out["replica_disk_bytes"] = int(sum(
                h.disk_bytes for h in self._holders.values()))
        out.update(self._latency_stats())
        return out

    def _latency_stats(self) -> Dict[str, float]:
        """Exact cluster-level latency stats from the union of the acting
        backends' request logs (percentiles cannot be aggregated from
        per-shard summaries).  Empty for backends without a log (engine).
        A killed shard's pre-kill samples die with its process — its
        proxy's log covers the failover era only."""
        lats: List[float] = []
        for sid in self.shard_ids:
            b = self._acting_or_none(sid)
            if b is None:
                continue
            log = getattr(b, "log", None)
            if log is None:
                return {}
            lats.extend(log.latency_ms)
        if not lats:
            return {}
        arr = np.asarray(lats)
        return {"mean_ms": float(arr.mean()),
                "p50_ms": float(np.percentile(arr, 50)),
                "p95_ms": float(np.percentile(arr, 95)),
                "p99_ms": float(np.percentile(arr, 99))}
