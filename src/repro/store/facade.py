"""``LatentBox`` — the single client-facing facade of the object store.

The paper's system is *storage*: objects are put once, read billions of
times, and demoted across durability classes as they cool.  This class is
that contract as an API:

    box = LatentBox.engine()                      # real jitted decode
    box.put(42, image=img, recipe=Recipe(seed=7, height=64, width=64))
    r = box.get(42)                               # GetResult: pixels +
    #                                               hit class + latency
    box.demote(42)                                # recipe-only durability
    box.get(42).regenerated                       # True: cold regen path
    box.stat(42), box.delete(42), box.summary()

``LatentBox.simulated()`` swaps the backend for the discrete latency plant
— same tier walk, same classifications, no GPU — which is how trace-scale
capacity studies and unit tests drive the identical read path the real
engine serves with.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.regen_tier import Recipe
from repro.store.api import GetResult, ObjectStat, PutResult, StoreConfig


class LatentBox:
    """Unified object-store facade over a pluggable tier backend."""

    def __init__(self, backend):
        self._backend = backend
        self._meta: Dict[int, Dict[str, Any]] = {}

    # -- constructors --------------------------------------------------------
    @classmethod
    def engine(cls, vae=None, config: Optional[StoreConfig] = None,
               seed: int = 0, shards: int = 1,
               replication: Optional[int] = None, hedge=None,
               fault_plan=None) -> "LatentBox":
        """Real-decode box.  Without an explicit ``vae`` a small demo VAE
        is built (the paper-scale decoder swaps in transparently).
        ``shards > 1`` serves a consistent-hash-sharded cluster of engine
        backends with ``config.n_nodes`` nodes per shard;
        ``replication``/``hedge``/``fault_plan`` configure R-way replica
        placement, hedged reads, and scripted failure injection on that
        cluster (see :class:`~repro.store.sharding.ShardedLatentBox`)."""
        from repro.store.backends import EngineBackend
        if vae is None:
            from repro.vae.model import demo_vae
            vae = demo_vae(seed=seed)
        if shards > 1 or (replication or 1) > 1 or fault_plan is not None:
            from repro.store.sharding import ShardedLatentBox
            return cls(ShardedLatentBox.engine(
                vae, shards, config, replication=replication, hedge=hedge,
                fault_plan=fault_plan))
        return cls(EngineBackend(vae, config))

    @classmethod
    def simulated(cls, config: Optional[StoreConfig] = None,
                  shards: int = 1, replication: Optional[int] = None,
                  hedge=None, fault_plan=None) -> "LatentBox":
        """Latency-plant box: identical classifications, modeled latency.
        ``shards > 1`` serves a consistent-hash-sharded cluster of sim
        backends, each with its own GPU plant and tuner state;
        ``replication``/``hedge``/``fault_plan`` as for :meth:`engine`."""
        from repro.store.backends import SimBackend
        if shards > 1 or (replication or 1) > 1 or fault_plan is not None:
            from repro.store.sharding import ShardedLatentBox
            return cls(ShardedLatentBox.simulated(
                shards, config, replication=replication, hedge=hedge,
                fault_plan=fault_plan))
        return cls(SimBackend(config))

    @classmethod
    def open(cls, path, mode: str = "engine",
             config: Optional[StoreConfig] = None, vae=None, seed: int = 0,
             shards: int = 1, replication: Optional[int] = None,
             hedge=None, fault_plan=None) -> "LatentBox":
        """Open (or create) a *persistent* box on ``path``.

        The durable-latent and recipe tiers write through one
        log-structured segment store under ``path`` (per-shard
        subdirectories when ``shards > 1``).  The reopen guarantee:
        after ANY process exit — clean ``close()``, hard kill mid-write,
        or kill mid-compaction — ``LatentBox.open(path)`` recovers every
        *acknowledged* put (``PutResult.durable`` / past ``flush()``) and
        serves it bit-exact: same blob bytes, same decoded pixels on the
        same stack, same recipes and demotion flags.  Unacknowledged tail
        records are detected by checksum and cleanly ignored.  Cache
        warmth and store-latency warmth are process state and restart
        cold, like a node rejoining a fleet.
        """
        import dataclasses as _dc
        cfg = _dc.replace(config or StoreConfig(), data_dir=str(path))
        if mode == "engine":
            return cls.engine(vae=vae, config=cfg, seed=seed, shards=shards,
                              replication=replication, hedge=hedge,
                              fault_plan=fault_plan)
        if mode == "sim":
            return cls.simulated(cfg, shards=shards,
                                 replication=replication, hedge=hedge,
                                 fault_plan=fault_plan)
        raise ValueError(f"mode must be 'engine' or 'sim': {mode!r}")

    @property
    def backend(self):
        return self._backend

    # -- writes --------------------------------------------------------------
    def put(self, oid: int, image: Optional[np.ndarray] = None,
            latent: Optional[np.ndarray] = None,
            recipe: Optional[Recipe] = None,
            nbytes: Optional[float] = None,
            meta: Optional[Dict[str, Any]] = None,
            prewarm: bool = False) -> PutResult:
        """Durable write: encode (pixels) -> compress -> latent store.

        Any one of ``image`` / ``latent`` / ``recipe`` suffices on the
        engine backend (a lone recipe is synthesized first); the simulator
        additionally accepts ``nbytes``-only registrations.  ``prewarm``
        pins decoded pixels at the hash owner so the first read is an
        image hit.
        """
        res = self._backend.put(int(oid), image=image, latent=latent,
                                recipe=recipe, nbytes=nbytes, prewarm=prewarm)
        if meta is not None:
            self._meta[int(oid)] = dict(meta)
        return res

    # -- reads ---------------------------------------------------------------
    def get(self, oid: int) -> GetResult:
        return self.get_many([oid])[0]

    def get_many(self, oids: Sequence[int],
                 timestamps_ms: Optional[Sequence[float]] = None
                 ) -> List[GetResult]:
        """Serve a request window through the tier walk.  ``timestamps_ms``
        drives open-loop trace replay on the simulator backend; the engine
        serves at wall-clock and ignores it."""
        return self._backend.get_many(oids, timestamps_ms=timestamps_ms)

    def serve_stream(self, requests, runtime_cfg=None):
        """Replay an open-loop request stream (timestamped arrivals)
        through the event-loop serving runtime: continuous microbatching,
        per-tenant QoS, SLO classes, and admission control.

        ``requests`` is a :class:`~repro.trace.synth.SyntheticTrace` or a
        sequence of :class:`repro.serve.runtime.Request`; ``runtime_cfg``
        a :class:`repro.serve.runtime.RuntimeConfig` (defaults derive the
        service model from this box's ``StoreConfig``).  Returns a
        :class:`repro.serve.runtime.StreamReport` with per-request
        outcomes in arrival order, the columnar :class:`RequestLog`
        (queue delay, deadlines, tenants), and scheduler counters.
        """
        stream = getattr(self._backend, "serve_stream", None)
        if stream is not None:          # backend owns the continuous feed
            return stream(requests, runtime_cfg=runtime_cfg)
        from repro.serve.runtime import RuntimeConfig, ServingRuntime
        if runtime_cfg is None:
            cfg = getattr(self._backend, "cfg", None)
            runtime_cfg = (RuntimeConfig.from_store(cfg)
                           if cfg is not None else RuntimeConfig())
        return ServingRuntime.for_target(self._backend, runtime_cfg).run(
            requests)

    def pixels_resident(self, oid: int) -> bool:
        """Pure peek: is ``oid`` currently pixel-cache resident at its
        hash owner?  (No stats impact — used by degrade-mode admission.)"""
        probe = getattr(self._backend, "pixels_resident", None)
        return bool(probe(int(oid))) if probe is not None else False

    # -- lifecycle -----------------------------------------------------------
    def delete(self, oid: int) -> bool:
        """Remove the object from every tier (pixels, latents, durable,
        recipe) and forget its metadata.  The metadata is dropped only
        after the backend delete returns: a raising backend must not
        silently lose the object's metadata."""
        found = self._backend.delete(int(oid))
        self._meta.pop(int(oid), None)
        return found

    def stat(self, oid: int) -> Optional[ObjectStat]:
        st = self._backend.stat(int(oid))
        if st is not None:
            st.meta = self._meta.get(int(oid))
        return st

    def demote(self, oid: int, rung=None) -> bool:
        """Demote the object down the rate-distortion ladder.

        Default (``rung=None`` / ``"recipe"``): the pre-ladder behavior —
        drop the durable latent entirely, keep only the recipe; the next
        cold read regenerates (and re-admits) it.  A lossy rung (index
        1-3 or name ``"high"``/``"mid"``/``"low"``) instead re-encodes
        the durable latent at that colder quality: the object keeps its
        durable class, just cheaper bytes (on a persistent box the
        transcode piggybacks on the next compaction pass)."""
        return self._backend.demote(int(oid), rung)

    def promote(self, oid: int) -> bool:
        """Undo a demotion ahead of traffic: regenerate the latent into
        the durable tier now, off the read path."""
        return self._backend.promote(int(oid))

    # -- durability ----------------------------------------------------------
    def flush(self) -> None:
        """Crash-durability barrier: every write accepted so far (including
        write-behind puts) is on disk, and the manifest checkpoint bounds
        the next reopen's recovery scan.  No-op on in-memory boxes."""
        flush = getattr(self._backend, "flush", None)
        if flush is not None:
            flush()

    def close(self) -> None:
        """Seal the active segment, checkpoint the manifest, and release
        file handles.  The box must not be used afterwards; reopen with
        :meth:`open`.  No-op on in-memory boxes."""
        close = getattr(self._backend, "close", None)
        if close is not None:
            close()

    def __enter__(self) -> "LatentBox":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- introspection -------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        return self._backend.summary()

    def __contains__(self, oid: int) -> bool:
        return self._backend.stat(int(oid)) is not None
