"""The ``Tier`` protocol and adapters over the existing storage layers.

A tier is one durability/performance class in the walk

    pixel cache -> latent cache -> durable latent store -> recipe store

The durable class is no longer a single codec setting: its latents sit
on a rate-distortion ladder (lossless -> high -> mid -> low lossy rungs,
see :mod:`repro.compression.ladder`), and the recipe store is the
ladder's final rung — zero latent bytes, full regeneration on read.

Each tier answers five questions: does it hold an object (``contains``),
can it serve a lookup (``load`` — the mutating cascade step: LRU touches,
promotion counters, regen detection), how does an object enter it
(``store``), how does it leave (``evict`` + ``evict_cb`` listeners), and
how many bytes are resident (``resident_bytes``).

The adapters wrap — not replace — the battle-tested layers underneath:
:class:`DualCacheTier` over :class:`~repro.core.dual_cache.DualFormatCache`
(covering both the pixel and latent cache classes of one node),
:class:`DurableTier` over :class:`~repro.core.latent_store.LatentStore`,
and :class:`RecipeTier` over
:class:`~repro.core.regen_tier.RegenTierStore`.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Callable, List, Optional

from repro.core.dual_cache import (DualFormatCache, FULL_MISS, IMAGE_HIT,
                                   LATENT_HIT)
from repro.core.latent_store import LatentStore
from repro.core.regen_tier import Recipe, RegenTierStore
from repro.core.tuner import MarginalHitTuner, TunerConfig
from repro.store.api import REGEN_MISS


@dataclasses.dataclass(frozen=True)
class TierHit:
    """Outcome of one tier's ``load`` during the walk."""

    tier: str                       # tier name that answered
    hit_class: str                  # IMAGE_HIT | LATENT_HIT | FULL_MISS | REGEN_MISS
    tail_hit: bool = False
    promoted: bool = False
    needs_decode: bool = True       # pixels must still be produced
    needs_fetch: bool = False       # durable fetch required
    needs_regen: bool = False       # generation pipeline required


class Tier(abc.ABC):
    """One durability class in the tier walk."""

    name: str = "tier"

    @abc.abstractmethod
    def contains(self, oid: int) -> bool:
        """Non-mutating residency probe."""

    @abc.abstractmethod
    def load(self, oid: int) -> Optional[TierHit]:
        """Mutating lookup step of the walk: ``None`` falls through to the
        next tier; a :class:`TierHit` classifies the request."""

    @abc.abstractmethod
    def store(self, oid: int, **kw) -> None:
        """Admit an object into this tier."""

    @abc.abstractmethod
    def evict(self, oid: int) -> bool:
        """Drop an object from this tier (True if it was resident)."""

    def evict_cb(self, cb: Callable[[int], None]) -> None:
        """Register a listener invoked with the oid on every eviction
        (capacity-driven or explicit).  Default: evictions are silent."""
        self._listeners().append(cb)

    def _listeners(self) -> List[Callable[[int], None]]:
        if not hasattr(self, "_evict_listeners"):
            self._evict_listeners: List[Callable[[int], None]] = []
        return self._evict_listeners

    def _notify_evict(self, oid: int) -> None:
        for cb in self._listeners():
            cb(oid)

    @property
    @abc.abstractmethod
    def resident_bytes(self) -> float:
        ...


class DualCacheTier(Tier):
    """One node's dual-format cache: the pixel and latent cache classes.

    ``load`` is the cascading :meth:`DualFormatCache.lookup` (stats,
    segmented-LRU touches, h-threshold promotion) plus the per-request
    tuner hook, so walking through this adapter evolves cache state exactly
    like the pre-facade engine and simulator did.
    """

    def __init__(self, capacity_bytes: float, *, alpha: float, tau: float,
                 promote_threshold: int, image_bytes: float,
                 latent_bytes: float, adaptive: bool = True,
                 tuner: Optional[TunerConfig] = None, name: str = "cache"):
        self.name = name
        self.cache = DualFormatCache(
            capacity_bytes, alpha=alpha, tau=tau,
            promote_threshold=promote_threshold,
            image_size_fn=lambda _oid: image_bytes,
            latent_size_fn=lambda _oid: latent_bytes)
        self.tuner: Optional[MarginalHitTuner] = (
            MarginalHitTuner(self.cache, tuner) if adaptive else None)
        # capacity evictions from either format notify tier listeners
        self.cache.image_tier.on_evict = \
            lambda oid, _sz: self._notify_evict(oid)
        base_cb = self.cache.latent_tier.on_evict    # promotion-counter pop
        def _lat_evict(oid, sz, _base=base_cb):
            if _base is not None:
                _base(oid, sz)
            self._notify_evict(oid)
        self.cache.latent_tier.on_evict = _lat_evict

    def contains(self, oid: int) -> bool:
        return self.cache.contains(oid) is not None

    def load(self, oid: int) -> Optional[TierHit]:
        res = self.cache.lookup(oid)
        if self.tuner is not None:
            self.tuner.on_request()
        if res.outcome == IMAGE_HIT:
            return TierHit(self.name, IMAGE_HIT, tail_hit=res.tail_hit,
                           needs_decode=False)
        if res.outcome == LATENT_HIT:
            return TierHit(self.name, LATENT_HIT, tail_hit=res.tail_hit,
                           promoted=res.promoted)
        return None                                   # FULL_MISS: fall through

    def store(self, oid: int, format: str = "latent",
              nbytes: Optional[float] = None, **_kw) -> None:
        """Admit in either format; ``nbytes`` charges the payload's real
        byte size (engine backends know it, the simulator estimates)."""
        if format == "image":
            self.cache.insert_image(oid, nbytes=nbytes)
        else:
            self.cache.admit_latent(oid, nbytes=nbytes)

    def evict(self, oid: int) -> bool:
        found = self.cache.evict(oid)
        if found:
            self._notify_evict(oid)
        return found

    def set_capacity(self, capacity_bytes: float) -> None:
        """Autoscaler capacity handoff: resize the node's total cache
        bytes, preserving the tuner's alpha split (evictions fire the
        registered tier listeners via the ``on_evict`` hooks)."""
        self.cache.set_capacity(capacity_bytes)

    @property
    def resident_bytes(self) -> float:
        return self.cache.resident_bytes


class DurableTier(Tier):
    """The durable latent class over :class:`LatentStore`.

    Bytes live wherever the store's pluggable
    :class:`~repro.store.durable.backend.DurableBackend` puts them: the
    in-memory dict backend (simulation conformance) or the log-structured
    :class:`~repro.store.durable.backend.SegmentLogBackend` under
    ``StoreConfig.data_dir`` — in which case every ``store``/``evict``
    here is an append-only record (blob or tombstone) in the same
    crash-recoverable segment log the recipe tier journals through.

    Durable latents are NOT lossless-only: each object sits at a
    rate-distortion rung (:mod:`repro.compression.ladder`), descending
    via :meth:`set_target_rung` as it cools.  On the segment log the
    re-encode piggybacks on compaction; in memory it applies eagerly.
    Whatever the rung, the object classifies as the same ``FULL_MISS``
    durable fetch — only the recipe rung changes the walk.
    """

    name = "durable"

    def __init__(self, store: LatentStore):
        self.backing = store                        # the LatentStore

    def contains(self, oid: int) -> bool:
        return oid in self.backing

    def load(self, oid: int) -> Optional[TierHit]:
        if oid not in self.backing:
            return None
        return TierHit(self.name, FULL_MISS, needs_fetch=True)

    def store(self, oid: int, blob: Optional[bytes] = None,
              nbytes: Optional[float] = None, rung: int = 0,
              **_kw) -> None:
        if blob is not None:
            self.backing.put(oid, blob)             # blob carries its rung
        else:
            self.backing.put_size(oid, float(nbytes), int(rung))

    def evict(self, oid: int) -> bool:
        found = self.backing.delete(oid)
        if found:
            self._notify_evict(oid)
        return found

    # -- rate-distortion ladder ----------------------------------------------
    def rung_of(self, oid: int) -> Optional[int]:
        return self.backing.rung_of(oid)

    def target_rung_of(self, oid: int) -> Optional[int]:
        return self.backing.target_rung_of(oid)

    def set_target_rung(self, oid: int, rung: int) -> bool:
        return self.backing.set_target_rung(oid, rung)

    @property
    def resident_bytes(self) -> float:
        return self.backing.total_bytes


class RecipeTier(Tier):
    """The coldest durability class — the ladder's final rung: (prompt,
    seed, model) recipes that regenerate the latent bit-exactly when
    every byte-bearing tier misses.  Near-zero stored bytes, one full
    generation on read.

    On a persistent box the wrapped :class:`RegenTierStore` journals every
    state mutation (put / demote / readmit / delete) as a full-state
    record into the SAME segment log as the durable latents, so recipes
    and demotion flags survive a crash with the blobs they describe."""

    name = "recipe"

    def __init__(self, regen: Optional[RegenTierStore] = None):
        self.regen = regen or RegenTierStore()

    def contains(self, oid: int) -> bool:
        return oid in self.regen

    def load(self, oid: int) -> Optional[TierHit]:
        if oid not in self.regen:
            return None
        self.regen.n_regens += 1
        return TierHit(self.name, REGEN_MISS, needs_regen=True)

    def store(self, oid: int, nbytes: float = 0.0,
              recipe: Optional[Recipe] = None, now_mo: float = 0.0,
              **_kw) -> None:
        self.regen.put(oid, float(nbytes), now_mo=now_mo, recipe=recipe)

    def recipe_of(self, oid: int) -> Optional[Recipe]:
        return self.regen.recipe_of(oid)

    def evict(self, oid: int) -> bool:
        found = self.regen.delete(oid)
        if found:
            self._notify_evict(oid)
        return found

    @property
    def resident_bytes(self) -> float:
        return self.regen.recipe_bytes
