"""Log-structured durable persistence for LatentBox.

The subsystem that turns the repo's "durable" tier from an in-memory
stand-in into measurable on-disk truth:

* ``segment``   — the checksummed append-only record format;
* ``log``       — :class:`SegmentLog`: segments + index + manifest
                  checkpoints + torn-tail-safe recovery + lsn-preserving
                  rewrites + segment shipping for shard migration;
* ``backend``   — the :class:`DurableBackend` seam behind ``LatentStore``
                  (:class:`MemoryBackend` sim default,
                  :class:`SegmentLogBackend` engine default on disk);
* ``compact``   — :class:`Compactor`: coldest-first online compaction
                  driven from the serving loop.

Entry point for applications: ``LatentBox.open(path)`` (see
``repro.store.facade``), which wires a :class:`SegmentLog` under both the
durable-latent and recipe tiers and guarantees reopen-and-serve-bit-exact
for every acknowledged put.
"""

from repro.store.durable.backend import (DurableBackend, MemoryBackend,
                                         SegmentLogBackend)
from repro.store.durable.compact import CompactionStats, Compactor
from repro.store.durable.log import SegmentLog, Slot
from repro.store.durable.segment import (BLOB, HEADER_BYTES, RDEL, RSTATE,
                                         SIZE, TOMB, Record, pack_record,
                                         scan_records)

__all__ = [
    "DurableBackend", "MemoryBackend", "SegmentLogBackend",
    "SegmentLog", "Slot", "Compactor", "CompactionStats",
    "Record", "pack_record", "scan_records",
    "BLOB", "SIZE", "TOMB", "RSTATE", "RDEL", "HEADER_BYTES",
]
