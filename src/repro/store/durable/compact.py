"""Online compaction policy over a :class:`~repro.store.durable.log.SegmentLog`.

The log's append-only discipline turns every overwrite, delete, and
demotion into dead bytes that sit in sealed segments until someone
rewrites the survivors.  :class:`Compactor` is that someone: each
:meth:`step` (called from the serving engine's request loop, between
windows) picks the *coldest* sealed segment — the one with the lowest
live fraction, i.e. the most reclaimable bytes per byte rewritten — and
compacts it if it is below the configured live-fraction threshold.  The
mechanics (lsn-preserving rewrite, crash-safe copy-then-unlink order)
live in :meth:`SegmentLog.compact_segment`; this module owns only the
victim choice, the trigger thresholds, and the accounting.

Rate-distortion ladder demotion piggybacks here: when a live record has
a pending ``RUNG`` intent, the rewrite transcodes it to the target rung
via :func:`ladder_reencode` instead of copying it verbatim — re-encoding
rides along with segment rewrites rather than adding its own I/O pass.
When no segment is under the dead-bytes threshold but sealed segments
hold pending demotions, the compactor picks the one with the most
pending bytes (those bytes are reclaimable by re-encoding, which is the
same economics as reclaiming dead bytes).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.compression.ladder import RECIPE_RUNG, scaled_nbytes, transcode_blob
from repro.store.durable.log import SegmentLog
from repro.store.durable.segment import (BLOB, SIZE, pack_size_payload,
                                         unpack_size_rung)


def ladder_reencode(kind: int, payload: bytes,
                    target: int) -> Optional[bytes]:
    """Default compaction re-encode hook: transcode a BLOB payload down
    the ladder, or re-scale a SIZE registration's nominal bytes.  Returns
    None (= copy verbatim) for anything it cannot or need not demote."""
    if not 0 < int(target) < RECIPE_RUNG:
        return None                      # recipe demotion is not a rewrite
    if kind == BLOB:
        try:
            demoted = transcode_blob(payload, int(target))
        except (ValueError, TypeError):
            return None                  # opaque payload: leave it alone
        return None if demoted is payload else demoted
    if kind == SIZE:
        nbytes, rung = unpack_size_rung(payload)
        if rung >= int(target):
            return None
        return pack_size_payload(scaled_nbytes(nbytes, rung, int(target)),
                                 int(target))
    return None


@dataclasses.dataclass
class CompactionStats:
    runs: int = 0
    segments_compacted: int = 0
    bytes_rewritten: int = 0
    bytes_reclaimed: int = 0


class Compactor:
    """Pick-coldest-first online compaction.

    ``live_frac_threshold``: sealed segments whose live fraction is at or
    below this compact; 1.0 means "any dead byte qualifies", 0.0 disables.
    ``min_segment_bytes`` skips near-empty stub segments whose rewrite
    cost exceeds the bookkeeping win (they still compact under
    :meth:`compact_all`).  ``reencode`` is the ladder piggyback hook
    passed through to :meth:`SegmentLog.compact_segment` (None disables
    demotion-on-compaction; intents then stay pending).
    """

    def __init__(self, log: SegmentLog, *, live_frac_threshold: float = 0.6,
                 min_segment_bytes: int = 0, reencode=ladder_reencode):
        self.log = log
        self.live_frac_threshold = float(live_frac_threshold)
        self.min_segment_bytes = int(min_segment_bytes)
        self.reencode = reencode
        self.stats = CompactionStats()

    def _victim(self) -> Optional[int]:
        best, best_frac = None, None
        for sid, (nbytes, live) in self.log.sealed_segments().items():
            if nbytes <= self.min_segment_bytes or nbytes == 0:
                continue
            frac = max(live, 0) / nbytes
            if frac > self.live_frac_threshold:
                continue
            if best_frac is None or frac < best_frac:
                best, best_frac = sid, frac
        return best

    def _ladder_victim(self) -> Optional[int]:
        """Sealed segment with the most live bytes awaiting demotion —
        re-encoding reclaims those bytes, so it earns a rewrite even when
        the segment's dead fraction alone would not."""
        if self.reencode is None:
            return None
        sealed = self.log.sealed_segments()
        pending = {sid: b for sid, b in self.log.pending_segments().items()
                   if sid in sealed
                   and sealed[sid][0] > self.min_segment_bytes}
        if not pending:
            return None
        return max(pending.items(), key=lambda kv: (kv[1], -kv[0]))[0]

    def step(self, max_segments: int = 1, crash_hook=None) -> int:
        """Compact up to ``max_segments`` cold segments; returns how many
        were compacted (0: nothing under the threshold and no pending
        ladder work — the steady state).  Runs between serving windows,
        so 'online' here means bounded work per call, never a
        stop-the-world sweep."""
        if self.live_frac_threshold <= 0.0:
            return 0
        done = 0
        for _ in range(max_segments):
            sid = self._victim()
            if sid is None:
                sid = self._ladder_victim()
            if sid is None:
                break
            rewritten, reclaimed = self.log.compact_segment(
                sid, crash_hook=crash_hook, reencode=self.reencode)
            self.stats.segments_compacted += 1
            self.stats.bytes_rewritten += rewritten
            self.stats.bytes_reclaimed += reclaimed
            done += 1
        self.stats.runs += 1
        return done

    def compact_all(self) -> int:
        """Rewrite every sealed segment with any dead byte or pending
        ladder demotion (maintenance / pre-ship sweep); returns segments
        compacted."""
        done = 0
        while True:
            victim = None
            pending = (self.log.pending_segments()
                       if self.reencode is not None else {})
            for sid, (nbytes, live) in self.log.sealed_segments().items():
                if nbytes > 0 and (max(live, 0) < nbytes or sid in pending):
                    victim = sid
                    break
            if victim is None:
                return done
            rewritten, reclaimed = self.log.compact_segment(
                victim, reencode=self.reencode)
            self.stats.segments_compacted += 1
            self.stats.bytes_rewritten += rewritten
            self.stats.bytes_reclaimed += reclaimed
            done += 1

    def summary(self) -> Dict[str, float]:
        return {
            "compaction_runs": self.stats.runs,
            "segments_compacted": self.stats.segments_compacted,
            "compaction_bytes_rewritten": self.stats.bytes_rewritten,
            "compaction_bytes_reclaimed": self.stats.bytes_reclaimed,
            "reencoded_records": self.log.reencoded_records,
            "reencode_bytes_saved": self.log.reencode_bytes_saved,
        }
