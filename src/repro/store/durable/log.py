"""``SegmentLog`` — the log-structured persistence engine.

A log is a directory of append-only segment files plus one atomically
replaced ``MANIFEST.json`` checkpoint:

    <dir>/
      seg-00000001.lbx      sealed segment (never written again)
      seg-00000002.lbx      ...
      seg-00000007.lbx      active segment (current append target)
      MANIFEST.json         periodic checkpoint of the in-memory index

Writes append records (``segment.py`` format) to the active segment, which
seals and rolls when it exceeds ``segment_bytes``.  The in-memory index
maps each ``(namespace, oid)`` slot to its current (highest-lsn) record;
superseded records become dead bytes that online compaction reclaims by
rewriting a segment's live records (original lsns preserved) into the
active head and deleting the file.

Recovery (``__init__``) is manifest-first: load the checkpointed index,
then scan only the bytes appended after the checkpoint.  If the manifest
is missing, stale (references a segment compaction has deleted), or
corrupt, fall back to a full scan of every segment — the log never needs
the manifest for correctness, only for reopen speed.  A torn tail on the
highest segment (a record in flight when the process died) is truncated
away; acknowledged records are exactly those whose bytes were flushed, and
every one of them survives.

Durability contract: ``append`` buffers in the OS file; ``flush()`` makes
everything appended so far crash-durable (file flush + optional fsync) —
that is the acknowledgement point.  Callers wanting per-put acks flush per
put (``SegmentLogBackend`` default); the serving engine instead flushes
once per request window (write-behind).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.compression.latentcodec import blob_rung
from repro.store.durable.segment import (BLOB, HEADER_BYTES, RDEL, RSTATE,
                                         RUNG, SIZE, TOMB, Record,
                                         pack_record, pack_rung_payload,
                                         pack_size_payload, read_payload,
                                         record_bytes, scan_records,
                                         unpack_rung_payload,
                                         unpack_size_rung)

MANIFEST = "MANIFEST.json"
MANIFEST_VERSION = 3        # v3: slots carry a ladder-rung field
SEG_PREFIX, SEG_SUFFIX = "seg-", ".lbx"

#: index namespaces: one slot per (namespace, oid)
NS_OBJECT = 0       # BLOB / SIZE / TOMB
NS_RECIPE = 1       # RSTATE / RDEL
NS_RUNG = 2         # RUNG (ladder-demotion intent)

_NS_OF = {BLOB: NS_OBJECT, SIZE: NS_OBJECT, TOMB: NS_OBJECT,
          RSTATE: NS_RECIPE, RDEL: NS_RECIPE, RUNG: NS_RUNG}


def _blob_payload_rung(payload: bytes) -> int:
    """Ladder rung a BLOB payload carries in its own codec header; opaque
    (non-latent-codec) payloads count as rung 0."""
    try:
        return blob_rung(payload)
    except (ValueError, IndexError):
        return 0


def _seg_name(seg_id: int) -> str:
    return f"{SEG_PREFIX}{seg_id:08d}{SEG_SUFFIX}"


def _seg_id(name: str) -> Optional[int]:
    if name.startswith(SEG_PREFIX) and name.endswith(SEG_SUFFIX):
        try:
            return int(name[len(SEG_PREFIX):-len(SEG_SUFFIX)])
        except ValueError:
            return None
    return None


@dataclasses.dataclass
class Slot:
    """The current record of one ``(namespace, oid)`` slot."""

    lsn: int
    kind: int
    seg: int
    offset: int                 # header offset inside the segment
    payload_len: int
    size: float                 # accounting bytes (BLOB: payload len;
    #                             SIZE: stored float; tombstones: 0)
    value: Any = None           # parsed payload for SIZE/RSTATE/RUNG records
    rung: int = 0               # ladder rung the record's bytes encode
    #                             (BLOB: from the codec header; SIZE: from
    #                             the payload's rung byte; else 0)

    @property
    def nbytes(self) -> int:
        return record_bytes(self.payload_len)

    def to_json(self) -> list:
        return [self.lsn, self.kind, self.seg, self.offset,
                self.payload_len, self.size, self.value, self.rung]

    @staticmethod
    def from_json(row: list) -> "Slot":
        return Slot(int(row[0]), int(row[1]), int(row[2]), int(row[3]),
                    int(row[4]), float(row[5]), row[6],
                    int(row[7]) if len(row) > 7 else 0)


class SegmentLog:
    """Append-only segmented log with checksummed records, a checkpointed
    index, torn-tail-safe recovery, and compaction hooks."""

    def __init__(self, path: str, *, segment_bytes: float = 4e6,
                 fsync: bool = False, checkpoint_every: int = 1024):
        self.path = os.path.abspath(str(path))
        os.makedirs(self.path, exist_ok=True)
        self.segment_bytes = int(segment_bytes)
        self.fsync = bool(fsync)
        self.checkpoint_every = int(checkpoint_every)

        self.slots: Dict[Tuple[int, int], Slot] = {}
        self._seg_len: Dict[int, int] = {}       # valid bytes per segment
        self._seg_live: Dict[int, int] = {}      # live record bytes per seg
        self._read_handles: Dict[int, Any] = {}
        self._active_id: Optional[int] = None    # lazily created on append
        self._active_f = None
        self._next_seg = 1
        self.next_lsn = 1
        self._appends_since_ckpt = 0
        # write-amplification accounting: user vs compaction-rewrite bytes
        self.user_bytes_written = 0
        self.rewrite_bytes_written = 0
        # ladder accounting: blobs/sizes the compactor re-encoded in place
        self.reencoded_records = 0
        self.reencode_bytes_saved = 0
        self.closed = False
        self.recovery_stats: Dict[str, Any] = {}
        self._recover()

    # -- recovery -------------------------------------------------------------

    def _disk_segments(self) -> List[int]:
        ids = [sid for n in os.listdir(self.path)
               if (sid := _seg_id(n)) is not None]
        return sorted(ids)

    def _load_manifest(self) -> Optional[Dict[str, Any]]:
        p = os.path.join(self.path, MANIFEST)
        try:
            with open(p) as f:
                m = json.load(f)
        except (OSError, ValueError):
            return None
        if m.get("version") != MANIFEST_VERSION:
            return None
        # stale manifest (references a compacted-away segment): discard —
        # a full scan of what's on disk is always correct
        on_disk = set(self._disk_segments())
        if any(int(s) not in on_disk for s in m.get("segments", {})):
            return None
        return m

    def _recover(self) -> None:
        t0 = time.perf_counter()
        seg_ids = self._disk_segments()
        manifest = self._load_manifest()
        scanned_from: Dict[int, int] = {s: 0 for s in seg_ids}
        n_manifest_slots = 0
        if manifest is not None:
            for key, row in manifest["slots"]:
                ns, oid = int(key[0]), int(key[1])
                self._apply_slot((ns, oid), Slot.from_json(row))
                n_manifest_slots += 1
            for s, ln in manifest["segments"].items():
                scanned_from[int(s)] = int(ln)
            self.next_lsn = int(manifest["next_lsn"])
            self.user_bytes_written = int(manifest.get("user_bytes", 0))
            self.rewrite_bytes_written = int(manifest.get("rewrite_bytes", 0))
            self.reencoded_records = int(manifest.get("reencoded", 0))
            self.reencode_bytes_saved = int(
                manifest.get("reencode_saved", 0))
        torn = 0
        n_records = 0
        for sid in seg_ids:
            p = self._seg_path(sid)
            with open(p, "rb") as f:
                buf = f.read()
            start = min(scanned_from.get(sid, 0), len(buf))
            recs, valid_end = scan_records(buf, start)
            self._seg_len[sid] = valid_end
            self._seg_live.setdefault(sid, 0)
            for r in recs:
                self._apply_record(sid, r)
                n_records += 1
            if valid_end < len(buf):
                # torn tail: unacknowledged bytes from the crashed writer.
                # Truncate so the file never accretes garbage mid-stream.
                torn += len(buf) - valid_end
                with open(p, "r+b") as f:
                    f.truncate(valid_end)
        self._next_seg = (seg_ids[-1] + 1) if seg_ids else 1
        self.recovery_stats = {
            "ms": (time.perf_counter() - t0) * 1e3,
            "segments": len(seg_ids),
            "from_manifest": manifest is not None,
            "manifest_slots": n_manifest_slots,
            "scanned_records": n_records,
            "torn_tail_bytes": torn,
        }

    @staticmethod
    def _parse_payload(kind: int, payload: bytes):
        """(size, value, rung) of one record payload, shared by recovery
        replay and the live append path."""
        if kind == SIZE:
            size, rung = unpack_size_rung(payload)
            return size, size, rung
        if kind == BLOB:
            return float(len(payload)), None, _blob_payload_rung(payload)
        if kind == RSTATE:
            return 0.0, json.loads(payload.decode()), 0
        if kind == RUNG:
            return 0.0, unpack_rung_payload(payload), 0
        return 0.0, None, 0                      # TOMB / RDEL

    def _apply_record(self, sid: int, r: Record) -> None:
        if r.lsn >= self.next_lsn:
            self.next_lsn = r.lsn + 1
        size, value, rung = self._parse_payload(r.kind, r.payload)
        slot = Slot(r.lsn, r.kind, sid, r.offset, len(r.payload), size,
                    value, rung)
        self._apply_slot((_NS_OF[r.kind], r.oid), slot)

    def _apply_slot(self, key: Tuple[int, int], slot: Slot) -> None:
        cur = self.slots.get(key)
        if cur is not None:
            if cur.lsn > slot.lsn:               # strictly stale record
                return
            # equal lsn = the same logical record relocated by compaction
            # (or its duplicate surviving a crash between copy and unlink):
            # repoint, never double-count
            self._seg_live[cur.seg] = \
                self._seg_live.get(cur.seg, 0) - cur.nbytes
        self.slots[key] = slot
        self._seg_live[slot.seg] = \
            self._seg_live.get(slot.seg, 0) + slot.nbytes

    def _drop_slot(self, key: Tuple[int, int]) -> None:
        """Retire a slot whose record is being compacted away (stale
        ladder intent): remove it from the index and its live count."""
        s = self.slots.pop(key, None)
        if s is not None:
            self._seg_live[s.seg] = self._seg_live.get(s.seg, 0) - s.nbytes

    # -- append path ----------------------------------------------------------

    def _seg_path(self, sid: int) -> str:
        return os.path.join(self.path, _seg_name(sid))

    def _open_active(self) -> None:
        sid = self._next_seg
        self._next_seg += 1
        self._active_id = sid
        self._active_f = open(self._seg_path(sid), "ab")
        self._seg_len[sid] = 0
        self._seg_live.setdefault(sid, 0)

    def _seal_active(self) -> None:
        if self._active_f is None:
            return
        self._active_f.flush()
        if self.fsync:
            os.fsync(self._active_f.fileno())
        self._active_f.close()
        self._active_f = None
        self._active_id = None

    def append(self, kind: int, oid: int, payload: bytes,
               lsn: Optional[int] = None,
               rewrite: Optional[bool] = None) -> Slot:
        """Append one record and update the index.  ``lsn=None`` assigns
        the next sequence number (user write); compaction passes the
        record's original lsn so replay order is preserved.  ``rewrite``
        overrides the write-amplification attribution: a ladder re-encode
        takes a *new* lsn (it is a different logical record) but is still
        charged to compaction's rewrite budget, not to the user."""
        if self.closed:
            raise ValueError("log is closed")
        if rewrite is None:
            rewrite = lsn is not None
        if lsn is None:
            lsn = self.next_lsn
        self.next_lsn = max(self.next_lsn, lsn + 1)
        if self._active_f is None:
            self._open_active()
        elif self._seg_len[self._active_id] >= self.segment_bytes:
            self._seal_active()
            self._open_active()
        sid = self._active_id
        rec = pack_record(lsn, kind, oid, payload)
        offset = self._seg_len[sid]
        self._active_f.write(rec)
        self._seg_len[sid] = offset + len(rec)
        if rewrite:
            self.rewrite_bytes_written += len(rec)
        else:
            self.user_bytes_written += len(rec)
        size, value, rung = self._parse_payload(kind, payload)
        slot = Slot(lsn, kind, sid, offset, len(payload), size, value, rung)
        self._apply_slot((_NS_OF[kind], oid), slot)
        self._appends_since_ckpt += 1
        if (self.checkpoint_every > 0
                and self._appends_since_ckpt >= self.checkpoint_every):
            self.flush(manifest=True)
        return slot

    # -- durable-object namespace --------------------------------------------

    def put_blob(self, oid: int, blob: bytes) -> Slot:
        return self.append(BLOB, int(oid), bytes(blob))

    def put_size(self, oid: int, nbytes: float, rung: int = 0) -> Slot:
        return self.append(SIZE, int(oid), pack_size_payload(nbytes, rung))

    def tombstone(self, oid: int) -> Slot:
        return self.append(TOMB, int(oid), b"")

    def _obj_slot(self, oid: int) -> Optional[Slot]:
        s = self.slots.get((NS_OBJECT, int(oid)))
        return s if s is not None and s.kind != TOMB else None

    def contains_object(self, oid: int) -> bool:
        return self._obj_slot(oid) is not None

    def has_blob(self, oid: int) -> bool:
        s = self._obj_slot(oid)
        return s is not None and s.kind == BLOB

    def size_of(self, oid: int) -> Optional[float]:
        s = self._obj_slot(oid)
        return None if s is None else s.size

    def get_blob(self, oid: int) -> Optional[bytes]:
        s = self._obj_slot(oid)
        if s is None or s.kind != BLOB:
            return None
        return self._read_slot_payload(s)

    def object_oids(self) -> Iterator[int]:
        for (ns, oid), s in self.slots.items():
            if ns == NS_OBJECT and s.kind != TOMB:
                yield oid

    # -- ladder namespace -----------------------------------------------------

    def rung_of(self, oid: int) -> Optional[int]:
        """Rate-distortion rung the object's durable bytes are encoded at
        (None if the object has no durable record)."""
        s = self._obj_slot(oid)
        return None if s is None else int(s.rung)

    def set_target_rung(self, oid: int, rung: int) -> Slot:
        """Record a ladder-demotion *intent*: the compactor re-encodes the
        object's bytes to ``rung`` when it next rewrites their segment —
        no immediate I/O beyond this one tiny record."""
        return self.append(RUNG, int(oid), pack_rung_payload(rung))

    def target_rung_of(self, oid: int) -> Optional[int]:
        """Pending demotion target for ``oid``, or None.  An intent is
        pending only while it is newer than the object record (a fresh
        put invalidates it) and targets a strictly colder rung."""
        intent = self.slots.get((NS_RUNG, int(oid)))
        if intent is None or intent.kind != RUNG:
            return None
        obj = self._obj_slot(oid)
        if obj is None:
            return None
        if intent.lsn <= obj.lsn or int(intent.value) <= int(obj.rung):
            return None
        return int(intent.value)

    def pending_rungs(self) -> Dict[int, int]:
        """oid -> pending target rung, across the whole log."""
        out = {}
        for (ns, oid), _ in list(self.slots.items()):
            if ns != NS_RUNG:
                continue
            t = self.target_rung_of(oid)
            if t is not None:
                out[oid] = t
        return out

    def pending_segments(self) -> Dict[int, int]:
        """sealed seg_id -> bytes of live object records awaiting ladder
        demotion there (the compactor's re-encode yield estimate)."""
        out: Dict[int, int] = {}
        for oid in self.pending_rungs():
            s = self._obj_slot(oid)
            if s is not None and s.seg != self._active_id:
                out[s.seg] = out.get(s.seg, 0) + s.nbytes
        return out

    # -- recipe namespace -----------------------------------------------------

    def put_recipe_state(self, oid: int, state: Dict[str, Any]) -> Slot:
        return self.append(RSTATE, int(oid),
                           json.dumps(state, sort_keys=True).encode())

    def delete_recipe(self, oid: int) -> Slot:
        return self.append(RDEL, int(oid), b"")

    def recipe_states(self) -> Dict[int, Dict[str, Any]]:
        """oid -> latest RSTATE payload (recovery view of the regen tier)."""
        return {oid: s.value for (ns, oid), s in self.slots.items()
                if ns == NS_RECIPE and s.kind == RSTATE}

    def recipe_state_of(self, oid: int) -> Optional[Dict[str, Any]]:
        s = self.slots.get((NS_RECIPE, int(oid)))
        return s.value if s is not None and s.kind == RSTATE else None

    # -- reads ---------------------------------------------------------------

    def _read_slot_payload(self, s: Slot) -> Optional[bytes]:
        if s.seg == self._active_id and self._active_f is not None:
            self._active_f.flush()               # readable before fsync
        f = self._read_handles.get(s.seg)
        if f is None:
            f = open(self._seg_path(s.seg), "rb")
            self._read_handles[s.seg] = f
        return read_payload(f, s.offset, s.payload_len)

    # -- durability ----------------------------------------------------------

    def flush(self, manifest: bool = False) -> None:
        """Acknowledgement point: every record appended so far becomes
        crash-durable (``fsync=True`` additionally forces the platters)."""
        if self._active_f is not None:
            self._active_f.flush()
            if self.fsync:
                os.fsync(self._active_f.fileno())
        if manifest:
            self.write_manifest()

    def write_manifest(self) -> None:
        """Atomically checkpoint the index (tmp + rename), bounding the
        next recovery's scan to bytes appended after this point."""
        m = {
            "version": MANIFEST_VERSION,
            "next_lsn": self.next_lsn,
            "segments": {str(s): int(ln) for s, ln in self._seg_len.items()},
            "slots": [[[ns, oid], s.to_json()]
                      for (ns, oid), s in self.slots.items()],
            "user_bytes": self.user_bytes_written,
            "rewrite_bytes": self.rewrite_bytes_written,
            "reencoded": self.reencoded_records,
            "reencode_saved": self.reencode_bytes_saved,
        }
        tmp = os.path.join(self.path, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(m, f)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.path, MANIFEST))
        self._appends_since_ckpt = 0

    def close(self) -> None:
        if self.closed:
            return
        self._seal_active()
        self.write_manifest()
        for f in self._read_handles.values():
            f.close()
        self._read_handles.clear()
        self.closed = True

    def abandon(self) -> None:
        """Emulate a process kill in-process: drop the userspace append
        buffer (bytes not yet flushed to the OS are lost, exactly as on
        ``os._exit``), close every handle, write no manifest.  The on-disk
        state is what a real crash would leave — the failure-injection
        harness kills shards this way, then reopens a fresh log to recover.
        """
        if self.closed:
            return
        if self._active_f is not None:
            p = self._seg_path(self._active_id)
            flushed = os.path.getsize(p)         # what the OS already has
            self._active_f.close()               # flushes the tail...
            with open(p, "r+b") as f:
                f.truncate(flushed)              # ...which the kill loses
            self._active_f = None
            self._active_id = None
        for f in self._read_handles.values():
            f.close()
        self._read_handles.clear()
        self.closed = True

    # -- compaction mechanics -------------------------------------------------

    def sealed_segments(self) -> Dict[int, Tuple[int, int]]:
        """seg_id -> (valid_bytes, live_bytes) for every sealed segment."""
        return {sid: (ln, self._seg_live.get(sid, 0))
                for sid, ln in self._seg_len.items()
                if sid != self._active_id}

    def compact_segment(self, sid: int, crash_hook=None,
                        reencode=None) -> Tuple[int, int]:
        """Rewrite ``sid``'s live records into the active head (original
        lsns preserved) and delete the file.  Returns (bytes_rewritten,
        bytes_reclaimed).  Safe order: the copies are appended and flushed
        *before* the victim file is unlinked, so a crash at any point
        leaves either duplicates (deduped by lsn on replay) or the intact
        victim — never a hole.  ``crash_hook`` is a test seam invoked
        between the durable rewrite and the unlink.

        ``reencode(kind, payload, target_rung) -> payload-or-None`` is the
        ladder piggyback: when a live BLOB/SIZE record has a pending
        demotion intent, the compactor transcodes it *during* the rewrite
        it was going to do anyway.  The demoted record takes a new lsn —
        so it supersedes the intent and wins any replay — and a crash
        between copy and unlink leaves the old record intact (the intent
        simply stays pending).  ``None`` from the hook means "copy
        verbatim"."""
        if sid == self._active_id:
            raise ValueError("cannot compact the active segment")
        if sid not in self._seg_len:
            raise KeyError(f"no segment {sid}")
        with open(self._seg_path(sid), "rb") as f:
            recs, _ = scan_records(f.read(), 0)
        rewritten = 0
        for r in recs:
            key = (_NS_OF[r.kind], r.oid)
            cur = self.slots.get(key)
            if cur is None or cur.seg != sid or cur.lsn != r.lsn:
                continue                          # dead record: drop
            if r.kind == RUNG and self.target_rung_of(r.oid) is None:
                self._drop_slot(key)              # stale intent: retire it
                continue
            if r.kind in (BLOB, SIZE) and reencode is not None:
                target = self.target_rung_of(r.oid)
                if target is not None:
                    demoted = reencode(r.kind, r.payload, target)
                    if demoted is not None:
                        self.append(r.kind, r.oid, demoted, rewrite=True)
                        self.reencoded_records += 1
                        self.reencode_bytes_saved += max(
                            0, len(r.payload) - len(demoted))
                        rewritten += record_bytes(len(demoted))
                        continue
                    # the hook declined a *pending* record: the intent is
                    # unsatisfiable (e.g. opaque payload) — retire it so
                    # it cannot re-elect this data for compaction forever
                    self._drop_slot((NS_RUNG, r.oid))
            self.append(r.kind, r.oid, r.payload, lsn=r.lsn)
            rewritten += r.nbytes
        self.flush()                              # copies durable first
        if crash_hook is not None:
            crash_hook()
        reclaimed = self._seg_len.pop(sid)
        self._seg_live.pop(sid, None)
        f = self._read_handles.pop(sid, None)
        if f is not None:
            f.close()
        os.remove(self._seg_path(sid))
        self.write_manifest()                     # never reference the dead file
        return rewritten, reclaimed

    # -- segment shipping (shard migration) -----------------------------------

    def export_records(self, oids) -> bytes:
        """Seal a migration batch: the current object + recipe records of
        ``oids`` as one raw segment image (no decompression, no re-encode)
        ready for :meth:`ingest_segment` on the destination log."""
        parts: List[bytes] = []
        for oid in oids:
            oid = int(oid)
            s = self._obj_slot(oid)
            if s is not None:
                payload = self._read_slot_payload(s)
                if payload is None:
                    raise IOError(f"checksum failure exporting oid {oid}")
                parts.append(pack_record(s.lsn, s.kind, oid, payload))
            rs = self.slots.get((NS_RECIPE, oid))
            if rs is not None and rs.kind == RSTATE:
                parts.append(pack_record(
                    rs.lsn, RSTATE, oid,
                    json.dumps(rs.value, sort_keys=True).encode()))
            # pending ladder intent migrates with the object; it is packed
            # *after* the object record so the destination's re-stamped
            # lsns keep it newer (i.e. still pending).  Stale intents stay
            # behind and die with the source.
            if s is not None and self.target_rung_of(oid) is not None:
                rg = self.slots[(NS_RUNG, oid)]
                parts.append(pack_record(rg.lsn, RUNG, oid,
                                         pack_rung_payload(int(rg.value))))
        return b"".join(parts)

    def export_delta(self, since_lsn: int, oids=None) -> bytes:
        """Replication catch-up image: every *current* slot (both
        namespaces, deletions included as TOMB/RDEL records) with
        ``lsn > since_lsn``, lsn-ordered, as one raw segment image.
        Unlike :meth:`export_records` this ships deletions — a replica
        must learn that an object died.  ``oids`` narrows the export to a
        designated subset (None: everything)."""
        want = None if oids is None else {int(o) for o in oids}
        picked = []
        for (ns, oid), s in self.slots.items():
            if s.lsn <= since_lsn:
                continue
            if want is not None and oid not in want:
                continue
            picked.append((s.lsn, ns, oid, s))
        parts: List[bytes] = []
        for _, ns, oid, s in sorted(picked):
            if s.kind in (TOMB, RDEL):
                payload = b""
            elif s.kind == RSTATE:
                payload = json.dumps(s.value, sort_keys=True).encode()
            elif s.kind == SIZE:
                payload = pack_size_payload(s.size, s.rung)
            elif s.kind == RUNG:
                payload = pack_rung_payload(int(s.value))
            else:
                payload = self._read_slot_payload(s)
                if payload is None:
                    raise IOError(f"checksum failure exporting oid {oid}")
            parts.append(pack_record(s.lsn, s.kind, oid, payload))
        return b"".join(parts)

    def ingest_segment(self, raw: bytes) -> Dict[str, Any]:
        """Adopt a shipped segment as one fresh *sealed* segment file:
        records are re-stamped with local lsns while streaming to disk
        (no per-key put path), then indexed.  Corrupt input (a flipped
        bit fails a record checksum, truncation breaks framing) is
        rejected up front with ``ValueError`` — nothing is applied and no
        segment file is created.  Returns the applied view:
        ``{"objects": [...], "recipes": {...}, "removed_objects": [...],
        "removed_recipes": [...], "segment": sid-or-None}``."""
        recs, valid_end = scan_records(raw, 0)
        if valid_end != len(raw):
            raise ValueError(
                f"shipped segment is corrupt: checksum/framing failure at "
                f"byte {valid_end} of {len(raw)}; nothing applied")
        applied_objects: List[int] = []
        recipes: Dict[int, Dict[str, Any]] = {}
        removed_objects: List[int] = []
        removed_recipes: List[int] = []
        rungs: Dict[int, int] = {}
        if not recs:
            return {"objects": [], "recipes": {}, "removed_objects": [],
                    "removed_recipes": [], "rungs": {}, "segment": None}
        self._seal_active()
        sid = self._next_seg
        self._next_seg += 1
        with open(self._seg_path(sid), "wb") as f:
            off = 0
            self._seg_len[sid] = 0
            self._seg_live.setdefault(sid, 0)
            for r in recs:
                lsn = self.next_lsn
                self.next_lsn += 1
                rec = pack_record(lsn, r.kind, r.oid, r.payload)
                f.write(rec)
                self.user_bytes_written += len(rec)
                self._seg_len[sid] = off + len(rec)
                self._apply_record(sid, Record(off, lsn, r.kind, r.oid,
                                               r.payload))
                off += len(rec)
                if r.kind in (BLOB, SIZE):
                    applied_objects.append(r.oid)
                elif r.kind == RSTATE:
                    recipes[r.oid] = json.loads(r.payload.decode())
                elif r.kind == TOMB:
                    removed_objects.append(r.oid)
                elif r.kind == RDEL:
                    removed_recipes.append(r.oid)
                elif r.kind == RUNG:
                    rungs[r.oid] = unpack_rung_payload(r.payload)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        self.write_manifest()
        return {"objects": applied_objects, "recipes": recipes,
                "removed_objects": removed_objects,
                "removed_recipes": removed_recipes, "rungs": rungs,
                "segment": sid}

    # -- accounting -----------------------------------------------------------

    @property
    def live_bytes(self) -> int:
        """Bytes of current (non-superseded) records across all segments."""
        return sum(max(v, 0) for v in self._seg_live.values())

    @property
    def on_disk_bytes(self) -> int:
        """Real bytes in segment files (valid prefixes; dead bytes incl.)."""
        return sum(self._seg_len.values())

    @property
    def payload_bytes(self) -> float:
        """Accounting bytes of live durable objects (BLOB payload sizes +
        SIZE registrations) — the logical ``LatentStore.total_bytes``."""
        return float(sum(s.size for (ns, _), s in self.slots.items()
                         if ns == NS_OBJECT and s.kind != TOMB))

    @property
    def write_amplification(self) -> float:
        """(user + compaction rewrite bytes) / user bytes ever appended."""
        if self.user_bytes_written <= 0:
            return 1.0
        return (self.user_bytes_written + self.rewrite_bytes_written) \
            / self.user_bytes_written

    def stats(self) -> Dict[str, Any]:
        return {
            "segments": len(self._seg_len),
            "on_disk_bytes": self.on_disk_bytes,
            "live_bytes": self.live_bytes,
            "payload_bytes": self.payload_bytes,
            "user_bytes_written": self.user_bytes_written,
            "rewrite_bytes_written": self.rewrite_bytes_written,
            "write_amplification": self.write_amplification,
            "reencoded_records": self.reencoded_records,
            "reencode_bytes_saved": self.reencode_bytes_saved,
            "pending_rungs": len(self.pending_rungs()),
            "recovery": dict(self.recovery_stats),
        }
