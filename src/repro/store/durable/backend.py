"""``DurableBackend`` — the pluggable persistence seam of ``LatentStore``.

:class:`~repro.core.latent_store.LatentStore` keeps what it always owned —
the S3-style latency model, warmth windows, and per-object latency epochs —
and delegates *where bytes live* to one of these backends:

* :class:`MemoryBackend` — the original in-process dicts.  Default, and
  the simulator-conformance substrate: byte-for-byte the pre-refactor
  behavior, nothing survives process exit.
* :class:`SegmentLogBackend` — the engine-grade backend over a
  :class:`~repro.store.durable.log.SegmentLog`: append-only segments,
  checksummed records, manifest-checkpointed recovery, and online
  compaction via an attached :class:`~repro.store.durable.compact.Compactor`.

Both expose the same small protocol (blob/size puts, reads, tombstoning,
iteration, accounting) plus durability hooks (``flush`` / ``maybe_compact``
/ ``close``) that are no-ops in memory — so every caller can drive them
unconditionally.
"""

from __future__ import annotations

import abc
from typing import Any, Dict, Iterator, Optional

from repro.store.durable.compact import Compactor
from repro.store.durable.log import SegmentLog


class DurableBackend(abc.ABC):
    """Byte-custody protocol behind ``LatentStore``."""

    name: str = "durable-backend"
    #: True when an acknowledged put survives process death.
    persistent: bool = False

    @abc.abstractmethod
    def put_blob(self, oid: int, blob: bytes) -> None: ...

    @abc.abstractmethod
    def put_size(self, oid: int, nbytes: float) -> None: ...

    @abc.abstractmethod
    def get_blob(self, oid: int) -> Optional[bytes]: ...

    @abc.abstractmethod
    def size_of(self, oid: int) -> Optional[float]: ...

    @abc.abstractmethod
    def has_blob(self, oid: int) -> bool: ...

    @abc.abstractmethod
    def contains(self, oid: int) -> bool: ...

    @abc.abstractmethod
    def delete(self, oid: int) -> bool: ...

    @abc.abstractmethod
    def oids(self) -> Iterator[int]: ...

    @property
    @abc.abstractmethod
    def total_bytes(self) -> float: ...

    # -- durability hooks (no-ops in memory) ---------------------------------
    def flush(self) -> None:
        """Make every acknowledged write crash-durable."""

    def maybe_compact(self) -> int:
        """One bounded online-compaction step; returns segments compacted."""
        return 0

    def close(self) -> None:
        """Seal, checkpoint, and release file handles."""

    def stats(self) -> Dict[str, Any]:
        return {}


class MemoryBackend(DurableBackend):
    """The pre-refactor in-memory dict store (sim-mode conformance)."""

    name = "memory"
    persistent = False

    def __init__(self) -> None:
        self._blobs: Dict[int, bytes] = {}
        self._sizes: Dict[int, float] = {}

    def put_blob(self, oid: int, blob: bytes) -> None:
        self._blobs[oid] = blob
        self._sizes[oid] = float(len(blob))

    def put_size(self, oid: int, nbytes: float) -> None:
        self._sizes[oid] = float(nbytes)

    def get_blob(self, oid: int) -> Optional[bytes]:
        return self._blobs.get(oid)

    def size_of(self, oid: int) -> Optional[float]:
        return self._sizes.get(oid)

    def has_blob(self, oid: int) -> bool:
        return oid in self._blobs

    def contains(self, oid: int) -> bool:
        return oid in self._sizes or oid in self._blobs

    def delete(self, oid: int) -> bool:
        found = self.contains(oid)
        self._blobs.pop(oid, None)
        self._sizes.pop(oid, None)
        return found

    def oids(self) -> Iterator[int]:
        return iter(set(self._sizes) | set(self._blobs))

    @property
    def total_bytes(self) -> float:
        return float(sum(self._sizes.values()))


class SegmentLogBackend(DurableBackend):
    """Log-structured on-disk backend (the engine default under
    ``StoreConfig.data_dir``).

    ``flush_each_put=True`` acknowledges each put only after its record is
    flushed to the OS (the facade's durable-put contract); the serving
    engine constructs it with ``False`` and instead flushes once per
    request window (write-behind) through :meth:`flush`.
    """

    name = "segment_log"
    persistent = True

    def __init__(self, log: SegmentLog, *, flush_each_put: bool = True,
                 compact_live_frac: float = 0.6):
        self.log = log
        self.flush_each_put = bool(flush_each_put)
        self.compactor = Compactor(log, live_frac_threshold=compact_live_frac)

    @classmethod
    def open(cls, path: str, *, segment_bytes: float = 4e6,
             fsync: bool = False, checkpoint_every: int = 1024,
             flush_each_put: bool = True,
             compact_live_frac: float = 0.6) -> "SegmentLogBackend":
        return cls(SegmentLog(path, segment_bytes=segment_bytes, fsync=fsync,
                              checkpoint_every=checkpoint_every),
                   flush_each_put=flush_each_put,
                   compact_live_frac=compact_live_frac)

    def put_blob(self, oid: int, blob: bytes) -> None:
        self.log.put_blob(oid, blob)
        if self.flush_each_put:
            self.log.flush()

    def put_size(self, oid: int, nbytes: float) -> None:
        self.log.put_size(oid, nbytes)
        if self.flush_each_put:
            self.log.flush()

    def get_blob(self, oid: int) -> Optional[bytes]:
        return self.log.get_blob(oid)

    def size_of(self, oid: int) -> Optional[float]:
        return self.log.size_of(oid)

    def has_blob(self, oid: int) -> bool:
        return self.log.has_blob(oid)

    def contains(self, oid: int) -> bool:
        return self.log.contains_object(oid)

    def delete(self, oid: int) -> bool:
        found = self.log.contains_object(oid)
        if found:
            self.log.tombstone(oid)
            if self.flush_each_put:
                self.log.flush()
        return found

    def oids(self) -> Iterator[int]:
        return self.log.object_oids()

    @property
    def total_bytes(self) -> float:
        return self.log.payload_bytes

    def flush(self) -> None:
        self.log.flush()

    def maybe_compact(self) -> int:
        return self.compactor.step()

    def close(self) -> None:
        self.log.close()

    def stats(self) -> Dict[str, Any]:
        out = self.log.stats()
        out.update(self.compactor.summary())
        return out
