"""``DurableBackend`` — the pluggable persistence seam of ``LatentStore``.

:class:`~repro.core.latent_store.LatentStore` keeps what it always owned —
the S3-style latency model, warmth windows, and per-object latency epochs —
and delegates *where bytes live* to one of these backends:

* :class:`MemoryBackend` — the original in-process dicts.  Default, and
  the simulator-conformance substrate: byte-for-byte the pre-refactor
  behavior, nothing survives process exit.
* :class:`SegmentLogBackend` — the engine-grade backend over a
  :class:`~repro.store.durable.log.SegmentLog`: append-only segments,
  checksummed records, manifest-checkpointed recovery, and online
  compaction via an attached :class:`~repro.store.durable.compact.Compactor`.

Both expose the same small protocol (blob/size puts, reads, tombstoning,
iteration, accounting) plus durability hooks (``flush`` / ``maybe_compact``
/ ``close``) that are no-ops in memory — so every caller can drive them
unconditionally.
"""

from __future__ import annotations

import abc
from typing import Any, Dict, Iterator, Optional

from repro.compression.ladder import (RECIPE_RUNG, scaled_nbytes,
                                      transcode_blob)
from repro.compression.latentcodec import blob_rung
from repro.store.durable.compact import Compactor
from repro.store.durable.log import SegmentLog


class DurableBackend(abc.ABC):
    """Byte-custody protocol behind ``LatentStore``."""

    name: str = "durable-backend"
    #: True when an acknowledged put survives process death.
    persistent: bool = False

    @abc.abstractmethod
    def put_blob(self, oid: int, blob: bytes) -> None: ...

    @abc.abstractmethod
    def put_size(self, oid: int, nbytes: float, rung: int = 0) -> None: ...

    @abc.abstractmethod
    def get_blob(self, oid: int) -> Optional[bytes]: ...

    @abc.abstractmethod
    def size_of(self, oid: int) -> Optional[float]: ...

    @abc.abstractmethod
    def has_blob(self, oid: int) -> bool: ...

    @abc.abstractmethod
    def contains(self, oid: int) -> bool: ...

    @abc.abstractmethod
    def delete(self, oid: int) -> bool: ...

    @abc.abstractmethod
    def oids(self) -> Iterator[int]: ...

    @property
    @abc.abstractmethod
    def total_bytes(self) -> float: ...

    # -- rate-distortion ladder ----------------------------------------------
    def rung_of(self, oid: int) -> Optional[int]:
        """Ladder rung the object's durable bytes sit at (None: absent)."""
        return 0 if self.contains(oid) else None

    def target_rung_of(self, oid: int) -> Optional[int]:
        """Pending (not yet applied) demotion target, or None."""
        return None

    def set_target_rung(self, oid: int, rung: int) -> bool:
        """Ask for the object to be re-encoded at a colder rung.  Returns
        False when the backend cannot ladder this object."""
        return False

    # -- durability hooks (no-ops in memory) ---------------------------------
    def flush(self) -> None:
        """Make every acknowledged write crash-durable."""

    def maybe_compact(self) -> int:
        """One bounded online-compaction step; returns segments compacted."""
        return 0

    def close(self) -> None:
        """Seal, checkpoint, and release file handles."""

    def stats(self) -> Dict[str, Any]:
        return {}


class MemoryBackend(DurableBackend):
    """The pre-refactor in-memory dict store (sim-mode conformance).

    Ladder demotion applies *eagerly* here: there is no compactor to
    piggyback on (the deferred-re-encode optimization is a segment-log
    property), so ``set_target_rung`` transcodes the blob — or re-scales
    the size registration — on the spot.  No intent is ever pending.
    """

    name = "memory"
    persistent = False

    def __init__(self) -> None:
        self._blobs: Dict[int, bytes] = {}
        self._sizes: Dict[int, float] = {}
        self._rungs: Dict[int, int] = {}

    @staticmethod
    def _sniff_rung(blob: bytes) -> int:
        try:
            return blob_rung(blob)
        except (ValueError, IndexError):
            return 0

    def put_blob(self, oid: int, blob: bytes) -> None:
        self._blobs[oid] = blob
        self._sizes[oid] = float(len(blob))
        self._rungs[oid] = self._sniff_rung(blob)

    def put_size(self, oid: int, nbytes: float, rung: int = 0) -> None:
        self._sizes[oid] = float(nbytes)
        self._rungs[oid] = int(rung)

    def get_blob(self, oid: int) -> Optional[bytes]:
        return self._blobs.get(oid)

    def size_of(self, oid: int) -> Optional[float]:
        return self._sizes.get(oid)

    def has_blob(self, oid: int) -> bool:
        return oid in self._blobs

    def contains(self, oid: int) -> bool:
        return oid in self._sizes or oid in self._blobs

    def delete(self, oid: int) -> bool:
        found = self.contains(oid)
        self._blobs.pop(oid, None)
        self._sizes.pop(oid, None)
        self._rungs.pop(oid, None)
        return found

    def oids(self) -> Iterator[int]:
        return iter(set(self._sizes) | set(self._blobs))

    @property
    def total_bytes(self) -> float:
        return float(sum(self._sizes.values()))

    def rung_of(self, oid: int) -> Optional[int]:
        if not self.contains(oid):
            return None
        return int(self._rungs.get(oid, 0))

    def set_target_rung(self, oid: int, rung: int) -> bool:
        rung = int(rung)
        cur = self.rung_of(oid)
        if cur is None or rung <= cur or not 0 < rung < RECIPE_RUNG:
            return False
        blob = self._blobs.get(oid)
        if blob is not None:
            try:
                demoted = transcode_blob(blob, rung)
            except (ValueError, TypeError):
                return False             # opaque payload: cannot ladder
            self._blobs[oid] = demoted
            self._sizes[oid] = float(len(demoted))
        else:
            self._sizes[oid] = scaled_nbytes(self._sizes[oid], cur, rung)
        self._rungs[oid] = rung
        return True


class SegmentLogBackend(DurableBackend):
    """Log-structured on-disk backend (the engine default under
    ``StoreConfig.data_dir``).

    ``flush_each_put=True`` acknowledges each put only after its record is
    flushed to the OS (the facade's durable-put contract); the serving
    engine constructs it with ``False`` and instead flushes once per
    request window (write-behind) through :meth:`flush`.
    """

    name = "segment_log"
    persistent = True

    def __init__(self, log: SegmentLog, *, flush_each_put: bool = True,
                 compact_live_frac: float = 0.6):
        self.log = log
        self.flush_each_put = bool(flush_each_put)
        self.compactor = Compactor(log, live_frac_threshold=compact_live_frac)

    @classmethod
    def open(cls, path: str, *, segment_bytes: float = 4e6,
             fsync: bool = False, checkpoint_every: int = 1024,
             flush_each_put: bool = True,
             compact_live_frac: float = 0.6) -> "SegmentLogBackend":
        return cls(SegmentLog(path, segment_bytes=segment_bytes, fsync=fsync,
                              checkpoint_every=checkpoint_every),
                   flush_each_put=flush_each_put,
                   compact_live_frac=compact_live_frac)

    def put_blob(self, oid: int, blob: bytes) -> None:
        self.log.put_blob(oid, blob)
        if self.flush_each_put:
            self.log.flush()

    def put_size(self, oid: int, nbytes: float, rung: int = 0) -> None:
        self.log.put_size(oid, nbytes, rung)
        if self.flush_each_put:
            self.log.flush()

    def get_blob(self, oid: int) -> Optional[bytes]:
        return self.log.get_blob(oid)

    def size_of(self, oid: int) -> Optional[float]:
        return self.log.size_of(oid)

    def has_blob(self, oid: int) -> bool:
        return self.log.has_blob(oid)

    def contains(self, oid: int) -> bool:
        return self.log.contains_object(oid)

    def delete(self, oid: int) -> bool:
        found = self.log.contains_object(oid)
        if found:
            self.log.tombstone(oid)
            if self.flush_each_put:
                self.log.flush()
        return found

    def oids(self) -> Iterator[int]:
        return self.log.object_oids()

    @property
    def total_bytes(self) -> float:
        return self.log.payload_bytes

    def rung_of(self, oid: int) -> Optional[int]:
        return self.log.rung_of(oid)

    def target_rung_of(self, oid: int) -> Optional[int]:
        return self.log.target_rung_of(oid)

    def set_target_rung(self, oid: int, rung: int) -> bool:
        """Record the demotion intent; the attached compactor's next pass
        over the object's segment re-encodes the bytes (piggybacked on
        the rewrite — never a standalone I/O pass)."""
        rung = int(rung)
        cur = self.log.rung_of(oid)
        if cur is None or rung <= cur or not 0 < rung < RECIPE_RUNG:
            return False
        if self.log.target_rung_of(oid) == rung:
            return True                  # idempotent: intent already queued
        self.log.set_target_rung(oid, rung)
        if self.flush_each_put:
            self.log.flush()
        return True

    def flush(self) -> None:
        self.log.flush()

    def maybe_compact(self) -> int:
        return self.compactor.step()

    def close(self) -> None:
        self.log.close()

    def stats(self) -> Dict[str, Any]:
        out = self.log.stats()
        out.update(self.compactor.summary())
        return out
