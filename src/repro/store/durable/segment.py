"""On-disk record format of the log-structured durable store.

A segment file is a flat sequence of self-describing records:

    ┌───────┬───────┬───────┬──────┬───────┬───────┬─────────────┐
    │ magic │ crc32 │  lsn  │ kind │  oid  │ plen  │   payload   │
    │  4 B  │  4 B  │  8 B  │ 1 B  │  8 B  │  4 B  │   plen B    │
    └───────┴───────┴───────┴──────┴───────┴───────┴─────────────┘

``crc32`` covers everything after itself (lsn..payload), so a torn tail —
a record the process was writing when it was killed — fails either the
magic check, the length check, or the checksum, and the scanner stops
cleanly at the last intact record.  ``lsn`` is a store-global, strictly
increasing log sequence number: replay applies records in *lsn* order, not
file order, which is what lets compaction rewrite old records into new
segments (keeping their original lsn) without ever changing the outcome of
a recovery scan.

Record kinds (one keyspace per ``oid``, three namespaces):

* durable-object namespace — ``BLOB`` (compressed latent payload),
  ``SIZE`` (size-only registration, simulator mode; payload is one
  little-endian float64 followed by one rung byte — legacy 8-byte
  payloads decode as rung 0), ``TOMB`` (delete/demote tombstone; empty
  payload);
* recipe namespace — ``RSTATE`` (full regen-tier state of one object as
  JSON: recipe fields, accounting bytes, latent residency, last access),
  ``RDEL`` (recipe tombstone);
* ladder namespace — ``RUNG`` (demotion *intent*: payload is one byte,
  the target rate-distortion rung).  The intent is deliberately a
  separate record, not a blob rewrite: the compactor transcodes the
  object's bytes when it next rewrites the segment, so ladder demotion
  never adds its own I/O pass.  An intent is *pending* only while it is
  newer than the object record and targets a colder rung — a fresh put
  (higher lsn) silently invalidates it.

Full-state ``RSTATE`` records (instead of incremental demote/readmit
deltas) make recovery order-free within the namespace: the highest-lsn
record *is* the state.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Iterator, Optional, Tuple

MAGIC = b"LBS1"

#: record kinds — durable-object namespace
BLOB = 1            # payload = compressed latent bytes
SIZE = 2            # payload = struct '<d' accounting size (sim mode)
TOMB = 3            # payload = b'' (delete / demote)
#: record kinds — recipe namespace
RSTATE = 4          # payload = JSON regen-tier state
RDEL = 5            # payload = b''
#: record kinds — ladder namespace
RUNG = 6            # payload = struct '<B' target rate-distortion rung

OBJECT_KINDS = (BLOB, SIZE, TOMB)
RECIPE_KINDS = (RSTATE, RDEL)
LADDER_KINDS = (RUNG,)

_HEADER = struct.Struct("<4sIQBqI")      # magic, crc, lsn, kind, oid, plen
HEADER_BYTES = _HEADER.size
_TAIL = struct.Struct("<QBqI")           # the crc-covered header fields

_SIZE_PAYLOAD = struct.Struct("<d")      # legacy (pre-ladder) SIZE payload
_SIZE_RUNG_PAYLOAD = struct.Struct("<dB")
_RUNG_PAYLOAD = struct.Struct("<B")


def record_bytes(payload_len: int) -> int:
    """Total on-disk bytes of a record with ``payload_len`` payload."""
    return HEADER_BYTES + int(payload_len)


def pack_record(lsn: int, kind: int, oid: int, payload: bytes) -> bytes:
    """Serialize one record (header crc over lsn..payload)."""
    tail = _TAIL.pack(lsn, kind, oid, len(payload))
    crc = zlib.crc32(payload, zlib.crc32(tail)) & 0xFFFFFFFF
    return _HEADER.pack(MAGIC, crc, lsn, kind, oid, len(payload)) + payload


def pack_size_payload(nbytes: float, rung: int = 0) -> bytes:
    return _SIZE_RUNG_PAYLOAD.pack(float(nbytes), int(rung) & 0xFF)


def unpack_size_payload(payload: bytes) -> float:
    return float(_SIZE_PAYLOAD.unpack_from(payload)[0])


def unpack_size_rung(payload: bytes) -> Tuple[float, int]:
    """(nbytes, rung) of a SIZE payload; legacy 8-byte payloads -> rung 0."""
    if len(payload) >= _SIZE_RUNG_PAYLOAD.size:
        nbytes, rung = _SIZE_RUNG_PAYLOAD.unpack_from(payload)
        return float(nbytes), int(rung)
    return float(_SIZE_PAYLOAD.unpack_from(payload)[0]), 0


def pack_rung_payload(rung: int) -> bytes:
    return _RUNG_PAYLOAD.pack(int(rung) & 0xFF)


def unpack_rung_payload(payload: bytes) -> int:
    return int(_RUNG_PAYLOAD.unpack_from(payload)[0])


@dataclasses.dataclass(frozen=True)
class Record:
    """One decoded record plus its location inside its segment."""

    offset: int                  # byte offset of the header in the segment
    lsn: int
    kind: int
    oid: int
    payload: bytes

    @property
    def nbytes(self) -> int:
        return record_bytes(len(self.payload))

    @property
    def end(self) -> int:
        return self.offset + self.nbytes


def scan_records(buf: bytes, start: int = 0) -> Tuple[list, int]:
    """Decode records from ``buf[start:]`` until the end or a torn tail.

    Returns ``(records, valid_end)`` where ``valid_end`` is the offset one
    past the last intact record — everything beyond it (bad magic, short
    header, short payload, or checksum mismatch) is an unacknowledged tail
    and must be ignored (and, for the active segment, truncated away).
    """
    out = []
    off = start
    n = len(buf)
    while off + HEADER_BYTES <= n:
        magic, crc, lsn, kind, oid, plen = _HEADER.unpack_from(buf, off)
        if magic != MAGIC:
            break
        end = off + HEADER_BYTES + plen
        if end > n:
            break
        payload = buf[off + HEADER_BYTES:end]
        tail = _TAIL.pack(lsn, kind, oid, plen)
        if zlib.crc32(payload, zlib.crc32(tail)) & 0xFFFFFFFF != crc:
            break
        out.append(Record(off, lsn, kind, oid, payload))
        off = end
    return out, off


def read_payload(f, offset: int, payload_len: int) -> Optional[bytes]:
    """Read one record's payload given its header offset; verifies the
    stored checksum so a corrupt read can never be served as object bytes.
    Returns ``None`` on any mismatch."""
    f.seek(offset)
    raw = f.read(HEADER_BYTES + payload_len)
    recs, _ = scan_records(raw)
    if not recs or len(recs[0].payload) != payload_len:
        return None
    return recs[0].payload


def iter_file_records(path: str, start: int = 0) -> Iterator[Record]:
    """Convenience full-file scan (tools/tests); stops at the torn tail."""
    with open(path, "rb") as f:
        recs, _ = scan_records(f.read(), start)
    yield from recs
