"""LatentBox object-store API (the paper's storage system, as a library).

One client-facing facade — :class:`LatentBox` — exposes the full object
lifecycle (``put`` / ``get`` / ``get_many`` / ``delete`` / ``stat`` /
``demote`` / ``promote``) over a tier-walk read path

    pixel cache -> latent cache -> durable latent store -> recipe regen

with two interchangeable backends: the **engine** backend runs real jitted
VAE decodes through the microbatching scheduler, the **sim** backend runs
the same tier walk against the discrete-latency plant.  Both classify every
request identically; they differ only in how payloads and latencies are
produced.
"""

from repro.store.api import (DEFAULT_OBJECT_BYTES, GetResult, HIT_CLASSES,
                             ObjectStat, PutResult, StoreConfig, IMAGE_HIT,
                             LATENT_HIT, FULL_MISS, REGEN_MISS)
from repro.store.backends import EngineBackend, SimBackend
from repro.store.durable import (Compactor, DurableBackend, MemoryBackend,
                                 SegmentLog, SegmentLogBackend)
from repro.store.facade import LatentBox
from repro.store.faults import FaultEvent, FaultPlan
from repro.store.replication import (HedgeConfig, LogReplicaHolder,
                                     MemoryReplica)
from repro.store.sharding import ReshardReport, ShardedLatentBox
from repro.store.tiers import (DualCacheTier, DurableTier, RecipeTier, Tier,
                               TierHit)
from repro.store.walk import TierWalk, WalkTicket

__all__ = [
    "LatentBox", "StoreConfig", "GetResult", "PutResult", "ObjectStat",
    "EngineBackend", "SimBackend", "ShardedLatentBox", "ReshardReport",
    "Tier", "TierHit", "DualCacheTier", "DurableTier", "RecipeTier",
    "TierWalk", "WalkTicket",
    "DurableBackend", "MemoryBackend", "SegmentLogBackend", "SegmentLog",
    "Compactor", "DEFAULT_OBJECT_BYTES",
    "FaultPlan", "FaultEvent", "HedgeConfig",
    "LogReplicaHolder", "MemoryReplica",
    "IMAGE_HIT", "LATENT_HIT", "FULL_MISS", "REGEN_MISS", "HIT_CLASSES",
]
