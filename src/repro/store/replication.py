"""Replica holders — the storage side of R-way shard replication.

Placement walks the consistent-hash ring: an object's replica set is the
first R *distinct shards* among ``ring.successors(oid)`` (the first is the
primary, i.e. the owner).  Every non-primary replica shard hosts one
*holder* per source shard it follows — a small write-behind copy of the
source's durable state for exactly the objects designated to that
(follower, source) pair.

Two holder kinds, one interface:

``LogReplicaHolder``
    A :class:`~repro.store.durable.log.SegmentLog` nested under the
    follower shard's directory (``shard00N/replica-of-00M/``) plus an
    atomically replaced ``HWM.json`` sidecar.  Shipped records are applied
    *state-wise with local lsns* — the holder never tries to merge the
    source's lsn space into its own, which makes re-shipping (catch-up
    after downtime, R=3 duplicate deliveries) idempotent by construction:
    a re-applied record is the same current state appended again, never a
    rollback.

``MemoryReplica``
    Dict-backed equivalent for memory-mode clusters (simulation /
    in-memory engine); "lsns" are application indices.

Two watermarks, two directions:

``hwm``
    The *source-stream* position (source lsn for persistent sources, a
    cluster-kept per-source sequence for memory sources) the holder has
    durably seen.  Used when the *holder's* shard comes back: the source
    re-ships ``export_delta(holder.hwm, designated)`` — only the delta.

``durable_frontier``
    The holder's *local* position as of the source's last durability
    barrier.  Snapshotted when the *source* shard is killed: everything
    the holder applied after that point may be exactly the write-behind
    tail the source lost, so restart catch-up ships
    ``holder.export_delta(frontier, designated)`` back to the source.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Iterator, Optional, Tuple

from repro.store.durable.log import (NS_OBJECT, NS_RECIPE, NS_RUNG,
                                     SegmentLog)
from repro.store.durable.segment import (BLOB, RDEL, RSTATE, RUNG, SIZE,
                                         TOMB, pack_record,
                                         pack_size_payload, scan_records,
                                         unpack_size_payload)

HWM_FILE = "HWM.json"

_NS_OF = {BLOB: NS_OBJECT, SIZE: NS_OBJECT, TOMB: NS_OBJECT,
          RSTATE: NS_RECIPE, RDEL: NS_RECIPE, RUNG: NS_RUNG}


@dataclasses.dataclass(frozen=True)
class HedgeConfig:
    """Hedged-read policy (Dean & Barroso tail-at-scale style).

    A read whose primary exceeds the adaptive *hedge delay* fires a
    speculative fetch to the next replica; the first response wins.  The
    delay is a percentile of the recent latencies of the *other* live
    shards — a shard that stalls cannot talk the cluster out of hedging
    against it.  Hedging races only the durable *fetch* leg: the decode
    stays single-flight, so a won hedge never costs a second decode.
    """

    enabled: bool = True
    quantile: float = 0.95      # hedge delay = this pct of peer latencies
    min_delay_ms: float = 1.0   # floor: never hedge essentially instantly
    window: int = 64            # per-shard latency samples retained
    min_samples: int = 8        # below this, no hedging (delay unknown)
    net_hop_ms: float = 0.25    # modeled extra hop to a non-owner replica


def pack_state_records(oid: int, store, regen, lsn: int) -> bytes:
    """Snapshot one object's current durable state (both namespaces,
    absence shipped as TOMB/RDEL) as a raw segment image — the forwarding
    unit for *memory-mode* sources, which have no
    :meth:`~repro.store.durable.log.SegmentLog.export_delta` to call.
    ``lsn`` is the cluster's per-source forwarding sequence; holders use
    it only as the source-stream high-water mark."""
    oid = int(oid)
    parts = []
    st = store.stat(oid)
    if st is None:
        parts.append(pack_record(lsn, TOMB, oid, b""))
    elif st["has_payload"]:
        parts.append(pack_record(lsn, BLOB, oid, store.get(oid)))
    else:
        parts.append(pack_record(lsn, SIZE, oid,
                                 pack_size_payload(st["nbytes"],
                                                   st.get("rung") or 0)))
    state = regen.state_of(oid)
    if state is None:
        parts.append(pack_record(lsn + 1, RDEL, oid, b""))
    else:
        parts.append(pack_record(
            lsn + 1, RSTATE, oid,
            json.dumps(state, sort_keys=True).encode()))
    return b"".join(parts)


class MemoryReplica:
    """Dict-backed holder: latest (kind, payload) per slot, application
    indices for lsns.  Nothing survives the process — a memory-mode
    restart always re-ships full state, so ``hwm``/``durable_frontier``
    only matter within one process lifetime."""

    kind = "memory"

    def __init__(self):
        # (ns, oid) -> (local_lsn, kind, payload)
        self._slots: Dict[Tuple[int, int], Tuple[int, int, bytes]] = {}
        self._lsn = 0
        self.hwm = 0
        self.durable_frontier = 0
        #: source incarnation this holder last synced against (the cluster
        #: bumps it on every source restart — a mismatch means the source's
        #: lsn space shifted and hwm deltas are meaningless)
        self.src_inc = 0

    # -- write path -----------------------------------------------------------
    def apply_records(self, raw: bytes, source_hwm: int = 0) -> int:
        recs, valid_end = scan_records(raw, 0)
        if valid_end != len(raw):
            raise ValueError(
                f"replica shipment is corrupt: checksum/framing failure at "
                f"byte {valid_end} of {len(raw)}; nothing applied")
        for r in recs:
            self._lsn += 1
            self._slots[(_NS_OF[r.kind], r.oid)] = (self._lsn, r.kind,
                                                    r.payload)
        self.hwm = max(self.hwm, int(source_hwm))
        return len(recs)

    def discard(self, oid: int) -> None:
        """De-designation: record both namespaces as absent (kept as
        tombstone slots so accounting stays uniform with the log holder;
        never shipped — exports always filter by the designated set)."""
        oid = int(oid)
        for ns, kind in ((NS_OBJECT, TOMB), (NS_RECIPE, RDEL)):
            if (ns, oid) in self._slots:
                self._lsn += 1
                self._slots[(ns, oid)] = (self._lsn, kind, b"")

    def checkpoint(self) -> None:
        self.durable_frontier = self._lsn

    def set_hwm(self, pos: int) -> None:
        """Directly (re)base the source-stream mark — used after a full
        reconcile, when the source's lsn space may have *shifted down*
        (crash-truncated tail) and ``max`` would keep a stale mark."""
        self.hwm = int(pos)

    def abandon(self) -> None:                   # memory: kill loses all
        self._slots.clear()
        self._lsn = 0
        self.hwm = 0
        self.durable_frontier = 0

    def close(self) -> None:
        pass

    # -- read path ------------------------------------------------------------
    @property
    def frontier(self) -> int:
        return self._lsn

    def _slot(self, ns: int, oid: int, dead_kind: int):
        s = self._slots.get((ns, int(oid)))
        return None if s is None or s[1] == dead_kind else s

    def has_object(self, oid: int) -> bool:
        return self._slot(NS_OBJECT, oid, TOMB) is not None

    def contains_any(self, oid: int) -> bool:
        return (self._slot(NS_OBJECT, oid, TOMB) is not None
                or self._slot(NS_RECIPE, oid, RDEL) is not None)

    def blob_of(self, oid: int) -> Optional[bytes]:
        s = self._slot(NS_OBJECT, oid, TOMB)
        return s[2] if s is not None and s[1] == BLOB else None

    def size_of(self, oid: int) -> Optional[float]:
        s = self._slot(NS_OBJECT, oid, TOMB)
        if s is None:
            return None
        return float(len(s[2])) if s[1] == BLOB \
            else unpack_size_payload(s[2])

    def recipe_state_of(self, oid: int) -> Optional[Dict[str, Any]]:
        s = self._slot(NS_RECIPE, oid, RDEL)
        return json.loads(s[2].decode()) if s is not None else None

    def object_oids(self) -> Iterator[int]:
        for (ns, oid), (_, kind, _p) in self._slots.items():
            if ns == NS_OBJECT and kind != TOMB:
                yield oid

    def live_oids(self) -> set:
        """Every oid with a live slot in either namespace — the candidate
        set for shipping a holder's state back to a recovering source
        (discarded oids are tombstoned in both namespaces, so they are
        excluded by construction)."""
        out = set()
        for (ns, oid), (_, kind, _p) in self._slots.items():
            if (ns == NS_OBJECT and kind != TOMB) \
                    or (ns == NS_RECIPE and kind != RDEL):
                out.add(oid)
        return out

    def export_delta(self, since_lsn: int, oids=None) -> bytes:
        want = None if oids is None else {int(o) for o in oids}
        picked = sorted(
            (lsn, kind, oid, payload)
            for (ns, oid), (lsn, kind, payload) in self._slots.items()
            if lsn > since_lsn and (want is None or oid in want))
        return b"".join(pack_record(lsn, kind, oid, payload)
                        for lsn, kind, oid, payload in picked)

    @property
    def disk_bytes(self) -> int:
        return 0


class LogReplicaHolder:
    """Persistent holder: a nested :class:`SegmentLog` plus the ``hwm``
    sidecar.  The sidecar is written only at :meth:`checkpoint` (after the
    holder's own flush), so a crash can only *understate* the hwm — the
    source then re-ships a delta the holder already has, and state-wise
    application makes that a no-op rather than a rollback."""

    kind = "log"

    def __init__(self, path: str, *, segment_bytes: float = 4e6,
                 fsync: bool = False):
        self.path = os.path.abspath(str(path))
        self.log = SegmentLog(self.path, segment_bytes=segment_bytes,
                              fsync=fsync)
        hwm, frontier = self._load_sidecar()
        self.hwm = hwm
        # Records at or below the checkpointed frontier were flushed when
        # it was written, so they survived any crash and the recovered log
        # reaches at least that far; records after it are NOT known to be
        # source-durable (the sidecar is older than they are).
        self.durable_frontier = min(frontier, self.log.next_lsn - 1)
        self.src_inc = 0

    def _load_sidecar(self):
        try:
            with open(os.path.join(self.path, HWM_FILE)) as f:
                d = json.load(f)
            return int(d["hwm"]), int(d.get("frontier", 0))
        except (OSError, ValueError, KeyError):
            return 0, 0

    def _write_sidecar(self) -> None:
        tmp = os.path.join(self.path, HWM_FILE + ".tmp")
        with open(tmp, "w") as f:
            json.dump({"hwm": self.hwm,
                       "frontier": self.durable_frontier}, f)
            f.flush()
            if self.log.fsync:
                os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.path, HWM_FILE))

    # -- write path -----------------------------------------------------------
    def apply_records(self, raw: bytes, source_hwm: int = 0) -> int:
        recs, valid_end = scan_records(raw, 0)
        if valid_end != len(raw):
            raise ValueError(
                f"replica shipment is corrupt: checksum/framing failure at "
                f"byte {valid_end} of {len(raw)}; nothing applied")
        for r in recs:
            self.log.append(r.kind, r.oid, r.payload)   # local lsn
        self.hwm = max(self.hwm, int(source_hwm))
        return len(recs)

    def discard(self, oid: int) -> None:
        oid = int(oid)
        if self.log._obj_slot(oid) is not None:
            self.log.tombstone(oid)
        if self.log.recipe_state_of(oid) is not None:
            self.log.delete_recipe(oid)

    def checkpoint(self) -> None:
        self.log.flush()
        self.durable_frontier = self.log.next_lsn - 1
        self._write_sidecar()

    def set_hwm(self, pos: int) -> None:
        self.hwm = int(pos)

    def abandon(self) -> None:
        self.log.abandon()

    def close(self) -> None:
        if not self.log.closed:
            self.checkpoint()
            self.log.close()

    # -- read path ------------------------------------------------------------
    @property
    def frontier(self) -> int:
        return self.log.next_lsn - 1

    def has_object(self, oid: int) -> bool:
        return self.log.contains_object(oid)

    def contains_any(self, oid: int) -> bool:
        return (self.log.contains_object(oid)
                or self.log.recipe_state_of(oid) is not None)

    def blob_of(self, oid: int) -> Optional[bytes]:
        return self.log.get_blob(oid)

    def size_of(self, oid: int) -> Optional[float]:
        return self.log.size_of(oid)

    def recipe_state_of(self, oid: int) -> Optional[Dict[str, Any]]:
        return self.log.recipe_state_of(oid)

    def object_oids(self) -> Iterator[int]:
        return self.log.object_oids()

    def live_oids(self) -> set:
        return set(self.log.object_oids()) | set(self.log.recipe_states())

    def export_delta(self, since_lsn: int, oids=None) -> bytes:
        return self.log.export_delta(since_lsn, oids=oids)

    @property
    def disk_bytes(self) -> int:
        return self.log.on_disk_bytes
