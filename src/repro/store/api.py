"""Public value types of the LatentBox object-store API.

Kept import-light (numpy + core configs only) so every store module —
tiers, walk, backends, facade — and both serving stacks can depend on it
without cycles.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.autoscale import AutoscaleConfig
from repro.core.dual_cache import FULL_MISS, IMAGE_HIT, LATENT_HIT
from repro.core.latent_store import (DEFAULT_OBJECT_BYTES,
                                     StoreLatencyModel)
from repro.core.tuner import TunerConfig

#: Fourth hit class beyond the paper's three: the object was demoted to
#: recipe-only storage and must be regenerated before decode.
REGEN_MISS = "regen_miss"

HIT_CLASSES = (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS)

#: :data:`DEFAULT_OBJECT_BYTES` (re-exported above) is the canonical
#: accounting size of an object whose real byte count is unknown — a
#: 0.28 MB compressed SD3.5-class latent (paper Table 1b), THE named home
#: of the old scattered ``0.28e6`` literals.  The value itself lives in
#: ``repro.core.latent_store`` only because ``core`` modules cannot
#: import ``repro.store`` without a cycle; store-side code references it
#: from here.


@dataclasses.dataclass
class StoreConfig:
    """One config for both backends.

    The cache/routing half (everything through ``latent_bytes``) drives the
    shared tier walk, so an engine box and a sim box built from the same
    ``StoreConfig`` classify a shared trace identically.  The plant half
    (``gpus_per_node`` onward) is only consumed by the simulator backend.
    """

    n_nodes: int = 2
    #: Explicit node names for the walk's ring (default: ``node0..node{N-1}``).
    #: A sharded cluster hands each shard a *slice of one global namespace*
    #: (e.g. shard 1 of a 2x2 fleet gets ``("node2", "node3")``): consistent
    #: hashing guarantees the owner among a subset of the ring is the global
    #: owner whenever it lies in that subset, so sharding never moves an
    #: object to a different node than the unsharded fleet would pick.
    node_names: Optional[Tuple[str, ...]] = None
    cache_bytes_per_node: float = 64e6
    alpha0: float = 0.5                 # initial image-tier fraction
    tau: float = 0.1                    # tail-segment fraction (tuner signal)
    promote_threshold: int = 4          # paper h: latent hits before promote;
                                        # doubles as the spillover depth bound
    #: Per-object accounting sizes.  The pixel tier stores *decoded*
    #: pixels in ``pixel_format`` — at the uint8 default an entry costs
    #: H*W*3 bytes, 4x less than the float32 images the engine used to
    #: pin (the engine additionally corrects the charge to each stored
    #: array's real ``nbytes``).  16e3 is the uint8 charge at the nominal
    #: ~73x73 demo object the old 64e3 float32 default described.
    image_bytes: float = 16e3
    latent_bytes: float = 13e3
    #: Stored dtype of pixel-cache entries: 'uint8' (the fused-epilogue
    #: fast path — displayable bytes straight off the decode) or
    #: 'float32' (legacy [-1, 1] float pixels).  Selects the ENGINE's
    #: decode output; the simulator has no payloads and always charges
    #: ``image_bytes``, so set ``image_bytes`` to an entry's size in this
    #: format (the engine corrects its charges to each array's real
    #: nbytes, and conformance tests rely on the two agreeing).
    pixel_format: str = "uint8"
    #: Storage precision of the decoder weights the uint8 fast path
    #: serves from: 'float32' (identity), 'bfloat16' (default-safe
    #: half-storage), or 'int8' (opt-in per-channel quantization).  The
    #: ENGINE applies it to its VAE at open time behind a ±1-LSB uint8
    #: output gate per decode bucket (:mod:`repro.vae.quantize`): a
    #: config whose quantized pixels drift further than ±1 LSB from the
    #: f32 oracle is rejected.  The simulator has no weights — ignored.
    weight_dtype: str = "float32"
    #: Enable the persistent Pallas kernel autotuner
    #: (:mod:`repro.kernels.autotune`): the engine loads
    #: ``data_dir/tuning_cache.json`` at open (tuned block shapes are
    #: compiled by ``prewarm_decode``) and tunes missing (kernel, shape,
    #: bucket, weight_dtype) keys with bounded work per dispatched batch
    #: (tune-on-first-miss).  Engine-only; no-op for the simulator.
    autotune: bool = False
    adaptive: bool = True               # run the marginal-hit tuner
    tuner: TunerConfig = dataclasses.field(
        default_factory=lambda: TunerConfig(window=500, step=0.02))
    decode_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    # -- durable persistence (the log-structured on-disk tier) ---------------
    #: Directory of the segment log.  ``None`` (default) keeps the durable
    #: tier in memory (sim-mode conformance; nothing survives the
    #: process).  Set — usually via ``LatentBox.open(path)`` — to persist
    #: latents AND recipes through one append-only checksummed log with
    #: manifest-checkpointed recovery and online compaction.
    data_dir: Optional[str] = None
    segment_bytes: float = 4e6          # active segment seals past this
    fsync: bool = False                 # force platters on every flush/ack
    checkpoint_every: int = 1024        # appends between manifest checkpoints
    #: Sealed segments at or below this live fraction compact (coldest
    #: first), one per maintenance step.  0 disables online compaction.
    compact_live_frac: float = 0.6
    #: ``False`` (default): every put is flushed before it is acknowledged
    #: (``PutResult.durable``).  ``True``: puts buffer and become durable
    #: at the next ``flush()`` — the serving engine flushes once per
    #: request window, trading a bounded unacknowledged tail for
    #: sequential-append write cost.
    write_behind: bool = False
    #: Injectable wall clock (seconds) for the engine's store-latency
    #: draws; ``None`` = ``time.time``.  The simulator always uses its
    #: virtual clock; injecting a fake clock here makes the ENGINE's
    #: warm/cold latency classification deterministic under test.
    clock: Optional[Callable[[], float]] = None
    # -- simulator plant ----------------------------------------------------
    gpus_per_node: int = 1
    decode_ms: float = 31.0
    generation_ms: float = 3905.0       # full diffusion pipeline (regen cost)
    net_ms: float = 10.0
    latent_ship_ms: float = 1.0
    decode_jitter_sigma: float = 0.0    # 0 => deterministic sim latencies
    store_latency: StoreLatencyModel = dataclasses.field(
        default_factory=StoreLatencyModel)
    seed: int = 0
    # -- elastic autoscaling (off by default: provably a no-op) --------------
    #: Run the cost-model-driven :class:`~repro.core.autoscale.
    #: AutoscaleController`: every control window the backend trades
    #: decode-GPU count against cache bytes (and, on a sharded cluster,
    #: shard count) for the cheapest SLO-feasible plant.  ``False`` builds
    #: no controller at all — the default path is untouched.
    autoscale: bool = False
    #: Control-loop knobs; ``None`` = :class:`AutoscaleConfig` defaults.
    autoscale_cfg: Optional[AutoscaleConfig] = None

    def __post_init__(self) -> None:
        if self.pixel_format not in ("uint8", "float32"):
            raise ValueError(f"pixel_format must be 'uint8' or 'float32': "
                             f"{self.pixel_format!r}")
        if self.weight_dtype not in ("float32", "bfloat16", "int8"):
            raise ValueError(f"weight_dtype must be 'float32', 'bfloat16' "
                             f"or 'int8': {self.weight_dtype!r}")
        if self.node_names is not None:
            self.node_names = tuple(self.node_names)
            if len(set(self.node_names)) != len(self.node_names):
                raise ValueError(f"duplicate node names: {self.node_names}")
            self.n_nodes = len(self.node_names)

    def now_s(self) -> float:
        """The injectable wall clock every engine-side ``now_s`` routes
        through (satellite of the durable-store PR: no more bare
        ``time.time()`` on the serve path)."""
        return time.time() if self.clock is None else float(self.clock())


@dataclasses.dataclass
class PutResult:
    oid: int
    stored_bytes: float                 # durable latent bytes written
    recipe_bytes: float = 0.0           # recipe payload bytes (0: none)
    format: str = "latent"              # 'latent' | 'size' (sim, size-only)
    prewarmed: bool = False
    #: True when this put is crash-durable at return: its record (and the
    #: recipe's) is flushed to the on-disk log.  False in memory mode and
    #: under ``write_behind`` (durable at the next ``flush()``).
    durable: bool = False


@dataclasses.dataclass
class GetResult:
    """One request's answer: payload + hit class + latency breakdown."""

    oid: int
    hit_class: str                        # one of HIT_CLASSES
    payload: Optional[np.ndarray] = None  # decoded pixels (engine); None (sim)
    node: int = -1                        # cache owner (hash-pinned home)
    exec_node: int = -1                   # where the decode ran
    spilled: bool = False
    regenerated: bool = False
    #: The owner shard was dead/partitioned and a replica served the read.
    failover: bool = False
    #: A speculative replica fetch was fired AND won the race; latency_ms
    #: reflects the hedged path.  (Fired-but-lost hedges only count in the
    #: cluster's ``hedges_fired``.)
    hedged: bool = False
    latency_ms: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_ms(self) -> float:
        return self.latency_ms.get("total", 0.0)


@dataclasses.dataclass
class ObjectStat:
    oid: int
    residency: List[str]                  # e.g. ['image@node0', 'durable']
    durable_bytes: float = 0.0
    recipe_bytes: float = 0.0
    #: Bytes the pixel tier charges for this object (0.0 when not
    #: pixel-resident) — real stored-array bytes on the engine backend.
    pixel_bytes: float = 0.0
    demoted: bool = False                 # recipe-only durability class
    #: Rate-distortion ladder position (``repro.compression.ladder``):
    #: the rung the durable bytes are encoded at (0 = lossless; the
    #: recipe rung when demoted; None when the object has no durable
    #: class at all), its name, and any not-yet-applied demotion target
    #: awaiting the compactor (segment-log backends only).
    rung: Optional[int] = None
    rung_name: Optional[str] = None
    target_rung: Optional[int] = None
    meta: Optional[Dict[str, Any]] = None
