"""The two backends of the ``LatentBox`` facade.

Both run the identical :class:`~repro.store.walk.TierWalk` read path, so
they classify a shared trace identically; they differ only in how payloads
and latencies are produced:

* :class:`EngineBackend` — real compute: jitted VAE decode through the
  microbatching scheduler (``serve/engine.py``), measured wall-clock in the
  latency breakdown, true pixels in ``GetResult.payload``.
* :class:`SimBackend` — the discrete latency plant from ``core/cluster.py``
  (:class:`~repro.core.cluster.GpuQueue` + the S3 latency model): no pixels,
  but queue/fetch/decode/regen milliseconds for capacity planning at trace
  scale.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.compression.ladder import RECIPE_RUNG, resolve_rung
from repro.core.cluster import GpuQueue
from repro.core.dual_cache import IMAGE_HIT, LATENT_HIT
from repro.core.latent_store import LatentStore
from repro.core.metrics import RequestLog
from repro.core.regen_tier import Recipe, RegenTierStore
from repro.store.api import (GetResult, ObjectStat, PutResult, StoreConfig)
from repro.store.durable.backend import SegmentLogBackend
from repro.store.durable.log import SegmentLog
from repro.store.tiers import DurableTier, RecipeTier
from repro.store.walk import TierWalk

MS_PER_MONTH = 30 * 86_400.0 * 1e3


def _open_durable(cfg: StoreConfig
                  ) -> Tuple[LatentStore, RegenTierStore,
                             Optional[SegmentLog]]:
    """Build the durable pair (latent store + regen tier) for one backend.

    Without ``cfg.data_dir`` both are in-memory, exactly the pre-refactor
    behavior.  With it, one :class:`SegmentLog` under ``data_dir`` carries
    BOTH the latent blobs/sizes and the recipe/demotion records; recovery
    replays the log (manifest checkpoint + tail scan) into the two stores
    so a reopened box serves every acknowledged put bit-exact.
    """
    if cfg.data_dir is None:
        return (LatentStore(cfg.store_latency, seed=cfg.seed + 1),
                RegenTierStore(), None)
    log = SegmentLog(cfg.data_dir, segment_bytes=cfg.segment_bytes,
                     fsync=cfg.fsync, checkpoint_every=cfg.checkpoint_every)
    backend = SegmentLogBackend(log,
                                flush_each_put=not cfg.write_behind,
                                compact_live_frac=cfg.compact_live_frac)
    store = LatentStore(cfg.store_latency, seed=cfg.seed + 1,
                        backend=backend)
    regen = RegenTierStore(journal=log)
    for oid, state in log.recipe_states().items():
        regen.restore_state(oid, state)
    return store, regen, log


def _stat(walk: TierWalk, store: LatentStore, regen: RegenTierStore,
          oid: int) -> Optional[ObjectStat]:
    residency = walk.residency(oid)
    if not residency:
        return None
    st = store.stat(oid)
    demoted = regen.is_demoted(oid)
    # ladder position: the durable rung when bytes exist, the recipe rung
    # when demoted to recipe-only, None when the object has no durable class
    rung = st["rung"] if st else (RECIPE_RUNG if demoted else None)
    return ObjectStat(
        oid=oid,
        residency=residency,
        durable_bytes=st["nbytes"] if st else 0.0,
        recipe_bytes=(regen.recipe_of(oid).nbytes
                      if regen.recipe_of(oid) else 0.0),
        pixel_bytes=walk.pixel_bytes_of(oid),
        demoted=demoted,
        rung=rung,
        rung_name=resolve_rung(rung).name if rung is not None else None,
        target_rung=st["target_rung"] if st else None)


class EngineBackend:
    """Real-decode backend: wraps :class:`repro.serve.engine.ServingEngine`."""

    name = "engine"

    def __init__(self, vae, cfg: Optional[StoreConfig] = None):
        # deferred import: serve.engine imports the store package too
        from repro.serve.engine import ServingEngine
        self.cfg = cfg or StoreConfig()
        self.store, self.regen, self.durable_log = _open_durable(self.cfg)
        # ServingEngine consumes the StoreConfig directly — no per-field
        # copying that could drift from the simulator backend
        self.engine = ServingEngine(vae, self.store, self.cfg,
                                    recipes=self.regen)
        self.walk = self.engine.walk

    # -- object lifecycle ---------------------------------------------------
    def put(self, oid: int, image=None, latent=None,
            recipe: Optional[Recipe] = None, nbytes: Optional[float] = None,
            prewarm: bool = False) -> PutResult:
        if image is None and latent is None and recipe is None:
            raise ValueError(
                "the engine backend stores real payloads: pass an image, "
                "a latent, or a recipe (nbytes-only puts are sim-only)")
        stored = self.engine.put(oid, image=image, latent=latent,
                                 recipe=recipe)
        if prewarm:
            self.engine.prewarm(oid)
        return PutResult(oid, float(stored),
                         recipe_bytes=float(recipe.nbytes) if recipe else 0.0,
                         format="latent", prewarmed=prewarm,
                         durable=self._ack())

    def _ack(self) -> bool:
        """Acknowledgement barrier after a mutating call: the recipe
        tier journals RSTATE/RDEL records straight into the log (NOT via
        the per-put-flushing store backend), so the ack must flush the
        log itself or acknowledged recipe/demotion/delete records could
        die in the file buffer.  Returns whether the mutation is durable
        at return (False in memory mode and under write-behind)."""
        if self.durable_log is None or self.cfg.write_behind:
            return False
        self.durable_log.flush()
        return True

    def get_many(self, oids: Sequence[int],
                 timestamps_ms=None) -> List[GetResult]:
        # timestamps are a simulator concept; the engine serves at wall-clock
        tickets = self.engine.serve_window(oids)
        out = []
        for t in tickets:
            total = t.fetch_ms + t.regen_ms + t.decode_ms
            out.append(GetResult(
                oid=t.oid, hit_class=t.outcome, payload=t.img,
                node=t.owner.idx,
                exec_node=t.exec_node.idx if t.exec_node else t.owner.idx,
                spilled=t.spilled, regenerated=t.regen_ms > 0,
                latency_ms={"fetch": t.fetch_ms, "regen": t.regen_ms,
                            "decode": t.decode_ms, "total": total}))
        return out

    def serve_stream(self, requests, runtime_cfg=None):
        """Open-loop stream replay through the event-loop serving runtime,
        feeding the engine's ``DecodeBatcher`` continuously (the
        ``admit``/``dispatch`` path — no fixed windows).  Returns a
        :class:`repro.serve.runtime.StreamReport`."""
        return self.engine.serve_stream(requests, runtime_cfg)

    def pixels_resident(self, oid: int) -> bool:
        return self.walk.pixels_resident(oid)

    def delete(self, oid: int) -> bool:
        found = self.engine.delete(oid)
        self._ack()
        return found

    def demote(self, oid: int, rung=None) -> bool:
        out = self.engine.demote(oid, rung)
        self._ack()
        return out

    def promote(self, oid: int) -> bool:
        out = self.engine.promote(oid)
        self._ack()
        return out

    def stat(self, oid: int) -> Optional[ObjectStat]:
        return _stat(self.walk, self.store, self.regen, oid)

    def flush(self) -> None:
        """Durability barrier: every acknowledged write is on disk after
        this (and the manifest checkpoint bounds the next recovery)."""
        if self.durable_log is not None:
            self.durable_log.flush(manifest=True)

    def close(self) -> None:
        if self.durable_log is not None:
            self.store.close()
        tc = getattr(self.engine, "tuning_cache", None)
        if tc is not None:
            # persist any wins and release the process-global dispatch
            # hook — a closed box must not keep steering kernel blocking
            from repro.kernels import autotune as _at
            tc.save()
            if _at.get_active_cache() is tc:
                _at.set_active_cache(None)

    def summary(self) -> Dict:
        out = self.engine.summary()
        if self.durable_log is not None:
            out.update(_durable_summary(self.store))
        return out


def _durable_summary(store: LatentStore) -> Dict:
    """On-disk truth for ``summary()``: real segment bytes, live bytes,
    and cumulative write amplification (1.0 until compaction rewrites)."""
    st = store.backend.stats()
    return {"durable_disk_bytes": float(st["on_disk_bytes"]),
            "durable_live_bytes": float(st["live_bytes"]),
            "durable_segments": int(st["segments"]),
            "write_amplification": float(st["write_amplification"]),
            "segments_compacted": int(st.get("segments_compacted", 0)),
            "reencoded_records": int(st.get("reencoded_records", 0)),
            "reencode_bytes_saved": float(
                st.get("reencode_bytes_saved", 0.0)),
            "pending_rungs": int(st.get("pending_rungs", 0))}


class SimBackend:
    """Latency-plant backend: the same tier walk, no real decode.

    Requests replay sequentially; with no explicit timestamps the replay
    is closed-loop (each request arrives when the previous completed).
    Store-fetch latencies use the per-call seed path, so a request's
    sample depends only on ``(seed, oid, arrival index)`` — reproducible
    under request reordering.
    """

    name = "sim"

    def __init__(self, cfg: Optional[StoreConfig] = None):
        self.cfg = cfg or StoreConfig()
        self.store, self.regen, self.durable_log = _open_durable(self.cfg)
        self.walk = TierWalk(self.cfg, DurableTier(self.store),
                             RecipeTier(self.regen))
        self.gpus = [GpuQueue(self.cfg.gpus_per_node)
                     for _ in self.walk.caches]
        self.clock_ms = 0.0
        self._seq = 0
        self.log = RequestLog()
        # live plant dimensions — never mutate self.cfg (it may be shared
        # across backends/shards); the autoscaler moves these instead
        self.gpus_per_node = int(self.cfg.gpus_per_node)
        self.cache_bytes_per_node = float(self.cfg.cache_bytes_per_node)
        # provisioned-resource time integrals (the $-per-M-req inputs);
        # accumulated lazily against the replay clock, always on
        self._gpu_ms = 0.0
        self._cache_byte_ms = 0.0
        self._acct_mark_ms = 0.0
        self.autoscaler = None
        if self.cfg.autoscale:
            from repro.core.autoscale import (AutoscaleConfig,
                                              AutoscaleController, PlantState)
            from repro.core.cost_model import params_for_store
            acfg = self.cfg.autoscale_cfg or AutoscaleConfig()
            if self.cfg.autoscale_cfg is None:
                import dataclasses as _dc
                acfg = _dc.replace(acfg, params=params_for_store(self.cfg))
            self.autoscaler = AutoscaleController(
                PlantState(self.gpus_per_node, len(self.walk.caches),
                           self.cache_bytes_per_node), acfg)
            # per-window observation marks
            self._as_mark = {"reqs": 0, "clock": 0.0, "busy": 0.0}

    # -- object lifecycle ---------------------------------------------------
    def put(self, oid: int, image=None, latent=None,
            recipe: Optional[Recipe] = None, nbytes: Optional[float] = None,
            prewarm: bool = False) -> PutResult:
        if oid in self.store:           # overwrite: purge cached copies,
            for tier in self.walk.caches:   # mirroring the engine backend
                tier.evict(oid)
        if nbytes is None:
            if latent is not None and hasattr(latent, "nbytes"):
                nbytes = float(latent.nbytes)
            elif isinstance(latent, (bytes, bytearray)):
                nbytes = float(len(latent))
            else:
                nbytes = self.cfg.latent_bytes
        self.store.put_size(oid, float(nbytes))
        if recipe is not None:
            self.regen.put(oid, float(nbytes),
                           now_mo=self.clock_ms / MS_PER_MONTH, recipe=recipe)
        if prewarm:
            owner = self.walk._idx[self.walk.router.ring.owner(oid)]
            self.walk.caches[owner].store(oid, format="image")
        return PutResult(oid, float(nbytes),
                         recipe_bytes=float(recipe.nbytes) if recipe else 0.0,
                         format="size", prewarmed=prewarm,
                         durable=self._ack())

    def _ack(self) -> bool:
        """Same ack barrier as the engine backend: flushes the shared
        log (recipe records bypass the store backend's per-put flush)."""
        if self.durable_log is None or self.cfg.write_behind:
            return False
        self.durable_log.flush()
        return True

    def _decode_time(self, oid: int, seq: int) -> float:
        c = self.cfg
        if c.decode_jitter_sigma <= 0:
            return c.decode_ms
        rng = np.random.default_rng((c.seed, 0xDEC0DE, oid & 0xFFFFFFFF, seq))
        return float(c.decode_ms * rng.lognormal(0.0, c.decode_jitter_sigma))

    def get_many(self, oids: Sequence[int],
                 timestamps_ms: Optional[Sequence[float]] = None
                 ) -> List[GetResult]:
        cfg = self.cfg
        out: List[GetResult] = []
        for k, oid in enumerate(oids):
            if timestamps_ms is not None:
                self.clock_ms = max(self.clock_ms, float(timestamps_ms[k]))
            t = self.clock_ms
            for q in self.gpus:
                q.release(t)
            ticket = self.walk.lookup(
                oid, depth_of=lambda i: self.gpus[i].depth())
            seq = self._seq
            self._seq += 1
            owner_tier = self.walk.caches[ticket.owner]
            lat = {"queue": 0.0, "fetch": 0.0, "decode": 0.0, "regen": 0.0,
                   "net": cfg.net_ms}

            if ticket.hit_class == IMAGE_HIT:
                done = t + cfg.net_ms
            else:
                t_ready = t
                if ticket.needs_fetch:
                    f = self.store.fetch_ms(oid, t / 1e3,
                                            nbytes=cfg.latent_bytes, seq=seq)
                    lat["fetch"] = f
                    t_ready += f
                    if owner_tier.tuner is not None:
                        owner_tier.tuner.observe_fetch_ms(f)
                if ticket.hit_class == LATENT_HIT and ticket.spilled:
                    t_ready += cfg.latent_ship_ms   # owner -> spill transfer
                if ticket.needs_regen:
                    # the generation pipeline (which includes the decode)
                    # occupies the exec GPU; the latent becomes durable again
                    dur = cfg.generation_ms
                    lat["regen"] = dur
                    self.store.put_size(oid, cfg.latent_bytes)
                    self.regen.readmit(oid, cfg.latent_bytes,
                                       now_mo=t / MS_PER_MONTH)
                else:
                    dur = self._decode_time(oid, seq)
                    lat["decode"] = dur
                if ticket.needs_fetch or ticket.needs_regen:
                    self.walk.admit_latent(ticket.owner, oid)
                _, start = self.gpus[ticket.exec_node].start(t_ready, dur)
                lat["queue"] = start - t_ready
                if owner_tier.tuner is not None:
                    if ticket.needs_regen:
                        # regen replaces the durable fetch on the miss
                        # path: same EWMA class as the engine backend
                        owner_tier.tuner.observe_fetch_ms(dur)
                    else:
                        owner_tier.tuner.observe_decode_ms(
                            dur + lat["queue"])
                done = start + dur + cfg.net_ms

            lat["total"] = done - t
            self.log.add(t, done - t, ticket.hit_class,
                         queue_ms=lat["queue"], fetch_ms=lat["fetch"],
                         decode_ms=lat["decode"], net_ms=cfg.net_ms,
                         spilled=ticket.spilled, node=ticket.exec_node)
            if timestamps_ms is None:
                self.clock_ms = done                  # closed-loop replay
            out.append(GetResult(
                oid=int(oid), hit_class=ticket.hit_class, payload=None,
                node=ticket.owner, exec_node=ticket.exec_node,
                spilled=ticket.spilled, regenerated=ticket.needs_regen,
                latency_ms=lat))
        # end-of-window maintenance, mirroring the engine's request loop:
        # write-behind records become durable, then one bounded online
        # compaction step (both no-ops without a segment log)
        self.store.flush()
        self.store.maybe_compact()
        self._account_provisioned()
        if self.autoscaler is not None:
            self._autoscale_step()
        return out

    # -- elastic autoscaling --------------------------------------------------
    def _account_provisioned(self) -> None:
        """Advance the provisioned-resource integrals to the current
        replay clock (GPU-ms and cache-byte-ms actually *held*, busy or
        not — what a bill charges and what the autoscaler trades)."""
        dt = self.clock_ms - self._acct_mark_ms
        if dt <= 0.0:
            return
        self._gpu_ms += dt * sum(q.n_gpus for q in self.gpus)
        self._cache_byte_ms += dt * self.cache_bytes_per_node * len(self.gpus)
        self._acct_mark_ms = self.clock_ms

    def _autoscale_step(self) -> None:
        from repro.core.autoscale import WindowObs
        mark = self._as_mark
        n = len(self.log.latency_ms)
        if n - mark["reqs"] < self.autoscaler.cfg.window:
            return
        span = self.clock_ms - mark["clock"]
        busy = sum(q.busy_ms for q in self.gpus)
        outcomes = np.asarray(self.log.outcome[mark["reqs"]:n])
        queue = np.asarray(self.log.queue_ms[mark["reqs"]:n])
        obs = WindowObs(
            requests=n - mark["reqs"], span_ms=span,
            busy_ms=max(0.0, busy - mark["busy"]),
            decode_frac=float(np.mean(outcomes != 0)) if n > mark["reqs"]
            else 1.0,
            queue_p99_ms=float(np.percentile(queue, 99)) if queue.size
            else 0.0)
        self._as_mark = {"reqs": n, "clock": self.clock_ms, "busy": busy}
        ev = self.autoscaler.step(obs)
        if ev is not None:
            self._apply_scale(ev.state)

    def _apply_scale(self, state) -> None:
        """Actuate a controller decision: integrals are settled at the old
        plant first, then GPU queues resize (in-flight decodes preserved)
        and the tier walk re-splits cache capacity under the tuner's
        current alpha."""
        self._account_provisioned()
        if state.gpus_per_node != self.gpus_per_node:
            self.gpus_per_node = int(state.gpus_per_node)
            for q in self.gpus:
                q.resize(self.gpus_per_node)
        if state.cache_bytes_per_node != self.cache_bytes_per_node:
            self.cache_bytes_per_node = float(state.cache_bytes_per_node)
            self.walk.set_cache_capacity(self.cache_bytes_per_node)

    def serve_stream(self, requests, runtime_cfg=None):
        """Open-loop stream replay through the event-loop serving runtime:
        the scheduler owns the timeline (queue delay, deadlines, QoS) and
        calls ``get_many`` once per dispatched microbatch for
        classification.  Returns a :class:`repro.serve.runtime.StreamReport`."""
        from repro.serve.runtime import RuntimeConfig, ServingRuntime
        if runtime_cfg is None:
            runtime_cfg = RuntimeConfig.from_store(self.cfg)
        return ServingRuntime.for_target(self, runtime_cfg).run(requests)

    def pixels_resident(self, oid: int) -> bool:
        return self.walk.pixels_resident(oid)

    def delete(self, oid: int) -> bool:
        found = self.walk.delete(oid)
        self._ack()
        return found

    def demote(self, oid: int, rung=None) -> bool:
        out = self.walk.demote(oid, rung)
        self._ack()
        return out

    def promote(self, oid: int) -> bool:
        if not self.regen.is_demoted(oid):
            return False
        self.store.put_size(oid, self.cfg.latent_bytes)
        self.regen.readmit(oid, self.cfg.latent_bytes,
                           now_mo=self.clock_ms / MS_PER_MONTH)
        self._ack()
        return True

    def stat(self, oid: int) -> Optional[ObjectStat]:
        return _stat(self.walk, self.store, self.regen, oid)

    def flush(self) -> None:
        if self.durable_log is not None:
            self.durable_log.flush(manifest=True)

    def close(self) -> None:
        if self.durable_log is not None:
            self.store.close()

    def summary(self) -> Dict:
        out = self.walk.summary()
        out["sim_clock_ms"] = self.clock_ms
        s = self.log.summarize()
        for key in ("mean_ms", "p50_ms", "p95_ms", "p99_ms"):
            if key in s:
                out[key] = s[key]
        # decode-fleet observability (the autoscaler's feedback signal)
        self._account_provisioned()
        busy = float(sum(q.busy_ms for q in self.gpus))
        out["gpu_seconds"] = busy / 1e3
        out["decode_gpus"] = int(sum(q.n_gpus for q in self.gpus))
        out["decode_util"] = busy / self._gpu_ms if self._gpu_ms > 0 else 0.0
        out["provisioned_gpu_ms"] = self._gpu_ms
        out["provisioned_cache_byte_ms"] = self._cache_byte_ms
        if self.autoscaler is not None:
            out.update(self.autoscaler.summary())
        if self.durable_log is not None:
            out.update(_durable_summary(self.store))
        return out
