"""The one tier-walk read path shared by every LatentBox backend.

Before this module the hit/miss classification logic lived twice — once in
``serve/engine.py`` (real decode fleet) and once in ``core/cluster.py``
(discrete-event plant) — and the two drifted.  :class:`TierWalk` owns the
parts of a request that are *backend-independent*: consistent-hash
ownership, per-node dual-format cache lookup (stats, promotion, tuner
hook), queue-depth spillover choice, latent admission on a durable fetch,
and regen detection on the recipe tier.  Backends consume the resulting
:class:`WalkTicket` and supply only what differs: real decodes and
wall-clock on the engine, latency events on the simulator.

Two backends built from the same :class:`~repro.store.api.StoreConfig`
therefore classify a shared trace identically — the property
``tests/test_store_api.py`` locks in.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.compression.ladder import resolve_rung
from repro.core.dual_cache import IMAGE_HIT, LATENT_HIT, FULL_MISS
from repro.core.router import Router
from repro.store.api import REGEN_MISS, StoreConfig
from repro.store.tiers import DualCacheTier, DurableTier, RecipeTier


@dataclasses.dataclass
class WalkTicket:
    """One request's backend-independent routing/classification decision."""

    oid: int
    hit_class: str              # image_hit | latent_hit | full_miss | regen_miss
    owner: int                  # cache home (hash-pinned)
    exec_node: int              # where the decode should run
    spilled: bool = False
    tail_hit: bool = False
    promoted: bool = False
    write_image: bool = False   # pixel write-back decision made at lookup
    needs_fetch: bool = False   # durable fetch on the critical path
    needs_regen: bool = False   # generation pipeline on the critical path


class TierWalk:
    """Pixel cache -> latent cache -> durable store -> recipe regen."""

    def __init__(self, cfg: StoreConfig, durable: DurableTier,
                 recipes: Optional[RecipeTier] = None):
        self.cfg = cfg
        names = (list(cfg.node_names) if cfg.node_names is not None
                 else [f"node{i}" for i in range(cfg.n_nodes)])
        self.node_names = names
        self.caches: List[DualCacheTier] = [
            DualCacheTier(cfg.cache_bytes_per_node, alpha=cfg.alpha0,
                          tau=cfg.tau,
                          promote_threshold=cfg.promote_threshold,
                          image_bytes=cfg.image_bytes,
                          latent_bytes=cfg.latent_bytes,
                          adaptive=cfg.adaptive, tuner=cfg.tuner,
                          name=f"cache@{name}")
            for name in names]
        self.durable = durable
        self.recipes = recipes
        self.router = Router(names, theta=cfg.promote_threshold)
        self._idx: Dict[str, int] = {n: i for i, n in enumerate(names)}
        self.counts: Dict[str, int] = {
            IMAGE_HIT: 0, LATENT_HIT: 0, FULL_MISS: 0, REGEN_MISS: 0,
            "spilled": 0}

    # -- read path -----------------------------------------------------------
    def lookup(self, oid: int,
               depth_of: Optional[Callable[[int], int]] = None) -> WalkTicket:
        """Classify one request and evolve cache state.

        ``depth_of(node_idx)`` reports decode queue depth for the spillover
        decision (engine: pending unique decodes; sim: GPU outstanding);
        ``None`` disables spillover.  Raises ``KeyError`` when the object
        is in no tier at all.
        """
        owner = self._idx[self.router.ring.owner(oid)]
        cache = self.caches[owner]
        hit = cache.load(oid)

        if hit is not None and hit.hit_class == IMAGE_HIT:
            self.counts[IMAGE_HIT] += 1
            return WalkTicket(oid, IMAGE_HIT, owner, owner,
                              tail_hit=hit.tail_hit, write_image=True)

        # decode required: pick the execution node (spillover w/ pinning)
        exec_node, spilled = owner, False
        if depth_of is not None and len(self.caches) > 1:
            for name, i in self._idx.items():
                self.router.report_depth(name, depth_of(i))
            if depth_of(owner) > self.router.theta:
                cand = self._idx[self.router.least_loaded(
                    exclude=self.node_names[owner])]
                if depth_of(cand) < depth_of(owner):
                    exec_node, spilled = cand, True
                    self.counts["spilled"] += 1
                    self.router.n_spillover += 1

        if hit is not None:                           # latent cache hit
            self.counts[LATENT_HIT] += 1
            return WalkTicket(
                oid, LATENT_HIT, owner, exec_node, spilled=spilled,
                tail_hit=hit.tail_hit, promoted=hit.promoted,
                write_image=(hit.promoted
                             or cache.cache.contains(oid) == "image"))

        # NOTE: admission into the latent cache is the backend's job via
        # :meth:`admit_latent` AFTER the payload materializes — admitting
        # here would poison cache state when the fetch/regen fails.
        dh = self.durable.load(oid)
        if dh is not None:                            # durable latent fetch
            self.counts[FULL_MISS] += 1
            return WalkTicket(oid, FULL_MISS, owner, exec_node,
                              spilled=spilled, needs_fetch=True)

        rh = self.recipes.load(oid) if self.recipes is not None else None
        if rh is not None:                            # recipe-only: regenerate
            self.counts[REGEN_MISS] += 1
            return WalkTicket(oid, REGEN_MISS, owner, exec_node,
                              spilled=spilled, needs_regen=True)

        raise KeyError(f"object {oid} not in any tier")

    def admit_latent(self, owner: int, oid: int) -> bool:
        """Admit a successfully fetched/regenerated latent into the owner's
        cache; returns True when it is latent-tier resident afterwards."""
        cache = self.caches[owner]
        cache.store(oid, format="latent")
        return oid in cache.cache.latent_tier

    def set_cache_capacity(self, bytes_per_node: float) -> None:
        """Autoscaler capacity handoff: resize every node's total cache
        bytes.  Alpha (the pixel/latent split) is preserved per node —
        the marginal-hit tuner keeps owning the split."""
        for tier in self.caches:
            tier.set_capacity(bytes_per_node)

    # -- lifecycle -----------------------------------------------------------
    def delete(self, oid: int) -> bool:
        """Remove an object from every tier (caches, durable, recipes)."""
        found = False
        for tier in self.caches:
            found |= tier.evict(oid)
        found |= self.durable.evict(oid)
        if self.recipes is not None:
            found |= self.recipes.evict(oid)
        return found

    def demote(self, oid: int, rung=None) -> bool:
        """Durability-class demotion down the rate-distortion ladder.

        ``rung=None`` (or ``"recipe"``) keeps the pre-ladder meaning —
        all the way down: drop the durable latent and every cached copy,
        keep only the recipe.  A lossy rung (index/name) instead asks the
        durable tier to re-encode the object at that colder quality: the
        object stays durable (identical ``FULL_MISS`` classification on
        every backend — the segment log defers the transcode to its next
        compaction pass, the memory backend applies it eagerly), and
        cached copies are deliberately left alone: a cached latent is
        merely stale-at-higher-quality, which natural eviction resolves.
        Refuses (returns False) for the lossless rung, for unknown
        objects, and for targets not strictly colder than the current
        rung."""
        r = resolve_rung(rung)
        if not r.is_recipe:
            if r.index <= 0:
                return False              # "demote to lossless" is a no-op
            if not self.durable.contains(oid):
                return False
            return self.durable.set_target_rung(oid, r.index)
        if self.recipes is None or self.recipes.recipe_of(oid) is None:
            return False                  # no recipe: would strand the object
        if not self.durable.contains(oid):
            return False                  # already demoted / unknown
        self.durable.evict(oid)
        self.recipes.regen.demote(oid)
        for tier in self.caches:
            tier.evict(oid)
        return True

    def pixels_resident(self, oid: int) -> bool:
        """Pure peek (no stats, no state evolution): is ``oid`` currently
        resident in its hash owner's pixel tier?  The admission
        controller's ``degrade`` policy uses this to answer from the pixel
        cache without spending a decode slot."""
        owner = self._idx[self.router.ring.owner(oid)]
        return self.caches[owner].cache.contains(oid) == "image"

    def pixel_bytes_of(self, oid: int) -> float:
        """Bytes the pixel tier charges for ``oid`` (0.0 when not
        pixel-resident on any node).  The engine corrects these charges to
        the stored array's real dtype bytes, so this is actual-uint8-sized
        on the fast path."""
        for tier in self.caches:
            sz = tier.cache.image_tier.size_of(oid)
            if sz is not None:
                return float(sz)
        return 0.0

    def residency(self, oid: int) -> List[str]:
        out: List[str] = []
        for i, tier in enumerate(self.caches):
            where = tier.cache.contains(oid)
            if where is not None:
                out.append(f"{where}@{self.node_names[i]}")
        if self.durable.contains(oid):
            out.append("durable")
        if self.recipes is not None and self.recipes.contains(oid):
            out.append("recipe")
        return out

    def summary(self) -> Dict[str, float]:
        total = sum(self.counts[k] for k in
                    (IMAGE_HIT, LATENT_HIT, FULL_MISS, REGEN_MISS))
        out: Dict[str, float] = dict(self.counts)
        out["total"] = total
        if total:
            out["image_hit_frac"] = self.counts[IMAGE_HIT] / total
            out["decode_frac"] = 1.0 - out["image_hit_frac"]
        out["alpha"] = [round(t.cache.alpha, 3) for t in self.caches]
        out["cache_resident_bytes"] = float(
            sum(t.resident_bytes for t in self.caches))
        # pixel-tier byte economics: resident charges are real stored
        # bytes on the engine (uint8 fast path), config estimates on the sim
        out["pixel_cached_objects"] = int(
            sum(len(t.cache.image_tier) for t in self.caches))
        out["pixel_cached_bytes"] = float(
            sum(t.cache.image_tier.resident_bytes for t in self.caches))
        out["pixel_bytes_per_object"] = (
            out["pixel_cached_bytes"] / out["pixel_cached_objects"]
            if out["pixel_cached_objects"] else float(self.cfg.image_bytes))
        out["durable_bytes"] = self.durable.resident_bytes
        if self.recipes is not None:
            out["recipe_bytes"] = self.recipes.resident_bytes
        return out
