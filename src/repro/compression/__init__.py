from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.compression.metrics import psnr, ssim

__all__ = ["compress_latent", "decompress_latent", "psnr", "ssim"]
