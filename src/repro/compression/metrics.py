"""Image fidelity metrics (paper §6.6): PSNR and SSIM, pure numpy."""

from __future__ import annotations

import numpy as np


def psnr(a: np.ndarray, b: np.ndarray, data_range: float = 255.0) -> float:
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    mse = np.mean((a - b) ** 2)
    if mse == 0:
        return float("inf")
    return float(10.0 * np.log10(data_range ** 2 / mse))


def _gaussian_kernel(size: int = 11, sigma: float = 1.5) -> np.ndarray:
    ax = np.arange(size) - size // 2
    g = np.exp(-(ax ** 2) / (2 * sigma ** 2))
    k = np.outer(g, g)
    return k / k.sum()


def _filter2(img: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Valid-mode 2D correlation via FFT (fast for 1024^2 images)."""
    from numpy.fft import irfft2, rfft2
    ih, iw = img.shape
    kh, kw = k.shape
    fh, fw = ih + kh - 1, iw + kw - 1
    F = rfft2(img, s=(fh, fw)) * rfft2(k, s=(fh, fw))
    full = irfft2(F, s=(fh, fw))
    return full[kh - 1:ih, kw - 1:iw]


def ssim(a: np.ndarray, b: np.ndarray, data_range: float = 255.0,
         k1: float = 0.01, k2: float = 0.03) -> float:
    """Mean SSIM (Wang et al.), 11x11 gaussian window, per-channel mean."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if a.ndim == 2:
        a = a[..., None]
        b = b[..., None]
    c1 = (k1 * data_range) ** 2
    c2 = (k2 * data_range) ** 2
    k = _gaussian_kernel()
    vals = []
    for c in range(a.shape[-1]):
        x, y = a[..., c], b[..., c]
        mx = _filter2(x, k)
        my = _filter2(y, k)
        mxx = _filter2(x * x, k)
        myy = _filter2(y * y, k)
        mxy = _filter2(x * y, k)
        vx = mxx - mx * mx
        vy = myy - my * my
        cxy = mxy - mx * my
        s = ((2 * mx * my + c1) * (2 * cxy + c2)) / (
            (mx * mx + my * my + c1) * (vx + vy + c2))
        vals.append(s.mean())
    return float(np.mean(vals))
