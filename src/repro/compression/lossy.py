"""JPEG-class lossy codec (8x8 DCT + quantization + entropy stage), used as
the lossy-compression comparison point of paper §6.6 / Fig. 12.  This is a
faithful JPEG skeleton (YCbCr, standard luma/chroma tables, quality
scaling) with a zlib entropy stage instead of Huffman — sizes track real
JPEG within ~10-20 %, which is all the comparison needs."""

from __future__ import annotations

import zlib
from typing import Tuple

import numpy as np

_Q_LUMA = np.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99]], np.float64)

_Q_CHROMA = np.array([
    [17, 18, 24, 47, 99, 99, 99, 99],
    [18, 21, 26, 66, 99, 99, 99, 99],
    [24, 26, 56, 99, 99, 99, 99, 99],
    [47, 66, 99, 99, 99, 99, 99, 99],
    [99, 99, 99, 99, 99, 99, 99, 99],
    [99, 99, 99, 99, 99, 99, 99, 99],
    [99, 99, 99, 99, 99, 99, 99, 99],
    [99, 99, 99, 99, 99, 99, 99, 99]], np.float64)


def _qscale(q: int) -> float:
    q = max(1, min(100, q))
    return 5000.0 / q / 100.0 if q < 50 else (200.0 - 2 * q) / 100.0


def _dct_mat() -> np.ndarray:
    n = 8
    k = np.arange(n)
    M = np.sqrt(2.0 / n) * np.cos(np.pi * (2 * k[None, :] + 1) * k[:, None] / (2 * n))
    M[0] /= np.sqrt(2.0)
    return M

_DCT = _dct_mat()


def _rgb_to_ycbcr(img: np.ndarray) -> np.ndarray:
    m = np.array([[0.299, 0.587, 0.114],
                  [-0.168736, -0.331264, 0.5],
                  [0.5, -0.418688, -0.081312]])
    y = img @ m.T
    y[..., 1:] += 128.0
    return y


def _ycbcr_to_rgb(y: np.ndarray) -> np.ndarray:
    y = y.copy()
    y[..., 1:] -= 128.0
    m = np.array([[1.0, 0.0, 1.402],
                  [1.0, -0.344136, -0.714136],
                  [1.0, 1.772, 0.0]])
    return y @ m.T


def _blockify(ch: np.ndarray) -> np.ndarray:
    h, w = ch.shape
    return ch.reshape(h // 8, 8, w // 8, 8).transpose(0, 2, 1, 3)


def _unblockify(blocks: np.ndarray, h: int, w: int) -> np.ndarray:
    return blocks.transpose(0, 2, 1, 3).reshape(h, w)


def _encode_channel(ch: np.ndarray, qt: np.ndarray) -> Tuple[bytes, np.ndarray]:
    h, w = ch.shape
    blocks = _blockify(ch - 128.0)
    coef = np.einsum("ij,bcjk,lk->bcil", _DCT, blocks, _DCT)
    q = np.round(coef / qt).astype(np.int16)
    deq = q.astype(np.float64) * qt
    rec = np.einsum("ji,bcjk,kl->bcil", _DCT, deq, _DCT) + 128.0
    return q.tobytes(), _unblockify(rec, h, w)


def jpeg_like(img_u8: np.ndarray, quality: int = 95,
              level: int = 6) -> Tuple[int, np.ndarray]:
    """Returns (compressed_size_bytes, reconstructed uint8 image).

    Arbitrary H x W: edges are replicate-padded up to multiples of the
    8x8 block size before the transform and the reconstruction is
    cropped back, as a real JPEG encoder does (replication, not zeros,
    so the pad rows cost almost nothing and don't ring into the edge)."""
    h, w, _ = img_u8.shape
    ph, pw = (-h) % 8, (-w) % 8
    if ph or pw:
        img_u8 = np.pad(img_u8, ((0, ph), (0, pw), (0, 0)), mode="edge")
    s = _qscale(quality)
    ycc = _rgb_to_ycbcr(img_u8.astype(np.float64))
    payloads = []
    rec = np.empty_like(ycc)
    for c in range(3):
        qt = np.maximum(1.0, np.floor((_Q_LUMA if c == 0 else _Q_CHROMA) * s + 0.5))
        raw, rc = _encode_channel(ycc[..., c], qt)
        payloads.append(raw)
        rec[..., c] = rc
    size = len(zlib.compress(b"".join(payloads), level)) + 600  # hdr+tables
    out = np.clip(_ycbcr_to_rgb(rec), 0, 255).astype(np.uint8)
    return size, out[:h, :w]
