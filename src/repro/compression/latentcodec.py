"""Lossless numeric codec for latent tensors (the pcodec role, paper §5).

Diffusion latents are float tensors with spatial smoothness and
inter-channel correlation that byte-oriented compressors can't exploit.
The pipeline here mirrors pcodec's structure with numpy primitives:

  1. *total-order map*: reinterpret floats as unsigned ints ordered like the
     float values (sign-magnitude -> offset-binary), so numeric closeness
     becomes integer closeness;
  2. *spatial delta* along the innermost spatial axis (per channel), turning
     smoothness into small signed residuals;
  3. *zigzag* map to unsigned;
  4. *byte-plane split* (shuffle), grouping the near-constant high bytes;
  5. DEFLATE entropy stage per the shuffled buffer.

Bit-exact roundtrip for fp16/fp32/(u)intN; property-tested in
``tests/test_compression.py``.  On SD3.5-like latents this reaches the
paper's ~1.8x regime (512 KB raw fp16 -> ~280 KB), see bench_storage.

The lossy variant (``LBQ1``, :func:`compress_latent_lossy`) feeds the
rate-distortion ladder in :mod:`repro.compression.ladder`: uniform
quantization of the float tensor to ``bits`` levels over its observed
range, then the same delta/zigzag/byte-plane/DEFLATE stack.  The blob
carries its ladder rung (:func:`blob_rung`), and
:func:`decompress_latent` dispatches on magic so every read path decodes
both formats transparently.
"""

from __future__ import annotations

import struct
import zlib
from typing import Tuple

import numpy as np

MAGIC = b"LBC1"
MAGIC_LOSSY = b"LBQ1"

_UINT_OF = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _float_to_ordered_uint(u: np.ndarray) -> np.ndarray:
    """Map float bit patterns to order-preserving unsigned ints."""
    bits = 8 * u.itemsize
    sign = np.uint64(1) << np.uint64(bits - 1)
    sign = u.dtype.type(sign)
    return np.where(u & sign != 0, ~u, u | sign)


def _ordered_uint_to_float_bits(u: np.ndarray) -> np.ndarray:
    bits = 8 * u.itemsize
    sign = u.dtype.type(np.uint64(1) << np.uint64(bits - 1))
    return np.where(u & sign != 0, u & ~sign, ~u)


def _zigzag(d: np.ndarray) -> np.ndarray:
    """Signed (as two's-complement unsigned) -> small unsigned."""
    bits = 8 * d.itemsize
    s = d.astype(_UINT_OF[d.itemsize])
    sd = d.view(np.dtype(f"int{bits}"))
    return ((sd << 1) ^ (sd >> (bits - 1))).view(s.dtype)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    half = z >> 1                      # unsigned shift
    return np.where(z & 1, ~half, half)


def compress_latent(arr: np.ndarray, level: int = 6) -> bytes:
    """Compress a numeric ndarray losslessly.  Layout-aware: delta runs
    along the last axis (innermost spatial dim for HWC/CHW latents)."""
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype
    if dt.kind == "f":
        u = arr.view(_UINT_OF[dt.itemsize])
        u = _float_to_ordered_uint(u)
    elif dt.kind in "ui":
        u = arr.view(_UINT_OF[dt.itemsize]) if dt.kind == "i" else arr
    else:
        raise TypeError(f"unsupported dtype {dt}")

    flat = u.reshape(-1, arr.shape[-1]) if arr.ndim > 1 else u.reshape(1, -1)
    delta = flat.copy()
    delta[:, 1:] = flat[:, 1:] - flat[:, :-1]       # wrap-around uint delta
    zz = _zigzag(delta)

    # byte-plane shuffle: [n_elems, itemsize] -> itemsize planes
    raw = zz.reshape(-1).view(np.uint8).reshape(-1, dt.itemsize)
    shuffled = np.ascontiguousarray(raw.T).tobytes()
    payload = zlib.compress(shuffled, level)

    dstr = dt.str.encode()                          # e.g. b'<f2'
    header = MAGIC + struct.pack(
        "<B B B I", len(dstr), arr.ndim, 0, len(payload)) + dstr + struct.pack(
        f"<{arr.ndim}q", *arr.shape)
    return header + payload


def decompress_latent(blob: bytes) -> np.ndarray:
    if blob[:4] == MAGIC_LOSSY:
        return _decompress_lossy(blob)
    if blob[:4] != MAGIC:
        raise ValueError("not an LBC1/LBQ1 blob")
    dlen, ndim, _pad, plen = struct.unpack_from("<B B B I", blob, 4)
    off = 4 + 7
    dt = np.dtype(blob[off:off + dlen].decode())
    off += dlen
    shape = struct.unpack_from(f"<{ndim}q", blob, off)
    off += 8 * ndim
    payload = zlib.decompress(blob[off:off + plen])

    n_elems = int(np.prod(shape))
    planes = np.frombuffer(payload, np.uint8).reshape(dt.itemsize, n_elems)
    zz = np.ascontiguousarray(planes.T).reshape(-1).view(
        _UINT_OF[dt.itemsize]).copy()

    delta = _unzigzag(zz).reshape(-1, shape[-1] if ndim > 1 else n_elems)
    u = _cumsum_wrap(delta)

    if dt.kind == "f":
        u = _ordered_uint_to_float_bits(u)
        return u.view(dt).reshape(shape)
    if dt.kind == "i":
        return u.view(dt).reshape(shape)
    return u.astype(dt).reshape(shape)


def _cumsum_wrap(delta: np.ndarray) -> np.ndarray:
    """Wrap-around (modular) cumulative sum along axis 1."""
    # np.cumsum upcasts; do it in the same unsigned dtype via add.accumulate
    return np.add.accumulate(delta, axis=1, dtype=delta.dtype)


def compression_ratio(arr: np.ndarray, level: int = 6) -> Tuple[int, int, float]:
    blob = compress_latent(arr, level)
    raw = arr.nbytes
    return raw, len(blob), raw / len(blob)


# ---------------------------------------------------------------------------
# Lossy variant (LBQ1): uniform quantization + the same entropy stack.
# ---------------------------------------------------------------------------

def compress_latent_lossy(arr: np.ndarray, bits: int, rung: int = 0,
                          level: int = 6) -> bytes:
    """Quantize a float tensor to ``bits`` bits per element over its
    observed finite range, then run the lossless delta/zigzag/byte-plane
    stack on the quantized codes.  ``rung`` is recorded in the header so
    a blob knows its own ladder position (see :func:`blob_rung`)."""
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype
    if dt.kind != "f":
        raise TypeError(f"lossy codec is float-only, got {dt}")
    if not 1 <= int(bits) <= 16:
        raise ValueError(f"bits must be in [1, 16], got {bits}")
    bits = int(bits)

    f = arr.astype(np.float64, copy=False)
    finite = np.isfinite(f)
    if finite.all():
        lo, hi = float(f.min()), float(f.max())
    elif finite.any():
        lo, hi = float(f[finite].min()), float(f[finite].max())
        f = np.clip(np.nan_to_num(f, nan=lo, posinf=hi, neginf=lo), lo, hi)
    else:                                   # no finite values at all
        lo = hi = 0.0
        f = np.zeros_like(f)

    levels = (1 << bits) - 1
    scale = (hi - lo) / levels if hi > lo else 0.0
    q = (np.round((f - lo) / scale) if scale
         else np.zeros_like(f)).astype(
        np.uint8 if bits <= 8 else np.uint16)

    flat = q.reshape(-1, arr.shape[-1]) if arr.ndim > 1 else q.reshape(1, -1)
    delta = flat.copy()
    delta[:, 1:] = flat[:, 1:] - flat[:, :-1]
    zz = _zigzag(delta)
    raw = zz.reshape(-1).view(np.uint8).reshape(-1, q.itemsize)
    payload = zlib.compress(np.ascontiguousarray(raw.T).tobytes(), level)

    dstr = dt.str.encode()
    return (MAGIC_LOSSY
            + struct.pack("<B B B B I", len(dstr), arr.ndim, bits,
                          int(rung) & 0xFF, len(payload))
            + dstr + struct.pack(f"<{arr.ndim}q", *arr.shape)
            + struct.pack("<dd", lo, hi) + payload)


def _decompress_lossy(blob: bytes) -> np.ndarray:
    dlen, ndim, bits, _rung, plen = struct.unpack_from("<B B B B I", blob, 4)
    off = 4 + 8
    dt = np.dtype(blob[off:off + dlen].decode())
    off += dlen
    shape = struct.unpack_from(f"<{ndim}q", blob, off)
    off += 8 * ndim
    lo, hi = struct.unpack_from("<dd", blob, off)
    off += 16
    payload = zlib.decompress(blob[off:off + plen])

    qdt = np.dtype(np.uint8 if bits <= 8 else np.uint16)
    n_elems = int(np.prod(shape))
    planes = np.frombuffer(payload, np.uint8).reshape(qdt.itemsize, n_elems)
    zz = np.ascontiguousarray(planes.T).reshape(-1).view(qdt).copy()
    delta = _unzigzag(zz).reshape(-1, shape[-1] if ndim > 1 else n_elems)
    q = _cumsum_wrap(delta).astype(np.float64)

    levels = (1 << int(bits)) - 1
    scale = (hi - lo) / levels if hi > lo else 0.0
    return (lo + q * scale).astype(dt).reshape(shape)


def blob_rung(blob: bytes) -> int:
    """Ladder rung a durable blob was encoded at (0 = lossless LBC1)."""
    if blob[:4] == MAGIC:
        return 0
    if blob[:4] == MAGIC_LOSSY:
        return blob[7]
    raise ValueError("not an LBC1/LBQ1 blob")
