"""Lossless numeric codec for latent tensors (the pcodec role, paper §5).

Diffusion latents are float tensors with spatial smoothness and
inter-channel correlation that byte-oriented compressors can't exploit.
The pipeline here mirrors pcodec's structure with numpy primitives:

  1. *total-order map*: reinterpret floats as unsigned ints ordered like the
     float values (sign-magnitude -> offset-binary), so numeric closeness
     becomes integer closeness;
  2. *spatial delta* along the innermost spatial axis (per channel), turning
     smoothness into small signed residuals;
  3. *zigzag* map to unsigned;
  4. *byte-plane split* (shuffle), grouping the near-constant high bytes;
  5. DEFLATE entropy stage per the shuffled buffer.

Bit-exact roundtrip for fp16/fp32/(u)intN; property-tested in
``tests/test_compression.py``.  On SD3.5-like latents this reaches the
paper's ~1.8x regime (512 KB raw fp16 -> ~280 KB), see bench_storage.
"""

from __future__ import annotations

import struct
import zlib
from typing import Tuple

import numpy as np

MAGIC = b"LBC1"

_UINT_OF = {2: np.uint16, 4: np.uint32, 8: np.uint64}


def _float_to_ordered_uint(u: np.ndarray) -> np.ndarray:
    """Map float bit patterns to order-preserving unsigned ints."""
    bits = 8 * u.itemsize
    sign = np.uint64(1) << np.uint64(bits - 1)
    sign = u.dtype.type(sign)
    return np.where(u & sign != 0, ~u, u | sign)


def _ordered_uint_to_float_bits(u: np.ndarray) -> np.ndarray:
    bits = 8 * u.itemsize
    sign = u.dtype.type(np.uint64(1) << np.uint64(bits - 1))
    return np.where(u & sign != 0, u & ~sign, ~u)


def _zigzag(d: np.ndarray) -> np.ndarray:
    """Signed (as two's-complement unsigned) -> small unsigned."""
    bits = 8 * d.itemsize
    s = d.astype(_UINT_OF[d.itemsize])
    sd = d.view(np.dtype(f"int{bits}"))
    return ((sd << 1) ^ (sd >> (bits - 1))).view(s.dtype)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    half = z >> 1                      # unsigned shift
    return np.where(z & 1, ~half, half)


def compress_latent(arr: np.ndarray, level: int = 6) -> bytes:
    """Compress a numeric ndarray losslessly.  Layout-aware: delta runs
    along the last axis (innermost spatial dim for HWC/CHW latents)."""
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype
    if dt.kind == "f":
        u = arr.view(_UINT_OF[dt.itemsize])
        u = _float_to_ordered_uint(u)
    elif dt.kind in "ui":
        u = arr.view(_UINT_OF[dt.itemsize]) if dt.kind == "i" else arr
    else:
        raise TypeError(f"unsupported dtype {dt}")

    flat = u.reshape(-1, arr.shape[-1]) if arr.ndim > 1 else u.reshape(1, -1)
    delta = flat.copy()
    delta[:, 1:] = flat[:, 1:] - flat[:, :-1]       # wrap-around uint delta
    zz = _zigzag(delta)

    # byte-plane shuffle: [n_elems, itemsize] -> itemsize planes
    raw = zz.reshape(-1).view(np.uint8).reshape(-1, dt.itemsize)
    shuffled = np.ascontiguousarray(raw.T).tobytes()
    payload = zlib.compress(shuffled, level)

    dstr = dt.str.encode()                          # e.g. b'<f2'
    header = MAGIC + struct.pack(
        "<B B B I", len(dstr), arr.ndim, 0, len(payload)) + dstr + struct.pack(
        f"<{arr.ndim}q", *arr.shape)
    return header + payload


def decompress_latent(blob: bytes) -> np.ndarray:
    if blob[:4] != MAGIC:
        raise ValueError("not an LBC1 blob")
    dlen, ndim, _pad, plen = struct.unpack_from("<B B B I", blob, 4)
    off = 4 + 7
    dt = np.dtype(blob[off:off + dlen].decode())
    off += dlen
    shape = struct.unpack_from(f"<{ndim}q", blob, off)
    off += 8 * ndim
    payload = zlib.decompress(blob[off:off + plen])

    n_elems = int(np.prod(shape))
    planes = np.frombuffer(payload, np.uint8).reshape(dt.itemsize, n_elems)
    zz = np.ascontiguousarray(planes.T).reshape(-1).view(
        _UINT_OF[dt.itemsize]).copy()

    delta = _unzigzag(zz).reshape(-1, shape[-1] if ndim > 1 else n_elems)
    u = _cumsum_wrap(delta)

    if dt.kind == "f":
        u = _ordered_uint_to_float_bits(u)
        return u.view(dt).reshape(shape)
    if dt.kind == "i":
        return u.view(dt).reshape(shape)
    return u.astype(dt).reshape(shape)


def _cumsum_wrap(delta: np.ndarray) -> np.ndarray:
    """Wrap-around (modular) cumulative sum along axis 1."""
    # np.cumsum upcasts; do it in the same unsigned dtype via add.accumulate
    return np.add.accumulate(delta, axis=1, dtype=delta.dtype)


def compression_ratio(arr: np.ndarray, level: int = 6) -> Tuple[int, int, float]:
    blob = compress_latent(arr, level)
    raw = arr.nbytes
    return raw, len(blob), raw / len(blob)
