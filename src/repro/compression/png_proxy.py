"""PNG-size proxy: PNG is (per-scanline predictor) + DEFLATE.  We apply the
same pipeline (Paeth-class "up"/"sub"/"average" filters chosen per row by
minimum-sum-of-absolute heuristic, then zlib) to get representative lossless
image sizes without writing actual PNG containers."""

from __future__ import annotations

import zlib

import numpy as np


def _filters(img: np.ndarray) -> np.ndarray:
    """Per-row best-of {none, sub, up, avg} filter, PNG heuristic."""
    h, w, c = img.shape
    x = img.astype(np.int16)
    prev = np.vstack([np.zeros((1, w, c), np.int16), x[:-1]])
    left = np.concatenate([np.zeros((h, 1, c), np.int16), x[:, :-1]], axis=1)
    cands = {
        0: x,
        1: (x - left) & 0xFF,
        2: (x - prev) & 0xFF,
        3: (x - ((left + prev) // 2)) & 0xFF,
    }
    scores = {fid: np.abs(v.astype(np.int8)).sum(axis=(1, 2))
              for fid, v in cands.items()}
    best = np.argmin(np.stack([scores[i] for i in range(4)]), axis=0)
    out = np.empty((h, w * c + 1), np.uint8)
    for fid in range(4):
        rows = best == fid
        if rows.any():
            out[rows, 0] = fid
            out[rows, 1:] = cands[fid][rows].reshape(rows.sum(), -1).astype(np.uint8)
    return out


def png_like_bytes(img_u8: np.ndarray, level: int = 6) -> bytes:
    """img: [H, W, C] uint8 -> filtered + deflated byte stream."""
    if img_u8.dtype != np.uint8:
        raise TypeError("expected uint8 image")
    if img_u8.ndim == 2:
        img_u8 = img_u8[..., None]
    return zlib.compress(_filters(img_u8).tobytes(), level)


def png_like_size(img_u8: np.ndarray, level: int = 6) -> int:
    return len(png_like_bytes(img_u8, level)) + 57   # + PNG container overhead
