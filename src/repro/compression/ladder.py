"""Rate-distortion ladder: named quality rungs for cooling durable latents.

The durable tier used to know exactly one codec setting (lossless LBC1).
The trace analysis says coldness is continuous, so cooling objects now
descend a ladder of lossy latent rates before falling all the way to
recipe-only regeneration:

    rung 0  lossless   LBC1, bit-exact            (hot durable)
    rung 1  high       LBQ1 @ 10 bits/elem
    rung 2  mid        LBQ1 @  8 bits/elem
    rung 3  low        LBQ1 @  6 bits/elem
    rung 4  recipe     no latent bytes at all — regenerate from the
                       stored generation recipe on read

Each rung carries the PSNR/SSIM floor that ``bench_fidelity`` gates it
with, a nominal size scale (used by the byte-accounting simulator, which
stores sizes rather than payloads), and the idle-months trigger that the
default :class:`LadderPolicy` uses to pick a target rung for an object.

Re-encoding is *not* an I/O pass of its own: callers record a target
rung next to the object (a ``RUNG`` intent record in the segment log)
and the compactor transcodes the blob when it next rewrites the
segment — see ``store/durable/compact.py``.  :func:`transcode_blob` and
:func:`transcode_record` are the transformations it applies.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import numpy as np

from repro.compression.latentcodec import (blob_rung, compress_latent,
                                           compress_latent_lossy,
                                           decompress_latent)

__all__ = [
    "Rung", "RUNGS", "RECIPE_RUNG", "LOSSLESS_RUNG", "resolve_rung",
    "encode_at", "transcode_blob", "scaled_nbytes", "blob_rung",
    "LadderPolicy",
]


@dataclass(frozen=True)
class Rung:
    """One quality level of the durable ladder."""

    index: int
    name: str
    bits: Optional[int]       # quantizer bits/elem; None = lossless, 0 = recipe
    psnr_floor_db: float      # decoded-pixel PSNR floor vs lossless reference
    ssim_floor: float         # decoded-pixel SSIM floor vs lossless reference
    idle_mo: float            # default demotion trigger (months since access)
    scale: float              # nominal bytes fraction vs the lossless blob

    @property
    def lossy(self) -> bool:
        return self.bits is not None and self.bits > 0

    @property
    def is_recipe(self) -> bool:
        return self.bits == 0


# Floors are calibrated against the demo VAE (decoded pixels vs the
# lossless-rung decode; bench_fidelity gates them in CI).  Observed
# minima across the demo/tiny decoders: high ~54 dB / 0.9999,
# mid ~51 dB / 0.9998, low ~43 dB / 0.9988 — the floors sit a few dB
# under that so codec drift fails loudly without flaking.  Lossless and
# recipe rungs reproduce the reference bit-exactly: floors vacuous.
RUNGS = (
    Rung(0, "lossless", None, float("inf"), 1.0, 0.0, 1.00),
    Rung(1, "high", 10, 46.0, 0.995, 1.0, 0.62),
    Rung(2, "mid", 8, 40.0, 0.990, 3.0, 0.50),
    Rung(3, "low", 6, 30.0, 0.950, 6.0, 0.38),
    Rung(4, "recipe", 0, float("inf"), 1.0, 12.0, 0.0),
)

LOSSLESS_RUNG = 0
RECIPE_RUNG = 4

_BY_NAME = {r.name: r for r in RUNGS}


def resolve_rung(rung: Union[int, str, Rung, None]) -> Rung:
    """Accepts an index, a name, a Rung, or None (None -> recipe: the
    pre-ladder ``demote()`` call always meant 'all the way down')."""
    if rung is None:
        return RUNGS[RECIPE_RUNG]
    if isinstance(rung, Rung):
        return rung
    if isinstance(rung, str):
        try:
            return _BY_NAME[rung]
        except KeyError:
            raise ValueError(
                f"unknown rung {rung!r}; want one of {sorted(_BY_NAME)}"
            ) from None
    idx = int(rung)
    if not 0 <= idx < len(RUNGS):
        raise ValueError(f"rung index {idx} out of range [0, {len(RUNGS)})")
    return RUNGS[idx]


def encode_at(arr: np.ndarray, rung: Union[int, str, Rung],
              level: int = 6) -> bytes:
    """Encode a latent tensor at the given rung's codec setting."""
    r = resolve_rung(rung)
    if r.is_recipe:
        raise ValueError("recipe rung stores no latent bytes")
    if r.bits is None:
        return compress_latent(arr, level)
    return compress_latent_lossy(arr, r.bits, rung=r.index, level=level)


def transcode_blob(blob: bytes, rung: Union[int, str, Rung],
                   level: int = 6) -> bytes:
    """Re-encode a durable blob at a colder rung.  No-op if the blob is
    already at (or below) the target quality — the ladder only descends."""
    r = resolve_rung(rung)
    if blob_rung(blob) >= r.index:
        return blob
    return encode_at(decompress_latent(blob), r, level)


def scaled_nbytes(nbytes: float, cur: int, target: int) -> float:
    """Nominal size of a payload-less (simulator) object after demotion
    from rung ``cur`` to rung ``target``."""
    cs = resolve_rung(cur).scale
    ts = resolve_rung(target).scale
    if cs <= 0.0:
        return 0.0
    return float(nbytes) * ts / cs


@dataclass(frozen=True)
class LadderPolicy:
    """Maps idleness to a target rung: the coldest rung whose trigger the
    object's idle time has crossed.  ``None`` means 'stay put'."""

    enabled: bool = True

    def rung_for_idle(self, idle_mo: float, cur: int = 0) -> Optional[int]:
        if not self.enabled:
            return None
        target = max((r.index for r in RUNGS if idle_mo >= r.idle_mo),
                     default=LOSSLESS_RUNG)
        return target if target > cur else None
