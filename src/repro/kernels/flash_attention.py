"""Flash attention Pallas TPU kernel (online softmax, no S x S
materialization).

Used by (a) the VAE mid-block (1 head over H*W <= 16,384 tokens) and (b)
the LM prefill path (GQA, causal, optional sliding window).  Layout:
q [n, hq, sq, d]; k, v [n, hkv, skv, d]; hq % hkv == 0 (GQA: the k/v
BlockSpec index maps a q-head program to its kv head, so no repeated k/v
materialization in HBM).

Grid (n*hq, sq_tiles, skv_tiles): the kv axis is innermost/sequential; the
output block and the fp32 (m, l, acc) running stats live in VMEM scratch
revisited across kv steps.  Causal/window masking is computed from the
absolute positions (q tiles are offset by skv - sq so q/k align at the
sequence end); fully-masked kv tiles are skipped via block-level early-out.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               bq: int, bkv: int, q_off: int):
    kv_i = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bkv, d]
    s = q @ k.T                                       # [bq, bkv]

    if causal or window is not None:
        q_pos = (pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bkv), 0)) + q_off
        k_pos = kv_i * bkv + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                            # [bq, bkv]
    corr = jnp.exp(m_prev - m_new)                    # [bq, 1]
    l_new = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v_ref[0].astype(jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kv_i == nkv - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)          # fully-masked rows -> 0
        o_ref[0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "window",
                                             "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = False, scale: Optional[float] = None,
                    window: Optional[int] = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False) -> jax.Array:
    n, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, "GQA requires hq % hkv == 0"
    rep = hq // hkv
    scale = float(d ** -0.5) if scale is None else float(scale)

    bq = min(block_q, sq)
    while sq % bq:
        bq //= 2
    bkv = min(block_kv, skv)
    while skv % bkv:
        bkv //= 2
    grid = (n * hq, sq // bq, skv // bkv)
    q_off = skv - sq                                   # align at sequence end

    qf = q.reshape(n * hq, sq, d)
    kf = k.reshape(n * hkv, skv, d)
    vf = v.reshape(n * hkv, skv, d)

    def kv_index(h, i, j):
        return (h // rep, j, 0)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bkv=bkv, q_off=q_off),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), kv_index),
            pl.BlockSpec((1, bkv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, d), jnp.float32),    # fp32 accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(n, hq, sq, d)
