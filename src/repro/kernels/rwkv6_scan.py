"""RWKV-6 (Finch) linear-attention recurrence as a Pallas TPU kernel.

    out_t = r_t · (S + u ⊙ (k_t ⊗ v_t));   S ← diag(exp(-exp(w_t))) S + k_t ⊗ v_t

XLA's lax.scan keeps S live across steps but writes each step's output
through HBM and cannot overlap the tiny per-step ops; the kernel instead
pins the [d, d] fp32 state in VMEM scratch across a whole sequence-chunk
grid axis and streams (r, k, v, w) chunk-by-chunk, emitting output tiles.
Grid (n*h, T/chunk) with the chunk axis sequential — the classic
"state-resident" linear-attention layout on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                 s_scr, *, chunk: int):
    t_i = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t_i == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    u = u_ref[0].astype(jnp.float32)                # [1, d] (key dim)

    def step(i, _):
        r_t = r_ref[0, i].astype(jnp.float32)[None, :]       # [1, d]
        k_t = k_ref[0, i].astype(jnp.float32)[None, :]
        v_t = v_ref[0, i].astype(jnp.float32)[None, :]
        dec = jnp.exp(-jnp.exp(w_ref[0, i].astype(jnp.float32)))[:, None]
        kv = k_t.T @ v_t                                     # [d, d]
        s = s_scr[...]
        out = r_t @ (s + (u.T * kv))                         # [1, d]
        o_ref[0, i] = out[0].astype(o_ref.dtype)
        s_scr[...] = dec * s + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(t_i == nt - 1)
    def _emit_state():
        sT_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, state: Optional[jax.Array] = None,
               chunk: int = 64, interpret: bool = False
               ) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: [n, h, t, d]; u: [h, d] -> (out [n,h,t,d], state [n,h,d,d])."""
    n, h, t, d = r.shape
    if state is None:
        state = jnp.zeros((n, h, d, d), jnp.float32)
    chunk = min(chunk, t)
    while t % chunk:
        chunk //= 2
    nt = t // chunk

    def flat(x):
        return x.reshape(n * h, t, d)

    u_full = jnp.broadcast_to(u[None], (n, h, d)).reshape(n * h, 1, d)
    s0 = state.reshape(n * h, d, d).astype(jnp.float32)

    seq_spec = pl.BlockSpec((1, chunk, d), lambda b, ti: (b, ti, 0))
    out, s_final = pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=chunk),
        grid=(n * h, nt),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, 1, d), lambda b, ti: (b, 0, 0)),
            pl.BlockSpec((1, d, d), lambda b, ti: (b, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, d, d), lambda b, ti: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * h, t, d), r.dtype),
            jax.ShapeDtypeStruct((n * h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(w), u_full, s0)
    return out.reshape(n, h, t, d), s_final.reshape(n, h, d, d)
