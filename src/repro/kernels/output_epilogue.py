"""Fused decode output epilogue: GN + SiLU + conv_out + clamp + uint8.

The last stage of the VAE decode — ``conv_out(silu(gn(x)))`` followed by
the serving-side clamp to [-1, 1] and quantization to displayable uint8 —
previously ran as three ops with the float32 image crossing HBM (and the
device boundary) at 4x the displayed bytes.  This kernel extends the fused
res-block structure of :mod:`repro.kernels.gn_silu_conv` with the
quantize epilogue, so the jitted decode's final write is the uint8 HWC
image itself: 1/4 the output traffic, 1/4 the device->host transfer, and
pixel-cache entries charged at their real (uint8) byte size.

Quantization is the paper's display mapping ``round((clip(y, -1, 1) + 1)
* 127.5)`` computed in fp32 — identical on the oracle and the kernel, so
the two can only differ where the conv accumulation itself differs
(tests bound that at +-1 LSB).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv3x3 import band_rows, materialize_bands
from repro.kernels.gn_silu import _stats_kernel


def quantize_u8(y: jax.Array) -> jax.Array:
    """[-1, 1] float image -> uint8, the serving display mapping."""
    yf = jnp.clip(y.astype(jnp.float32), -1.0, 1.0)
    return jnp.round((yf + 1.0) * 127.5).astype(jnp.uint8)


def _epilogue_kernel(x_ref, sum_ref, sq_ref, scale_ref, bias_ref, w_ref,
                     *refs, rows: int, width: int, groups: int,
                     eps: float, count: float, nb: int):
    # refs is (b_ref, o_ref), or (s_ref, b_ref, o_ref) with a per-output-
    # channel dequant scale (int8 weight storage)
    s_ref, b_ref, o_ref = refs if len(refs) == 3 else (None, *refs)
    band = pl.program_id(0) % nb
    x = x_ref[0].astype(jnp.float32)                 # [rows+2, W+2, Cin]
    cin = x.shape[-1]
    cpg = cin // groups

    mean = sum_ref[...] / count                      # [1, G]
    var = sq_ref[...] / count - mean * mean
    inv = jax.lax.rsqrt(var + eps)
    mean_c = jnp.repeat(mean[0], cpg)                # [Cin]
    inv_c = jnp.repeat(inv[0], cpg)
    y = (x - mean_c) * inv_c * scale_ref[...].astype(jnp.float32) \
        + bias_ref[...].astype(jnp.float32)
    y = y * jax.nn.sigmoid(y)

    # re-zero the conv's SAME padding ring (silu(gn(0)) != 0)
    rr = jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
    cc = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
    valid = (cc >= 1) & (cc <= width)
    valid &= ~((rr == 0) & (band == 0))
    valid &= ~((rr == rows + 1) & (band == nb - 1))
    y = jnp.where(valid, y, 0.0)

    acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)  # [rows, W, tc]
    for dy in range(3):
        for dx in range(3):
            patch = y[dy:dy + rows, dx:dx + width, :]
            tap = w_ref[dy, dx].astype(jnp.float32)      # [Cin, tc]
            acc += jax.lax.dot_general(
                patch.reshape(rows * width, -1), tap,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).reshape(rows, width, -1)
    if s_ref is not None:
        acc = acc * s_ref[...].astype(jnp.float32)
    o_ref[0] = quantize_u8(acc + b_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("groups", "eps", "rows",
                                             "block_cout", "stats_tile",
                                             "interpret"))
def output_epilogue(x: jax.Array, scale: jax.Array, bias: jax.Array,
                    w: jax.Array, b: Optional[jax.Array] = None,
                    groups: int = 32, eps: float = 1e-6, rows: int = 32,
                    block_cout: int = 128, stats_tile: int = 512,
                    interpret: bool = False,
                    w_scale: Optional[jax.Array] = None) -> jax.Array:
    """``quantize_u8(conv3x3(silu(group_norm(x))))`` fused.  x [N, H, W,
    Cin] NHWC, scale/bias [Cin], w [3, 3, Cin, Cout], b [Cout] ->
    uint8 [N, H, W, Cout]."""
    n, h, width, cin = x.shape
    cout = w.shape[-1]
    if b is None:
        b = jnp.zeros((cout,), x.dtype)

    # -- pass 1: GN statistics (shared kernel with gn_silu) -----------------
    hw = h * width
    xf = x.reshape(n, hw, cin)
    tile = min(stats_tile, hw)
    while hw % tile:
        tile //= 2
    nt = hw // tile
    stats_shape = jax.ShapeDtypeStruct((n, groups), jnp.float32)
    sums, sqs = pl.pallas_call(
        functools.partial(_stats_kernel, groups=groups),
        grid=(n, nt),
        in_specs=[pl.BlockSpec((1, tile, cin), lambda i, t: (i, t, 0))],
        out_specs=[pl.BlockSpec((1, groups), lambda i, t: (i, 0)),
                   pl.BlockSpec((1, groups), lambda i, t: (i, 0))],
        out_shape=[stats_shape, stats_shape],
        interpret=interpret,
    )(xf)

    # -- pass 2: normalize + SiLU + conv + quantize per row band ------------
    rows = band_rows(h, width, cin, x.dtype.itemsize, rows)
    tc = min(block_cout, cout)
    while cout % tc:
        tc //= 2
    nb = h // rows

    in_specs = [
        pl.BlockSpec((1, rows + 2, width + 2, cin),
                     lambda i, c: (i, 0, 0, 0)),
        pl.BlockSpec((1, groups), lambda i, c: (i // nb, 0)),
        pl.BlockSpec((1, groups), lambda i, c: (i // nb, 0)),
        pl.BlockSpec((cin,), lambda i, c: (0,)),
        pl.BlockSpec((cin,), lambda i, c: (0,)),
        pl.BlockSpec((3, 3, cin, tc), lambda i, c: (0, 0, 0, c)),
    ]
    operands = [materialize_bands(x, rows), sums, sqs, scale, bias, w]
    if w_scale is not None:
        in_specs.append(pl.BlockSpec((tc,), lambda i, c: (c,)))
        operands.append(w_scale)
    in_specs.append(pl.BlockSpec((tc,), lambda i, c: (c,)))
    operands.append(b)

    out = pl.pallas_call(
        functools.partial(_epilogue_kernel, rows=rows, width=width,
                          groups=groups, eps=eps,
                          count=float(hw * (cin // groups)), nb=nb),
        grid=(n * nb, cout // tc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows, width, tc),
                               lambda i, c: (i, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n * nb, rows, width, cout),
                                       jnp.uint8),
        interpret=interpret,
    )(*operands)
    return out.reshape(n, h, width, cout)
