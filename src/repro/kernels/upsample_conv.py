"""Fused nearest-2x upsample + 3x3 SAME conv — the decoder's upsampler.

Every decoder level but the last ends in ``conv3x3(nearest_upsample_2x(x))``.
Unfused, XLA materializes the upsampled ``[N, 2H, 2W, C]`` intermediate in
HBM (a 4x-sized tensor written once and read once before every upsampler
conv) — the single largest avoidable traffic term on the decode path after
the res-block fusion.  This kernel computes the conv *directly from the
pre-upsample tensor*, so the 4x intermediate never exists in HBM.

The trick is a phase decomposition of the composite op.  An output pixel
``(2i+pi, 2j+pj)`` (phases ``pi, pj in {0, 1}``) reads a 3x3 window of the
upsampled image, but nearest upsampling makes those nine taps hit only a
2x2 neighborhood of ``x`` — with known multiplicities.  Collapsing the
duplicated taps *into the weights* (done once in the wrapper, not per
pixel) turns each phase into an independent 2x2 conv on ``x``:

  phase rows  pi=0: x[i-1]*w[0]     + x[i]*(w[1]+w[2])
              pi=1: x[i]*(w[0]+w[1]) + x[i+1]*w[2]        (cols identical)

so the fused op is 4 phases x 4 taps = 16 MXU matmuls over ``rows*W``
pixels vs 9 matmuls over ``4*rows*W`` for conv-on-upsampled: **2.25x fewer
MACs** on top of the traffic win.  The four ``[rows, W, tc]`` phase
accumulators interleave to the ``[2*rows, 2*W, tc]`` output block in VMEM.

Grid/banding follows :mod:`repro.kernels.conv3x3`: the wrapper stages
halo-padded input row bands once in HBM; zero halos at image edges are
exactly the SAME padding of the upsampled image, so no ring masking is
needed (the input is pre-activation — zeros stay zeros).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv3x3 import band_rows, materialize_bands

#: tap groups per phase: phase p sums these dy (dx) taps into its 2 row
#: (col) offsets — offset index a lands on band row ``p + a``
_PHASE_TAPS = {0: ((0,), (1, 2)), 1: ((0, 1), (2,))}


def phase_weights(w: jax.Array) -> jax.Array:
    """Collapse a ``[3, 3, Cin, Cout]`` filter into the ``[2, 2, 2, 2,
    Cin, Cout]`` per-phase 2x2 filters (index order ``[pi, pj, a, b]``)."""
    rows = []
    for pi in (0, 1):
        cols = []
        for pj in (0, 1):
            taps_a = []
            for dys in _PHASE_TAPS[pi]:
                taps_b = []
                for dxs in _PHASE_TAPS[pj]:
                    tap = sum(w[dy, dx] for dy in dys for dx in dxs)
                    taps_b.append(tap)
                taps_a.append(jnp.stack(taps_b))
            cols.append(jnp.stack(taps_a))
        rows.append(jnp.stack(cols))
    return jnp.stack(rows)


def _upsample_conv_kernel(x_ref, w_ref, *refs, rows: int, width: int):
    # refs is (b_ref, o_ref), or (s_ref, b_ref, o_ref) with a per-output-
    # channel dequant scale (int8 weight storage)
    s_ref, b_ref, o_ref = refs if len(refs) == 3 else (None, *refs)
    x = x_ref[0]                                     # [rows+2, W+2, Cin]
    tc = o_ref.shape[-1]
    bias = b_ref[...].astype(jnp.float32)
    w_scale = None if s_ref is None else s_ref[...].astype(jnp.float32)
    row_phases = []
    for pi in range(2):
        col_phases = []
        for pj in range(2):
            acc = jnp.zeros((rows, width, tc), jnp.float32)
            for a in range(2):
                for b in range(2):
                    patch = x[pi + a:pi + a + rows,
                              pj + b:pj + b + width, :].astype(jnp.float32)
                    tap = w_ref[pi, pj, a, b].astype(jnp.float32)  # [Cin, tc]
                    acc += jax.lax.dot_general(
                        patch.reshape(rows * width, -1), tap,
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32,
                    ).reshape(rows, width, -1)
            if w_scale is not None:
                acc = acc * w_scale
            col_phases.append(acc + bias)
        # column interleave: out[.., 2j+pj] = col_phases[pj][.., j]
        row_phases.append(jnp.stack(col_phases, axis=2)
                          .reshape(rows, 2 * width, -1))
    # row interleave: out[2i+pi] = row_phases[pi][i]
    out = jnp.stack(row_phases, axis=1).reshape(2 * rows, 2 * width, -1)
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows", "block_cout",
                                             "interpret"))
def upsample_conv3x3(x: jax.Array, w: jax.Array,
                     b: Optional[jax.Array] = None, rows: int = 16,
                     block_cout: int = 128,
                     interpret: bool = False,
                     w_scale: Optional[jax.Array] = None) -> jax.Array:
    """``conv3x3(nearest_upsample_2x(x))`` fused.  x [N, H, W, Cin] NHWC,
    w [3, 3, Cin, Cout], b [Cout] -> [N, 2H, 2W, Cout] (SAME).

    int8 ``w`` (with ``w_scale`` [Cout]) is phase-collapsed in int16 — a
    collapsed tap sums at most 4 int8 values, which int16 holds exactly,
    and the shared per-channel scale distributes over the sum — then
    dequantized on the phase accumulators in VMEM."""
    n, h, width, cin = x.shape
    cout = w.shape[-1]
    if b is None:
        b = jnp.zeros((cout,), x.dtype)

    # the output block is 4x the input band's area: budget both by sizing
    # the band as if the input carried the output's channel load too
    tc = min(block_cout, cout)
    while cout % tc:
        tc //= 2
    rows = band_rows(h, width, cin + 4 * tc, x.dtype.itemsize, rows)
    nb = h // rows
    if w.dtype == jnp.int8:
        wc = phase_weights(w.astype(jnp.int16))      # exact: |sum| <= 4*127
    else:
        wc = phase_weights(w)                        # [2, 2, 2, 2, Cin, Cout]

    in_specs = [
        pl.BlockSpec((1, rows + 2, width + 2, cin),
                     lambda i, c: (i, 0, 0, 0)),
        pl.BlockSpec((2, 2, 2, 2, cin, tc),
                     lambda i, c: (0, 0, 0, 0, 0, c)),
    ]
    operands = [materialize_bands(x, rows), wc]
    if w_scale is not None:
        in_specs.append(pl.BlockSpec((tc,), lambda i, c: (c,)))
        operands.append(w_scale)
    in_specs.append(pl.BlockSpec((tc,), lambda i, c: (c,)))
    operands.append(b)

    out = pl.pallas_call(
        functools.partial(_upsample_conv_kernel, rows=rows, width=width),
        grid=(n * nb, cout // tc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 2 * rows, 2 * width, tc),
                               lambda i, c: (i, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n * nb, 2 * rows, 2 * width, cout),
                                       x.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(n, 2 * h, 2 * width, cout)
