"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel in this package must match its oracle to float tolerance across
the shape/dtype sweeps in ``tests/test_kernels_*.py``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def group_norm_silu_ref(x: jax.Array, scale: jax.Array, bias: jax.Array,
                        groups: int = 32, eps: float = 1e-6) -> jax.Array:
    """GroupNorm (fp32 stats) + SiLU, NHWC."""
    n, h, w, c = x.shape
    xf = x.astype(jnp.float32).reshape(n, h * w, groups, c // groups)
    mean = xf.mean(axis=(1, 3), keepdims=True)
    var = xf.var(axis=(1, 3), keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(n, h, w, c) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return (xf * jax.nn.sigmoid(xf)).astype(x.dtype)


def gn_silu_conv3x3_ref(x: jax.Array, scale: jax.Array, bias: jax.Array,
                        w: jax.Array, b: Optional[jax.Array] = None,
                        groups: int = 32, eps: float = 1e-6) -> jax.Array:
    """``conv3x3(silu(group_norm(x)))`` — oracle for the fused res-block
    kernel; composition of the two oracles keeps it bit-identical to the
    unfused decode path."""
    return conv3x3_ref(group_norm_silu_ref(x, scale, bias, groups, eps), w, b)


def upsample_conv3x3_ref(x: jax.Array, w: jax.Array,
                         b: Optional[jax.Array] = None) -> jax.Array:
    """``conv3x3(nearest_upsample_2x(x))`` — oracle for the fused
    upsampler kernel (and the XLA decode path: this IS the unfused
    upsample, so rewiring the decoder onto the dispatch is bit-neutral
    on ``impl='xla'``)."""
    x2 = jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)
    return conv3x3_ref(x2, w, b)


def quantize_u8_ref(y: jax.Array) -> jax.Array:
    """[-1, 1] float image -> uint8 display bytes (fp32 math)."""
    yf = jnp.clip(y.astype(jnp.float32), -1.0, 1.0)
    return jnp.round((yf + 1.0) * 127.5).astype(jnp.uint8)


def output_epilogue_ref(x: jax.Array, scale: jax.Array, bias: jax.Array,
                        w: jax.Array, b: Optional[jax.Array] = None,
                        groups: int = 32, eps: float = 1e-6) -> jax.Array:
    """``quantize_u8(conv3x3(silu(group_norm(x))))`` — oracle for the
    fused decode output epilogue."""
    return quantize_u8_ref(gn_silu_conv3x3_ref(x, scale, bias, w, b,
                                               groups, eps))


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = False,
                        scale: Optional[float] = None,
                        window: Optional[int] = None) -> jax.Array:
    """Softmax attention.  q: [n, hq, sq, d]; k, v: [n, hkv, skv, d].

    hq must be a multiple of hkv (GQA broadcast).  ``window`` enables
    sliding-window masking (attend to the last ``window`` positions),
    assuming q/k positions align at the sequence end (sq == skv for the
    windowed case)."""
    n, hq, sq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = (d ** -0.5) if scale is None else scale
    logits = jnp.einsum("nhqd,nhkd->nhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    skv = k.shape[2]
    if causal or window is not None:
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("nhqk,nhkd->nhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array,
                         scale: Optional[float] = None) -> jax.Array:
    """Single-token decode attention against a KV cache.

    q: [n, hq, d]; k_cache/v_cache: [n, hkv, S, d]; lengths: [n] valid
    prefix lengths.  Returns [n, hq, d]."""
    n, hq, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    rep = hq // hkv
    kc = jnp.repeat(k_cache, rep, axis=1) if rep > 1 else k_cache
    vc = jnp.repeat(v_cache, rep, axis=1) if rep > 1 else v_cache
    scale = (d ** -0.5) if scale is None else scale
    logits = jnp.einsum("nhd,nhsd->nhs", q.astype(jnp.float32),
                        kc.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("nhs,nhsd->nhd", p, vc.astype(jnp.float32)).astype(q.dtype)


def conv3x3_ref(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None
                ) -> jax.Array:
    """3x3 SAME conv, NHWC x HWIO -> NHWC."""
    y = jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def rwkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array, state: Optional[jax.Array] = None):
    """RWKV-6 linear-attention recurrence (per head), fp32 state.

    r, k, v, w: [n, h, t, d]; u: [h, d].  State S: [n, h, d, d] with
        out_t = r_t · (S + u ⊙ (k_t ⊗ v_t))
        S     = diag(exp(-exp(w_t))) S + k_t ⊗ v_t
    Returns (out [n, h, t, d], final_state).
    """
    n, h, t, d = r.shape
    if state is None:
        state = jnp.zeros((n, h, d, d), jnp.float32)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    decay = jnp.exp(-jnp.exp(w.astype(jnp.float32)))          # [n,h,t,d]
    uf = u.astype(jnp.float32)

    def step(S, inputs):
        r_t, k_t, v_t, dec_t = inputs                          # [n,h,d]
        kv = k_t[..., :, None] * v_t[..., None, :]             # [n,h,d,d]
        out = jnp.einsum("nhd,nhde->nhe", r_t, S + uf[None, :, :, None] * kv)
        S = dec_t[..., :, None] * S + kv
        return S, out

    xs = (jnp.moveaxis(rf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(decay, 2, 0))
    final, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype), final
