"""Jitted entry points for the kernel layer with implementation dispatch.

``impl`` is one of
  'xla'               pure-jnp reference (the oracle; default on CPU)
  'pallas'            Pallas TPU kernel (Mosaic; requires TPU)
  'pallas_interpret'  Pallas kernel body interpreted on CPU (correctness)

The default is process-wide (``set_default_impl``) so models never thread
the flag explicitly; the dry-run/compile paths stay on 'xla' while kernel
tests pin 'pallas_interpret'.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_DEFAULT_IMPL = "xla"
_VALID = ("xla", "pallas", "pallas_interpret")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    if impl not in _VALID:
        raise ValueError(f"impl must be one of {_VALID}")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: Optional[str]) -> str:
    impl = impl or _DEFAULT_IMPL
    if impl not in _VALID:
        raise ValueError(f"impl must be one of {_VALID}")
    return impl


# ---------------------------------------------------------------------------

def group_norm_silu(x, scale, bias, groups: int = 32, eps: float = 1e-6,
                    impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.group_norm_silu_ref(x, scale, bias, groups, eps)
    from repro.kernels import gn_silu
    return gn_silu.group_norm_silu(x, scale, bias, groups=groups, eps=eps,
                                   interpret=impl == "pallas_interpret")


def gn_silu_conv3x3(x, scale, bias, w, b=None, groups: int = 32,
                    eps: float = 1e-6, impl: Optional[str] = None):
    """Fused GroupNorm + SiLU + 3x3 SAME conv (the res-block hot path)."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.gn_silu_conv3x3_ref(x, scale, bias, w, b, groups, eps)
    from repro.kernels import gn_silu_conv as gsc
    return gsc.gn_silu_conv3x3(x, scale, bias, w, b, groups=groups, eps=eps,
                               interpret=impl == "pallas_interpret")


def upsample_conv3x3(x, w, b=None, impl: Optional[str] = None):
    """Fused nearest-2x upsample + 3x3 SAME conv (the decoder upsampler);
    the Pallas kernel never materializes the 4x upsampled intermediate."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.upsample_conv3x3_ref(x, w, b)
    from repro.kernels import upsample_conv as uc
    return uc.upsample_conv3x3(x, w, b, interpret=impl == "pallas_interpret")


def output_epilogue(x, scale, bias, w, b=None, groups: int = 32,
                    eps: float = 1e-6, impl: Optional[str] = None):
    """Fused GN + SiLU + conv_out + clamp + uint8 quantize — the decode's
    final stage, returning displayable uint8 HWC pixels."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.output_epilogue_ref(x, scale, bias, w, b, groups, eps)
    from repro.kernels import output_epilogue as oe
    return oe.output_epilogue(x, scale, bias, w, b, groups=groups, eps=eps,
                              interpret=impl == "pallas_interpret")


def flash_attention(q, k, v, causal: bool = False, scale=None,
                    window: Optional[int] = None, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale,
                                       window=window)
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, causal=causal, scale=scale,
                              window=window,
                              interpret=impl == "pallas_interpret")


def decode_attention(q, k_cache, v_cache, lengths, scale=None,
                     impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths, scale)
    from repro.kernels import decode_attention as da
    return da.decode_attention(q, k_cache, v_cache, lengths, scale=scale,
                               interpret=impl == "pallas_interpret")


def conv3x3(x, w, b=None, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.conv3x3_ref(x, w, b)
    from repro.kernels import conv3x3 as c3
    return c3.conv3x3(x, w, b, interpret=impl == "pallas_interpret")


def rwkv6_scan(r, k, v, w, u, state=None, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.rwkv6_scan_ref(r, k, v, w, u, state)
    from repro.kernels import rwkv6_scan as rs
    return rs.rwkv6_scan(r, k, v, w, u, state,
                         interpret=impl == "pallas_interpret")
