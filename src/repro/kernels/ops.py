"""Jitted entry points for the kernel layer with implementation dispatch.

``impl`` is one of
  'xla'               pure-jnp reference (the oracle; default on CPU)
  'pallas'            Pallas TPU kernel (Mosaic; requires TPU)
  'pallas_interpret'  Pallas kernel body interpreted on CPU (correctness)

The default is process-wide (``set_default_impl``) so models never thread
the flag explicitly; the dry-run/compile paths stay on 'xla' while kernel
tests pin 'pallas_interpret'.

Two cross-cutting paths live at this layer (not inside individual
kernels), so every consumer gets them uniformly:

* **weight dtype** — the decode-path kernels (``conv3x3``,
  ``gn_silu_conv3x3``, ``upsample_conv3x3``, ``output_epilogue``) accept
  their conv weight as a plain array (float32 or bfloat16 storage, cast
  to fp32 per tap tile inside the kernel) or as a
  :class:`QuantizedWeight` (int8 storage + per-output-channel fp32
  scale, dequantized on the fly in VMEM) — the dequantized fp32 copy
  never exists in HBM.  See :mod:`repro.vae.quantize` for the parameter
  conversion and the ±1-LSB serving gate.
* **autotuned block shapes** — the Pallas paths consult the process
  tuning cache (:mod:`repro.kernels.autotune`) keyed on
  ``(kernel, call shape, weight dtype)`` and pass any tuned
  ``rows``/``block_cout`` through as static kernel parameters; with no
  cache installed (or on a cache miss) the hand-picked defaults apply
  unchanged.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import ref

_DEFAULT_IMPL = "xla"
_VALID = ("xla", "pallas", "pallas_interpret")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    if impl not in _VALID:
        raise ValueError(f"impl must be one of {_VALID}")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: Optional[str], kernel: str) -> str:
    impl = impl or _DEFAULT_IMPL
    if impl not in _VALID:
        raise ValueError(
            f"{kernel}: impl must be one of {_VALID}, got {impl!r}")
    return impl


# ---------------------------------------------------------------------------
# quantized weight container
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class QuantizedWeight:
    """int8 weight storage + per-output-channel fp32 dequant scale.

    ``q`` keeps the tensor's original shape in int8; ``scale`` is
    ``[cout]`` (the last axis).  The logical value is ``q * scale`` —
    kernels consume ``q`` directly and fold the scale into the fp32
    accumulator (one multiply per output tile), so the dequantized fp32
    weight never materializes in HBM.  Registered as a pytree so
    parameter trees holding it pass through ``jax.jit`` transparently.
    """

    def __init__(self, q: jax.Array, scale: jax.Array):
        self.q = q
        self.scale = scale

    # array-like surface so parameter trees can be inspected uniformly
    @property
    def shape(self) -> Tuple[int, ...]:
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def dtype(self):
        return self.q.dtype

    @property
    def nbytes(self) -> int:
        return int(self.q.nbytes) + int(self.scale.nbytes)

    @property
    def size(self) -> int:
        return int(self.q.size)

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        """The logical fp tensor (oracle paths only — kernels never call
        this; they dequantize per tile in VMEM)."""
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def __repr__(self) -> str:
        return (f"QuantizedWeight(shape={tuple(self.q.shape)}, "
                f"scale[{self.scale.shape[0]}])")


def weight_dtype_of(w) -> str:
    """The storage-dtype tag of a kernel weight ('float32' | 'bfloat16'
    | 'int8') — the autotuning-cache key component."""
    if isinstance(w, QuantizedWeight):
        return "int8"
    return str(jnp.asarray(w).dtype)


def _weight_parts(w):
    """(kernel weight array, per-cout scale or None) for dispatch."""
    if isinstance(w, QuantizedWeight):
        return w.q, w.scale
    return w, None


def _dequant(w, dtype=jnp.float32):
    if isinstance(w, QuantizedWeight):
        return w.dequant(dtype)
    return w


# ---------------------------------------------------------------------------

def group_norm_silu(x, scale, bias, groups: int = 32, eps: float = 1e-6,
                    impl: Optional[str] = None):
    impl = _resolve(impl, "group_norm_silu")
    if impl == "xla":
        return ref.group_norm_silu_ref(x, scale, bias, groups, eps)
    from repro.kernels import gn_silu
    return gn_silu.group_norm_silu(x, scale, bias, groups=groups, eps=eps,
                                   interpret=impl == "pallas_interpret")


def gn_silu_conv3x3(x, scale, bias, w, b=None, groups: int = 32,
                    eps: float = 1e-6, impl: Optional[str] = None):
    """Fused GroupNorm + SiLU + 3x3 SAME conv (the res-block hot path)."""
    impl = _resolve(impl, "gn_silu_conv3x3")
    if impl == "xla":
        return ref.gn_silu_conv3x3_ref(x, scale, bias, _dequant(w), b,
                                       groups, eps)
    from repro.kernels import gn_silu_conv as gsc
    wq, w_scale = _weight_parts(w)
    tuned = autotune.tuned_params("gn_silu_conv3x3", x.shape, wq.shape[-1],
                                  weight_dtype_of(w))
    return gsc.gn_silu_conv3x3(x, scale, bias, wq, b, groups=groups, eps=eps,
                               w_scale=w_scale,
                               interpret=impl == "pallas_interpret", **tuned)


def upsample_conv3x3(x, w, b=None, impl: Optional[str] = None):
    """Fused nearest-2x upsample + 3x3 SAME conv (the decoder upsampler);
    the Pallas kernel never materializes the 4x upsampled intermediate."""
    impl = _resolve(impl, "upsample_conv3x3")
    if impl == "xla":
        return ref.upsample_conv3x3_ref(x, _dequant(w), b)
    from repro.kernels import upsample_conv as uc
    wq, w_scale = _weight_parts(w)
    tuned = autotune.tuned_params("upsample_conv3x3", x.shape, wq.shape[-1],
                                  weight_dtype_of(w))
    return uc.upsample_conv3x3(x, wq, b, w_scale=w_scale,
                               interpret=impl == "pallas_interpret", **tuned)


def output_epilogue(x, scale, bias, w, b=None, groups: int = 32,
                    eps: float = 1e-6, impl: Optional[str] = None):
    """Fused GN + SiLU + conv_out + clamp + uint8 quantize — the decode's
    final stage, returning displayable uint8 HWC pixels."""
    impl = _resolve(impl, "output_epilogue")
    if impl == "xla":
        return ref.output_epilogue_ref(x, scale, bias, _dequant(w), b,
                                       groups, eps)
    from repro.kernels import output_epilogue as oe
    wq, w_scale = _weight_parts(w)
    tuned = autotune.tuned_params("output_epilogue", x.shape, wq.shape[-1],
                                  weight_dtype_of(w))
    return oe.output_epilogue(x, scale, bias, wq, b, groups=groups, eps=eps,
                              w_scale=w_scale,
                              interpret=impl == "pallas_interpret", **tuned)


def flash_attention(q, k, v, causal: bool = False, scale=None,
                    window: Optional[int] = None, impl: Optional[str] = None):
    impl = _resolve(impl, "flash_attention")
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale,
                                       window=window)
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, causal=causal, scale=scale,
                              window=window,
                              interpret=impl == "pallas_interpret")


def decode_attention(q, k_cache, v_cache, lengths, scale=None,
                     impl: Optional[str] = None):
    impl = _resolve(impl, "decode_attention")
    if impl == "xla":
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths, scale)
    from repro.kernels import decode_attention as da
    return da.decode_attention(q, k_cache, v_cache, lengths, scale=scale,
                               interpret=impl == "pallas_interpret")


def conv3x3(x, w, b=None, impl: Optional[str] = None):
    impl = _resolve(impl, "conv3x3")
    if impl == "xla":
        return ref.conv3x3_ref(x, _dequant(w), b)
    from repro.kernels import conv3x3 as c3
    wq, w_scale = _weight_parts(w)
    tuned = autotune.tuned_params("conv3x3", x.shape, wq.shape[-1],
                                  weight_dtype_of(w))
    return c3.conv3x3(x, wq, b, w_scale=w_scale,
                      interpret=impl == "pallas_interpret", **tuned)


def rwkv6_scan(r, k, v, w, u, state=None, impl: Optional[str] = None):
    impl = _resolve(impl, "rwkv6_scan")
    if impl == "xla":
        return ref.rwkv6_scan_ref(r, k, v, w, u, state)
    from repro.kernels import rwkv6_scan as rs
    return rs.rwkv6_scan(r, k, v, w, u, state,
                         interpret=impl == "pallas_interpret")
