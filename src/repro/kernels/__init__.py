# Pallas TPU kernels for the compute hot spots of the latent-first read
# path (VAE decode: conv / groupnorm+silu / mid-block attention) and the
# LM serving path (flash attention, KV-cache decode attention, RWKV6 scan).
# Each kernel has a pure-jnp oracle in ref.py; ops.py is the dispatch layer.
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
