"""Fused GroupNorm + SiLU Pallas TPU kernel.

The VAE decoder applies GN+SiLU before every conv — at 1024x1024 output the
activations dominate HBM traffic, so fusing the normalize+affine+activation
into one VMEM pass halves the memory term of the decode roofline vs
unfused GN / SiLU (traffic rows in ``benchmarks/bench_kernels.py``; the
decode path itself now goes one step further and fuses the trailing conv
too — see :mod:`repro.kernels.gn_silu_conv`).

Two-pass structure (stats must exist before scaling):
  pass 1  grid (N, T): per-spatial-tile partial sums -> (sum, sumsq) [N, G]
          accumulated across the T axis by revisiting the output block;
  pass 2  grid (N, T): y = silu((x - mean) * rsqrt(var + eps) * scale + bias)
          with mean/var broadcast from the [N, G] stats.

Blocks keep channels whole (C is a lane-dim multiple of 128 in the decoder)
and tile the fused spatial axis; fp32 statistics regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(x_ref, sum_ref, sq_ref, *, groups: int):
    t = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)          # [1, tile, C]
    _, tile, c = x.shape
    xg = x.reshape(tile, groups, c // groups)
    s = xg.sum(axis=(0, 2))                     # [G]
    sq = (xg * xg).sum(axis=(0, 2))

    @pl.when(t == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    sum_ref[...] += s[None]
    sq_ref[...] += sq[None]


def _apply_kernel(x_ref, sum_ref, sq_ref, scale_ref, bias_ref, o_ref, *,
                  groups: int, eps: float, count: float):
    x = x_ref[...].astype(jnp.float32)          # [1, tile, C]
    _, tile, c = x.shape
    cpg = c // groups
    mean = sum_ref[...] / count                 # [1, G]
    var = sq_ref[...] / count - mean * mean
    inv = jax.lax.rsqrt(var + eps)              # [1, G]
    mean_c = jnp.repeat(mean[0], cpg)           # [C]
    inv_c = jnp.repeat(inv[0], cpg)
    y = (x - mean_c) * inv_c * scale_ref[...].astype(jnp.float32) \
        + bias_ref[...].astype(jnp.float32)
    o_ref[...] = (y * jax.nn.sigmoid(y)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "eps", "tile",
                                             "interpret"))
def group_norm_silu(x: jax.Array, scale: jax.Array, bias: jax.Array,
                    groups: int = 32, eps: float = 1e-6, tile: int = 512,
                    interpret: bool = False) -> jax.Array:
    n, h, w, c = x.shape
    hw = h * w
    xf = x.reshape(n, hw, c)
    tile = min(tile, hw)
    while hw % tile:
        tile //= 2
    nt = hw // tile

    stats_shape = jax.ShapeDtypeStruct((n, groups), jnp.float32)
    sums, sqs = pl.pallas_call(
        functools.partial(_stats_kernel, groups=groups),
        grid=(n, nt),
        in_specs=[pl.BlockSpec((1, tile, c), lambda i, t: (i, t, 0))],
        out_specs=[pl.BlockSpec((1, groups), lambda i, t: (i, 0)),
                   pl.BlockSpec((1, groups), lambda i, t: (i, 0))],
        out_shape=[stats_shape, stats_shape],
        interpret=interpret,
    )(xf)

    y = pl.pallas_call(
        functools.partial(_apply_kernel, groups=groups, eps=eps,
                          count=float(hw * (c // groups))),
        grid=(n, nt),
        in_specs=[
            pl.BlockSpec((1, tile, c), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, groups), lambda i, t: (i, 0)),
            pl.BlockSpec((1, groups), lambda i, t: (i, 0)),
            pl.BlockSpec((c,), lambda i, t: (0,)),
            pl.BlockSpec((c,), lambda i, t: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile, c), lambda i, t: (i, t, 0)),
        out_shape=jax.ShapeDtypeStruct((n, hw, c), x.dtype),
        interpret=interpret,
    )(xf, sums, sqs, scale, bias)
    return y.reshape(n, h, w, c)
