"""Persistent Pallas kernel autotuner: per-shape block/band sweeps with a
versioned on-disk tuning cache.

The decode-path kernels take two blocking knobs — ``rows`` (the VMEM row
band) and ``block_cout`` (the output-channel tile) — whose hand-picked
defaults are right on average and wrong per shape: the best band for a
128-wide 512-channel mid-block tile is not the best band for a 512-wide
32-channel top level.  This module closes that gap:

* :func:`decode_shapes` derives, from a :class:`repro.vae.model.VAEConfig`
  + latent shape + batch bucket, the exact ``(kernel, call shape)`` set the
  ``decode_u8`` fast path will dispatch;
* :func:`tune` sweeps each shape's candidate grid with a timed best-of-N
  harness (injectable ``timer`` for deterministic tests; candidates that
  clamp to the same effective blocking are deduplicated, and the default
  config is always candidate 0 — so the winner can never be *worse* than
  the default under the measurements taken);
* :class:`TuningCache` persists winners to ``tuning_cache.json`` under the
  store's ``data_dir`` — schema-versioned, written atomically
  (tmp + rename), and loaded with a clean fall-back-to-defaults on a
  missing, corrupt, or stale-version file;
* ``ops.py`` dispatch consults the process-wide *active* cache
  (:func:`set_active_cache` — same process-global idiom as
  ``ops.set_default_impl``) on every Pallas call, so ``prewarm_decode``
  compiles the tuned shapes;
* :class:`KernelAutotuner` is the serving-side driver: the engine notes
  each (bucket, latent shape) it decodes, and ``step(budget)`` tunes a
  bounded number of missing keys per call — tune-on-first-miss threaded
  into the engine's end-of-batch maintenance, so cold clusters converge
  without a manual step.

Offline pre-tuning: ``python -m repro.kernels.autotune --cache PATH``
(``--smoke`` for the CI grid); point ``StoreConfig.data_dir`` at the same
directory and every reopen picks the winners up.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.conv3x3 import band_rows

SCHEMA_VERSION = 1
CACHE_FILENAME = "tuning_cache.json"

#: Kernels the tuner knows how to drive (the decode_u8 dispatch set).
KERNELS = ("conv3x3", "gn_silu_conv3x3", "upsample_conv3x3",
           "output_epilogue")

#: Hand-picked dispatch defaults (must mirror the kernel wrappers'
#: keyword defaults — candidate 0 of every sweep).
DEFAULTS = {
    "conv3x3": {"rows": 32, "block_cout": 128},
    "gn_silu_conv3x3": {"rows": 32, "block_cout": 128},
    "upsample_conv3x3": {"rows": 16, "block_cout": 128},
    "output_epilogue": {"rows": 32, "block_cout": 128},
}

_ROWS_GRID = (8, 16, 32, 64)
_BLOCK_COUT_GRID = (32, 64, 128, 256)


def cache_key(kernel: str, n: int, h: int, w: int, cin: int, cout: int,
              weight_dtype: str) -> str:
    """One tuning-cache key per (kernel, resolution, bucket, weight_dtype)."""
    return f"{kernel}|n{n}|{h}x{w}|{cin}->{cout}|{weight_dtype}"


# ---------------------------------------------------------------------------
# the persistent cache
# ---------------------------------------------------------------------------

class TuningCache:
    """Versioned JSON map ``cache_key -> {'rows', 'block_cout', ...}``.

    Loading never raises on bad files: a missing, unparseable, or
    wrong-``schema_version`` file yields an *empty* cache (the kernels
    then run on their hand-picked defaults), so a stale cache from an
    older code revision can degrade performance only back to the
    defaults, never correctness.  Writes go through a tmp file +
    ``os.replace`` so a crash mid-save leaves the previous cache intact.
    """

    def __init__(self, path: Optional[str] = None,
                 entries: Optional[Dict[str, Dict[str, Any]]] = None):
        self.path = path
        self.entries: Dict[str, Dict[str, Any]] = dict(entries or {})

    @classmethod
    def load(cls, path: Optional[str]) -> "TuningCache":
        cache = cls(path)
        if path is None or not os.path.exists(path):
            return cache
        try:
            with open(path, "r", encoding="utf-8") as f:
                doc = json.load(f)
            if (isinstance(doc, dict)
                    and doc.get("schema_version") == SCHEMA_VERSION
                    and isinstance(doc.get("entries"), dict)):
                cache.entries = {
                    str(k): dict(v) for k, v in doc["entries"].items()
                    if isinstance(v, dict)}
        except (OSError, ValueError):
            pass                        # corrupt file -> clean empty cache
        return cache

    def save(self) -> None:
        if self.path is None:
            return
        doc = {"schema_version": SCHEMA_VERSION,
               "jax_backend": jax.default_backend(),
               "entries": self.entries}
        tmp = self.path + ".tmp"
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        return self.entries.get(key)

    def put(self, key: str, entry: Dict[str, Any]) -> None:
        self.entries[key] = dict(entry)

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return key in self.entries


_ACTIVE: Optional[TuningCache] = None


def set_active_cache(cache: Optional[TuningCache]) -> None:
    """Install the process-wide cache ``ops.py`` dispatch consults (the
    ``set_default_impl`` idiom: models never thread it explicitly)."""
    global _ACTIVE
    _ACTIVE = cache


def get_active_cache() -> Optional[TuningCache]:
    return _ACTIVE


@contextlib.contextmanager
def active_cache(cache: Optional[TuningCache]):
    """Scoped :func:`set_active_cache` (benches/tests)."""
    prev = _ACTIVE
    set_active_cache(cache)
    try:
        yield cache
    finally:
        set_active_cache(prev)


def tuned_params(kernel: str, x_shape: Sequence[int], cout: int,
                 weight_dtype: str) -> Dict[str, int]:
    """The dispatch-side lookup: tuned ``{'rows', 'block_cout'}`` for this
    call, or ``{}`` (kernel defaults) on no active cache / cache miss /
    malformed entry.  Runs at trace time only (inside ``jax.jit`` the
    shapes are static)."""
    if _ACTIVE is None:
        return {}
    n, h, w, cin = x_shape
    entry = _ACTIVE.get(cache_key(kernel, n, h, w, cin, cout, weight_dtype))
    if not entry:
        return {}
    out = {}
    for knob in ("rows", "block_cout"):
        v = entry.get(knob)
        if isinstance(v, int) and v > 0:
            out[knob] = v
    return out if len(out) == 2 else {}


# ---------------------------------------------------------------------------
# shape derivation (what will decode_u8 actually dispatch?)
# ---------------------------------------------------------------------------

def decode_shapes(cfg, latent_hwc: Tuple[int, int, int],
                  bucket: int) -> List[Dict[str, Any]]:
    """The deduplicated ``(kernel, call shape)`` set of one ``decode_u8``
    at batch size ``bucket`` — derived from the decoder architecture, not
    traced, so it can run before any compile.  ``cfg`` is a
    :class:`repro.vae.model.VAEConfig`."""
    h, w, c_lat = (int(v) for v in latent_hwc)
    n = int(bucket)
    chs = cfg.block_out_channels
    top = chs[-1]
    shapes: List[Dict[str, Any]] = []
    seen = set()

    def add(kernel, h_, w_, cin, cout):
        spec = {"kernel": kernel, "n": n, "h": h_, "w": w_,
                "cin": cin, "cout": cout, "groups": cfg.groups}
        sig = (kernel, h_, w_, cin, cout)
        if sig not in seen:
            seen.add(sig)
            shapes.append(spec)

    add("conv3x3", h, w, c_lat, top)                 # conv_in
    add("gn_silu_conv3x3", h, w, top, top)           # mid res blocks
    cin = top
    for i, cout in enumerate(reversed(chs)):
        for _ in range(cfg.layers_per_block + 1):
            add("gn_silu_conv3x3", h, w, cin, cout)
            cin = cout
        if i < len(chs) - 1:
            add("upsample_conv3x3", h, w, cout, cout)
            h, w = 2 * h, 2 * w
    add("output_epilogue", h, w, chs[0], cfg.image_channels)
    return shapes


# ---------------------------------------------------------------------------
# candidate grids + the timed harness
# ---------------------------------------------------------------------------

def _effective(kernel: str, spec: Dict[str, Any], rows: int,
               block_cout: int, itemsize: int = 4) -> Tuple[int, int]:
    """The (band rows, cout tile) a candidate actually compiles to —
    mirrors the wrappers' clamping, so candidates that collapse to the
    same blocking are swept once."""
    h, w, cin, cout = spec["h"], spec["w"], spec["cin"], spec["cout"]
    tc = min(block_cout, cout)
    while cout % tc:
        tc //= 2
    if kernel == "upsample_conv3x3":
        r = band_rows(h, w, cin + 4 * tc, itemsize, rows)
    else:
        r = band_rows(h, w, cin, itemsize, rows)
    return r, tc


def candidates(kernel: str, spec: Dict[str, Any],
               rows_grid: Sequence[int] = _ROWS_GRID,
               block_cout_grid: Sequence[int] = _BLOCK_COUT_GRID,
               ) -> List[Dict[str, int]]:
    """Deduplicated candidate list; the kernel's default config is always
    candidate 0 (ties in the sweep resolve to the earliest candidate, so
    'no measurable win' keeps the default)."""
    default = DEFAULTS[kernel]
    out: List[Dict[str, int]] = []
    seen = set()
    for cand in ([default]
                 + [{"rows": r, "block_cout": bc}
                    for r in rows_grid for bc in block_cout_grid]):
        eff = _effective(kernel, spec, cand["rows"], cand["block_cout"])
        if eff not in seen:
            seen.add(eff)
            out.append(dict(cand))
    return out


def _make_inputs(spec: Dict[str, Any], weight_dtype: str, seed: int = 0):
    """Deterministic synthetic operands for one kernel call."""
    rng = np.random.default_rng(seed)
    h, w, cin, cout = spec["h"], spec["w"], spec["cin"], spec["cout"]
    x = jnp.asarray(rng.standard_normal((spec["n"], h, w, cin)), jnp.float32)
    wf = rng.standard_normal((3, 3, cin, cout)).astype(np.float32)
    wf /= np.sqrt(9 * cin)
    b = jnp.asarray(rng.standard_normal((cout,)) * 0.01, jnp.float32)
    w_scale = None
    if weight_dtype == "bfloat16":
        wk = jnp.asarray(wf).astype(jnp.bfloat16)
    elif weight_dtype == "int8":
        from repro.vae.quantize import quantize_int8   # lazy: no cycle
        qw = quantize_int8(jnp.asarray(wf))
        wk, w_scale = qw.q, qw.scale
    else:
        wk = jnp.asarray(wf)
    gscale = jnp.ones((cin,), jnp.float32)
    gbias = jnp.zeros((cin,), jnp.float32)
    return x, wk, b, w_scale, gscale, gbias


def _make_thunk(spec: Dict[str, Any], weight_dtype: str, impl: str,
                cand: Dict[str, int]) -> Callable[[], Any]:
    """A zero-arg callable running one kernel at one candidate config."""
    from repro.kernels import (conv3x3 as c3, gn_silu_conv as gsc,
                               output_epilogue as oe, upsample_conv as uc)
    kernel = spec["kernel"]
    interp = impl == "pallas_interpret"
    x, wk, b, w_scale, gscale, gbias = _make_inputs(spec, weight_dtype)
    kw = dict(rows=cand["rows"], block_cout=cand["block_cout"],
              interpret=interp, w_scale=w_scale)
    if kernel == "conv3x3":
        return lambda: c3.conv3x3(x, wk, b, **kw)
    if kernel == "upsample_conv3x3":
        return lambda: uc.upsample_conv3x3(x, wk, b, **kw)
    if kernel == "gn_silu_conv3x3":
        return lambda: gsc.gn_silu_conv3x3(x, gscale, gbias, wk, b,
                                           groups=spec["groups"], **kw)
    if kernel == "output_epilogue":
        return lambda: oe.output_epilogue(x, gscale, gbias, wk, b,
                                          groups=spec["groups"], **kw)
    raise ValueError(f"unknown kernel {kernel!r} (valid: {KERNELS})")


def time_call(thunk: Callable[[], Any], reps: int = 2,
              timer: Callable[[], float] = time.perf_counter) -> float:
    """Best-of-N wall time in microseconds.  One untimed warmup call pays
    the compile; then exactly 2 ``timer()`` reads per rep (a scripted fake
    timer makes winner selection fully deterministic in tests)."""
    jax.block_until_ready(thunk())
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = timer()
        jax.block_until_ready(thunk())
        best = min(best, timer() - t0)
    return best * 1e6


def tune(spec: Dict[str, Any], weight_dtype: str = "float32",
         impl: str = "pallas_interpret", reps: int = 2,
         timer: Callable[[], float] = time.perf_counter,
         rows_grid: Sequence[int] = _ROWS_GRID,
         block_cout_grid: Sequence[int] = _BLOCK_COUT_GRID,
         ) -> Dict[str, Any]:
    """Sweep one shape's candidate grid; returns the cache entry.

    The default config is always measured (candidate 0) and ties break
    toward it, so ``entry['us'] <= entry['default_us']`` by construction
    under the harness's own measurements."""
    cands = candidates(spec["kernel"], spec, rows_grid, block_cout_grid)
    best_i, best_us, default_us = 0, float("inf"), None
    for i, cand in enumerate(cands):
        us = time_call(_make_thunk(spec, weight_dtype, impl, cand),
                       reps=reps, timer=timer)
        if i == 0:
            default_us = us
        if us < best_us:
            best_i, best_us = i, us
    return {"rows": cands[best_i]["rows"],
            "block_cout": cands[best_i]["block_cout"],
            "us": best_us, "default_us": default_us,
            "candidates": len(cands), "impl": impl,
            "weight_dtype": weight_dtype}


# ---------------------------------------------------------------------------
# serving-side driver: tune-on-first-miss
# ---------------------------------------------------------------------------

class KernelAutotuner:
    """Bounded background tuner the :class:`ServingEngine` drives.

    ``note_bucket`` records a (bucket, latent shape) the engine is
    decoding and queues every derived kernel shape missing from the
    cache; ``step(budget)`` tunes at most ``budget`` queued keys (one
    engine maintenance slice = one key by default) and persists the cache
    after each batch of wins.  Tuning runs the kernels *standalone* — by
    default in ``pallas_interpret`` off-TPU — so the serving decode path
    itself never blocks on a sweep.
    """

    def __init__(self, cache: TuningCache, vae_cfg,
                 weight_dtype: str = "float32", impl: Optional[str] = None,
                 reps: int = 2,
                 timer: Callable[[], float] = time.perf_counter,
                 rows_grid: Sequence[int] = _ROWS_GRID,
                 block_cout_grid: Sequence[int] = _BLOCK_COUT_GRID):
        if impl is None:
            impl = ("pallas" if jax.default_backend() == "tpu"
                    else "pallas_interpret")
        self.cache = cache
        self.vae_cfg = vae_cfg
        self.weight_dtype = weight_dtype
        self.impl = impl
        self.reps = reps
        self.timer = timer
        self.rows_grid = tuple(rows_grid)
        self.block_cout_grid = tuple(block_cout_grid)
        self._queue: List[Tuple[str, Dict[str, Any]]] = []
        self._queued: set = set()

    @property
    def pending(self) -> int:
        return len(self._queue)

    def note_bucket(self, bucket: int,
                    latent_hwc: Tuple[int, int, int]) -> int:
        """Queue every kernel shape of this (bucket, latent) decode that
        the cache doesn't cover yet; returns how many were enqueued."""
        added = 0
        for spec in decode_shapes(self.vae_cfg, latent_hwc, bucket):
            key = cache_key(spec["kernel"], spec["n"], spec["h"], spec["w"],
                            spec["cin"], spec["cout"], self.weight_dtype)
            if key in self.cache or key in self._queued:
                continue
            self._queued.add(key)
            self._queue.append((key, spec))
            added += 1
        return added

    def step(self, budget: int = 1) -> List[str]:
        """Tune up to ``budget`` queued keys; persists the cache if any
        were tuned and returns their keys (callers re-warm the decode so
        new compilations land outside timed serving regions)."""
        tuned: List[str] = []
        while self._queue and len(tuned) < budget:
            key, spec = self._queue.pop(0)
            entry = tune(spec, weight_dtype=self.weight_dtype,
                         impl=self.impl, reps=self.reps, timer=self.timer,
                         rows_grid=self.rows_grid,
                         block_cout_grid=self.block_cout_grid)
            self.cache.put(key, entry)
            tuned.append(key)
        if tuned:
            self.cache.save()
        return tuned


# ---------------------------------------------------------------------------
# offline pre-tuning CLI
# ---------------------------------------------------------------------------

def _cli_sweep(cache: TuningCache, vae_cfg, latent_hwc, buckets,
               weight_dtypes, impl, reps, rows_grid, block_cout_grid,
               verbose: bool = True) -> int:
    tuned = 0
    for wd in weight_dtypes:
        tuner = KernelAutotuner(cache, vae_cfg, weight_dtype=wd, impl=impl,
                                reps=reps, rows_grid=rows_grid,
                                block_cout_grid=block_cout_grid)
        for b in buckets:
            tuner.note_bucket(b, latent_hwc)
        while tuner.pending:
            for key in tuner.step(4):
                e = cache.get(key)
                tuned += 1
                if verbose:
                    speed = e["default_us"] / max(e["us"], 1e-9)
                    print(f"  {key}: rows={e['rows']} "
                          f"block_cout={e['block_cout']} "
                          f"{e['us']:.0f}us ({speed:.2f}x vs default)")
    return tuned


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="Offline Pallas kernel pre-tuner (persists winners to "
                    "a versioned tuning cache that StoreConfig.data_dir "
                    "picks up)")
    p.add_argument("--cache", default=os.path.join("artifacts",
                                                   CACHE_FILENAME))
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI grid: demo decoder, buckets 1/2, "
                        "float32+bfloat16, 1 rep")
    p.add_argument("--impl", default=None,
                   choices=("pallas", "pallas_interpret"),
                   help="default: pallas on TPU, pallas_interpret elsewhere")
    p.add_argument("--buckets", type=int, nargs="+", default=None)
    p.add_argument("--latent", type=int, nargs=3, default=None,
                   metavar=("H", "W", "C"))
    p.add_argument("--weight-dtypes", nargs="+", default=None,
                   choices=("float32", "bfloat16", "int8"))
    p.add_argument("--reps", type=int, default=None)
    args = p.parse_args(argv)

    # the facade's demo decoder (LatentBox.engine default stack)
    from repro.vae.model import DEMO_VAE as vae_cfg
    impl = args.impl or ("pallas" if jax.default_backend() == "tpu"
                         else "pallas_interpret")
    if args.smoke:
        buckets = args.buckets or (1, 2)
        latent = tuple(args.latent or (8, 8, 4))
        wdtypes = args.weight_dtypes or ("float32", "bfloat16")
        reps = args.reps or 1
        rows_grid, bc_grid = (8, 16, 32), (32, 64, 128)
    else:
        buckets = args.buckets or (1, 2, 4, 8)
        latent = tuple(args.latent or (8, 8, 4))
        wdtypes = args.weight_dtypes or ("float32", "bfloat16", "int8")
        reps = args.reps or 3
        rows_grid, bc_grid = _ROWS_GRID, _BLOCK_COUT_GRID

    cache = TuningCache.load(args.cache)
    print(f"tuning {vae_cfg.name} decoder @ latent {latent}, "
          f"buckets {tuple(buckets)}, weight_dtypes {tuple(wdtypes)}, "
          f"impl={impl} ({len(cache)} cached entries loaded)")
    n = _cli_sweep(cache, vae_cfg, latent, buckets, wdtypes, impl, reps,
                   rows_grid, bc_grid)
    cache.save()
    print(f"tuned {n} new keys -> {args.cache} ({len(cache)} total)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
