"""Fused GroupNorm + SiLU + 3x3 conv — the decoder's res-block hot path.

Every res block in the VAE decoder is ``conv3x3(silu(gn(x)))``; unfused,
the normalized activation makes a full HBM round-trip between the GN+SiLU
kernel and the conv.  This kernel keeps it in VMEM: the input row band
(with 1-row halo) is normalized, activated, and immediately consumed by
the nine implicit-GEMM filter-tap matmuls, eliminating one read + one
write of the [H, W, C] activation per block — the decoder's dominant
memory term (see the roofline in :mod:`repro.vae.serve` and the traffic
rows in ``benchmarks/bench_kernels.py``).

Structure (GN stats must exist before the conv can run):
  pass 1  grid (N, T): per-spatial-tile partial sums -> (sum, sumsq) [N, G]
          (shared with :mod:`repro.kernels.gn_silu`);
  pass 2  grid (N*nb, Cout/tc): per row-band, normalize + SiLU the band in
          VMEM — including its halo rows, which are real neighbor pixels —
          then accumulate the nine shifted (rows*W, Cin) x (Cin, tc) MXU
          matmuls exactly as :mod:`repro.kernels.conv3x3` does.

The conv's SAME zero-padding ring must stay zero *after* the activation
(``silu(gn(0)) != 0``), so the kernel masks the ring: columns 0 and W+1
always, the top halo row on an image's first band, the bottom halo row on
its last.  Interior halo rows are neighbor data and are left normalized.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv3x3 import band_rows, materialize_bands
from repro.kernels.gn_silu import _stats_kernel


def _fused_kernel(x_ref, sum_ref, sq_ref, scale_ref, bias_ref, w_ref, *refs,
                  rows: int, width: int, groups: int, eps: float,
                  count: float, nb: int):
    # refs is (b_ref, o_ref), or (s_ref, b_ref, o_ref) with a per-output-
    # channel dequant scale (int8 weight storage)
    s_ref, b_ref, o_ref = refs if len(refs) == 3 else (None, *refs)
    band = pl.program_id(0) % nb
    x = x_ref[0].astype(jnp.float32)                 # [rows+2, W+2, Cin]
    cin = x.shape[-1]
    cpg = cin // groups

    mean = sum_ref[...] / count                      # [1, G]
    var = sq_ref[...] / count - mean * mean
    inv = jax.lax.rsqrt(var + eps)
    mean_c = jnp.repeat(mean[0], cpg)                # [Cin]
    inv_c = jnp.repeat(inv[0], cpg)
    y = (x - mean_c) * inv_c * scale_ref[...].astype(jnp.float32) \
        + bias_ref[...].astype(jnp.float32)
    y = y * jax.nn.sigmoid(y)

    # re-zero the conv's SAME padding ring (silu(gn(0)) != 0)
    rr = jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
    cc = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
    valid = (cc >= 1) & (cc <= width)
    valid &= ~((rr == 0) & (band == 0))
    valid &= ~((rr == rows + 1) & (band == nb - 1))
    y = jnp.where(valid, y, 0.0)

    acc = jnp.zeros_like(o_ref[0], dtype=jnp.float32)  # [rows, W, tc]
    for dy in range(3):
        for dx in range(3):
            patch = y[dy:dy + rows, dx:dx + width, :]
            tap = w_ref[dy, dx].astype(jnp.float32)    # [Cin, tc]
            acc += jax.lax.dot_general(
                patch.reshape(rows * width, -1), tap,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).reshape(rows, width, -1)
    if s_ref is not None:
        acc = acc * s_ref[...].astype(jnp.float32)
    o_ref[0] = (acc + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "eps", "rows",
                                             "block_cout", "stats_tile",
                                             "interpret"))
def gn_silu_conv3x3(x: jax.Array, scale: jax.Array, bias: jax.Array,
                    w: jax.Array, b: Optional[jax.Array] = None,
                    groups: int = 32, eps: float = 1e-6, rows: int = 32,
                    block_cout: int = 128, stats_tile: int = 512,
                    interpret: bool = False,
                    w_scale: Optional[jax.Array] = None) -> jax.Array:
    """``conv3x3(silu(group_norm(x)))`` fused.  x [N, H, W, Cin] NHWC,
    scale/bias [Cin], w [3, 3, Cin, Cout], b [Cout] -> [N, H, W, Cout]."""
    n, h, width, cin = x.shape
    cout = w.shape[-1]
    if b is None:
        b = jnp.zeros((cout,), x.dtype)

    # -- pass 1: GN statistics (shared kernel with gn_silu) -----------------
    hw = h * width
    xf = x.reshape(n, hw, cin)
    tile = min(stats_tile, hw)
    while hw % tile:
        tile //= 2
    nt = hw // tile
    stats_shape = jax.ShapeDtypeStruct((n, groups), jnp.float32)
    sums, sqs = pl.pallas_call(
        functools.partial(_stats_kernel, groups=groups),
        grid=(n, nt),
        in_specs=[pl.BlockSpec((1, tile, cin), lambda i, t: (i, t, 0))],
        out_specs=[pl.BlockSpec((1, groups), lambda i, t: (i, 0)),
                   pl.BlockSpec((1, groups), lambda i, t: (i, 0))],
        out_shape=[stats_shape, stats_shape],
        interpret=interpret,
    )(xf)

    # -- pass 2: normalize + SiLU + implicit-GEMM conv per row band ---------
    rows = band_rows(h, width, cin, x.dtype.itemsize, rows)
    tc = min(block_cout, cout)
    while cout % tc:
        tc //= 2
    nb = h // rows

    in_specs = [
        pl.BlockSpec((1, rows + 2, width + 2, cin),
                     lambda i, c: (i, 0, 0, 0)),
        pl.BlockSpec((1, groups), lambda i, c: (i // nb, 0)),
        pl.BlockSpec((1, groups), lambda i, c: (i // nb, 0)),
        pl.BlockSpec((cin,), lambda i, c: (0,)),
        pl.BlockSpec((cin,), lambda i, c: (0,)),
        pl.BlockSpec((3, 3, cin, tc), lambda i, c: (0, 0, 0, c)),
    ]
    operands = [materialize_bands(x, rows), sums, sqs, scale, bias, w]
    if w_scale is not None:
        in_specs.append(pl.BlockSpec((tc,), lambda i, c: (c,)))
        operands.append(w_scale)
    in_specs.append(pl.BlockSpec((tc,), lambda i, c: (c,)))
    operands.append(b)

    out = pl.pallas_call(
        functools.partial(_fused_kernel, rows=rows, width=width,
                          groups=groups, eps=eps,
                          count=float(hw * (cin // groups)), nb=nb),
        grid=(n * nb, cout // tc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows, width, tc),
                               lambda i, c: (i, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n * nb, rows, width, cout), x.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(n, h, width, cout)
