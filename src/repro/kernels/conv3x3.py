"""3x3 SAME conv as implicit GEMM — the decode path's dominant FLOP source.

TPU-native formulation (not an im2col port): for each output row-band the
kernel holds an input band + 1-row halo in VMEM and accumulates nine
(rows*W, Cin) x (Cin, Cout-tile) MXU matmuls — one per filter tap — shifted
in the spatial dims.  Channels stay on the lane axis; Cin/Cout tiles are
128-aligned for the MXU.

Overlapping halo reads don't fit disjoint BlockSpec tiling, so the wrapper
materializes the row bands (with halo) once in HBM — an extra 2/rows_tile
of input traffic (~6 % at the default 32-row band) — and the kernel itself
then streams disjoint blocks.  VMEM per step at W=1024, Cin=128 fp32:
(34 * 1026 * 128 * 4) ≈ 17 MB/2 with bf16 — the wrapper halves rows if the
estimate exceeds the budget.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VMEM_BUDGET = 12 * 2 ** 20      # conservative VMEM budget per input block


def band_rows(h: int, width: int, cin: int, itemsize: int,
              rows: int) -> int:
    """Largest halving of ``rows`` that divides ``h`` AND whose padded
    input band fits the VMEM budget (shared by conv3x3 and the fused
    GN+SiLU+conv kernel so the sizing policy can't drift)."""
    rows = min(rows, h)
    while rows > 1 and (h % rows
                        or (rows + 2) * (width + 2) * cin * itemsize
                        > VMEM_BUDGET):
        rows //= 2
    return rows


def materialize_bands(x: jax.Array, rows: int) -> jax.Array:
    """[N, H, W, C] -> flattened row bands with 1-pixel halo
    [N * H/rows, rows+2, W+2, C] (the overlapping halo reads don't fit
    disjoint BlockSpec tiling, so the bands are staged once in HBM)."""
    n, h, width, cin = x.shape
    nb = h // rows
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    bands = jnp.stack([xp[:, i * rows:i * rows + rows + 2]
                       for i in range(nb)], axis=1)
    return bands.reshape(n * nb, rows + 2, width + 2, cin)


def _conv_kernel(x_ref, w_ref, *refs, rows: int, width: int):
    # refs is (b_ref, o_ref) for fp weights, (s_ref, b_ref, o_ref) when a
    # per-output-channel dequant scale rides along (int8 storage)
    s_ref, b_ref, o_ref = refs if len(refs) == 3 else (None, *refs)
    x = x_ref[0]                                     # [rows+2, W+2, Cin]
    acc = jnp.zeros_like(o_ref[0], dtype=jnp.float32)  # [rows, W, tc]
    for dy in range(3):
        for dx in range(3):
            patch = x[dy:dy + rows, dx:dx + width, :].astype(jnp.float32)
            tap = w_ref[dy, dx].astype(jnp.float32)  # [Cin, tc]
            acc += jax.lax.dot_general(
                patch.reshape(rows * width, -1), tap,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).reshape(rows, width, -1)
    if s_ref is not None:
        # scale is per output channel, so one fp32 multiply of the summed
        # accumulator dequantizes all nine taps exactly
        acc = acc * s_ref[...].astype(jnp.float32)
    o_ref[0] = (acc + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows", "block_cout", "interpret"))
def conv3x3(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
            rows: int = 32, block_cout: int = 128,
            interpret: bool = False,
            w_scale: Optional[jax.Array] = None) -> jax.Array:
    """x [N, H, W, Cin], w [3, 3, Cin, Cout] -> [N, H, W, Cout] (SAME).

    ``w`` may be stored float32/bfloat16 (cast to fp32 per tap tile) or
    int8 with ``w_scale`` [Cout] — the per-channel dequant then happens on
    the accumulator in VMEM, never as an fp32 weight copy in HBM."""
    n, h, width, cin = x.shape
    cout = w.shape[-1]
    if b is None:
        b = jnp.zeros((cout,), x.dtype)

    rows = band_rows(h, width, cin, x.dtype.itemsize, rows)
    tc = min(block_cout, cout)
    while cout % tc:
        tc //= 2
    nb = h // rows

    in_specs = [
        pl.BlockSpec((1, rows + 2, width + 2, cin),
                     lambda i, c: (i, 0, 0, 0)),
        pl.BlockSpec((3, 3, cin, tc), lambda i, c: (0, 0, 0, c)),
    ]
    operands = [materialize_bands(x, rows), w]
    if w_scale is not None:
        in_specs.append(pl.BlockSpec((tc,), lambda i, c: (c,)))
        operands.append(w_scale)
    in_specs.append(pl.BlockSpec((tc,), lambda i, c: (c,)))
    operands.append(b)

    out = pl.pallas_call(
        functools.partial(_conv_kernel, rows=rows, width=width),
        grid=(n * nb, cout // tc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows, width, tc),
                               lambda i, c: (i, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((n * nb, rows, width, cout), x.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(n, h, width, cout)
