"""Single-token KV-cache decode attention Pallas TPU kernel (GQA).

The LM serving hot loop: one query token per sequence against a long KV
cache.  Decode attention is memory-bound (the whole cache streams once per
step), so the kernel's job is to keep the streaming tight: each (batch,
kv-head) program reads its cache exactly once, processes the ``rep``
grouped q-heads together (one [rep, d] x [d, bkv] MXU op per tile instead
of rep vector ops), and keeps the online-softmax state in VMEM.

q [n, hq, d]; k_cache/v_cache [n, hkv, S, d]; lengths [n] valid prefixes.
Grid (n, hkv, S_tiles), kv axis sequential.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, bkv: int):
    s_i = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(s_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [rep, d]
    k = k_ref[0, 0].astype(jnp.float32)               # [bkv, d]
    s = q @ k.T                                       # [rep, bkv]

    length = len_ref[0]
    pos = s_i * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + p @ v_ref[0, 0].astype(jnp.float32)
    m_scr[...] = m_new

    @pl.when(s_i == nkv - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_kv", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, scale: Optional[float] = None,
                     block_kv: int = 256, interpret: bool = False) -> jax.Array:
    n, hq, d = q.shape
    _, hkv, s_max, _ = k_cache.shape
    assert hq % hkv == 0
    rep = hq // hkv
    scale = float(d ** -0.5) if scale is None else float(scale)

    bkv = min(block_kv, s_max)
    while s_max % bkv:
        bkv //= 2

    qg = q.reshape(n, hkv, rep, d)
    lengths = lengths.astype(jnp.int32).reshape(n, 1)

    out = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale, bkv=bkv),
        grid=(n, hkv, s_max // bkv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, rep, d), lambda b, h, s: (b * pl.num_programs(1)
                                                       + h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, rep, d), lambda b, h, s: (
            b * pl.num_programs(1) + h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n * hkv, rep, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg.reshape(n * hkv, rep, d), k_cache, v_cache)
    return out.reshape(n, hq, d)
