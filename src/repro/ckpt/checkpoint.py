"""Sharded, atomic, async checkpointing with elastic resharding.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json     tree structure, shapes, dtypes, pspecs, step
        arr_00000.npy ... one file per leaf (host-gathered)
        _COMMITTED        written last — readers ignore dirs without it

Fault-tolerance properties:
  * atomic: tmp-dir + rename + commit marker, so a preempted writer never
    corrupts the latest checkpoint;
  * async: `save(..., blocking=False)` snapshots to host memory and writes
    on a background thread (training continues);
  * elastic: restore() only needs the manifest + the target sharding — the
    mesh may have a different shape/axis layout than at save time (leaves
    are re-sharded on load via device_put with the new NamedSharding);
  * self-pruning: keep_last bounds disk usage.

On a real multi-host pod each host writes its addressable shards; this
container is single-process so save gathers to host RAM first — the format
and the restart semantics are identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_COMMIT = "_COMMITTED"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3, clock=None):
        """``clock`` is the injectable wall clock (seconds) the manifest
        ``created`` field and commit marker are stamped with — the same
        convention as ``StoreConfig.clock``/``now_s``; ``None`` means
        ``time.time``."""
        self.dir = directory
        self.keep_last = keep_last
        self.clock = clock
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def now_s(self) -> float:
        return time.time() if self.clock is None else float(self.clock())

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = True,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot ``tree`` (pytree of jax/np arrays) at ``step``."""
        self.wait()                       # one async save in flight at a time
        named = _flatten_with_paths(tree)
        # snapshot to host memory (device buffers may be donated next step);
        # non-native dtypes (bfloat16) are stored as uint16 views with the
        # logical dtype recorded in the manifest
        host = []
        logical = []
        for k, v in named:
            a = np.asarray(v)
            logical.append(str(a.dtype))
            if "bfloat16" in str(a.dtype) or a.dtype.kind == "V":
                a = a.view(np.uint16)
            host.append((k, a))
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "created": self.now_s(),
            "treedef": str(treedef),
            "leaves": [{"key": k, "shape": list(a.shape),
                        "dtype": logical[i], "file": f"arr_{i:05d}.npy"}
                       for i, (k, a) in enumerate(host)],
            "extra": extra or {},
        }

        def write():
            final = os.path.join(self.dir, f"step_{step:09d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, (_, a) in enumerate(host):
                np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, _COMMIT), "w") as f:
                f.write(str(self.now_s()))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._prune()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(full, _COMMIT)):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Load into the structure of ``template``.  ``shardings`` (matching
        pytree of NamedSharding) re-shards onto the *current* mesh — this is
        the elastic-rescale path: save on 256 chips, restore on 512 (or 1).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {e["key"]: e for e in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(flat))
        out = []
        for (path, leaf), sh in zip(flat, shard_flat):
            key = jax.tree_util.keystr(path)
            if key not in by_key:
                raise KeyError(f"checkpoint missing leaf {key}")
            e = by_key[key]
            arr = np.load(os.path.join(d, e["file"]))
            if "bfloat16" in e["dtype"]:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            want_dtype = (leaf.dtype if hasattr(leaf, "dtype")
                          else arr.dtype)
            arr = arr.astype(want_dtype)
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"template {leaf.shape}")
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
