"""Atomic/async/elastic checkpointing."""
