"""Architecture registry: ``--arch <id>`` resolution, reduced smoke
configs, input specs (ShapeDtypeStructs for the dry-run), and per-cell
applicability (long_500k needs sub-quadratic decode state)."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import LM_SHAPES, VAE_SHAPES, ShapeSpec
from repro.models.common import ModelConfig

_ARCH_MODULES = {
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "granite-8b": "repro.configs.granite_8b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)
VISION_PREFIX = 256      # stub patch-embedding prefix length for [vlm]


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test scale: same family/topology, tiny dimensions."""
    subs: Dict[str, Any] = dict(
        n_layers=4 if cfg.attn_every else 2,
        d_model=128, d_ff=256, vocab_size=512,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_head=None, dtype=jnp.float32, remat=False,
    )
    if cfg.family == "encdec":
        subs.update(encoder_layers=2, encoder_seq=16)
    if cfg.n_experts:
        # generous capacity at smoke scale so routing never drops tokens
        # (keeps prefill/decode exactly consistent with the full forward)
        subs.update(n_experts=4, experts_per_token=2, capacity_factor=8.0)
    if cfg.ssm_type:
        subs.update(ssm_head_dim=32, ssm_state=16)
    if cfg.attn_every:
        subs.update(attn_every=2)
    if cfg.sliding_window:
        subs.update(sliding_window=16)
    if cfg.mrope_sections:
        subs.update(mrope_sections=(4, 6, 6))     # sums to head_dim/2 = 16
    return dataclasses.replace(cfg, **subs)


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    from repro.models.lm import CausalLM
    return CausalLM(cfg)


# ---------------------------------------------------------------------------
# applicability (assignment rules)
# ---------------------------------------------------------------------------

def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k":
        if not cfg.subquadratic:
            return False, ("pure full-attention arch: 500k-token decode "
                           "needs sub-quadratic attention (DESIGN.md "
                           "§Arch-applicability)")
        if cfg.family == "encdec":
            return False, "enc-dec target length is architecturally bounded"
    return True, ""


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs for the step function selected by ``shape.kind``.

    train   -> batch dict for ``loss`` / train_step
    prefill -> token (+frontend) arrays
    decode  -> KV cache pytree + one token per sequence
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(shp):
        return jax.ShapeDtypeStruct(shp, i32)

    if shape.kind == "train":
        if cfg.family == "encdec":
            return {"frames": jax.ShapeDtypeStruct(
                        (b, cfg.encoder_seq, cfg.d_model), cfg.dtype),
                    "tokens": tok((b, s)), "labels": tok((b, s))}
        if cfg.family == "vlm":
            return {"vision_embeds": jax.ShapeDtypeStruct(
                        (b, VISION_PREFIX, cfg.d_model), cfg.dtype),
                    "tokens": tok((b, s - VISION_PREFIX)),
                    "labels": tok((b, s - VISION_PREFIX))}
        return {"tokens": tok((b, s)), "labels": tok((b, s))}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": jax.ShapeDtypeStruct(
                        (b, cfg.encoder_seq, cfg.d_model), cfg.dtype),
                    "tokens": tok((b, s))}
        if cfg.family == "vlm":
            return {"vision_embeds": jax.ShapeDtypeStruct(
                        (b, VISION_PREFIX, cfg.d_model), cfg.dtype),
                    "tokens": tok((b, s - VISION_PREFIX))}
        return {"tokens": tok((b, s))}

    if shape.kind == "decode":
        model = build_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
        return {"cache": cache, "tokens": tok((b,))}

    raise ValueError(f"unknown shape kind {shape.kind}")
