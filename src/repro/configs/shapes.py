"""Assigned input-shape sets (LM family) + the paper's own VAE shapes."""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


LM_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# The paper's own architecture (SD3.5 VAE decode fleet): batched latent ->
# image reconstruction, the read path of the latent-first store.
VAE_SHAPES: Dict[str, ShapeSpec] = {
    "decode_1k_b256": ShapeSpec("decode_1k_b256", "vae_decode", 1024, 256),
    "decode_512_b512": ShapeSpec("decode_512_b512", "vae_decode", 512, 512),
}
