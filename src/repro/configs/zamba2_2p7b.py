"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]  SSM state => long_500k runs."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", ssm_type="mamba2",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    conv_width=4, attn_every=6, rope_theta=1e4,
    tie_embeddings=True, subquadratic=True,
)
