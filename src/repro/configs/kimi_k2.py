"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 384 experts top-8
(paper-table).  [arXiv:2501.kimi2; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, act="swiglu", rope_theta=5e6,
    n_experts=384, experts_per_token=8, tie_embeddings=False,
)
