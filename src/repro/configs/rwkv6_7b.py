"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]  O(1) decode state => long_500k runs."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", ssm_type="rwkv6",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab_size=65536, ssm_head_dim=64, rope_theta=0.0,
    tie_embeddings=False, subquadratic=True,
)
