"""whisper-large-v3 [audio]: enc-dec, conv frontend STUB (precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, encoder_layers=32, encoder_seq=1500,
    d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, act="gelu", qkv_bias=True, rope_theta=0.0,
    tie_embeddings=True, frontend="audio", norm_eps=1e-5,
)
