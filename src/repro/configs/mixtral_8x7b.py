"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]  SWA => sub-quadratic => long_500k runs."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, act="swiglu", rope_theta=1e6,
    n_experts=8, experts_per_token=2, sliding_window=4096,
    tie_embeddings=False, subquadratic=True,
)
