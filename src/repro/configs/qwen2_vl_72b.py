"""qwen2-vl-72b [vlm]: M-RoPE, dynamic resolution; vision frontend STUB
(precomputed patch embeddings).  [arXiv:2409.12191; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, act="swiglu", qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), tie_embeddings=False, frontend="vision",
)
