"""Deterministic, resumable synthetic token pipeline.

Production framing: every batch is a pure function of (seed, step, shard),
so a restarted/rescaled job regenerates exactly the stream it would have
seen — no state files, no skip-ahead replay cost.  A real corpus loader
would persist its cursor in the checkpoint ``extra`` field instead; the
trainer already round-trips that.

The generator models a Zipf unigram distribution with Markov locality so
losses move (unlike uniform noise) and MoE routers see realistic skew.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    locality: float = 0.7           # P(next token near previous token)
    shard_index: int = 0            # this host's shard
    num_shards: int = 1


class SyntheticTokens:
    """batch(step) -> {'tokens': [b, S], 'labels': [b, S]} for this shard."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.num_shards:
            raise ValueError("global_batch must divide evenly across shards")
        self.local_batch = cfg.global_batch // cfg.num_shards
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._cdf = np.cumsum(p / p.sum())

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Generates the GLOBAL batch from (seed, step) and slices this
        shard's rows — so the global token stream is invariant under
        re-sharding (the elastic-rescale property: a job restarted on a
        different host count replays the identical stream)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        b, s = cfg.global_batch, cfg.seq_len
        base = np.searchsorted(self._cdf, rng.random((b, s + 1)))
        # Markov locality: with prob `locality`, stay near the prior token
        stay = rng.random((b, s + 1)) < cfg.locality
        jitter = rng.integers(-64, 65, (b, s + 1))
        toks = base.copy()
        for t in range(1, s + 1):
            local = np.clip(toks[:, t - 1] + jitter[:, t], 0,
                            cfg.vocab_size - 1)
            toks[:, t] = np.where(stay[:, t], local, base[:, t])
        lo = cfg.shard_index * self.local_batch
        sl = slice(lo, lo + self.local_batch)
        tokens = toks[sl, :-1].astype(np.int32)
        labels = toks[sl, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
