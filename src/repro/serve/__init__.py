"""Serving runtime: LatentBox engine over the real VAE decode fleet."""
