"""Serving engine: LatentBox's routing/cache layer driving a real JAX
decode fleet, with a microbatching decode scheduler on the miss path.

This is the non-simulated end-to-end path (examples/serve_trace_replay.py):
requests -> Router (coalescing, consistent hashing, spillover w/ pinning)
-> per-node DualFormatCache -> on miss, the *real* VAE decode (jitted,
batched) reconstructs pixels from compressed latents fetched from the
LatentStore.

Misses do not decode one-by-one: they accumulate in a ``DecodeBatcher``
queue where duplicate in-flight object ids coalesce into a single decode
(single-flight), then flush as batches padded up to a small set of
bucketed batch sizes (default 1/2/4/8) so ``jax.jit`` compiles once per
bucket instead of once per arrival pattern.  Per-image wall-clock
(batch time / real images in the batch) feeds the marginal-hit tuner's
EWMAs, closing the paper's feedback loop on real measurements.  Decode is
deterministic per image, so bucketed batching (and its padding) returns
bit-identical pixels to a batch-1 decode of the same latent.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.compression.latentcodec import decompress_latent
from repro.core.dual_cache import DualFormatCache, IMAGE_HIT, LATENT_HIT
from repro.core.latent_store import LatentStore
from repro.core.router import Router
from repro.core.tuner import MarginalHitTuner, TunerConfig
from repro.vae.model import VAE


@dataclasses.dataclass
class EngineConfig:
    n_nodes: int = 2
    cache_bytes_per_node: float = 64e6
    alpha0: float = 0.5
    tau: float = 0.1
    promote_threshold: int = 4
    theta: int = 4
    decode_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    tuner: TunerConfig = dataclasses.field(
        default_factory=lambda: TunerConfig(window=500, step=0.02))


class _Node:
    def __init__(self, idx: int, cfg: EngineConfig, image_bytes: float,
                 latent_bytes: float):
        self.idx = idx
        self.cache = DualFormatCache(
            cfg.cache_bytes_per_node, alpha=cfg.alpha0, tau=cfg.tau,
            promote_threshold=cfg.promote_threshold,
            image_size_fn=lambda _: image_bytes,
            latent_size_fn=lambda _: latent_bytes)
        self.tuner = MarginalHitTuner(self.cache, cfg.tuner)
        self.images: Dict[int, np.ndarray] = {}     # decoded-image payloads
        self.latents: Dict[int, bytes] = {}         # compressed payloads
        self.queue_depth = 0


def _node_index(name: str) -> int:
    """Parse a ``node<idx>`` ring/router name into a fleet index."""
    if not name.startswith("node"):
        raise ValueError(f"malformed node name {name!r} (want 'node<idx>')")
    try:
        return int(name[4:])
    except ValueError as e:
        raise ValueError(
            f"malformed node name {name!r} (want 'node<idx>')") from e


class DecodeBatcher:
    """Microbatching decode scheduler over one jitted VAE decode.

    Pending misses queue up via :meth:`submit`; duplicate in-flight object
    ids coalesce into one decode (single-flight).  :meth:`flush` drains the
    queue in FIFO order as batches, each padded up to the smallest
    configured bucket that fits so the jitted decode sees only
    ``len(buckets)`` distinct batch shapes.  Padding repeats the last real
    latent — the decode is per-image independent and deterministic, so
    padded slots never perturb the real outputs.
    """

    def __init__(self, vae: VAE, buckets: Sequence[int] = (1, 2, 4, 8)):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive: {buckets!r}")
        self.vae = vae
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_batch = self.buckets[-1]
        # oid -> (latent z [h, w, c] float32, exec node) in arrival order
        self._pending: Dict[int, Tuple[np.ndarray, Any]] = {}
        self._warm: set = set()       # buckets whose decode shape is compiled
        self.stats = {"decodes": 0, "batches": 0, "coalesced": 0,
                      "padded_slots": 0}

    def __len__(self) -> int:
        return len(self._pending)

    def clear(self) -> None:
        """Drop everything pending (a window aborted mid-admission)."""
        self._pending.clear()

    def submit(self, oid: int, blob: bytes, node: Any) -> bool:
        """Queue a decode for ``oid``; returns True if newly enqueued,
        False if it coalesced with an in-flight decode of the same oid."""
        if oid in self._pending:
            self.stats["coalesced"] += 1
            return False
        # fixed decode dtype: determinism holds per (latent, stack) pair
        z = np.asarray(decompress_latent(blob), np.float32)
        self._pending[oid] = (z, node)
        return True

    def bucket_for(self, n: int) -> int:
        """Smallest configured bucket >= n (n itself beyond the largest)."""
        for b in self.buckets:
            if b >= n:
                return b
        return n

    def flush(self) -> Dict[int, np.ndarray]:
        """Decode everything pending; returns oid -> image and feeds each
        exec node's tuner the per-image wall clock of its batch."""
        results: Dict[int, np.ndarray] = {}
        items = list(self._pending.items())
        self._pending.clear()
        for start in range(0, len(items), self.max_batch):
            chunk = items[start:start + self.max_batch]
            results.update(self._decode_chunk(chunk))
        return results

    def _decode_chunk(self, chunk) -> Dict[int, np.ndarray]:
        n_real = len(chunk)
        bucket = self.bucket_for(n_real)
        zs = [z for _, (z, _) in chunk]
        zs.extend([zs[-1]] * (bucket - n_real))       # pad with the last real z
        zb = jnp.stack(zs)
        if bucket not in self._warm:
            # compile this bucket's shape outside the timed region so jit
            # compile time never poisons the tuner's decode EWMA
            self.vae.decode(zb).block_until_ready()
            self._warm.add(bucket)
        t0 = time.perf_counter()
        imgs = np.asarray(self.vae.decode(zb))
        ms = (time.perf_counter() - t0) * 1e3
        per_image_ms = ms / n_real
        self.stats["batches"] += 1
        self.stats["decodes"] += n_real
        self.stats["padded_slots"] += bucket - n_real
        out = {}
        for i, (oid, (_, node)) in enumerate(chunk):
            node.tuner.observe_decode_ms(per_image_ms)
            out[oid] = imgs[i]
        return out


@dataclasses.dataclass
class _Ticket:
    """One request's routing decision, held across the batched decode."""
    oid: int
    outcome: str
    owner: _Node
    exec_node: Optional[_Node] = None
    img: Optional[np.ndarray] = None          # set on image hit
    write_image: bool = False                 # promote/pin decision at lookup


class ServingEngine:
    """Single-process stand-in for the Ray fleet: N logical nodes share one
    device, but the cache/routing/tuning logic is the production code."""

    def __init__(self, vae: VAE, store: LatentStore,
                 cfg: Optional[EngineConfig] = None,
                 image_bytes: float = 64e3, latent_bytes: float = 13e3):
        self.vae = vae
        self.store = store
        self.cfg = cfg or EngineConfig()
        self.nodes = [_Node(i, self.cfg, image_bytes, latent_bytes)
                      for i in range(self.cfg.n_nodes)]
        self.router = Router([f"node{i}" for i in range(self.cfg.n_nodes)],
                             theta=self.cfg.theta)
        self.batcher = DecodeBatcher(vae, self.cfg.decode_buckets)
        self.stats = {"image_hit": 0, "latent_hit": 0, "full_miss": 0,
                      "spilled": 0}

    # -- request admission ---------------------------------------------------

    def _lookup(self, oid: int) -> _Ticket:
        """Route one request up to (but excluding) the decode: cache lookup,
        spillover pick, latent fetch/admission, and decode enqueue."""
        owner_name = self.router.ring.owner(oid)
        owner = self.nodes[_node_index(owner_name)]
        res = owner.cache.lookup(oid)
        owner.tuner.on_request()

        if res.outcome == IMAGE_HIT:
            self.stats["image_hit"] += 1
            img = owner.images.get(oid)
            if img is not None:
                return _Ticket(oid, IMAGE_HIT, owner, img=img)
            # admitted to the image tier, but the pixel payload is still
            # in-flight in this window's batch: join the pending decode
            # (single-flight) and write back on flush.
            blob = owner.latents.get(oid) or self.store.get(oid)
            if blob is None:
                raise KeyError(f"object {oid} not in store")
            if self.batcher.submit(oid, blob, owner):
                owner.queue_depth += 1
            return _Ticket(oid, IMAGE_HIT, owner, exec_node=owner,
                           write_image=True)

        # pick the execution node (spillover with cache pinning)
        for n in self.nodes:
            self.router.report_depth(f"node{n.idx}", n.queue_depth)
        exec_node = owner
        if owner.queue_depth > self.cfg.theta:
            cand = self.nodes[_node_index(
                self.router.least_loaded(exclude=owner_name))]
            if cand.queue_depth < owner.queue_depth:
                exec_node = cand
                self.stats["spilled"] += 1

        if res.outcome == LATENT_HIT:
            self.stats["latent_hit"] += 1
            blob = owner.latents[oid]
        else:
            self.stats["full_miss"] += 1
            t0 = time.perf_counter()
            blob = self.store.get(oid)
            if blob is None:
                raise KeyError(f"object {oid} not in store")
            owner.tuner.observe_fetch_ms(
                (time.perf_counter() - t0) * 1e3
                + self.store.fetch_ms(oid, time.time()))
            owner.cache.admit_latent(oid)
            if oid in owner.cache.latent_tier:
                owner.latents[oid] = blob

        if self.batcher.submit(oid, blob, exec_node):
            exec_node.queue_depth += 1          # one slot per unique decode
        return _Ticket(
            oid, res.outcome, owner, exec_node=exec_node,
            write_image=res.promoted or owner.cache.contains(oid) == "image")

    # -- public API ----------------------------------------------------------

    def get(self, oid: int) -> Tuple[np.ndarray, str]:
        return self.get_many([oid])[0]

    def get_many(self, oids: Sequence[int]
                 ) -> List[Tuple[np.ndarray, str]]:
        """Serve a window of requests with one batched decode flush.

        Lookups/routing run in request order (cache state evolves exactly
        as with sequential ``get`` calls); all resulting misses decode in
        bucketed microbatches, then results write back to their hash
        owners (cache pinning) in request order.
        """
        try:
            tickets = [self._lookup(int(oid)) for oid in oids]
        except Exception:
            # a window aborted mid-admission (e.g. unknown oid) must not
            # leak queued decodes or queue-depth into the next window
            self.batcher.clear()
            for n in self.nodes:
                n.queue_depth = 0
            raise
        decoded = self._flush()
        out: List[Tuple[np.ndarray, str]] = []
        touched = {}
        for t in tickets:
            if t.img is not None:
                out.append((t.img, t.outcome))
                continue
            img = decoded[t.oid]
            # cache pinning: decoded result written back to the OWNER node
            if t.write_image or t.owner.cache.contains(t.oid) == "image":
                t.owner.images[t.oid] = img
            touched[id(t.owner)] = t.owner
            out.append((img, t.outcome))
        for node in touched.values():
            self._gc(node)
        return out

    def _flush(self) -> Dict[int, np.ndarray]:
        try:
            return self.batcher.flush()
        finally:
            for n in self.nodes:
                n.queue_depth = 0               # all in-flight decodes drained

    def _gc(self, node: _Node) -> None:
        if len(node.images) > 2 * len(node.cache.image_tier) + 32:
            live = set(iter(node.cache.image_tier))
            node.images = {k: v for k, v in node.images.items() if k in live}
        if len(node.latents) > 2 * len(node.cache.latent_tier) + 32:
            live = set(iter(node.cache.latent_tier))
            node.latents = {k: v for k, v in node.latents.items()
                            if k in live}

    def summary(self) -> Dict[str, Any]:
        total = sum(self.stats[k] for k in
                    ("image_hit", "latent_hit", "full_miss"))
        out = dict(self.stats)
        out["total"] = total
        if total:
            out["image_hit_frac"] = self.stats["image_hit"] / total
            out["decode_frac"] = (self.stats["latent_hit"]
                                  + self.stats["full_miss"]) / total
        out["alpha"] = [round(n.cache.alpha, 3) for n in self.nodes]
        out["decode_batches"] = self.batcher.stats["batches"]
        out["decodes"] = self.batcher.stats["decodes"]
        out["coalesced_decodes"] = self.batcher.stats["coalesced"]
        return out
