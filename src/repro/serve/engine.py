"""The ENGINE backend of the LatentBox object-store API: real jitted
decode behind the shared tier-walk read path.

Since the store refactor there is exactly one read path —
:class:`repro.store.walk.TierWalk` (pixel cache -> latent cache -> durable
latent -> recipe regeneration) — and two backends of the same ``LatentBox``
facade: this module supplies *real compute* (jitted VAE decode, measured
wall-clock feeding the tuner EWMAs), while ``core/cluster.py`` supplies
*latency events* for the same walk.  ``ServingEngine`` keeps its direct
``get``/``get_many`` surface for existing callers/tests, but every
classification, admission, promotion, and spillover decision now comes from
the shared walk, so the engine can no longer drift from the simulator.

Misses do not decode one-by-one: they accumulate in a ``DecodeBatcher``
queue where duplicate in-flight object ids coalesce into a single decode
(single-flight), then flush as batches padded up to a small set of
bucketed batch sizes (default 1/2/4/8) so ``jax.jit`` compiles once per
bucket instead of once per arrival pattern.  Per-image wall-clock
(batch time / real images in the batch) feeds the marginal-hit tuner's
EWMAs, closing the paper's feedback loop on real measurements.  Decode is
deterministic per image, so bucketed batching (and its padding) returns
bit-identical pixels to a batch-1 decode of the same latent.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.core.dual_cache import IMAGE_HIT, LATENT_HIT
from repro.core.latent_store import LatentStore
from repro.core.regen_tier import Recipe, RegenTierStore, synthesize_image
from repro.core.router import parse_node_index
from repro.core.tuner import MarginalHitTuner, TunerConfig
from repro.store.api import StoreConfig
from repro.store.tiers import DurableTier, RecipeTier
from repro.store.walk import TierWalk
from repro.vae.model import VAE


@dataclasses.dataclass
class EngineConfig:
    n_nodes: int = 2
    cache_bytes_per_node: float = 64e6
    alpha0: float = 0.5
    tau: float = 0.1
    #: Paper parameter ``h``: latent hits before promotion to the pixel
    #: tier; doubles as the spillover queue-depth bound (the deprecated
    #: ``theta`` alias encoded the same value).
    promote_threshold: int = 4
    decode_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    adaptive: bool = True               # run the marginal-hit tuner
    tuner: TunerConfig = dataclasses.field(
        default_factory=lambda: TunerConfig(window=500, step=0.02))
    #: Deprecated alias of ``promote_threshold`` — passing it is an error.
    theta: dataclasses.InitVar[Optional[int]] = None

    def __post_init__(self, theta: Optional[int]) -> None:
        if theta is not None:
            raise TypeError(
                "EngineConfig.theta was merged into promote_threshold "
                "(both encode the paper's h); pass promote_threshold "
                "instead")

    def store_config(self, image_bytes: float,
                     latent_bytes: float) -> StoreConfig:
        """The cache/routing half of this config, for the shared walk."""
        return StoreConfig(
            n_nodes=self.n_nodes,
            cache_bytes_per_node=self.cache_bytes_per_node,
            alpha0=self.alpha0, tau=self.tau,
            promote_threshold=self.promote_threshold,
            image_bytes=image_bytes, latent_bytes=latent_bytes,
            adaptive=self.adaptive, tuner=self.tuner,
            decode_buckets=self.decode_buckets)


class _Node:
    """Engine-side view of one walk node: payload dicts + decode queue
    depth around the walk's cache/tuner."""

    def __init__(self, idx: int, tier) -> None:
        self.idx = idx
        self.tier = tier
        self.cache = tier.cache
        self.tuner: Optional[MarginalHitTuner] = tier.tuner
        self.images: Dict[int, np.ndarray] = {}     # decoded-image payloads
        self.latents: Dict[int, bytes] = {}         # compressed payloads
        self.queue_depth = 0

    def drop_payloads(self, oid: int) -> None:
        self.images.pop(oid, None)
        self.latents.pop(oid, None)


# legacy alias: the parser moved to core.router (the sharded cluster's
# global namespace relies on it too)
_node_index = parse_node_index


class DecodeBatcher:
    """Microbatching decode scheduler over one jitted VAE decode.

    Pending misses queue up via :meth:`submit`; duplicate in-flight object
    ids coalesce into one decode (single-flight).  :meth:`flush` drains the
    queue in FIFO order as batches, each padded up to the smallest
    configured bucket that fits so the jitted decode sees only
    ``len(buckets)`` distinct batch shapes.  Padding repeats the last real
    latent — the decode is per-image independent and deterministic, so
    padded slots never perturb the real outputs.
    """

    def __init__(self, vae: VAE, buckets: Sequence[int] = (1, 2, 4, 8)):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive: {buckets!r}")
        self.vae = vae
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_batch = self.buckets[-1]
        # oid -> (latent z [h, w, c] float32, exec node) in arrival order
        self._pending: Dict[int, Tuple[np.ndarray, Any]] = {}
        self._warm: set = set()       # buckets whose decode shape is compiled
        self.stats = {"decodes": 0, "batches": 0, "coalesced": 0,
                      "padded_slots": 0}
        self.last_per_image_ms: Dict[int, float] = {}

    def __len__(self) -> int:
        return len(self._pending)

    def clear(self) -> None:
        """Drop everything pending (a window aborted mid-admission)."""
        self._pending.clear()

    def submit(self, oid: int, blob: bytes, node: Any) -> bool:
        """Queue a decode for ``oid``; returns True if newly enqueued,
        False if it coalesced with an in-flight decode of the same oid."""
        if oid in self._pending:
            self.stats["coalesced"] += 1
            return False
        # fixed decode dtype: determinism holds per (latent, stack) pair
        z = np.asarray(decompress_latent(blob), np.float32)
        self._pending[oid] = (z, node)
        return True

    def bucket_for(self, n: int) -> int:
        """Smallest configured bucket >= n (n itself beyond the largest)."""
        for b in self.buckets:
            if b >= n:
                return b
        return n

    def flush(self) -> Dict[int, np.ndarray]:
        """Decode everything pending; returns oid -> image and feeds each
        exec node's tuner the per-image wall clock of its batch."""
        results: Dict[int, np.ndarray] = {}
        items = list(self._pending.items())
        self._pending.clear()
        self.last_per_image_ms = {}
        for start in range(0, len(items), self.max_batch):
            chunk = items[start:start + self.max_batch]
            results.update(self._decode_chunk(chunk))
        return results

    def _decode_chunk(self, chunk) -> Dict[int, np.ndarray]:
        n_real = len(chunk)
        bucket = self.bucket_for(n_real)
        zs = [z for _, (z, _) in chunk]
        zs.extend([zs[-1]] * (bucket - n_real))       # pad with the last real z
        zb = jnp.stack(zs)
        if bucket not in self._warm:
            # compile this bucket's shape outside the timed region so jit
            # compile time never poisons the tuner's decode EWMA
            self.vae.decode(zb).block_until_ready()
            self._warm.add(bucket)
        t0 = time.perf_counter()
        imgs = np.asarray(self.vae.decode(zb))
        ms = (time.perf_counter() - t0) * 1e3
        per_image_ms = ms / n_real
        self.stats["batches"] += 1
        self.stats["decodes"] += n_real
        self.stats["padded_slots"] += bucket - n_real
        out = {}
        for i, (oid, (_, node)) in enumerate(chunk):
            if node.tuner is not None:
                node.tuner.observe_decode_ms(per_image_ms)
            self.last_per_image_ms[oid] = per_image_ms
            out[oid] = imgs[i]
        return out


@dataclasses.dataclass
class _Ticket:
    """One request's routing decision, held across the batched decode."""
    oid: int
    outcome: str
    owner: _Node
    exec_node: Optional[_Node] = None
    img: Optional[np.ndarray] = None          # set on image hit
    write_image: bool = False                 # promote/pin decision at lookup
    spilled: bool = False
    fetch_ms: float = 0.0                     # measured durable-fetch wall
    regen_ms: float = 0.0                     # measured regeneration wall
    decode_ms: float = 0.0                    # per-image share of its batch


class ServingEngine:
    """Single-process stand-in for the Ray fleet: N logical nodes share one
    device, but the cache/routing/tuning logic is the production code —
    and, since the store refactor, the exact same ``TierWalk`` the
    simulator backend classifies with."""

    def __init__(self, vae: VAE, store: LatentStore,
                 cfg=None, image_bytes: float = 64e3,
                 latent_bytes: float = 13e3,
                 recipes: Optional[RegenTierStore] = None):
        """``cfg`` is either a :class:`StoreConfig` (the facade path — its
        ``image_bytes``/``latent_bytes`` fields win) or a legacy
        :class:`EngineConfig` combined with the explicit size arguments."""
        self.vae = vae
        self.store = store
        if isinstance(cfg, StoreConfig):
            self.cfg = cfg
        else:
            self.cfg = (cfg or EngineConfig()).store_config(
                image_bytes, latent_bytes)
        self.recipes = recipes
        self.walk = TierWalk(
            self.cfg,
            durable=DurableTier(store),
            recipes=RecipeTier(recipes) if recipes is not None else None)
        self.nodes = [_Node(i, t) for i, t in enumerate(self.walk.caches)]
        for node in self.nodes:
            # capacity evictions drop the decoded/compressed payload too
            node.tier.evict_cb(node.drop_payloads)
        self.router = self.walk.router
        self.batcher = DecodeBatcher(vae, self.cfg.decode_buckets)
        self.stats = self.walk.counts           # shared hit/spill accounting

    # -- writes ---------------------------------------------------------------

    def put(self, oid: int, image: Optional[np.ndarray] = None,
            latent: Optional[np.ndarray] = None,
            recipe: Optional[Recipe] = None) -> int:
        """Durable write: encode (if given pixels) -> compress -> latent
        store; the recipe (if any) becomes the coldest durability class.
        Returns the durable byte count."""
        if latent is None:
            if image is None:
                if recipe is None:
                    raise ValueError("put needs an image, latent, or recipe")
                image = synthesize_image(recipe)
            img4 = np.asarray(image, np.float32)
            if img4.ndim == 3:
                img4 = img4[None]
            latent = np.asarray(
                self.vae.encode_mean(jnp.asarray(img4)))[0].astype(np.float16)
        blob = compress_latent(np.asarray(latent))
        self.store.put(oid, blob)
        if recipe is not None and self.recipes is not None:
            self.recipes.put(oid, float(len(blob)), recipe=recipe)
        return len(blob)

    def delete(self, oid: int) -> bool:
        """Remove from every tier, payload dicts included."""
        found = self.walk.delete(oid)
        for node in self.nodes:
            node.drop_payloads(oid)
        return found

    def demote(self, oid: int) -> bool:
        """Drop the durable latent, keep the recipe (recipe-only class).
        Cached copies are purged so the next read exercises regeneration;
        the eviction listeners drop the decoded payloads with them."""
        return self.walk.demote(oid)

    def promote(self, oid: int) -> bool:
        """Regenerate a demoted object's latent back into the durable tier
        without waiting for a read to pay the regen latency."""
        if self.recipes is None or not self.recipes.is_demoted(oid):
            return False
        self._regenerate(oid)
        return True

    def prewarm(self, oid: int) -> bool:
        """Decode now and pin pixels at the hash owner (no stats impact)."""
        blob = self.store.get(oid)
        if blob is None:
            return False
        z = np.asarray(decompress_latent(blob), np.float32)
        img = np.asarray(self.vae.decode(z[None]))[0]
        owner = self.nodes[self.walk._idx[self.walk.router.ring.owner(oid)]]
        owner.cache.insert_image(oid)
        owner.images[oid] = img
        return True

    def _regenerate(self, oid: int) -> bytes:
        """Recipe -> pixels -> latent -> durable re-admission (bit-exact on
        the same stack, which is what makes recipes a durability class)."""
        recipe = self.recipes.recipe_of(oid) if self.recipes else None
        if recipe is None:
            raise KeyError(f"object {oid} has no recipe to regenerate from")
        z = np.asarray(self.vae.encode_mean(
            jnp.asarray(synthesize_image(recipe))))[0].astype(np.float16)
        blob = compress_latent(z)
        self.store.put(oid, blob)
        self.recipes.readmit(oid, float(len(blob)), now_mo=0.0)
        return blob

    # -- request admission ---------------------------------------------------

    def _lookup(self, oid: int) -> _Ticket:
        """Route one request up to (but excluding) the decode: the shared
        tier-walk classifies and admits; this method materializes payloads
        (durable fetch / regeneration) and enqueues the decode."""
        ticket = self.walk.lookup(
            oid, depth_of=lambda i: self.nodes[i].queue_depth)
        owner = self.nodes[ticket.owner]
        exec_node = self.nodes[ticket.exec_node]

        if ticket.hit_class == IMAGE_HIT:
            img = owner.images.get(oid)
            if img is not None:
                return _Ticket(oid, IMAGE_HIT, owner, img=img)
            # admitted to the image tier, but the pixel payload is still
            # in-flight in this window's batch: join the pending decode
            # (single-flight) and write back on flush.
            blob = owner.latents.get(oid) or self.store.get(oid)
            if blob is None:
                raise KeyError(f"object {oid} not in store")
            if self.batcher.submit(oid, blob, owner):
                owner.queue_depth += 1
            return _Ticket(oid, IMAGE_HIT, owner, exec_node=owner,
                           write_image=True)

        fetch_ms = regen_ms = 0.0
        if ticket.hit_class == LATENT_HIT:
            blob = owner.latents.get(oid) or self.store.get(oid)
            if blob is None:
                raise KeyError(f"object {oid} lost its latent payload")
        elif ticket.needs_regen:
            t0 = time.perf_counter()
            blob = self._regenerate(oid)
            regen_ms = (time.perf_counter() - t0) * 1e3
            # regen replaces the durable fetch on the miss path, so it
            # feeds the fetch EWMA (same signal class on both backends)
            if owner.tuner is not None:
                owner.tuner.observe_fetch_ms(regen_ms)
            if self.walk.admit_latent(ticket.owner, oid):
                owner.latents[oid] = blob
        else:                                         # durable fetch
            t0 = time.perf_counter()
            blob = self.store.get(oid)
            if blob is None:
                raise KeyError(f"object {oid} has no durable payload "
                               "(size-only registration?)")
            fetch_ms = ((time.perf_counter() - t0) * 1e3
                        + self.store.fetch_ms(oid, time.time()))
            if owner.tuner is not None:
                owner.tuner.observe_fetch_ms(fetch_ms)
            if self.walk.admit_latent(ticket.owner, oid):
                owner.latents[oid] = blob

        if self.batcher.submit(oid, blob, exec_node):
            exec_node.queue_depth += 1          # one slot per unique decode
        return _Ticket(oid, ticket.hit_class, owner, exec_node=exec_node,
                       write_image=ticket.write_image, spilled=ticket.spilled,
                       fetch_ms=fetch_ms, regen_ms=regen_ms)

    # -- public API ----------------------------------------------------------

    def get(self, oid: int) -> Tuple[np.ndarray, str]:
        return self.get_many([oid])[0]

    def get_many(self, oids: Sequence[int]
                 ) -> List[Tuple[np.ndarray, str]]:
        """Serve a window of requests with one batched decode flush;
        returns ``(pixels, hit_class)`` pairs in request order."""
        return [(t.img, t.outcome) for t in self.serve_window(oids)]

    def serve_window(self, oids: Sequence[int]) -> List[_Ticket]:
        """Serve a window of requests with one batched decode flush.

        Lookups/routing run in request order (cache state evolves exactly
        as with sequential ``get`` calls); all resulting misses decode in
        bucketed microbatches, then results write back to their hash
        owners (cache pinning) in request order.  Tickets carry the
        measured per-request latency components for ``GetResult``.
        """
        try:
            tickets = [self._lookup(int(oid)) for oid in oids]
        except Exception:
            # a window aborted mid-admission (e.g. unknown oid) must not
            # leak queued decodes or queue-depth into the next window
            self.batcher.clear()
            for n in self.nodes:
                n.queue_depth = 0
            raise
        decoded = self._flush()
        touched = {}
        for t in tickets:
            if t.img is not None:
                continue
            img = decoded[t.oid]
            t.decode_ms = self.batcher.last_per_image_ms.get(t.oid, 0.0)
            # cache pinning: decoded result written back to the OWNER node
            if t.write_image or t.owner.cache.contains(t.oid) == "image":
                t.owner.images[t.oid] = img
            touched[id(t.owner)] = t.owner
            t.img = img
        for node in touched.values():
            self._gc(node)
        return tickets

    def _flush(self) -> Dict[int, np.ndarray]:
        try:
            return self.batcher.flush()
        finally:
            for n in self.nodes:
                n.queue_depth = 0               # all in-flight decodes drained

    def _gc(self, node: _Node) -> None:
        if len(node.images) > 2 * len(node.cache.image_tier) + 32:
            live = set(iter(node.cache.image_tier))
            node.images = {k: v for k, v in node.images.items() if k in live}
        if len(node.latents) > 2 * len(node.cache.latent_tier) + 32:
            live = set(iter(node.cache.latent_tier))
            node.latents = {k: v for k, v in node.latents.items()
                            if k in live}

    def summary(self) -> Dict[str, Any]:
        out = self.walk.summary()
        out["decode_batches"] = self.batcher.stats["batches"]
        out["decodes"] = self.batcher.stats["decodes"]
        out["coalesced_decodes"] = self.batcher.stats["coalesced"]
        return out
