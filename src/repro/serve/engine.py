"""Serving engine: LatentBox's routing/cache layer driving a real JAX
decode fleet.

This is the non-simulated end-to-end path (examples/serve_trace_replay.py):
requests -> Router (coalescing, consistent hashing, spillover w/ pinning)
-> per-node DualFormatCache -> on miss, the *real* VAE decode (jitted,
batched) reconstructs pixels from compressed latents fetched from the
LatentStore.  Wall-clock decode/fetch times feed the marginal-hit tuner's
EWMAs, closing the paper's feedback loop on real measurements.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.core.dual_cache import (DualFormatCache, FULL_MISS, IMAGE_HIT,
                                   LATENT_HIT)
from repro.core.latent_store import LatentStore
from repro.core.router import Router
from repro.core.tuner import MarginalHitTuner, TunerConfig
from repro.vae.model import VAE, VAEConfig


@dataclasses.dataclass
class EngineConfig:
    n_nodes: int = 2
    cache_bytes_per_node: float = 64e6
    alpha0: float = 0.5
    tau: float = 0.1
    promote_threshold: int = 4
    theta: int = 4
    tuner: TunerConfig = dataclasses.field(
        default_factory=lambda: TunerConfig(window=500, step=0.02))


class _Node:
    def __init__(self, idx: int, cfg: EngineConfig, image_bytes: float,
                 latent_bytes: float):
        self.idx = idx
        self.cache = DualFormatCache(
            cfg.cache_bytes_per_node, alpha=cfg.alpha0, tau=cfg.tau,
            promote_threshold=cfg.promote_threshold,
            image_size_fn=lambda _: image_bytes,
            latent_size_fn=lambda _: latent_bytes)
        self.tuner = MarginalHitTuner(self.cache, cfg.tuner)
        self.images: Dict[int, np.ndarray] = {}     # decoded-image payloads
        self.latents: Dict[int, bytes] = {}         # compressed payloads
        self.queue_depth = 0


class ServingEngine:
    """Single-process stand-in for the Ray fleet: N logical nodes share one
    device, but the cache/routing/tuning logic is the production code."""

    def __init__(self, vae: VAE, store: LatentStore,
                 cfg: Optional[EngineConfig] = None,
                 image_bytes: float = 64e3, latent_bytes: float = 13e3):
        self.vae = vae
        self.store = store
        self.cfg = cfg or EngineConfig()
        self.nodes = [_Node(i, self.cfg, image_bytes, latent_bytes)
                      for i in range(self.cfg.n_nodes)]
        self.router = Router([f"node{i}" for i in range(self.cfg.n_nodes)],
                             theta=self.cfg.theta)
        self.stats = {"image_hit": 0, "latent_hit": 0, "full_miss": 0,
                      "spilled": 0}

    def _decode(self, node: _Node, blob: bytes) -> Tuple[np.ndarray, float]:
        t0 = time.perf_counter()
        # fixed decode dtype: determinism holds per (latent, stack) pair
        z = jnp.asarray(decompress_latent(blob), jnp.float32)[None]
        img = np.asarray(self.vae.decode(z))[0]
        ms = (time.perf_counter() - t0) * 1e3
        node.tuner.observe_decode_ms(ms)
        return img, ms

    def get(self, oid: int) -> Tuple[np.ndarray, str]:
        owner_name = self.router.ring.owner(oid)
        owner = self.nodes[int(owner_name[4:])]
        res = owner.cache.lookup(oid)
        owner.tuner.on_request()

        if res.outcome == IMAGE_HIT:
            self.stats["image_hit"] += 1
            return owner.images[oid], IMAGE_HIT

        # pick the execution node (spillover with cache pinning)
        for n in self.nodes:
            self.router.report_depth(f"node{n.idx}", n.queue_depth)
        exec_node = owner
        if owner.queue_depth > self.cfg.theta:
            cand = self.nodes[int(self.router.least_loaded(
                exclude=owner_name)[4:])]
            if cand.queue_depth < owner.queue_depth:
                exec_node = cand
                self.stats["spilled"] += 1

        exec_node.queue_depth += 1
        try:
            if res.outcome == LATENT_HIT:
                self.stats["latent_hit"] += 1
                blob = owner.latents[oid]
                img, _ = self._decode(exec_node, blob)
            else:
                self.stats["full_miss"] += 1
                t0 = time.perf_counter()
                blob = self.store.get(oid)
                if blob is None:
                    raise KeyError(f"object {oid} not in store")
                owner.tuner.observe_fetch_ms(
                    (time.perf_counter() - t0) * 1e3
                    + self.store.fetch_ms(oid, time.time()))
                owner.cache.admit_latent(oid)
                if oid in owner.cache.latent_tier:
                    owner.latents[oid] = blob
                img, _ = self._decode(exec_node, blob)
        finally:
            exec_node.queue_depth -= 1

        # cache pinning: decoded result written back to the OWNER node
        if res.promoted or owner.cache.contains(oid) == "image":
            owner.images[oid] = img
        self._gc(owner)
        return img, res.outcome

    def _gc(self, node: _Node) -> None:
        if len(node.images) > 2 * len(node.cache.image_tier) + 32:
            live = set(iter(node.cache.image_tier))
            node.images = {k: v for k, v in node.images.items() if k in live}
        if len(node.latents) > 2 * len(node.cache.latent_tier) + 32:
            live = set(iter(node.cache.latent_tier))
            node.latents = {k: v for k, v in node.latents.items()
                            if k in live}

    def summary(self) -> Dict[str, Any]:
        total = sum(self.stats[k] for k in
                    ("image_hit", "latent_hit", "full_miss"))
        out = dict(self.stats)
        out["total"] = total
        if total:
            out["image_hit_frac"] = self.stats["image_hit"] / total
            out["decode_frac"] = (self.stats["latent_hit"]
                                  + self.stats["full_miss"]) / total
        out["alpha"] = [round(n.cache.alpha, 3) for n in self.nodes]
        return out
