"""The ENGINE backend of the LatentBox object-store API: real jitted
decode behind the shared tier-walk read path.

Since the store refactor there is exactly one read path —
:class:`repro.store.walk.TierWalk` (pixel cache -> latent cache -> durable
latent -> recipe regeneration) — and two backends of the same ``LatentBox``
facade: this module supplies *real compute* (jitted VAE decode, measured
wall-clock feeding the tuner EWMAs), while ``core/cluster.py`` supplies
*latency events* for the same walk.  ``ServingEngine`` keeps its direct
``get``/``get_many`` surface for existing callers/tests, but every
classification, admission, promotion, and spillover decision now comes from
the shared walk, so the engine can no longer drift from the simulator.

Serving is no longer window-only: ``admit``/``dispatch`` expose the open
microbatch directly, so the event-loop serving runtime
(``repro.serve.runtime``) can feed the ``DecodeBatcher`` *continuously* —
closing a batch when a size bucket fills or a queued deadline forces it —
while ``serve_window`` remains as the fixed-group path (admit-all then
dispatch) that the drain-mode conformance guarantee is defined against.
``serve_stream`` replays a timestamped open-loop request stream through
that runtime.

Misses do not decode one-by-one: they accumulate in a ``DecodeBatcher``
queue where duplicate in-flight object ids coalesce into a single decode
(single-flight), then flush as batches padded up to a small set of
bucketed batch sizes (default 1/2/4/8) so ``jax.jit`` compiles once per
bucket instead of once per arrival pattern.  Per-image wall-clock
(batch time / real images in the batch) feeds the marginal-hit tuner's
EWMAs, closing the paper's feedback loop on real measurements.  Decode is
deterministic per image, so bucketed batching (and its padding) returns
bit-identical pixels to a batch-1 decode of the same latent.
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.compression.latentcodec import compress_latent, decompress_latent
from repro.core.dual_cache import IMAGE_HIT, LATENT_HIT
from repro.core.latent_store import LatentStore
from repro.core.regen_tier import Recipe, RegenTierStore, synthesize_image
from repro.core.router import parse_node_index
from repro.core.tuner import MarginalHitTuner, TunerConfig
from repro.store.api import StoreConfig
from repro.store.tiers import DurableTier, RecipeTier
from repro.store.walk import TierWalk
from repro.vae.model import VAE


@dataclasses.dataclass
class EngineConfig:
    n_nodes: int = 2
    cache_bytes_per_node: float = 64e6
    alpha0: float = 0.5
    tau: float = 0.1
    #: Paper parameter ``h``: latent hits before promotion to the pixel
    #: tier; doubles as the spillover queue-depth bound (the deprecated
    #: ``theta`` alias encoded the same value).
    promote_threshold: int = 4
    decode_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    #: 'uint8' serves displayable bytes straight off the fused decode
    #: epilogue (1/4 the transfer + pixel-cache charge); 'float32' keeps
    #: the legacy [-1, 1] float pixels.
    pixel_format: str = "uint8"
    #: Decoder weight storage precision for the uint8 fast path
    #: ('float32' | 'bfloat16' | 'int8'), applied behind the ±1-LSB
    #: open-time gate — see :class:`repro.store.api.StoreConfig`.
    weight_dtype: str = "float32"
    #: Persistent Pallas kernel autotuning (tune-on-first-miss; cache
    #: under ``data_dir``) — see :class:`repro.store.api.StoreConfig`.
    autotune: bool = False
    adaptive: bool = True               # run the marginal-hit tuner
    tuner: TunerConfig = dataclasses.field(
        default_factory=lambda: TunerConfig(window=500, step=0.02))
    #: Injectable wall clock (seconds): every engine-side ``now_s`` —
    #: notably the store-latency warmth draws — routes through it, so
    #: tests can pin or advance time deterministically.  ``None`` =
    #: ``time.time``.
    clock: Optional[Any] = None
    #: Deprecated alias of ``promote_threshold`` — passing it is an error.
    theta: dataclasses.InitVar[Optional[int]] = None

    def __post_init__(self, theta: Optional[int]) -> None:
        if theta is not None:
            raise TypeError(
                "EngineConfig.theta was merged into promote_threshold "
                "(both encode the paper's h); pass promote_threshold "
                "instead")

    def store_config(self, image_bytes: float,
                     latent_bytes: float) -> StoreConfig:
        """The cache/routing half of this config, for the shared walk."""
        return StoreConfig(
            n_nodes=self.n_nodes,
            cache_bytes_per_node=self.cache_bytes_per_node,
            alpha0=self.alpha0, tau=self.tau,
            promote_threshold=self.promote_threshold,
            image_bytes=image_bytes, latent_bytes=latent_bytes,
            adaptive=self.adaptive, tuner=self.tuner,
            decode_buckets=self.decode_buckets,
            pixel_format=self.pixel_format,
            weight_dtype=self.weight_dtype, autotune=self.autotune,
            clock=self.clock)


class _Node:
    """Engine-side view of one walk node: payload dicts + decode queue
    depth around the walk's cache/tuner."""

    def __init__(self, idx: int, tier) -> None:
        self.idx = idx
        self.tier = tier
        self.cache = tier.cache
        self.tuner: Optional[MarginalHitTuner] = tier.tuner
        self.images: Dict[int, np.ndarray] = {}     # decoded-image payloads
        self.latents: Dict[int, bytes] = {}         # compressed payloads
        self.queue_depth = 0

    def drop_payloads(self, oid: int) -> None:
        self.images.pop(oid, None)
        self.latents.pop(oid, None)


# legacy alias: the parser moved to core.router (the sharded cluster's
# global namespace relies on it too)
_node_index = parse_node_index


class DecodeBatcher:
    """Microbatching decode scheduler over one jitted VAE decode.

    Pending misses queue up via :meth:`submit`; duplicate in-flight object
    ids coalesce into one decode (single-flight).  :meth:`flush` drains the
    queue in FIFO order as batches, each padded up to the smallest
    configured bucket that fits so the jitted decode sees only
    ``len(buckets)`` distinct batch shapes.  Padding repeats the last real
    latent — the decode is per-image independent and deterministic, so
    padded slots never perturb the real outputs.

    The regeneration fast path (PR 4) layers three optimizations on top:

    * ``pixel_format='uint8'`` routes through the donated
      :meth:`VAE.decode_u8` — one compiled graph from normalized latent to
      displayable uint8 bytes (1/4 the device->host transfer and pixel
      cache charge of float32);
    * host DEFLATE decompression is *memoized per oid* (bounded LRU keyed
      on the exact blob), so repeat decodes of a hot object — and every
      coalesced duplicate — never pay the codec twice;
    * ``pipeline=True`` overlaps codec and compute: each chunk's decode
      dispatches asynchronously, the next chunk's latents decompress while
      it runs on device, and the result is only awaited when the following
      dispatch is in flight (no ``block_until_ready`` between chunks).
    """

    def __init__(self, vae: VAE, buckets: Sequence[int] = (1, 2, 4, 8),
                 pixel_format: str = "uint8", pipeline: bool = True,
                 memo_entries: int = 256):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive: {buckets!r}")
        if pixel_format not in ("uint8", "float32"):
            raise ValueError(f"pixel_format must be uint8|float32: "
                             f"{pixel_format!r}")
        self.vae = vae
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_batch = self.buckets[-1]
        self.pixel_format = pixel_format
        self.pipeline = bool(pipeline)
        self.memo_entries = int(memo_entries)
        # oid -> (compressed blob, exec node) in arrival order; the blob
        # decompresses lazily at flush (overlapped with the device decode)
        self._pending: Dict[int, Tuple[bytes, Any]] = {}
        # oid -> (blob, decompressed z): reused only when the blob matches
        self._zmemo: "OrderedDict[int, Tuple[bytes, np.ndarray]]" = \
            OrderedDict()
        self._warm: set = set()       # buckets whose decode shape is compiled
        # (bucket, latent shape) pairs this batcher has decoded, in first-
        # seen order — the kernel autotuner's tune-on-first-miss feed
        self._shape_log: List[Tuple[int, Tuple[int, ...]]] = []
        self._shapes_seen: set = set()
        self.stats = {"decodes": 0, "batches": 0, "coalesced": 0,
                      "padded_slots": 0, "decompressions": 0, "memo_hits": 0}
        self.last_per_image_ms: Dict[int, float] = {}
        #: Cumulative decode wall occupancy (ms) — the engine-side analog
        #: of ``GpuQueue.busy_ms``, window deltas feed the autoscaler.
        self.busy_ms = 0.0

    def __len__(self) -> int:
        return len(self._pending)

    def clear(self) -> None:
        """Drop everything pending (a window aborted mid-admission)."""
        self._pending.clear()

    def forget(self, oid: int) -> None:
        """Invalidate the decompression memo for ``oid`` (its durable blob
        was deleted or rewritten)."""
        self._zmemo.pop(oid, None)

    def submit(self, oid: int, blob: bytes, node: Any) -> bool:
        """Queue a decode for ``oid``; returns True if newly enqueued,
        False if it coalesced with an in-flight decode of the same oid."""
        if oid in self._pending:
            self.stats["coalesced"] += 1
            return False
        self._pending[oid] = (blob, node)
        return True

    def bucket_for(self, n: int) -> int:
        """Smallest configured bucket >= n (n itself beyond the largest)."""
        for b in self.buckets:
            if b >= n:
                return b
        return n

    # -- decode plumbing ------------------------------------------------------

    def _decode_fn(self, zb):
        if self.pixel_format == "uint8":
            return self.vae.decode_u8(zb)
        return self.vae.decode(zb)

    def decode_single(self, z: np.ndarray) -> np.ndarray:
        """One-off decode of a single latent in the configured pixel
        format (prewarm / promotion paths outside the batched window)."""
        return np.asarray(self._decode_fn(jnp.asarray(z, jnp.float32)[None]))[0]

    def prewarm(self, latent_hwc: Tuple[int, int, int]) -> None:
        """Compile every bucket's decode shape up front so no serving
        window ever pays jit time (first-flush warmup otherwise compiles
        lazily, bucket by bucket).  With a tuning cache active, the trace
        consults it — so prewarming compiles the *tuned* kernel shapes."""
        for b in self.buckets:
            self._note_shape(b, latent_hwc)
            if b not in self._warm:
                z = jnp.zeros((b,) + tuple(latent_hwc), jnp.float32)
                np.asarray(self._decode_fn(z))
                self._warm.add(b)

    def _note_shape(self, bucket: int, latent_hwc) -> None:
        key = (int(bucket), tuple(int(v) for v in latent_hwc))
        if key not in self._shapes_seen:
            self._shapes_seen.add(key)
            self._shape_log.append(key)

    def drain_shapes(self) -> List[Tuple[int, Tuple[int, ...]]]:
        """(bucket, latent shape) pairs first seen since the last drain —
        the engine forwards them to the kernel autotuner."""
        out, self._shape_log = self._shape_log, []
        return out

    def rewarm(self) -> None:
        """Drop compiled decodes so the next warmup re-traces the kernel
        dispatch (picking up freshly tuned block shapes); called by the
        engine after a tuning step so recompiles land in warmup, never in
        a timed serving region."""
        self._warm.clear()
        refresh = getattr(self.vae, "refresh_kernels", None)
        if refresh is not None:
            refresh()

    def _latent_of(self, oid: int, blob: bytes) -> np.ndarray:
        """Memoized host decompression (fixed decode dtype: determinism
        holds per (latent, stack) pair)."""
        hit = self._zmemo.get(oid)
        if hit is not None and hit[0] == blob:
            self._zmemo.move_to_end(oid)
            self.stats["memo_hits"] += 1
            return hit[1]
        self.stats["decompressions"] += 1
        z = np.asarray(decompress_latent(blob), np.float32)
        if self.memo_entries > 0:
            self._zmemo[oid] = (blob, z)
            self._zmemo.move_to_end(oid)
            while len(self._zmemo) > self.memo_entries:
                self._zmemo.popitem(last=False)
        return z

    def _assemble(self, chunk):
        """Host half of one chunk: decompress (memoized), pad to the
        bucket, stack, and make sure the bucket's shape is compiled."""
        n_real = len(chunk)
        bucket = self.bucket_for(n_real)
        zs = [self._latent_of(oid, blob) for oid, (blob, _) in chunk]
        zs.extend([zs[-1]] * (bucket - n_real))       # pad with the last real z
        zb = jnp.stack(zs)
        self._note_shape(bucket, zb.shape[1:])
        if bucket not in self._warm:
            # compile this bucket's shape outside the timed region so jit
            # compile time never poisons the tuner's decode EWMA.  Warm on
            # a THROWAWAY zeros buffer: the u8 decode donates its input,
            # so warming on zb itself would delete the buffer the real
            # decode still needs (CPU ignores donation, accelerators
            # do not)
            np.asarray(self._decode_fn(jnp.zeros(zb.shape, zb.dtype)))
            self._warm.add(bucket)
        return zb, bucket, n_real

    def _account(self, chunk, imgs, per_image_ms, bucket, n_real):
        self.stats["batches"] += 1
        self.stats["decodes"] += n_real
        self.stats["padded_slots"] += bucket - n_real
        self.busy_ms += per_image_ms * n_real
        out = {}
        for i, (oid, (_, node)) in enumerate(chunk):
            if node.tuner is not None:
                node.tuner.observe_decode_ms(per_image_ms)
            self.last_per_image_ms[oid] = per_image_ms
            out[oid] = imgs[i]
        return out

    def flush(self) -> Dict[int, np.ndarray]:
        """Decode everything pending; returns oid -> image and feeds each
        exec node's tuner the per-image wall clock of its batch.

        With ``pipeline=True`` chunk k+1's host decompression overlaps
        chunk k's in-flight device decode; the await of chunk k happens
        only after chunk k+1 has dispatched."""
        results: Dict[int, np.ndarray] = {}
        items = list(self._pending.items())
        self._pending.clear()
        self.last_per_image_ms = {}
        chunks = [items[s:s + self.max_batch]
                  for s in range(0, len(items), self.max_batch)]
        if not self.pipeline:
            for chunk in chunks:
                zb, bucket, n_real = self._assemble(chunk)
                t0 = time.perf_counter()
                imgs = np.asarray(self._decode_fn(zb))
                ms = (time.perf_counter() - t0) * 1e3
                results.update(self._account(chunk, imgs, ms / n_real,
                                             bucket, n_real))
            return results

        inflight = None           # (chunk, future, start, bucket, n_real)
        prev_done = 0.0
        for chunk in chunks:
            zb, bucket, n_real = self._assemble(chunk)
            t0 = time.perf_counter()
            fut = self._decode_fn(zb)                 # async dispatch
            if inflight is not None:
                prev_done = self._collect(results, *inflight)
            # the device runs chunks serially: this chunk only starts once
            # the previous one finished, so its timed span begins there
            inflight = (chunk, fut, max(t0, prev_done), bucket, n_real)
        if inflight is not None:
            self._collect(results, *inflight)
        return results

    def _collect(self, results, chunk, fut, start, bucket, n_real) -> float:
        imgs = np.asarray(fut)                        # blocks until done
        done = time.perf_counter()
        per_image_ms = (done - start) * 1e3 / n_real
        results.update(self._account(chunk, imgs, per_image_ms, bucket,
                                     n_real))
        return done


@dataclasses.dataclass
class _Ticket:
    """One request's routing decision, held across the batched decode."""
    oid: int
    outcome: str
    owner: _Node
    exec_node: Optional[_Node] = None
    img: Optional[np.ndarray] = None          # set on image hit
    write_image: bool = False                 # promote/pin decision at lookup
    spilled: bool = False
    fetch_ms: float = 0.0                     # measured durable-fetch wall
    regen_ms: float = 0.0                     # measured regeneration wall
    decode_ms: float = 0.0                    # per-image share of its batch


class ServingEngine:
    """Single-process stand-in for the Ray fleet: N logical nodes share one
    device, but the cache/routing/tuning logic is the production code —
    and, since the store refactor, the exact same ``TierWalk`` the
    simulator backend classifies with."""

    def __init__(self, vae: VAE, store: LatentStore,
                 cfg=None, image_bytes: float = 16e3,
                 latent_bytes: float = 13e3,
                 recipes: Optional[RegenTierStore] = None):
        """``cfg`` is either a :class:`StoreConfig` (the facade path — its
        ``image_bytes``/``latent_bytes`` fields win) or a legacy
        :class:`EngineConfig` combined with the explicit size arguments."""
        self.vae = vae
        self.store = store
        if isinstance(cfg, StoreConfig):
            self.cfg = cfg
        else:
            self.cfg = (cfg or EngineConfig()).store_config(
                image_bytes, latent_bytes)
        self.recipes = recipes
        self.walk = TierWalk(
            self.cfg,
            durable=DurableTier(store),
            recipes=RecipeTier(recipes) if recipes is not None else None)
        self.nodes = [_Node(i, t) for i, t in enumerate(self.walk.caches)]
        for node in self.nodes:
            # capacity evictions drop the decoded/compressed payload too
            node.tier.evict_cb(node.drop_payloads)
        self.router = self.walk.router
        self.batcher = DecodeBatcher(vae, self.cfg.decode_buckets,
                                     pixel_format=self.cfg.pixel_format)
        self.stats = self.walk.counts           # shared hit/spill accounting
        self._inflight: List[_Ticket] = []      # open microbatch (admit/dispatch)
        # -- quantized decoder (gated) + persistent kernel autotuner ---------
        self.gate_lsb: Optional[Dict[int, int]] = None
        if self.cfg.weight_dtype != "float32":
            if self.cfg.pixel_format != "uint8":
                raise ValueError(
                    "weight_dtype quantization serves the uint8 fast path "
                    "only; the float32 pixel format stays on f32 weights")
            from repro.vae.quantize import check_u8_gate
            vae.set_weight_dtype(self.cfg.weight_dtype)
            # the ±1-LSB open-time gate: quantized vs f32-oracle uint8
            # pixels on probe latents, every decode bucket — raises
            # QuantizationGateError (config rejected) on breach
            self.gate_lsb = check_u8_gate(
                vae, self.cfg.decode_buckets,
                (8, 8, vae.cfg.latent_channels))
        # -- elastic autoscaling (off by default: no controller at all) ------
        # the engine's decode fleet is one shared device, so the GPU knob
        # moves a VIRTUAL fleet width (provisioned-cost accounting + the
        # utilization denominator); the cache knob is fully real via the
        # walk's capacity handoff
        self.gpus_per_node = int(getattr(self.cfg, "gpus_per_node", 1))
        self._opened_s = self.cfg.now_s()
        self._gpu_ms = 0.0
        self._cache_byte_ms = 0.0
        self._acct_mark_s = self._opened_s
        self._cache_bytes_per_node = float(self.cfg.cache_bytes_per_node)
        self.autoscaler = None
        if getattr(self.cfg, "autoscale", False):
            from repro.core.autoscale import (AutoscaleConfig,
                                              AutoscaleController, PlantState)
            from repro.core.cost_model import params_for_store
            acfg = self.cfg.autoscale_cfg or dataclasses.replace(
                AutoscaleConfig(), params=params_for_store(self.cfg))
            self.autoscaler = AutoscaleController(
                PlantState(self.gpus_per_node, len(self.walk.caches),
                           self._cache_bytes_per_node), acfg)
            self._as_mark = {"reqs": 0, "now_s": self._opened_s,
                             "busy": 0.0, "image_hits": 0}
        self.autotuner = None
        self.tuning_cache = None
        if self.cfg.autotune:
            from repro.kernels import autotune as _at
            path = (os.path.join(self.cfg.data_dir, _at.CACHE_FILENAME)
                    if self.cfg.data_dir else None)
            self.tuning_cache = _at.TuningCache.load(path)
            _at.set_active_cache(self.tuning_cache)
            self.autotuner = _at.KernelAutotuner(
                self.tuning_cache, vae.cfg,
                weight_dtype=self.cfg.weight_dtype)

    def prewarm_decode(self, latent_hwc: Tuple[int, int, int]) -> None:
        """Compile every decode bucket for the given latent shape up
        front, so no serving batch ever pays jit time."""
        self.batcher.prewarm(latent_hwc)

    # -- writes ---------------------------------------------------------------

    def put(self, oid: int, image: Optional[np.ndarray] = None,
            latent: Optional[np.ndarray] = None,
            recipe: Optional[Recipe] = None) -> int:
        """Durable write: encode (if given pixels) -> compress -> latent
        store; the recipe (if any) becomes the coldest durability class.
        Overwriting an existing object purges its cached copies (pixels,
        latents, memo) so no tier can keep serving the old content.
        Returns the durable byte count."""
        if oid in self.store:           # overwrite: drop every cached copy
            for tier in self.walk.caches:
                tier.evict(oid)
            for node in self.nodes:
                node.drop_payloads(oid)
        if latent is None:
            if image is None:
                if recipe is None:
                    raise ValueError("put needs an image, latent, or recipe")
                image = synthesize_image(recipe)
            img4 = np.asarray(image)
            if img4.dtype == np.uint8:      # display bytes -> [-1, 1] floats
                img4 = img4.astype(np.float32) / 127.5 - 1.0
            img4 = img4.astype(np.float32)
            if img4.ndim == 3:
                img4 = img4[None]
            latent = np.asarray(
                self.vae.encode_mean(jnp.asarray(img4)))[0].astype(np.float16)
        blob = compress_latent(np.asarray(latent))
        self.store.put(oid, blob)
        self.batcher.forget(oid)            # durable blob rewritten
        if recipe is not None and self.recipes is not None:
            self.recipes.put(oid, float(len(blob)), recipe=recipe)
        return len(blob)

    def delete(self, oid: int) -> bool:
        """Remove from every tier, payload dicts included."""
        found = self.walk.delete(oid)
        for node in self.nodes:
            node.drop_payloads(oid)
        self.batcher.forget(oid)
        return found

    def demote(self, oid: int, rung=None) -> bool:
        """Demote down the rate-distortion ladder.  Default (None /
        "recipe"): drop the durable latent, keep the recipe (recipe-only
        class) — cached copies are purged so the next read exercises
        regeneration, and the eviction listeners drop the decoded
        payloads with them.  A lossy rung re-encodes the durable blob at
        that colder quality instead (deferred to compaction on a
        persistent box); cached latents/pixels are left to age out, and
        the batcher memo is keyed on blob bytes so a rewritten blob can
        never serve a stale decode."""
        return self.walk.demote(oid, rung)

    def promote(self, oid: int) -> bool:
        """Regenerate a demoted object's latent back into the durable tier
        without waiting for a read to pay the regen latency."""
        if self.recipes is None or not self.recipes.is_demoted(oid):
            return False
        self._regenerate(oid)
        return True

    def prewarm(self, oid: int) -> bool:
        """Decode now and pin pixels at the hash owner (no stats impact)."""
        blob = self.store.get(oid)
        if blob is None:
            return False
        z = np.asarray(decompress_latent(blob), np.float32)
        img = self.batcher.decode_single(z)
        owner = self.nodes[self.walk._idx[self.walk.router.ring.owner(oid)]]
        owner.cache.insert_image(oid, nbytes=img.nbytes)
        owner.images[oid] = img
        return True

    def _regenerate(self, oid: int) -> bytes:
        """Recipe -> pixels -> latent -> durable re-admission (bit-exact on
        the same stack, which is what makes recipes a durability class)."""
        recipe = self.recipes.recipe_of(oid) if self.recipes else None
        if recipe is None:
            raise KeyError(f"object {oid} has no recipe to regenerate from")
        z = np.asarray(self.vae.encode_mean(
            jnp.asarray(synthesize_image(recipe))))[0].astype(np.float16)
        blob = compress_latent(z)
        self.store.put(oid, blob)
        self.batcher.forget(oid)            # durable blob rewritten
        self.recipes.readmit(oid, float(len(blob)), now_mo=0.0)
        return blob

    # -- request admission ---------------------------------------------------

    def _lookup(self, oid: int) -> _Ticket:
        """Route one request up to (but excluding) the decode: the shared
        tier-walk classifies and admits; this method materializes payloads
        (durable fetch / regeneration) and enqueues the decode."""
        ticket = self.walk.lookup(
            oid, depth_of=lambda i: self.nodes[i].queue_depth)
        owner = self.nodes[ticket.owner]
        exec_node = self.nodes[ticket.exec_node]

        if ticket.hit_class == IMAGE_HIT:
            img = owner.images.get(oid)
            if img is not None:
                return _Ticket(oid, IMAGE_HIT, owner, img=img)
            # admitted to the image tier, but the pixel payload is still
            # in-flight in the open microbatch: join the pending decode
            # (single-flight) and write back on dispatch.
            blob = owner.latents.get(oid) or self.store.get(oid)
            if blob is None:
                raise KeyError(f"object {oid} not in store")
            if self.batcher.submit(oid, blob, owner):
                owner.queue_depth += 1
            return _Ticket(oid, IMAGE_HIT, owner, exec_node=owner,
                           write_image=True)

        fetch_ms = regen_ms = 0.0
        if ticket.hit_class == LATENT_HIT:
            blob = owner.latents.get(oid) or self.store.get(oid)
            if blob is None:
                raise KeyError(f"object {oid} lost its latent payload")
        elif ticket.needs_regen:
            t0 = time.perf_counter()
            blob = self._regenerate(oid)
            regen_ms = (time.perf_counter() - t0) * 1e3
            # regen replaces the durable fetch on the miss path, so it
            # feeds the fetch EWMA (same signal class on both backends)
            if owner.tuner is not None:
                owner.tuner.observe_fetch_ms(regen_ms)
            if self.walk.admit_latent(ticket.owner, oid):
                owner.latents[oid] = blob
        else:                                         # durable fetch
            t0 = time.perf_counter()
            blob = self.store.get(oid)
            if blob is None:
                raise KeyError(f"object {oid} has no durable payload "
                               "(size-only registration?)")
            # store warmth keys on the INJECTABLE clock (cfg.now_s), not
            # bare wall time, so latency draws are deterministic under test
            fetch_ms = ((time.perf_counter() - t0) * 1e3
                        + self.store.fetch_ms(oid, self.cfg.now_s()))
            if owner.tuner is not None:
                owner.tuner.observe_fetch_ms(fetch_ms)
            if self.walk.admit_latent(ticket.owner, oid):
                owner.latents[oid] = blob

        if self.batcher.submit(oid, blob, exec_node):
            exec_node.queue_depth += 1          # one slot per unique decode
        return _Ticket(oid, ticket.hit_class, owner, exec_node=exec_node,
                       write_image=ticket.write_image, spilled=ticket.spilled,
                       fetch_ms=fetch_ms, regen_ms=regen_ms)

    # -- public API ----------------------------------------------------------

    def get(self, oid: int) -> Tuple[np.ndarray, str]:
        return self.get_many([oid])[0]

    def get_many(self, oids: Sequence[int]
                 ) -> List[Tuple[np.ndarray, str]]:
        """Serve one group of requests with one batched decode flush;
        returns ``(pixels, hit_class)`` pairs in request order."""
        return [(t.img, t.outcome) for t in self.serve_window(oids)]

    def admit(self, oid: int) -> _Ticket:
        """Admit one request into the currently *open* microbatch without
        flushing it: classify via the shared walk, materialize payloads
        (durable fetch / regeneration), and enqueue the decode.  This is
        the continuous feed path of the serving runtime — the scheduler
        decides when the batch closes (size bucket filled or deadline
        slack exhausted) and then calls :meth:`dispatch`.  The returned
        ticket is live: its ``img``/``decode_ms`` fill in at dispatch.
        """
        try:
            ticket = self._lookup(int(oid))
        except Exception:
            self._abort_open_batch()
            raise
        self._inflight.append(ticket)
        return ticket

    def dispatch(self) -> List[_Ticket]:
        """Close the open microbatch: flush the queued decodes, write
        decoded pixels back to their hash owners (cache pinning) in
        admission order, then run the bounded end-of-batch durable
        maintenance.  Returns the admitted tickets in admission order."""
        tickets, self._inflight = self._inflight, []
        decoded = self._flush()
        touched = {}
        for t in tickets:
            if t.img is not None:
                continue
            img = decoded[t.oid]
            t.decode_ms = self.batcher.last_per_image_ms.get(t.oid, 0.0)
            # cache pinning: decoded result written back to the OWNER node
            if t.write_image or t.owner.cache.contains(t.oid) == "image":
                t.owner.images[t.oid] = img
                # charge the pixel tier the stored array's real bytes
                # (uint8 on the fast path) — a size-only correction, so
                # the LRU order stays identical to the simulator's
                t.owner.cache.set_image_nbytes(t.oid, img.nbytes)
            touched[id(t.owner)] = t.owner
            t.img = img
        for node in touched.values():
            self._gc(node)
        self._durable_maintenance()
        return tickets

    def _abort_open_batch(self) -> None:
        """A group aborted mid-admission (e.g. unknown oid) must not leak
        queued decodes, queue-depth, or half-admitted tickets into the
        next group."""
        self.batcher.clear()
        for n in self.nodes:
            n.queue_depth = 0
        self._inflight = []

    def serve_window(self, oids: Sequence[int]) -> List[_Ticket]:
        """Serve one fixed group of requests with a single batched decode
        flush — ``admit`` every id in request order (cache state evolves
        exactly as with sequential ``get`` calls), then ``dispatch``.
        Tickets carry the measured per-request latency components for
        ``GetResult``.  The serving runtime's drain-mode conformance
        guarantee is defined against this path.
        """
        for oid in oids:
            self.admit(oid)
        return self.dispatch()

    def serve_stream(self, requests, runtime_cfg=None):
        """Replay an open-loop request stream through the event-loop
        serving runtime (simulated clock, per-tenant QoS, SLO-aware
        admission), feeding this engine's batcher continuously via
        :meth:`admit`/:meth:`dispatch`.  ``requests`` is a sequence of
        :class:`repro.serve.runtime.Request` or a ``SyntheticTrace``;
        returns a :class:`repro.serve.runtime.StreamReport`."""
        from repro.serve.runtime import RuntimeConfig, ServingRuntime
        if runtime_cfg is None:
            runtime_cfg = RuntimeConfig.from_store(self.cfg)
        return ServingRuntime.for_engine(self, runtime_cfg).run(requests)

    def _durable_maintenance(self) -> None:
        """End-of-batch durability work, threaded into the request loop:
        flush write-behind appends (acknowledging them), run at most one
        online-compaction step, and — with autotuning on — tune at most
        one missing kernel-shape key (tune-on-first-miss).  Bounded work
        per dispatched batch, so serving latency never absorbs a
        stop-the-world sweep; the first two are no-ops on the in-memory
        backend."""
        self.store.flush()
        self.store.maybe_compact()
        if self.autoscaler is not None:
            self._autoscale_step()
        if self.autotuner is not None:
            for bucket, hwc in self.batcher.drain_shapes():
                self.autotuner.note_bucket(bucket, hwc)
            if self.autotuner.step(1):
                # new winners: recompile in warmup, not in a timed region
                self.batcher.rewarm()

    # -- elastic autoscaling --------------------------------------------------
    def _account_provisioned(self) -> None:
        """Advance the provisioned GPU/cache time integrals to the
        (injectable) wall clock — held capacity, busy or idle."""
        now_s = self.cfg.now_s()
        dt_ms = (now_s - self._acct_mark_s) * 1e3
        if dt_ms <= 0.0:
            return
        self._gpu_ms += dt_ms * len(self.nodes) * self.gpus_per_node
        self._cache_byte_ms += (dt_ms * len(self.nodes)
                                * self._cache_bytes_per_node)
        self._acct_mark_s = now_s

    def _autoscale_step(self) -> None:
        """Engine-side control step, run inside the bounded end-of-batch
        maintenance slice.  Observations come from the engine's own
        signals: walk hit counts (arrival volume + decode fraction) and
        the batcher's measured decode occupancy.  The engine has no plant
        queue, so it scales on utilization alone (queue_p99 = 0)."""
        from repro.core.autoscale import WindowObs
        from repro.store.api import HIT_CLASSES
        mark = self._as_mark
        reqs = sum(self.walk.counts[k] for k in HIT_CLASSES)
        if reqs - mark["reqs"] < self.autoscaler.cfg.window:
            return
        now_s = self.cfg.now_s()
        span_ms = (now_s - mark["now_s"]) * 1e3
        n = reqs - mark["reqs"]
        hits = self.walk.counts[IMAGE_HIT] - mark["image_hits"]
        obs = WindowObs(
            requests=n, span_ms=span_ms,
            busy_ms=max(0.0, self.batcher.busy_ms - mark["busy"]),
            decode_frac=1.0 - hits / n if n else 1.0)
        self._as_mark = {"reqs": reqs, "now_s": now_s,
                         "busy": self.batcher.busy_ms,
                         "image_hits": self.walk.counts[IMAGE_HIT]}
        ev = self.autoscaler.step(obs)
        if ev is not None:
            self._apply_scale(ev.state)

    def _apply_scale(self, state) -> None:
        self._account_provisioned()
        self.gpus_per_node = int(state.gpus_per_node)
        if state.cache_bytes_per_node != self._cache_bytes_per_node:
            self._cache_bytes_per_node = float(state.cache_bytes_per_node)
            self.walk.set_cache_capacity(self._cache_bytes_per_node)

    def _flush(self) -> Dict[int, np.ndarray]:
        try:
            return self.batcher.flush()
        finally:
            for n in self.nodes:
                n.queue_depth = 0               # all in-flight decodes drained

    def _gc(self, node: _Node) -> None:
        if len(node.images) > 2 * len(node.cache.image_tier) + 32:
            live = set(iter(node.cache.image_tier))
            node.images = {k: v for k, v in node.images.items() if k in live}
        if len(node.latents) > 2 * len(node.cache.latent_tier) + 32:
            live = set(iter(node.cache.latent_tier))
            node.latents = {k: v for k, v in node.latents.items()
                            if k in live}

    def summary(self) -> Dict[str, Any]:
        out = self.walk.summary()
        # decode-fleet observability, mirroring the simulator backend's keys
        self._account_provisioned()
        out["gpu_seconds"] = self.batcher.busy_ms / 1e3
        out["decode_gpus"] = len(self.nodes) * self.gpus_per_node
        out["decode_util"] = (min(1.0, self.batcher.busy_ms / self._gpu_ms)
                              if self._gpu_ms > 0 else 0.0)
        out["provisioned_gpu_ms"] = self._gpu_ms
        out["provisioned_cache_byte_ms"] = self._cache_byte_ms
        if self.autoscaler is not None:
            out.update(self.autoscaler.summary())
        out["decode_batches"] = self.batcher.stats["batches"]
        out["decodes"] = self.batcher.stats["decodes"]
        out["coalesced_decodes"] = self.batcher.stats["coalesced"]
        out["decompressions"] = self.batcher.stats["decompressions"]
        out["decompress_memo_hits"] = self.batcher.stats["memo_hits"]
        out["pixel_format"] = self.cfg.pixel_format
        out["weight_dtype"] = self.cfg.weight_dtype
        if self.gate_lsb is not None:
            out["quantize_gate_lsb"] = dict(self.gate_lsb)
        if self.tuning_cache is not None:
            out["tuned_kernel_keys"] = len(self.tuning_cache)
            out["tuning_pending"] = self.autotuner.pending
        return out
