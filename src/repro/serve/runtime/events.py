"""Deterministic discrete-event substrate of the serving runtime.

The runtime schedules on a *simulated* clock: every latency-bearing step
(arrival, microbatch service, deadline-forced dispatch) is an event on one
heap, popped in ``(time, insertion order)`` order.  No wall-clock threads
exist anywhere in the loop, so a stream replay is exactly reproducible —
the property every runtime test (and the drain-mode conformance guarantee)
relies on.  The engine backend still performs *real* jitted decodes inside
a dispatch; only the queueing/SLO timeline is virtual.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, List, Optional, Tuple

#: SLO classes of the serving runtime (paper-adjacent: interactive image
#: traffic needs deadline treatment distinct from bulk/archival reads).
SLO_INTERACTIVE = "interactive"
SLO_BATCH = "batch"
SLO_CLASSES = (SLO_INTERACTIVE, SLO_BATCH)


@dataclasses.dataclass
class Request:
    """One timestamped request of an open-loop arrival process."""

    oid: int
    arrival_ms: float
    #: Arrival index in the stream (assigned by the runtime when < 0);
    #: report outcomes are keyed on it, so results stay in arrival order
    #: even when QoS reorders service.
    seq: int = -1
    tenant: int = 0
    slo: str = SLO_INTERACTIVE
    #: Absolute completion deadline; ``None`` = filled from the runtime
    #: config's per-class deadline at admission.
    deadline_ms: Optional[float] = None

    def __post_init__(self) -> None:
        if self.slo not in SLO_CLASSES:
            raise ValueError(f"slo must be one of {SLO_CLASSES}: {self.slo!r}")


class EventLoop:
    """Simulated-clock event loop: a heap of ``(time_ms, seq, callback)``.

    Events scheduled in the past clamp to ``now`` (they fire next, after
    already-queued events at the same instant), so callbacks can never
    move the clock backwards.  Ties break by insertion order — the loop is
    fully deterministic for a fixed schedule.
    """

    def __init__(self, start_ms: float = 0.0):
        self.now: float = float(start_ms)
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._counter = itertools.count()

    def __len__(self) -> int:
        return len(self._heap)

    def at(self, t_ms: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` to fire at simulated time ``t_ms``."""
        heapq.heappush(self._heap,
                       (max(float(t_ms), self.now), next(self._counter), fn))

    def after(self, dt_ms: float, fn: Callable[[], None]) -> None:
        self.at(self.now + max(0.0, float(dt_ms)), fn)

    def run(self) -> float:
        """Drain every event; returns the final simulated time (ms)."""
        while self._heap:
            t, _, fn = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            fn()
        return self.now
