"""Concurrent serving runtime: deterministic event-loop scheduler with
per-tenant QoS and SLO-aware admission control (see ``runtime.py``)."""

from repro.serve.runtime.admission import (ADMIT, AdmissionConfig,
                                           AdmissionController, DEFER,
                                           DEGRADE, POLICIES, SHED)
from repro.serve.runtime.events import (EventLoop, Request, SLO_BATCH,
                                        SLO_CLASSES, SLO_INTERACTIVE)
from repro.serve.runtime.qos import FairQueue, TokenBucket
from repro.serve.runtime.runtime import (EngineStreamService, FacadeService,
                                         RuntimeConfig, ServingRuntime,
                                         StreamReport, requests_from_trace)

__all__ = [
    "ADMIT", "SHED", "DEGRADE", "DEFER", "POLICIES",
    "AdmissionConfig", "AdmissionController",
    "EventLoop", "Request", "SLO_BATCH", "SLO_CLASSES", "SLO_INTERACTIVE",
    "FairQueue", "TokenBucket",
    "EngineStreamService", "FacadeService", "RuntimeConfig",
    "ServingRuntime", "StreamReport", "requests_from_trace",
]
