"""SLO-aware admission control: shed or degrade batch work under overload.

Following the web-serving argument of the related work (degrade quality
rather than miss deadlines), the controller never rejects interactive
traffic — it protects the interactive SLO by acting on the *batch* class
as soon as the predicted queueing delay would blow through the deadline
budget.  Three policies:

* ``shed``    — reject the batch request outright (client retries later);
* ``degrade`` — answer immediately from the pixel cache if the object is
  resident (a possibly stale but displayable image, no decode spent);
  shed when it is not;
* ``defer``   — park the request on a side queue that only drains when
  the plant is underloaded (decode deferred, deadline likely missed but
  the work is not lost).

Overload is a *prediction*, not a queue-length threshold: the runtime
feeds the controller its current busy horizon plus an EWMA of measured
per-request service time, and the controller compares the resulting wait
estimate against ``headroom x deadline`` for the arriving class.
"""

from __future__ import annotations

import dataclasses

from repro.serve.runtime.events import Request, SLO_INTERACTIVE

ADMIT, SHED, DEGRADE, DEFER = "admit", "shed", "degrade", "defer"
POLICIES = (SHED, DEGRADE, DEFER)


@dataclasses.dataclass
class AdmissionConfig:
    enabled: bool = True
    policy: str = DEGRADE               # one of POLICIES
    #: Fraction of a class's deadline budget the predicted wait may
    #: consume before its (batch-class) arrivals are shed/degraded.
    headroom: float = 0.7
    #: Never shed while fewer requests than this are queued — a full
    #: microbatch of backlog is normal operation, not overload.
    min_backlog: int = 8

    def __post_init__(self) -> None:
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}: "
                             f"{self.policy!r}")


class AdmissionController:
    """Stateless decision point; all load state arrives per call."""

    def __init__(self, cfg: AdmissionConfig, deadline_budget_of):
        """``deadline_budget_of(slo) -> ms``: the class's relative
        deadline (interactive/batch), from the runtime config."""
        self.cfg = cfg
        self._budget_of = deadline_budget_of
        self.counts = {SHED: 0, DEGRADE: 0, DEFER: 0}

    def decide(self, req: Request, queued: int,
               predicted_wait_ms: float) -> str:
        """Admit/shed/degrade/defer one arrival.

        ``predicted_wait_ms`` is the runtime's estimate of how long this
        request would sit in queue (busy horizon + backlog x EWMA service
        time).  Interactive requests always admit — the whole point is to
        confine degradation to the batch class.
        """
        if not self.cfg.enabled or req.slo == SLO_INTERACTIVE:
            return ADMIT
        if queued < self.cfg.min_backlog:
            return ADMIT
        budget = self.cfg.headroom * float(self._budget_of(req.slo))
        if predicted_wait_ms <= budget:
            return ADMIT
        self.counts[self.cfg.policy] += 1
        return self.cfg.policy
