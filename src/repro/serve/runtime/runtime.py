"""The concurrent serving runtime: event-loop scheduler over a LatentBox.

This is the layer the paper's production trace implies but the per-window
engine never had: timestamped requests from an *open-loop* arrival process
are admitted into a central per-tenant queue that feeds the decode plant
*continuously* — a microbatch closes when a size bucket fills OR when the
oldest queued deadline's slack forces dispatch, never on a fixed window
boundary.  On top of that loop sit per-tenant QoS (token buckets +
weighted-fair dequeue), SLO classes (``interactive`` vs ``batch`` with
distinct deadlines), and SLO-aware admission control that sheds or
degrades batch-class work under overload instead of letting every class's
tail collapse together.

Determinism: the scheduler runs on a simulated clock
(:class:`~repro.serve.runtime.events.EventLoop`) and a virtual service
model, so a stream replay is bit-reproducible on both backends; the
engine backend still produces *real* pixels inside each dispatch via the
continuous-feed ``admit``/``dispatch`` path of the ``DecodeBatcher``.

Conformance contract (locked in ``tests/test_serving_runtime.py``): with
``RuntimeConfig.conformance()`` — QoS off, admission off, drain-mode
schedule — the runtime dequeues FIFO in full ``max(buckets)`` groups,
which is exactly the legacy ``serve_window`` grouping, so every request
classifies identically to the per-window path and engine pixels are
bit-exact.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import RequestLog
from repro.serve.runtime.admission import (ADMIT, AdmissionConfig,
                                           AdmissionController, DEFER,
                                           DEGRADE, SHED)
from repro.serve.runtime.events import (EventLoop, Request, SLO_BATCH,
                                        SLO_INTERACTIVE)
from repro.serve.runtime.qos import FairQueue
from repro.store.api import FULL_MISS, IMAGE_HIT, LATENT_HIT, REGEN_MISS


@dataclasses.dataclass
class RuntimeConfig:
    """Knobs of the serving runtime (scheduler + QoS + admission + the
    virtual service model used for deterministic timeline accounting)."""

    #: Microbatch size buckets (mirrors ``StoreConfig.decode_buckets``);
    #: a batch closes as soon as ``max(buckets)`` requests are queued.
    buckets: Tuple[int, ...] = (1, 2, 4, 8)
    #: Weighted-fair per-tenant dequeue + token buckets.  Off = global FIFO.
    qos: bool = True
    #: Drain-mode schedule: ignore arrival pacing and deadlines, dequeue
    #: FIFO in full buckets — the legacy ``serve_window`` grouping.
    #: Implies ``qos=False`` and disables admission control.
    drain: bool = False
    # -- SLO classes ---------------------------------------------------------
    interactive_deadline_ms: float = 250.0
    batch_deadline_ms: float = 4000.0
    #: Safety margin subtracted from the deadline-forced dispatch time.
    slack_margin_ms: float = 4.0
    # -- per-tenant QoS ------------------------------------------------------
    tenant_weights: Dict[int, float] = dataclasses.field(default_factory=dict)
    #: Token-bucket contracted rate per tenant (requests/s); ``None``
    #: disables rate classification (every request conforms).
    tenant_rate_rps: Optional[float] = None
    tenant_burst: float = 8.0
    # -- admission control ---------------------------------------------------
    admission: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig)
    # -- virtual service model (ms, simulated clock) -------------------------
    net_ms: float = 10.0
    fetch_ms: float = 45.0              # durable fetch (overlapped per batch)
    regen_ms: float = 3905.0            # full generation pipeline on regen
    decode_fixed_ms: float = 12.0       # per-dispatch overhead
    decode_per_image_ms: float = 8.0    # per real decoded image
    #: EWMA smoothing of the measured per-request service time feeding the
    #: admission controller's wait predictions.
    service_ewma: float = 0.3
    #: Keep engine pixels per request in the report (tests only — O(n) RAM).
    keep_payloads: bool = False

    def deadline_budget_of(self, slo: str) -> float:
        return (self.interactive_deadline_ms if slo == SLO_INTERACTIVE
                else self.batch_deadline_ms)

    @property
    def max_bucket(self) -> int:
        return max(self.buckets)

    @classmethod
    def conformance(cls, **kw) -> "RuntimeConfig":
        """Drain-mode config of the conformance guarantee: FIFO full-bucket
        dispatch, no QoS, no admission — classification must equal the
        legacy ``serve_window`` path request-for-request."""
        kw.setdefault("drain", True)
        kw.setdefault("qos", False)
        kw.setdefault("admission", AdmissionConfig(enabled=False))
        return cls(**kw)

    @classmethod
    def from_store(cls, store_cfg, **kw) -> "RuntimeConfig":
        """Derive the service model from a ``StoreConfig``'s plant half so
        runtime timelines and the simulator's latency plant agree on
        nominal costs (decode splits 40/60 into per-dispatch overhead and
        per-image work, which makes an 8-batch ~2.4x cheaper per image
        than singles — the reason microbatching exists)."""
        kw.setdefault("buckets", tuple(store_cfg.decode_buckets))
        kw.setdefault("net_ms", store_cfg.net_ms)
        kw.setdefault("regen_ms", store_cfg.generation_ms)
        kw.setdefault("decode_fixed_ms", 0.4 * store_cfg.decode_ms)
        kw.setdefault("decode_per_image_ms", 0.6 * store_cfg.decode_ms)
        kw.setdefault("fetch_ms", store_cfg.store_latency.warm_ms)
        return cls(**kw)


class FacadeService:
    """Microbatch service over anything with ``get_many`` (a ``LatentBox``
    facade, a bare backend, or the sharded cluster)."""

    def __init__(self, target):
        self.target = target

    def serve(self, oids: Sequence[int]):
        return self.target.get_many(list(oids))

    def pixels_resident(self, oid: int) -> bool:
        probe = getattr(self.target, "pixels_resident", None)
        return bool(probe(oid)) if probe is not None else False


class EngineStreamService:
    """Continuous-feed service over a :class:`ServingEngine`: admissions
    enqueue straight into the real ``DecodeBatcher`` (single-flight
    coalescing included) and one ``dispatch`` flushes the microbatch the
    scheduler closed — no fixed window anywhere."""

    def __init__(self, engine):
        self.engine = engine

    def serve(self, oids: Sequence[int]):
        tickets = [self.engine.admit(oid) for oid in oids]
        self.engine.dispatch()
        return [_Served(t.outcome, t.owner.idx, t.img) for t in tickets]

    def pixels_resident(self, oid: int) -> bool:
        return self.engine.walk.pixels_resident(oid)


@dataclasses.dataclass
class _Served:
    hit_class: str
    node: int
    payload: Any = None


@dataclasses.dataclass
class StreamReport:
    """Outcome of one stream replay through the runtime."""

    log: RequestLog
    #: Per-request ``(hit_class, node)`` in ARRIVAL order (shed requests
    #: report ``("shed", -1)``, degraded ``("degraded", -1)``) — the
    #: drain-mode signature compared against the legacy window path.
    outcomes: List[Tuple[str, int]]
    counters: Dict[str, float]
    makespan_ms: float = 0.0
    #: seq -> decoded pixels (engine + ``keep_payloads`` only).
    payloads: Optional[Dict[int, Any]] = None

    def summary(self) -> Dict[str, Any]:
        out = dict(self.counters)
        out["makespan_ms"] = self.makespan_ms
        out.update(self.log.summarize())
        out.update(self.log.slo_summary())
        return out


def requests_from_trace(trace, tenant_by_model: Optional[bool] = None,
                        default_slo: str = SLO_INTERACTIVE,
                        limit: Optional[int] = None) -> List[Request]:
    """Turn a :class:`~repro.trace.synth.SyntheticTrace` into runtime
    requests.  ``tenant_by_model=None`` auto-detects: scenarios that carry
    per-object SLO classes (``multi_tenant``) use ``model_ids`` as tenant
    ids, everything else is single-tenant.  Per-object ``slo_class``
    (0=interactive, 1=batch) overrides ``default_slo``."""
    slo_arr = getattr(trace, "slo_class", None)
    if tenant_by_model is None:
        tenant_by_model = slo_arr is not None
    ids = trace.object_ids if limit is None else trace.object_ids[:limit]
    ts = trace.timestamps if limit is None else trace.timestamps[:limit]
    reqs = []
    for k, (oid, t) in enumerate(zip(ids, ts)):
        oid = int(oid)
        slo = default_slo
        if slo_arr is not None and slo_arr[oid]:
            slo = SLO_BATCH
        reqs.append(Request(
            oid=oid, arrival_ms=float(t) * 1e3, seq=k,
            tenant=int(trace.model_ids[oid]) if tenant_by_model else 0,
            slo=slo))
    return reqs


class ServingRuntime:
    """Deterministic event-loop scheduler feeding one decode plant."""

    def __init__(self, service, cfg: Optional[RuntimeConfig] = None):
        self.service = service
        self.cfg = cfg or RuntimeConfig()

    @classmethod
    def for_engine(cls, engine, cfg=None) -> "ServingRuntime":
        return cls(EngineStreamService(engine), cfg)

    @classmethod
    def for_target(cls, target, cfg=None) -> "ServingRuntime":
        return cls(FacadeService(target), cfg)

    # -- one replay ----------------------------------------------------------

    def run(self, requests) -> StreamReport:
        cfg = self.cfg
        if hasattr(requests, "object_ids"):       # a SyntheticTrace
            requests = requests_from_trace(requests)
        reqs = self._normalize(requests)

        self.loop = EventLoop()
        self.queue = FairQueue(
            qos=cfg.qos and not cfg.drain,
            weights=cfg.tenant_weights,
            rate_rps=None if cfg.drain else cfg.tenant_rate_rps,
            burst=cfg.tenant_burst)
        adm_cfg = cfg.admission if not cfg.drain \
            else dataclasses.replace(cfg.admission, enabled=False)
        self.admission = AdmissionController(adm_cfg, cfg.deadline_budget_of)
        self.log = RequestLog()
        self.outcomes: List[Tuple[str, int]] = [("", -1)] * len(reqs)
        self.payloads: Optional[Dict[int, Any]] = \
            {} if cfg.keep_payloads else None
        self._deferred: List[Request] = []
        self._arrivals_left = len(reqs)
        self._serving = False
        self._busy_until = 0.0
        self._force_at: Optional[float] = None
        # initial per-request service estimate: a full decode bucket
        self._svc_ewma = (cfg.decode_fixed_ms / cfg.max_bucket
                          + cfg.decode_per_image_ms)
        self.counters: Dict[str, float] = {
            "served": 0, "shed": 0, "degraded": 0, "deferred": 0,
            "dispatches": 0, "forced_dispatches": 0, "full_dispatches": 0,
            "batched_requests": 0, "deadline_misses": 0,
            "qos": float(self.queue.qos),
        }

        for r in reqs:
            self.loop.at(r.arrival_ms, lambda r=r: self._on_arrival(r))
        makespan = self.loop.run()
        self.counters["over_rate_arrivals"] = self.queue.n_over_rate
        return StreamReport(log=self.log, outcomes=self.outcomes,
                            counters=self.counters, makespan_ms=makespan,
                            payloads=self.payloads)

    def _normalize(self, requests: Sequence[Request]) -> List[Request]:
        cfg = self.cfg
        out = []
        for k, r in enumerate(requests):
            seq = r.seq if r.seq >= 0 else k
            arrival = 0.0 if cfg.drain else r.arrival_ms
            deadline = math.inf if cfg.drain else (
                r.deadline_ms if r.deadline_ms is not None
                else arrival + cfg.deadline_budget_of(r.slo))
            out.append(dataclasses.replace(
                r, seq=seq, arrival_ms=arrival, deadline_ms=deadline))
        return out

    # -- event handlers ------------------------------------------------------

    def _on_arrival(self, req: Request) -> None:
        self._arrivals_left -= 1
        decision = self.admission.decide(
            req, queued=len(self.queue),
            predicted_wait_ms=self._predicted_wait())
        if decision == SHED:
            self._record_rejected(req, "shed")
        elif decision == DEGRADE:
            # pixel-cache-only answer: stale-but-displayable now, or shed
            if self.service.pixels_resident(req.oid):
                self._record_rejected(req, "degraded")
            else:
                self._record_rejected(req, "shed")
        elif decision == DEFER:
            self.counters["deferred"] += 1
            self._deferred.append(req)
        else:
            assert decision == ADMIT
            self.queue.push(req, self.loop.now)
        self._maybe_dispatch()

    def _on_free(self) -> None:
        self._serving = False
        self._maybe_dispatch()

    def _on_force(self) -> None:
        self._force_at = None
        self._maybe_dispatch()

    # -- scheduling ----------------------------------------------------------

    def _predicted_wait(self) -> float:
        """Queueing-delay estimate for a request arriving now: remaining
        busy horizon plus the backlog at the measured per-request rate."""
        busy = max(0.0, self._busy_until - self.loop.now) \
            if self._serving else 0.0
        return busy + len(self.queue) * self._svc_ewma

    def _est_service(self, n: int) -> float:
        """Worst-case service estimate for dispatching ``n`` queued
        requests now (durable fetch + a padded decode) — used for the
        deadline-forced dispatch time, so conservative is safe: firing
        early shrinks the batch but never misses the deadline."""
        n = min(n, self.cfg.max_bucket)
        return (self.cfg.fetch_ms + self.cfg.decode_fixed_ms
                + self.cfg.decode_per_image_ms * n)

    def _maybe_dispatch(self) -> None:
        if self._serving:
            return
        if len(self.queue) == 0 and self._deferred:
            # the plant is idle and nothing admitted waits: drain deferred
            # batch work a bucketful at a time
            for r in self._deferred[:self.cfg.max_bucket]:
                self.queue.push(r, self.loop.now)
            del self._deferred[:self.cfg.max_bucket]
        qlen = len(self.queue)
        if qlen == 0:
            return
        if qlen >= self.cfg.max_bucket:           # a size bucket filled
            self._dispatch(self.cfg.max_bucket, forced=False)
            return
        if self.cfg.drain:
            if self._arrivals_left == 0:          # final partial bucket
                self._dispatch(qlen, forced=False)
            return
        t_force = (self.queue.earliest_deadline() - self._est_service(qlen)
                   - self.cfg.net_ms - self.cfg.slack_margin_ms)
        if self._arrivals_left == 0 or self.loop.now >= t_force:
            self._dispatch(qlen, forced=True)
            return
        if math.isfinite(t_force) and (self._force_at is None
                                       or t_force < self._force_at - 1e-9):
            self._force_at = t_force
            self.loop.at(t_force, self._on_force)

    def _dispatch(self, k: int, forced: bool) -> None:
        members = [self.queue.pop() for _ in range(k)]
        results = self.service.serve([m.oid for m in members])
        t0 = self.loop.now
        svc = self._service_ms(results)
        self._serving = True
        self._busy_until = t0 + svc
        self.counters["dispatches"] += 1
        self.counters["batched_requests"] += k
        if forced:
            self.counters["forced_dispatches"] += 1
        if k >= self.cfg.max_bucket:
            self.counters["full_dispatches"] += 1
        for m, r in zip(members, results):
            self._complete(m, r, t0, svc)
        a = self.cfg.service_ewma
        self._svc_ewma = (1 - a) * self._svc_ewma + a * (svc / k)
        self.loop.at(self._busy_until, self._on_free)

    # -- completion / accounting --------------------------------------------

    def _service_ms(self, results) -> float:
        """Virtual service time of one dispatched group: fetches overlap
        (one fetch latency covers the batch), regenerations serialize on
        the plant (the generation pipeline owns the GPU), and the decode
        pays a fixed dispatch cost plus a per-real-image cost."""
        cfg = self.cfg
        n_regen = sum(1 for r in results if r.hit_class == REGEN_MISS)
        n_dec = sum(1 for r in results
                    if r.hit_class in (LATENT_HIT, FULL_MISS))
        svc = 0.0
        if any(r.hit_class == FULL_MISS for r in results):
            svc += cfg.fetch_ms
        svc += n_regen * cfg.regen_ms
        if n_dec:
            svc += cfg.decode_fixed_ms + cfg.decode_per_image_ms * n_dec
        return svc

    def _complete(self, m: Request, r, t0: float, svc: float) -> None:
        cfg = self.cfg
        is_hit = r.hit_class == IMAGE_HIT
        done = t0 + (0.0 if is_hit else svc) + cfg.net_ms
        met = done <= m.deadline_ms
        self.counters["served"] += 1
        if not met:
            self.counters["deadline_misses"] += 1
        self.log.add(
            m.arrival_ms, done - m.arrival_ms, r.hit_class,
            fetch_ms=cfg.fetch_ms if r.hit_class == FULL_MISS else 0.0,
            decode_ms=0.0 if is_hit else (
                cfg.regen_ms if r.hit_class == REGEN_MISS
                else cfg.decode_per_image_ms),
            net_ms=cfg.net_ms, node=r.node,
            queue_delay_ms=t0 - m.arrival_ms, tenant=m.tenant, slo=m.slo,
            deadline_ms=m.deadline_ms, deadline_met=met)
        self.outcomes[m.seq] = (r.hit_class, r.node)
        if self.payloads is not None and r.payload is not None:
            self.payloads[m.seq] = r.payload

    def _record_rejected(self, req: Request, outcome: str) -> None:
        """A request admission refused: ``shed`` (no answer) or
        ``degraded`` (immediate stale pixels, no decode spent)."""
        cfg = self.cfg
        served_now = outcome == "degraded"
        latency = cfg.net_ms if served_now else 0.0
        done = self.loop.now + latency
        met = served_now and done <= req.deadline_ms
        self.counters[outcome] += 1
        if not met:
            self.counters["deadline_misses"] += 1
        self.log.add(req.arrival_ms, latency, outcome,
                     net_ms=cfg.net_ms if served_now else 0.0,
                     queue_delay_ms=0.0, tenant=req.tenant, slo=req.slo,
                     deadline_ms=req.deadline_ms, deadline_met=met)
        self.outcomes[req.seq] = (outcome, -1)
