"""Per-tenant QoS primitives: token buckets and weighted-fair queueing.

The central queue of the serving runtime is a :class:`FairQueue` — two
strict-priority bands (``interactive`` dispatches ahead of ``batch``,
the "queue-jump" half of SLO-aware scheduling) and, within a band,
start-time fair queuing (SFQ) across tenants so one tenant's burst cannot
starve another's steady trickle.  A per-tenant :class:`TokenBucket`
(simulated-clock, like everything in the runtime) classifies each arrival
as *conforming* or *over-rate*; over-rate requests are never dropped here
— they queue behind every conforming request of their band, so a tenant
flooding past its contracted rate only ever competes for leftover
capacity.  With ``qos=False`` the whole structure degrades to one global
FIFO, which is what the drain-mode conformance guarantee runs on.
"""

from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Tuple

from repro.serve.runtime.events import Request, SLO_BATCH, SLO_INTERACTIVE


class TokenBucket:
    """Classic token bucket on the simulated clock (ms timestamps)."""

    def __init__(self, rate_per_s: float, burst: float,
                 now_ms: float = 0.0):
        if rate_per_s <= 0 or burst <= 0:
            raise ValueError("token bucket needs positive rate and burst")
        self.rate_per_s = float(rate_per_s)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._last_ms = float(now_ms)

    def _refill(self, now_ms: float) -> None:
        dt_s = max(0.0, now_ms - self._last_ms) / 1e3
        self.tokens = min(self.burst, self.tokens + dt_s * self.rate_per_s)
        self._last_ms = max(self._last_ms, now_ms)

    def available(self, now_ms: float) -> float:
        self._refill(now_ms)
        return self.tokens

    def try_take(self, now_ms: float, n: float = 1.0) -> bool:
        """Consume ``n`` tokens if available; False marks the caller
        over-rate (the request still serves, at background priority)."""
        self._refill(now_ms)
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False


#: Dispatch bands in strict priority order: conforming interactive first,
#: then over-rate interactive, then batch (conforming before over-rate).
_BANDS = ((SLO_INTERACTIVE, True), (SLO_INTERACTIVE, False),
          (SLO_BATCH, True), (SLO_BATCH, False))


class FairQueue:
    """Two-band weighted-fair central queue of the serving runtime.

    SFQ bookkeeping: each pushed request gets a start tag
    ``S = max(V, tenant_finish)`` and finish tag ``F = S + 1/weight``;
    dequeue picks the band-first minimum-``F`` request and advances the
    virtual time ``V`` to its start tag.  Two backlogged tenants of equal
    weight therefore alternate 1:1 regardless of a 10:1 arrival-rate
    imbalance — the property ``tests/test_serving_runtime.py`` locks in.
    """

    def __init__(self, qos: bool = True,
                 weights: Optional[Dict[int, float]] = None,
                 rate_rps: Optional[float] = None,
                 burst: float = 8.0):
        self.qos = bool(qos)
        self.weights = dict(weights or {})
        self.rate_rps = rate_rps
        self.burst = float(burst)
        self._buckets: Dict[int, TokenBucket] = {}
        self._finish: Dict[int, float] = {}      # per-tenant SFQ finish tag
        self._vtime = 0.0
        self._fifo_seq = 0
        # (finish_tag, push_seq, request) per band
        self._q: Dict[Tuple[str, bool], List[Tuple[float, int, Request]]] = {
            band: [] for band in _BANDS}
        # lazy min-deadline tracking over everything queued
        self._deadlines: List[Tuple[float, int]] = []
        self._queued_seqs: set = set()
        self.n_over_rate = 0

    def __len__(self) -> int:
        return sum(len(q) for q in self._q.values())

    def n_queued(self, slo: Optional[str] = None) -> int:
        if slo is None:
            return len(self)
        return sum(len(q) for (band_slo, _), q in self._q.items()
                   if band_slo == slo)

    def _bucket(self, tenant: int, now_ms: float) -> Optional[TokenBucket]:
        if self.rate_rps is None:
            return None
        b = self._buckets.get(tenant)
        if b is None:
            b = self._buckets[tenant] = TokenBucket(
                self.rate_rps, self.burst, now_ms=now_ms)
        return b

    def push(self, req: Request, now_ms: float) -> bool:
        """Enqueue; returns whether the request was rate-conforming."""
        self._fifo_seq += 1
        bucket = self._bucket(req.tenant, now_ms)
        conforming = True if bucket is None else bucket.try_take(now_ms)
        if not conforming:
            self.n_over_rate += 1
        if self.qos:
            w = float(self.weights.get(req.tenant, 1.0))
            start = max(self._vtime, self._finish.get(req.tenant, 0.0))
            finish = start + 1.0 / max(w, 1e-9)
            self._finish[req.tenant] = finish
            band = (req.slo, conforming)
        else:                         # QoS off: one global FIFO
            finish = float(self._fifo_seq)
            band = _BANDS[0]
        heapq.heappush(self._q[band], (finish, self._fifo_seq, req))
        self._queued_seqs.add(req.seq)
        if req.deadline_ms is not None and math.isfinite(req.deadline_ms):
            heapq.heappush(self._deadlines, (req.deadline_ms, req.seq))
        return conforming

    def pop(self) -> Optional[Request]:
        for band in _BANDS:
            q = self._q[band]
            if q:
                finish, _, req = heapq.heappop(q)
                if self.qos:
                    # V advances to the dequeued request's start tag
                    w = float(self.weights.get(req.tenant, 1.0))
                    self._vtime = max(self._vtime, finish - 1.0 / max(w, 1e-9))
                self._queued_seqs.discard(req.seq)
                return req
        return None

    def earliest_deadline(self) -> float:
        """Smallest absolute deadline over everything still queued
        (``inf`` when nothing queued carries a deadline)."""
        while self._deadlines and \
                self._deadlines[0][1] not in self._queued_seqs:
            heapq.heappop(self._deadlines)       # already dispatched
        return self._deadlines[0][0] if self._deadlines else math.inf
