# The paper's primary contribution: latent-first storage with a dual-format
# adaptive cache, online marginal-hit tuning, and consistent-hash routing
# with spillover + cache pinning.
from repro.core.dual_cache import (DualFormatCache, LookupResult, SegmentedLRU,
                                   WindowStats, IMAGE_HIT, LATENT_HIT,
                                   FULL_MISS)
from repro.core.tuner import MarginalHitTuner, TunerConfig, TunerRecord
from repro.core.router import ConsistentHashRing, Router
from repro.core.latent_store import LatentStore, StoreLatencyModel
from repro.core.cluster import (ClusterConfig, ClusterSim, GpuQueue,
                                replay_cluster)
from repro.core.regen_tier import (Recipe, RegenPolicy, RegenTierStore,
                                   synthesize_image)
from repro.core.replay import ReplayConfig, ReplayResult, replay, sweep_static_alpha
from repro.core import cost_model, metrics, policies

__all__ = [
    "Recipe", "RegenPolicy", "RegenTierStore", "synthesize_image",
    "GpuQueue",
    "DualFormatCache", "LookupResult", "SegmentedLRU", "WindowStats",
    "IMAGE_HIT", "LATENT_HIT", "FULL_MISS",
    "MarginalHitTuner", "TunerConfig", "TunerRecord",
    "ConsistentHashRing", "Router",
    "LatentStore", "StoreLatencyModel",
    "ClusterConfig", "ClusterSim", "replay_cluster",
    "ReplayConfig", "ReplayResult", "replay", "sweep_static_alpha",
    "cost_model", "metrics", "policies",
]
