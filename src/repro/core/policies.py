"""Cache replacement policies used by the trace characterization (Fig. 4c)
and by the baseline configurations in the evaluation (§6.1).

All policies expose ``access(oid, size=1.0) -> bool`` (True on hit) so the
MRC benchmark can drive them uniformly.  Sizes default to 1.0 which makes
``capacity`` an object count; byte-based capacities work by passing sizes.
"""

from __future__ import annotations

import heapq
from collections import OrderedDict, deque
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.latent_store import DEFAULT_OBJECT_BYTES


class CachePolicy:
    name = "base"

    def access(self, oid: int, size: float = 1.0) -> bool:
        raise NotImplementedError

    def __contains__(self, oid: int) -> bool:
        raise NotImplementedError


class LRUCache(CachePolicy):
    """Plain byte-capacity LRU."""

    name = "lru"

    def __init__(self, capacity: float):
        self.capacity = float(capacity)
        self._entries: "OrderedDict[int, float]" = OrderedDict()
        self._bytes = 0.0

    def __contains__(self, oid: int) -> bool:
        return oid in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def resident_bytes(self) -> float:
        return self._bytes

    def access(self, oid: int, size: float = 1.0) -> bool:
        if oid in self._entries:
            self._entries.move_to_end(oid)
            return True
        self.insert(oid, size)
        return False

    def insert(self, oid: int, size: float = 1.0) -> None:
        if size > self.capacity:
            return
        if oid in self._entries:
            self._bytes -= self._entries.pop(oid)
        self._entries[oid] = size
        self._bytes += size
        while self._bytes > self.capacity:
            _, sz = self._entries.popitem(last=False)
            self._bytes -= sz

    def remove(self, oid: int) -> None:
        if oid in self._entries:
            self._bytes -= self._entries.pop(oid)


class S3FIFOCache(CachePolicy):
    """S3-FIFO (Yang et al., SOSP'23): small FIFO + main FIFO + ghost queue.

    Implemented with object-count segment sizing on the byte capacity:
    ``small`` gets ``small_ratio`` of the capacity, ``main`` the rest, and
    the ghost remembers as many ids as main holds objects (classic setting).
    """

    name = "s3fifo"

    def __init__(self, capacity: float, small_ratio: float = 0.1):
        self.small_cap = capacity * small_ratio
        self.main_cap = capacity * (1.0 - small_ratio)
        self._small: deque = deque()            # (oid, size)
        self._main: deque = deque()
        self._small_bytes = 0.0
        self._main_bytes = 0.0
        self._freq: Dict[int, int] = {}         # 2-bit counter, resident only
        self._where: Dict[int, str] = {}        # 'S' | 'M'
        self._ghost: "OrderedDict[int, None]" = OrderedDict()
        self._ghost_cap = 0                     # tracks len(main)

    def __contains__(self, oid: int) -> bool:
        return oid in self._where

    def __len__(self) -> int:
        return len(self._where)

    def access(self, oid: int, size: float = 1.0) -> bool:
        if oid in self._where:
            self._freq[oid] = min(3, self._freq.get(oid, 0) + 1)
            return True
        # miss
        if oid in self._ghost:
            del self._ghost[oid]
            self._insert_main(oid, size)
        else:
            self._insert_small(oid, size)
        return False

    def _insert_small(self, oid: int, size: float) -> None:
        if size > self.small_cap:
            return
        self._small.append((oid, size))
        self._small_bytes += size
        self._where[oid] = "S"
        self._freq[oid] = 0
        while self._small_bytes > self.small_cap:
            self._evict_small()

    def _insert_main(self, oid: int, size: float) -> None:
        if size > self.main_cap:
            return
        self._main.append((oid, size))
        self._main_bytes += size
        self._where[oid] = "M"
        self._freq[oid] = 0
        while self._main_bytes > self.main_cap:
            self._evict_main()

    def _evict_small(self) -> None:
        while self._small:
            oid, size = self._small.popleft()
            if self._where.get(oid) != "S":
                continue
            self._small_bytes -= size
            if self._freq.get(oid, 0) > 1:
                del self._where[oid]
                del self._freq[oid]
                self._insert_main(oid, size)
            else:
                del self._where[oid]
                del self._freq[oid]
                self._ghost[oid] = None
                self._trim_ghost()
            return

    def _evict_main(self) -> None:
        while self._main:
            oid, size = self._main.popleft()
            if self._where.get(oid) != "M":
                continue
            if self._freq.get(oid, 0) > 0:
                self._freq[oid] -= 1
                self._main.append((oid, size))     # second chance
                continue
            self._main_bytes -= size
            del self._where[oid]
            del self._freq[oid]
            return

    def _trim_ghost(self) -> None:
        ghost_cap = max(1, len(self._main))
        while len(self._ghost) > ghost_cap:
            self._ghost.popitem(last=False)


class BeladyCache(CachePolicy):
    """Offline-optimal (Belady/MIN).  Requires the full future: feed the
    request sequence to :meth:`prepare` first, then replay via ``access``
    in the same order."""

    name = "belady"
    _INF = np.iinfo(np.int64).max

    def __init__(self, capacity: float):
        self.capacity = float(capacity)
        self._next_use: Optional[np.ndarray] = None
        self._clock = 0
        self._resident: Dict[int, float] = {}
        self._bytes = 0.0
        self._heap: List = []                    # (-next_use, oid)
        self._cur_next: Dict[int, int] = {}

    def prepare(self, object_ids: Sequence[int]) -> None:
        ids = np.asarray(object_ids, dtype=np.int64)
        n = len(ids)
        next_use = np.full(n, self._INF, dtype=np.int64)
        last_seen: Dict[int, int] = {}
        for i in range(n - 1, -1, -1):
            oid = int(ids[i])
            next_use[i] = last_seen.get(oid, self._INF)
            last_seen[oid] = i
        self._next_use = next_use
        self._clock = 0

    def __contains__(self, oid: int) -> bool:
        return oid in self._resident

    def access(self, oid: int, size: float = 1.0) -> bool:
        if self._next_use is None:
            raise RuntimeError("call prepare() with the full trace first")
        nxt = int(self._next_use[self._clock])
        self._clock += 1
        hit = oid in self._resident
        if hit:
            self._cur_next[oid] = nxt
            heapq.heappush(self._heap, (-nxt, oid))
            return True
        if size > self.capacity:
            return False
        if nxt == self._INF:
            return False                          # never used again: bypass
        self._resident[oid] = size
        self._bytes += size
        self._cur_next[oid] = nxt
        heapq.heappush(self._heap, (-nxt, oid))
        while self._bytes > self.capacity:
            self._evict_farthest()
        return False

    def _evict_farthest(self) -> None:
        while self._heap:
            neg_nxt, oid = heapq.heappop(self._heap)
            if oid in self._resident and self._cur_next.get(oid) == -neg_nxt:
                self._bytes -= self._resident.pop(oid)
                del self._cur_next[oid]
                return
        raise RuntimeError("belady heap exhausted while over capacity")


class MixedFormatLRU(CachePolicy):
    """The rejected §4.2 strawman: one LRU order over BOTH formats.

    Objects enter as latents; after ``h`` hits the entry is re-inserted at
    image size.  The composition of formats at any capacity cut-off is
    uncontrolled — kept as an ablation baseline (benchmarks/bench_cache_sweep).
    """

    name = "mixed_lru"

    def __init__(self, capacity: float, image_size: float = 1.4e6,
                 latent_size: float = DEFAULT_OBJECT_BYTES, promote_threshold: int = 8):
        self.lru = LRUCache(capacity)
        self.image_size = image_size
        self.latent_size = latent_size
        self.h = promote_threshold
        self._format: Dict[int, str] = {}
        self._hits: Dict[int, int] = {}

    def __contains__(self, oid: int) -> bool:
        return oid in self.lru

    def access(self, oid: int, size: float = 1.0) -> bool:
        hit = oid in self.lru
        if hit:
            self.lru.access(oid)
            if self._format.get(oid) == "latent":
                cnt = self._hits.get(oid, 0) + 1
                if cnt >= self.h:
                    self.lru.insert(oid, self.image_size)
                    self._format[oid] = "image"
                    self._hits.pop(oid, None)
                else:
                    self._hits[oid] = cnt
        else:
            self.lru.insert(oid, self.latent_size)
            self._format[oid] = "latent"
            self._hits[oid] = 0
        self._gc()
        return hit

    def format_of(self, oid: int) -> Optional[str]:
        return self._format.get(oid) if oid in self.lru else None

    def _gc(self) -> None:
        if len(self._format) > 2 * len(self.lru) + 64:
            live = set(iter(self.lru._entries))
            self._format = {k: v for k, v in self._format.items() if k in live}
            self._hits = {k: v for k, v in self._hits.items() if k in live}


def miss_ratio(policy: CachePolicy, object_ids: Iterable[int],
               sizes: Optional[Sequence[float]] = None) -> float:
    """Replay a request stream through a policy; return the miss ratio."""
    misses = 0
    total = 0
    if isinstance(policy, BeladyCache):
        ids = list(object_ids)
        policy.prepare(ids)
        object_ids = ids
    if sizes is None:
        for oid in object_ids:
            total += 1
            if not policy.access(int(oid)):
                misses += 1
    else:
        for oid, sz in zip(object_ids, sizes):
            total += 1
            if not policy.access(int(oid), float(sz)):
                misses += 1
    return misses / total if total else 0.0
