"""Dual-format cache (paper §4.2).

Two independent byte-capacity LRU tiers sharing a fixed total capacity ``C``:
an *image tier* holding decoded images (fast hits) and a *latent tier*
holding compressed latents (more coverage, hit => GPU decode).  An ``alpha``
fraction of ``C`` goes to the image tier, ``1 - alpha`` to the latent tier.

Each tier is a :class:`SegmentedLRU`: a *main* segment of fraction
``1 - tau`` and a thin *tail* segment of fraction ``tau``.  Items evicted
from main enter the tail; items evicted from the tail leave the cache.  A
*tail hit* identifies a request that would have been a miss had the tier
been ``tau`` smaller — the marginal-hit signal consumed by the online tuner
(§4.3).

Invariants (enforced + property-tested):
  * every object lives in exactly one tier at a time;
  * resident bytes of each tier never exceed its capacity (after any op);
  * a latent-tier object is promoted to the image tier after ``h`` latent
    hits and atomically removed from the latent tier.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.latent_store import DEFAULT_OBJECT_BYTES

# ---------------------------------------------------------------------------
# Segmented LRU
# ---------------------------------------------------------------------------


class SegmentedLRU:
    """Byte-capacity LRU split into a main segment and a thin tail segment.

    ``tau`` is the fraction of the tier's capacity reserved for the tail.
    Lookup promotes hits (from main or tail) to the MRU position of main.
    """

    __slots__ = ("capacity", "tau", "on_evict", "_main", "_tail", "_main_bytes",
                 "_tail_bytes")

    def __init__(self, capacity: float, tau: float = 0.1,
                 on_evict: Optional[Callable[[int, float], None]] = None):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        if not (0.0 <= tau < 1.0):
            raise ValueError("tau must be in [0, 1)")
        self.capacity = float(capacity)
        self.tau = float(tau)
        self.on_evict = on_evict
        self._main: "OrderedDict[int, float]" = OrderedDict()  # id -> bytes
        self._tail: "OrderedDict[int, float]" = OrderedDict()
        self._main_bytes = 0.0
        self._tail_bytes = 0.0

    # -- capacities ---------------------------------------------------------
    @property
    def main_capacity(self) -> float:
        return self.capacity * (1.0 - self.tau)

    @property
    def tail_capacity(self) -> float:
        return self.capacity * self.tau

    @property
    def resident_bytes(self) -> float:
        return self._main_bytes + self._tail_bytes

    def __len__(self) -> int:
        return len(self._main) + len(self._tail)

    def __contains__(self, oid: int) -> bool:
        return oid in self._main or oid in self._tail

    def __iter__(self) -> Iterator[int]:
        yield from self._main
        yield from self._tail

    def size_of(self, oid: int) -> Optional[float]:
        if oid in self._main:
            return self._main[oid]
        if oid in self._tail:
            return self._tail[oid]
        return None

    # -- internal balancing -------------------------------------------------
    def _rebalance(self) -> List[Tuple[int, float]]:
        """Demote main overflow into tail, evict tail overflow. Returns
        evicted ``(id, bytes)`` pairs."""
        evicted: List[Tuple[int, float]] = []
        main_cap, tail_cap = self.main_capacity, self.tail_capacity
        # Demote main LRU -> tail MRU.
        while self._main and self._main_bytes > main_cap:
            oid, sz = self._main.popitem(last=False)
            self._main_bytes -= sz
            self._tail[oid] = sz
            self._tail_bytes += sz
        # Evict tail LRU out of the cache.
        while self._tail and self._tail_bytes > tail_cap:
            oid, sz = self._tail.popitem(last=False)
            self._tail_bytes -= sz
            evicted.append((oid, sz))
        # Degenerate case: tau == 0 -> tail capacity 0; everything demoted is
        # evicted immediately (handled above since tail_cap == 0).
        if self.on_evict is not None:
            for oid, sz in evicted:
                self.on_evict(oid, sz)
        return evicted

    # -- public ops ----------------------------------------------------------
    def lookup(self, oid: int) -> Optional[str]:
        """Return ``'main'`` / ``'tail'`` on hit (after promoting the entry to
        main-MRU) or ``None`` on miss.  A ``'tail'`` return is a *tail hit*."""
        if oid in self._main:
            self._main.move_to_end(oid)
            return "main"
        if oid in self._tail:
            sz = self._tail.pop(oid)
            self._tail_bytes -= sz
            self._main[oid] = sz
            self._main_bytes += sz
            self._rebalance()
            return "tail"
        return None

    def insert(self, oid: int, nbytes: float) -> List[Tuple[int, float]]:
        """Insert (or refresh) ``oid`` at main-MRU.  Returns evictions.

        Objects larger than the tier capacity are not admitted (returned as
        an immediate self-eviction), mirroring production blob caches.
        """
        if nbytes < 0:
            raise ValueError("object size must be >= 0")
        self.remove(oid)
        if nbytes > self.capacity:
            return [(oid, nbytes)]
        self._main[oid] = nbytes
        self._main_bytes += nbytes
        return self._rebalance()

    def remove(self, oid: int) -> bool:
        if oid in self._main:
            self._main_bytes -= self._main.pop(oid)
            return True
        if oid in self._tail:
            self._tail_bytes -= self._tail.pop(oid)
            return True
        return False

    def resize(self, oid: int, nbytes: float) -> bool:
        """Correct a resident entry's byte charge *in place* — no LRU
        reorder (unlike :meth:`insert`), so accounting fixes (e.g. the
        engine charging a decoded image's real dtype bytes) cannot perturb
        eviction order.  Growth may trigger evictions; returns False when
        the object is not resident."""
        if nbytes < 0:
            raise ValueError("object size must be >= 0")
        for seg, attr in ((self._main, "_main_bytes"),
                          (self._tail, "_tail_bytes")):
            if oid in seg:
                old = seg[oid]
                if nbytes == old:
                    return True
                seg[oid] = nbytes
                setattr(self, attr, getattr(self, attr) + nbytes - old)
                if nbytes > old:
                    self._rebalance()
                return True
        return False

    def set_capacity(self, capacity: float) -> List[Tuple[int, float]]:
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = float(capacity)
        return self._rebalance()

    def check_invariants(self) -> None:
        assert abs(self._main_bytes - sum(self._main.values())) < 1e-6
        assert abs(self._tail_bytes - sum(self._tail.values())) < 1e-6
        assert self._main_bytes <= self.main_capacity + 1e-6
        assert self._tail_bytes <= self.tail_capacity + 1e-6
        assert not (set(self._main) & set(self._tail))


# ---------------------------------------------------------------------------
# Window statistics (consumed by the tuner, §4.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WindowStats:
    """Counters accumulated over one tuning window of W requests."""

    total_requests: int = 0
    image_hits: int = 0
    image_misses: int = 0          # requests not found in the image tier
    latent_hits: int = 0           # of which found in the latent tier
    full_misses: int = 0           # absent from both tiers
    image_tail_hits: int = 0
    latent_tail_hits: int = 0
    promotions: int = 0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)

    # Ratios per the paper's Eq. (measured under the current partition).
    def mr_img(self) -> float:
        return self.image_misses / self.total_requests if self.total_requests else 0.0

    def delta_img(self) -> float:
        return self.image_tail_hits / self.total_requests if self.total_requests else 0.0

    def mr_lat(self) -> float:
        return self.full_misses / self.image_misses if self.image_misses else 0.0

    def delta_lat(self) -> float:
        return self.latent_tail_hits / self.image_misses if self.image_misses else 0.0


@dataclasses.dataclass(frozen=True)
class LookupResult:
    outcome: str                   # 'image_hit' | 'latent_hit' | 'full_miss'
    tail_hit: bool = False         # served from the tail segment
    promoted: bool = False         # latent->image promotion happened


IMAGE_HIT = "image_hit"
LATENT_HIT = "latent_hit"
FULL_MISS = "full_miss"


# ---------------------------------------------------------------------------
# Dual-format cache
# ---------------------------------------------------------------------------


class DualFormatCache:
    """Paper §4.2: image tier + latent tier under one capacity ``C``.

    ``image_size_fn`` / ``latent_size_fn`` map an object id to its byte size
    in each format (constants by default: 1.4 MB PNG vs 0.28 MB latent).
    """

    def __init__(
        self,
        capacity_bytes: float,
        alpha: float = 0.5,
        tau: float = 0.1,
        promote_threshold: int = 8,
        image_size_fn: Optional[Callable[[int], float]] = None,
        latent_size_fn: Optional[Callable[[int], float]] = None,
    ):
        if not (0.0 <= alpha <= 1.0):
            raise ValueError("alpha must be in [0, 1]")
        self.capacity = float(capacity_bytes)
        self.alpha = float(alpha)
        self.h = int(promote_threshold)
        self.image_size_fn = image_size_fn or (lambda oid: 1.4e6)
        self.latent_size_fn = latent_size_fn or (lambda oid: DEFAULT_OBJECT_BYTES)
        self._latent_hits: Dict[int, int] = {}   # promotion counters
        self.image_tier = SegmentedLRU(self.capacity * self.alpha, tau)
        self.latent_tier = SegmentedLRU(
            self.capacity * (1.0 - self.alpha), tau,
            on_evict=lambda oid, _sz: self._latent_hits.pop(oid, None))
        self.stats = WindowStats()
        self.lifetime = WindowStats()

    # -- alpha control (used by the adaptive resizer) ------------------------
    def set_alpha(self, alpha: float) -> None:
        alpha = min(1.0, max(0.0, alpha))
        self.alpha = alpha
        self.image_tier.set_capacity(self.capacity * alpha)
        self.latent_tier.set_capacity(self.capacity * (1.0 - alpha))

    def set_capacity(self, capacity_bytes: float) -> None:
        """External capacity handoff (the autoscaler's cache knob):
        re-split both tiers under the new total while *preserving* the
        current alpha — the marginal-hit tuner keeps sole ownership of
        the split and simply continues from its converged point.
        Shrinking evicts through the normal tail path, so ``on_evict``
        hooks (payload drops, promotion-counter cleanup) fire as usual."""
        if capacity_bytes < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = float(capacity_bytes)
        self.set_alpha(self.alpha)

    # -- lookup path ----------------------------------------------------------
    def lookup(self, oid: int) -> LookupResult:
        """Cascading lookup: image tier -> latent tier -> full miss.

        On a full miss the caller is expected to fetch the latent from cloud
        storage and call :meth:`admit_latent`.
        """
        for s in (self.stats, self.lifetime):
            s.total_requests += 1

        where = self.image_tier.lookup(oid)
        if where is not None:
            tail = where == "tail"
            for s in (self.stats, self.lifetime):
                s.image_hits += 1
                if tail:
                    s.image_tail_hits += 1
            return LookupResult(IMAGE_HIT, tail_hit=tail)

        for s in (self.stats, self.lifetime):
            s.image_misses += 1

        where = self.latent_tier.lookup(oid)
        if where is not None:
            tail = where == "tail"
            for s in (self.stats, self.lifetime):
                s.latent_hits += 1
                if tail:
                    s.latent_tail_hits += 1
            promoted = self._bump_and_maybe_promote(oid)
            return LookupResult(LATENT_HIT, tail_hit=tail, promoted=promoted)

        for s in (self.stats, self.lifetime):
            s.full_misses += 1
        return LookupResult(FULL_MISS)

    def _bump_and_maybe_promote(self, oid: int) -> bool:
        cnt = self._latent_hits.get(oid, 0) + 1
        # Never promote into a tier that cannot hold the image (alpha ~ 0 /
        # LB-LatentCache): doing so would drop the object from both tiers.
        if cnt >= self.h and self.image_size_fn(oid) <= self.image_tier.capacity:
            # Decode + insert into the image tier, atomically removed from
            # the latent tier (single-residency invariant).
            self.latent_tier.remove(oid)
            self._latent_hits.pop(oid, None)
            evicted = self.image_tier.insert(oid, self.image_size_fn(oid))
            del evicted  # evicted images leave the cache entirely
            for s in (self.stats, self.lifetime):
                s.promotions += 1
            return True
        self._latent_hits[oid] = cnt
        return False

    def admit_latent(self, oid: int,
                     nbytes: Optional[float] = None) -> None:
        """Admit a freshly fetched object into the latent tier (counter =
        0).  ``nbytes`` charges the payload's real byte size; default is
        the configured ``latent_size_fn`` estimate."""
        if oid in self.image_tier:     # raced promotion; keep single residency
            return
        self.latent_tier.insert(
            oid, self.latent_size_fn(oid) if nbytes is None else nbytes)
        if oid in self.latent_tier:    # not admitted if larger than the tier
            self._latent_hits[oid] = 0

    def insert_image(self, oid: int,
                     nbytes: Optional[float] = None) -> None:
        """Force-insert a decoded image (used by spillover write-back).
        ``nbytes`` charges the stored array's real byte size (uint8 on the
        fast path); default is the ``image_size_fn`` estimate."""
        self.latent_tier.remove(oid)
        self._latent_hits.pop(oid, None)
        self.image_tier.insert(
            oid, self.image_size_fn(oid) if nbytes is None else nbytes)

    def set_image_nbytes(self, oid: int, nbytes: float) -> bool:
        """Correct a cached image's byte charge to its real stored size
        without touching LRU order (no-op when not pixel-resident)."""
        return self.image_tier.resize(oid, float(nbytes))

    def evict(self, oid: int) -> bool:
        """Explicitly drop ``oid`` from whichever tier holds it (promotion
        counter included).  Returns True if the object was resident."""
        found = self.image_tier.remove(oid)
        found = self.latent_tier.remove(oid) or found
        self._latent_hits.pop(oid, None)
        return found

    # -- bookkeeping ----------------------------------------------------------
    def contains(self, oid: int) -> Optional[str]:
        if oid in self.image_tier:
            return "image"
        if oid in self.latent_tier:
            return "latent"
        return None

    def end_window(self) -> WindowStats:
        """Snapshot + reset the per-window counters."""
        snap = dataclasses.replace(self.stats)
        self.stats.reset()
        return snap

    def check_invariants(self) -> None:
        self.image_tier.check_invariants()
        self.latent_tier.check_invariants()
        assert not (set(self.image_tier) & set(self.latent_tier)), "dual residency"
        for oid in self._latent_hits:
            # counters may linger only for latent-resident objects
            if oid not in self.latent_tier:
                raise AssertionError(f"stale promotion counter for {oid}")

    @property
    def resident_bytes(self) -> float:
        return self.image_tier.resident_bytes + self.latent_tier.resident_bytes
