"""Durable object store (paper: AWS S3) over a pluggable byte backend.

Source of truth for every object.  Since the log-structured-store refactor
this class is a thin façade: *where bytes live* is delegated to a
:class:`~repro.store.durable.backend.DurableBackend` — the in-memory
:class:`~repro.store.durable.backend.MemoryBackend` by default (simulation
conformance; nothing survives the process), or a
:class:`~repro.store.durable.backend.SegmentLogBackend` when the box is
opened on a directory (``LatentBox.open(path)``), in which case every
acknowledged put is an on-disk, checksummed, crash-recoverable record.

What stays here is the store's *performance model* and per-process
bookkeeping: fetch latency the way §6.3.3 characterizes it — cold,
long-tail objects see higher and more variable latency than objects kept
warm by the store's own internal caching layers (the Decode-All effect):

    fetch_ms = lognormal(base)  +  nbytes / effective_bandwidth

with the lognormal median dropping from ``cold_ms`` to ``warm_ms`` when the
object was fetched within ``warm_window_s``.  Warmth and latency epochs are
deliberately NOT durable state: a reopened store serves every byte
bit-exact but starts cold, exactly like a store node rejoining a fleet.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

#: The canonical "I don't know this object's size" accounting default —
#: a 0.28 MB compressed SD3.5-class latent (paper Table 1b).  Re-exported
#: as :data:`repro.store.api.DEFAULT_OBJECT_BYTES` (the public name);
#: defined here because ``core`` modules cannot import ``repro.store``
#: at module scope without a cycle.
DEFAULT_OBJECT_BYTES = 0.28e6


@dataclasses.dataclass(frozen=True)
class StoreLatencyModel:
    warm_ms: float = 55.0           # lognormal median, recently-touched object
    cold_ms: float = 110.0          # lognormal median, cold object
    sigma: float = 0.35             # lognormal shape (tail heaviness)
    bandwidth_mb_s: float = 30.0    # effective single-stream S3 throughput
    warm_window_s: float = 600.0    # store-side warmth horizon
    first_byte_floor_ms: float = 15.0


class LatentStore:
    """Object store: id -> payload bytes (or just a size for simulation)."""

    def __init__(self, latency: Optional[StoreLatencyModel] = None,
                 seed: int = 0, backend=None):
        self.latency = latency or StoreLatencyModel()
        if backend is None:
            # deferred: repro.store imports this module at its own top level
            from repro.store.durable.backend import MemoryBackend
            backend = MemoryBackend()
        self.backend = backend
        self._seed = int(seed)
        self._rng = np.random.default_rng(seed)
        self._last_fetch_s: Dict[int, float] = {}
        self._epoch: Dict[int, int] = {}    # bumped on delete: re-put objects
        #                                     draw from a fresh latency stream
        self.n_fetches = 0
        self.bytes_fetched = 0.0

    # -- durable writes --------------------------------------------------------
    def put(self, oid: int, blob: bytes) -> None:
        self.backend.put_blob(oid, blob)

    def put_size(self, oid: int, nbytes: float, rung: int = 0) -> None:
        """Register an object by size only (simulation mode).  ``rung``
        tags which rate-distortion rung the nominal bytes represent."""
        self.backend.put_size(oid, float(nbytes), int(rung))

    def get(self, oid: int) -> Optional[bytes]:
        return self.backend.get_blob(oid)

    def size_of(self, oid: int,
                default: float = DEFAULT_OBJECT_BYTES) -> float:
        sz = self.backend.size_of(oid)
        return default if sz is None else sz

    @property
    def total_bytes(self) -> float:
        return self.backend.total_bytes

    def __contains__(self, oid: int) -> bool:
        return self.backend.contains(oid)

    # -- rate-distortion ladder --------------------------------------------------
    def rung_of(self, oid: int) -> Optional[int]:
        """Ladder rung the object's durable bytes sit at (None: absent)."""
        return self.backend.rung_of(oid)

    def target_rung_of(self, oid: int) -> Optional[int]:
        """Pending demotion target (segment-log backend only), or None."""
        return self.backend.target_rung_of(oid)

    def set_target_rung(self, oid: int, rung: int) -> bool:
        """Demote the object to a colder rung: eager on the memory
        backend, piggybacked on the next compaction pass on the log."""
        return self.backend.set_target_rung(oid, int(rung))

    # -- durability hooks --------------------------------------------------------
    def flush(self) -> None:
        """Crash-durability barrier (no-op on the memory backend)."""
        self.backend.flush()

    def maybe_compact(self) -> int:
        """One bounded online-compaction step (no-op in memory)."""
        return self.backend.maybe_compact()

    def close(self) -> None:
        self.backend.close()

    # -- lifecycle ---------------------------------------------------------------
    def delete(self, oid: int) -> bool:
        """Remove an object's durable payload AND size record (presence is
        ``size or blob``, so a demoted object must lose both to read as
        absent).  Clears ``_last_fetch_s`` too, so a re-created object
        starts cold instead of inheriting warmth from a deleted namesake —
        and bumps the object's latency epoch, so a re-put namesake draws
        from a fresh per-call seed stream instead of replaying the deleted
        object's fetch-latency samples."""
        found = self.backend.delete(oid)
        self._last_fetch_s.pop(oid, None)
        if found:
            self._epoch[oid] = self._epoch.get(oid, 0) + 1
        return found

    def stat(self, oid: int) -> Optional[Dict[str, float]]:
        """Non-mutating metadata probe: never samples the latency RNG and
        never warms the object (unlike :meth:`fetch_ms`)."""
        if oid not in self:
            return None
        return {
            "nbytes": self.size_of(oid),
            "has_payload": self.backend.has_blob(oid),
            "last_fetch_s": self._last_fetch_s.get(oid, float("-inf")),
            "epoch": self._epoch.get(oid, 0),
            "rung": self.backend.rung_of(oid),
            "target_rung": self.backend.target_rung_of(oid),
        }

    # -- modeled fetch ----------------------------------------------------------
    def fetch_ms(self, oid: int, now_s: float,
                 nbytes: Optional[float] = None,
                 seq: Optional[int] = None) -> float:
        """Sample a fetch latency and record the access (warming the object).

        With the default ``seq=None`` samples come from one shared RNG
        stream, so the latency an individual request sees depends on global
        request ordering.  Passing a per-call ``seq`` (e.g. the request's
        trace index) draws from an independent stream keyed on
        ``(store seed, oid epoch, oid, seq)`` instead, making each
        request's sample reproducible under request reordering.  The epoch
        bumps on :meth:`delete`, so deleting and re-putting an object id
        yields fresh (but still reorder-stable) latencies rather than a
        replay of the dead object's stream.
        """
        m = self.latency
        warm = (now_s - self._last_fetch_s.get(oid, -np.inf)) <= m.warm_window_s
        median = m.warm_ms if warm else m.cold_ms
        rng = self._rng if seq is None else np.random.default_rng(
            (self._seed, self._epoch.get(oid, 0),
             int(oid) & 0xFFFFFFFF, int(seq)))
        base = float(rng.lognormal(np.log(median), m.sigma))
        base = max(base, m.first_byte_floor_ms)
        size = self.size_of(oid) if nbytes is None else float(nbytes)
        transfer = size / (m.bandwidth_mb_s * 1e6) * 1e3
        self._last_fetch_s[oid] = now_s
        self.n_fetches += 1
        self.bytes_fetched += size
        return base + transfer
