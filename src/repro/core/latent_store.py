"""Durable object store stand-in (paper: AWS S3).

Source of truth for every object.  Stores real payloads when given them
(the quickstart/e2e examples store actual compressed latents) and models
fetch latency the way §6.3.3 characterizes it: cold, long-tail objects see
higher and more variable latency than objects kept warm by the store's own
internal caching layers (the Decode-All effect).

    fetch_ms = lognormal(base)  +  nbytes / effective_bandwidth

with the lognormal median dropping from ``cold_ms`` to ``warm_ms`` when the
object was fetched within ``warm_window_s``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class StoreLatencyModel:
    warm_ms: float = 55.0           # lognormal median, recently-touched object
    cold_ms: float = 110.0          # lognormal median, cold object
    sigma: float = 0.35             # lognormal shape (tail heaviness)
    bandwidth_mb_s: float = 30.0    # effective single-stream S3 throughput
    warm_window_s: float = 600.0    # store-side warmth horizon
    first_byte_floor_ms: float = 15.0


class LatentStore:
    """Object store: id -> payload bytes (or just a size for simulation)."""

    def __init__(self, latency: Optional[StoreLatencyModel] = None,
                 seed: int = 0):
        self.latency = latency or StoreLatencyModel()
        self._seed = int(seed)
        self._rng = np.random.default_rng(seed)
        self._blobs: Dict[int, bytes] = {}
        self._sizes: Dict[int, float] = {}
        self._last_fetch_s: Dict[int, float] = {}
        self._epoch: Dict[int, int] = {}    # bumped on delete: re-put objects
        #                                     draw from a fresh latency stream
        self.n_fetches = 0
        self.bytes_fetched = 0.0

    # -- durable writes --------------------------------------------------------
    def put(self, oid: int, blob: bytes) -> None:
        self._blobs[oid] = blob
        self._sizes[oid] = float(len(blob))

    def put_size(self, oid: int, nbytes: float) -> None:
        """Register an object by size only (simulation mode)."""
        self._sizes[oid] = float(nbytes)

    def get(self, oid: int) -> Optional[bytes]:
        return self._blobs.get(oid)

    def size_of(self, oid: int, default: float = 0.28e6) -> float:
        return self._sizes.get(oid, default)

    @property
    def total_bytes(self) -> float:
        return float(sum(self._sizes.values()))

    def __contains__(self, oid: int) -> bool:
        return oid in self._sizes or oid in self._blobs

    # -- lifecycle ---------------------------------------------------------------
    def delete(self, oid: int) -> bool:
        """Remove an object's durable payload AND size record (presence is
        ``size or blob``, so a demoted object must lose both to read as
        absent).  Clears ``_last_fetch_s`` too, so a re-created object
        starts cold instead of inheriting warmth from a deleted namesake —
        and bumps the object's latency epoch, so a re-put namesake draws
        from a fresh per-call seed stream instead of replaying the deleted
        object's fetch-latency samples."""
        found = oid in self
        self._blobs.pop(oid, None)
        self._sizes.pop(oid, None)
        self._last_fetch_s.pop(oid, None)
        if found:
            self._epoch[oid] = self._epoch.get(oid, 0) + 1
        return found

    def stat(self, oid: int) -> Optional[Dict[str, float]]:
        """Non-mutating metadata probe: never samples the latency RNG and
        never warms the object (unlike :meth:`fetch_ms`)."""
        if oid not in self:
            return None
        return {
            "nbytes": self.size_of(oid),
            "has_payload": oid in self._blobs,
            "last_fetch_s": self._last_fetch_s.get(oid, float("-inf")),
            "epoch": self._epoch.get(oid, 0),
        }

    # -- modeled fetch ----------------------------------------------------------
    def fetch_ms(self, oid: int, now_s: float,
                 nbytes: Optional[float] = None,
                 seq: Optional[int] = None) -> float:
        """Sample a fetch latency and record the access (warming the object).

        With the default ``seq=None`` samples come from one shared RNG
        stream, so the latency an individual request sees depends on global
        request ordering.  Passing a per-call ``seq`` (e.g. the request's
        trace index) draws from an independent stream keyed on
        ``(store seed, oid epoch, oid, seq)`` instead, making each
        request's sample reproducible under request reordering.  The epoch
        bumps on :meth:`delete`, so deleting and re-putting an object id
        yields fresh (but still reorder-stable) latencies rather than a
        replay of the dead object's stream.
        """
        m = self.latency
        warm = (now_s - self._last_fetch_s.get(oid, -np.inf)) <= m.warm_window_s
        median = m.warm_ms if warm else m.cold_ms
        rng = self._rng if seq is None else np.random.default_rng(
            (self._seed, self._epoch.get(oid, 0),
             int(oid) & 0xFFFFFFFF, int(seq)))
        base = float(rng.lognormal(np.log(median), m.sigma))
        base = max(base, m.first_byte_floor_ms)
        size = self.size_of(oid) if nbytes is None else float(nbytes)
        transfer = size / (m.bandwidth_mb_s * 1e6) * 1e3
        self._last_fetch_s[oid] = now_s
        self.n_fetches += 1
        self.bytes_fetched += size
        return base + transfer
