"""Frontend router (paper §4.4): request coalescing, consistent-hash
dispatch, and queue-depth-triggered spillover with cache pinning.

The router is engine-agnostic: the discrete-event simulator
(:mod:`repro.core.cluster`) and the real pjit decode fleet
(:mod:`repro.vae.serve`) both drive it.
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple


def _hash64(key: str) -> int:
    return int.from_bytes(hashlib.blake2b(key.encode(), digest_size=8).digest(),
                          "big")


def parse_node_index(name: str) -> int:
    """Parse a ``node<idx>`` ring/router name into a fleet index — the one
    parse point for the naming convention the engine fleet and the sharded
    cluster's global namespace both rely on."""
    if not name.startswith("node"):
        raise ValueError(f"malformed node name {name!r} (want 'node<idx>')")
    try:
        return int(name[4:])
    except ValueError as e:
        raise ValueError(
            f"malformed node name {name!r} (want 'node<idx>')") from e


class ConsistentHashRing:
    """Classic ring with virtual nodes; stable under node add/remove so the
    serving fleet can scale elastically with minimal cache-ownership churn."""

    def __init__(self, nodes: Sequence[str], vnodes: int = 128):
        self.vnodes = vnodes
        self._ring: List[Tuple[int, str]] = []
        self._keys: List[int] = []
        self._nodes: List[str] = []
        for n in nodes:
            self.add_node(n)

    @property
    def nodes(self) -> List[str]:
        return list(self._nodes)

    def add_node(self, node: str) -> None:
        if node in self._nodes:
            raise ValueError(f"duplicate node {node}")
        self._nodes.append(node)
        for v in range(self.vnodes):
            self._ring.append((_hash64(f"{node}#{v}"), node))
        self._ring.sort()
        self._keys = [h for h, _ in self._ring]

    def remove_node(self, node: str) -> None:
        self._nodes.remove(node)
        self._ring = [(h, n) for h, n in self._ring if n != node]
        self._keys = [h for h, _ in self._ring]

    def owner(self, oid: int) -> str:
        if not self._ring:
            raise RuntimeError("empty ring")
        h = _hash64(f"obj:{oid}")
        i = bisect.bisect_right(self._keys, h) % len(self._ring)
        return self._ring[i][1]

    def successors(self, oid: int):
        """Yield the distinct nodes encountered walking the ring clockwise
        from ``oid``'s position — the first yield is ``owner(oid)``.  Replica
        placement takes the first R distinct *shards* along this walk, so a
        node join/leave only reshuffles the replicas whose successor window
        it enters or exits."""
        if not self._ring:
            raise RuntimeError("empty ring")
        h = _hash64(f"obj:{oid}")
        start = bisect.bisect_right(self._keys, h) % len(self._ring)
        seen = set()
        for step in range(len(self._ring)):
            node = self._ring[(start + step) % len(self._ring)][1]
            if node not in seen:
                seen.add(node)
                yield node


class Router:
    """Coalescing + ownership + spillover decisions.

    Queue depths are *reported back* by nodes (as in the paper: per-GPU
    depths piggy-backed on responses); the router never inspects node
    internals directly.
    """

    def __init__(self, nodes: Sequence[str], theta: int = 4, vnodes: int = 128):
        self.ring = ConsistentHashRing(nodes, vnodes)
        self.theta = theta                       # spillover queue threshold
        self.queue_depth: Dict[str, int] = {n: 0 for n in nodes}
        self.inflight: Dict[int, List[object]] = {}   # oid -> waiter tokens
        # telemetry
        self.n_coalesced = 0
        self.n_spillover = 0
        self.n_dispatched = 0

    # -- coalescing -----------------------------------------------------------
    def try_coalesce(self, oid: int, waiter: object) -> bool:
        """True if an identical decode is in flight; waiter is parked."""
        if oid in self.inflight:
            self.inflight[oid].append(waiter)
            self.n_coalesced += 1
            return True
        return False

    def begin_inflight(self, oid: int) -> None:
        self.inflight.setdefault(oid, [])

    def finish_inflight(self, oid: int) -> List[object]:
        """Returns (and clears) the parked waiters for ``oid``."""
        return self.inflight.pop(oid, [])

    # -- dispatch --------------------------------------------------------------
    def report_depth(self, node: str, depth: int) -> None:
        self.queue_depth[node] = depth

    def least_loaded(self, exclude: Optional[str] = None) -> str:
        candidates = [(d, n) for n, d in self.queue_depth.items() if n != exclude]
        if not candidates:
            return exclude  # single-node cluster: no spillover possible
        return min(candidates)[1]

    def dispatch(self, oid: int, needs_gpu: bool = True) -> Tuple[str, str, bool]:
        """Returns ``(owner_node, exec_node, spilled)``.

        The *owner* is where the cache entry lives (hash-pinned); the *exec*
        node is where the decode runs.  They differ only on spillover, in
        which case the decode result is written back to the owner's cache
        (cache pinning, §4.4)."""
        owner = self.ring.owner(oid)
        self.n_dispatched += 1
        if not needs_gpu:
            return owner, owner, False
        if self.queue_depth.get(owner, 0) > self.theta:
            spill = self.least_loaded(exclude=owner)
            if spill != owner and self.queue_depth.get(spill, 0) < \
                    self.queue_depth.get(owner, 0):
                self.n_spillover += 1
                return owner, spill, True
        return owner, owner, False
