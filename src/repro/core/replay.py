"""Fast cache-only trace replay (paper §6.5's trace-driven simulator).

No queueing plant — each request costs exactly its outcome's latency
(image hit 0, latent hit T_decode, full miss T_decode + T_fetch), matching
the simulation methodology of the paper's sensitivity study (§6.5:
T_decode = 40 ms, T_fetch = 140 ms).  This is what makes multi-million-
request parameter sweeps tractable.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.dual_cache import (DualFormatCache, FULL_MISS, IMAGE_HIT,
                                   LATENT_HIT)
from repro.core.latent_store import DEFAULT_OBJECT_BYTES
from repro.core.tuner import MarginalHitTuner, TunerConfig, TunerRecord


@dataclasses.dataclass
class ReplayConfig:
    cache_bytes: float = 2e9
    alpha0: float = 0.5
    adaptive: bool = True
    tau: float = 0.10
    promote_threshold: int = 8
    admit_on_miss: str = "latent"
    image_bytes: float = 1.4e6
    latent_bytes: float = DEFAULT_OBJECT_BYTES
    t_decode_ms: float = 40.0
    t_fetch_ms: float = 140.0
    tuner: TunerConfig = dataclasses.field(
        default_factory=lambda: TunerConfig(window=1_000_000))


@dataclasses.dataclass
class ReplayResult:
    n: int
    mean_ms: float
    image_hit_frac: float
    latent_hit_frac: float
    full_miss_frac: float
    decode_trigger_frac: float          # fraction of requests touching a GPU
    alpha_final: float
    history: List[TunerRecord]
    window_mean_ms: np.ndarray          # per-window mean latency
    window_alpha: np.ndarray

    def as_dict(self) -> Dict[str, float]:
        return {
            "n": self.n, "mean_ms": self.mean_ms,
            "image_hit_frac": self.image_hit_frac,
            "latent_hit_frac": self.latent_hit_frac,
            "full_miss_frac": self.full_miss_frac,
            "decode_trigger_frac": self.decode_trigger_frac,
            "alpha_final": self.alpha_final,
        }


def replay(object_ids: np.ndarray, cfg: Optional[ReplayConfig] = None,
           limit: Optional[int] = None) -> ReplayResult:
    cfg = cfg or ReplayConfig()
    cache = DualFormatCache(
        cfg.cache_bytes, alpha=cfg.alpha0, tau=cfg.tau,
        promote_threshold=cfg.promote_threshold,
        image_size_fn=lambda oid: cfg.image_bytes,
        latent_size_fn=lambda oid: cfg.latent_bytes)
    tcfg = dataclasses.replace(cfg.tuner, t_decode_ms=cfg.t_decode_ms,
                               t_fetch_ms=cfg.t_fetch_ms)
    tuner = MarginalHitTuner(cache, tcfg) if cfg.adaptive else None

    ids = np.asarray(object_ids)
    n = len(ids) if limit is None else min(limit, len(ids))
    t_dec, t_fet = cfg.t_decode_ms, cfg.t_fetch_ms
    admit_image = cfg.admit_on_miss == "image"

    total_ms = 0.0
    n_img = n_lat = n_miss = 0
    win_cost = 0.0
    win_n = 0
    window = tcfg.window
    window_means: List[float] = []
    window_alphas: List[float] = []

    lookup = cache.lookup
    admit = cache.insert_image if admit_image else cache.admit_latent
    on_request = tuner.on_request if tuner is not None else None

    for i in range(n):
        oid = int(ids[i])
        res = lookup(oid)
        o = res.outcome
        if o == IMAGE_HIT:
            cost = 0.0
            n_img += 1
        elif o == LATENT_HIT:
            cost = t_dec
            n_lat += 1
        else:
            cost = t_dec + t_fet
            n_miss += 1
            admit(oid)
        total_ms += cost
        win_cost += cost
        win_n += 1
        if on_request is not None:
            rec = on_request()
        else:
            rec = None
        if win_n >= window:
            window_means.append(win_cost / win_n)
            window_alphas.append(cache.alpha)
            win_cost = 0.0
            win_n = 0
        del rec

    if win_n:
        window_means.append(win_cost / win_n)
        window_alphas.append(cache.alpha)

    return ReplayResult(
        n=n, mean_ms=total_ms / max(1, n),
        image_hit_frac=n_img / max(1, n),
        latent_hit_frac=n_lat / max(1, n),
        full_miss_frac=n_miss / max(1, n),
        decode_trigger_frac=(n_lat + n_miss) / max(1, n),
        alpha_final=cache.alpha,
        history=tuner.history if tuner else [],
        window_mean_ms=np.asarray(window_means),
        window_alpha=np.asarray(window_alphas))


def replay_scenario(scenario: str, cfg: Optional[ReplayConfig] = None,
                    limit: Optional[int] = None,
                    **trace_knobs) -> ReplayResult:
    """Replay a named workload from the scenario suite
    (:func:`repro.trace.synth.make_trace`) through the cache-only
    simulator: ``replay_scenario("zipf_drift", n_objects=2_000, ...)``."""
    from repro.trace.synth import make_trace
    tr = make_trace(scenario, **trace_knobs)
    return replay(tr.object_ids, cfg, limit=limit)


def sweep_static_alpha(object_ids: np.ndarray, alphas,
                       base: Optional[ReplayConfig] = None,
                       limit: Optional[int] = None
                       ) -> Dict[float, ReplayResult]:
    """§6.5.2: static-allocation oracle sweep."""
    base = base or ReplayConfig()
    out = {}
    for a in alphas:
        cfg = dataclasses.replace(base, alpha0=float(a), adaptive=False)
        out[float(a)] = replay(object_ids, cfg, limit=limit)
    return out
