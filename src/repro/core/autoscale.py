"""Cost-model-driven elastic autoscaling (closes the ROADMAP's open item).

The paper's §6.4 economics (Eq. 3-4) price LatentBox as a trade between
persistent storage and on-demand GPU decode — but a *live* cluster must
make that trade continuously: "given this diurnal load, how many decode
GPUs and how much cache?".  :class:`AutoscaleController` is the answer as
a control loop.  Every control window it consumes a
:class:`WindowObs` — arrival volume, decode-GPU occupancy, hit-class mix,
and the plant's queue-delay tail — and picks the **cheapest feasible**
plant among one-step moves along three knobs:

  * decode-GPU count per node   (``GpuQueue.resize`` on the simulator,
                                 virtual fleet width on the engine)
  * total cache bytes per node  (``TierWalk.set_cache_capacity`` — the
                                 capacity *handoff* API: the controller
                                 owns the total, the
                                 :class:`~repro.core.tuner.MarginalHitTuner`
                                 keeps sole ownership of the alpha split)
  * shard count                 (``ShardedLatentBox.add_shard`` /
                                 ``remove_shard``, riding the existing
                                 segment-shipping migration)

Feasibility is an SLO rule: a candidate is feasible when its *predicted*
decode utilization (the window's measured busy-ms divided by the
candidate's capacity-ms) stays under the scale-up band and the observed
queue-delay p99 respects ``queue_slo_ms``.  Cost ranks candidates via
:class:`~repro.core.cost_model.CostParams` prices — GPUs at $/hr, cache
and durable bytes at the S3 $/GB-month rate — so a cache step is chosen
over a GPU step exactly when it is cheaper *and* predicted to absorb the
demand.

Stability machinery (all enforced here, property-tested in
``tests/test_autoscale.py``):

  * **hysteresis bands** — scale up above ``util_high``, down below
    ``util_low``, and a scale-down must keep predicted utilization under
    the band *midpoint* so it cannot immediately re-trigger a scale-up;
  * **cooldown windows** — after any action the controller holds for
    ``cooldown_windows`` control windows;
  * **scale-down safety** — never below ``min_gpus_per_node`` /
    ``min_cache_frac`` / the replication factor R (the sharded wrapper
    pins ``min_shards`` to R), and the ``shard_guard`` hook refuses a
    shard removal while any shard is dead or a reshard is in flight.

This module is ``core``-only (no ``repro.store`` imports): the backends
own the actuation, the controller owns the policy, and the whole feature
is off unless ``StoreConfig.autoscale=True`` — a disabled box constructs
no controller at all, so the default path is provably untouched.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core.cost_model import CostParams

HOURS_PER_MONTH = 730.0

#: Actions the controller can take (event ``action`` values).
SCALE_UP_ACTIONS = ("gpu_up", "cache_up", "shard_up")
SCALE_DOWN_ACTIONS = ("gpu_down", "cache_down", "shard_down")


@dataclasses.dataclass
class AutoscaleConfig:
    """Control-loop knobs.  Defaults are deliberately conservative: wide
    hysteresis, a cooldown after every action, single-step moves."""

    window: int = 64              #: requests per control window
    cooldown_windows: int = 2     #: hold-off windows after any action
    util_high: float = 0.80       #: scale-up band (predicted decode util)
    util_low: float = 0.30        #: scale-down band
    queue_slo_ms: float = 250.0   #: queue-delay p99 feasibility bound
    # -- knob bounds ---------------------------------------------------------
    min_gpus_per_node: int = 1
    max_gpus_per_node: int = 8
    #: Cache bounds as fractions of the *configured* bytes-per-node, so one
    #: config serves differently sized plants.
    min_cache_frac: float = 0.25
    max_cache_frac: float = 4.0
    cache_step: float = 2.0       #: grow/shrink multiplier per cache action
    min_shards: int = 1
    max_shards: int = 16
    # -- knob enablement (the sharded wrapper owns only the shard knob) ------
    gpu_knob: bool = True
    cache_knob: bool = True
    shard_knob: bool = False
    #: Modeled fraction of decode demand one cache step absorbs (scaled by
    #: the window's decode fraction).  Conservative by design: the real
    #: gain is workload-dependent and the marginal-hit tuner, not this
    #: constant, owns the split once the bytes exist.
    cache_gain: float = 0.25
    # -- prices --------------------------------------------------------------
    params: CostParams = dataclasses.field(default_factory=CostParams)
    #: Decode-GPU $/hr; ``None`` uses ``params.p_gpu_hr_h100``.
    gpu_price_hr: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class PlantState:
    """One point in the configuration space the controller moves through."""

    gpus_per_node: int
    n_nodes: int
    cache_bytes_per_node: float
    n_shards: int = 1

    @property
    def total_gpus(self) -> int:
        return self.n_shards * self.n_nodes * self.gpus_per_node

    @property
    def total_cache_bytes(self) -> float:
        return self.n_shards * self.n_nodes * self.cache_bytes_per_node


@dataclasses.dataclass(frozen=True)
class WindowObs:
    """One control window's feedback, as both backends can produce it."""

    requests: int                 #: requests served this window
    span_ms: float                #: window span (sim clock / wall clock)
    busy_ms: float                #: summed decode-GPU occupancy
    decode_frac: float = 1.0      #: fraction of requests that decoded
    queue_p99_ms: float = 0.0     #: queue-delay p99 over the window


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One applied decision (kept for trajectories and benchmarks)."""

    window_index: int
    action: str
    reason: str
    util: float
    queue_p99_ms: float
    state: PlantState             #: plant AFTER the action
    cost_per_hr: float            #: of the new plant


class AutoscaleController:
    """Picks the cheapest SLO-feasible plant, one step per control window.

    The controller is pure policy: it never touches a cache or a GPU
    queue itself.  The owning backend calls :meth:`step` with a complete
    window's observations; a returned :class:`ScaleEvent` carries the new
    :class:`PlantState` for the backend to actuate (resize GPU queues,
    hand new capacity to the tier walk, add/remove a shard).
    """

    def __init__(self, state: PlantState,
                 config: Optional[AutoscaleConfig] = None, *,
                 shard_guard: Optional[Callable[[], bool]] = None):
        self.cfg = config or AutoscaleConfig()
        self.state = state
        self._base_cache = float(state.cache_bytes_per_node)
        self._shard_guard = shard_guard
        self.events: List[ScaleEvent] = []
        self.scale_ups = 0
        self.scale_downs = 0
        self._cooldown = 0
        self._window_index = 0

    # -- §6.4 pricing ---------------------------------------------------------
    @property
    def gpu_price_hr(self) -> float:
        if self.cfg.gpu_price_hr is not None:
            return float(self.cfg.gpu_price_hr)
        return float(self.cfg.params.p_gpu_hr_h100)

    def cost_per_hr(self, s: PlantState) -> float:
        """Provisioned $/hr of a plant: decode GPUs at the configured
        $/hr plus cache DRAM priced at the storage $/GB-month rate (the
        same convention Eq. 4 uses for the pixel-cache term)."""
        p = self.cfg.params
        gpu = s.total_gpus * self.gpu_price_hr
        cache = (s.total_cache_bytes / 1e9) * p.p_s3_gb_mo / HOURS_PER_MONTH
        return gpu + cache

    # -- feasibility ----------------------------------------------------------
    @staticmethod
    def utilization(obs: WindowObs, s: PlantState) -> float:
        if obs.span_ms <= 0.0 or s.total_gpus <= 0:
            return 0.0
        return obs.busy_ms / (obs.span_ms * s.total_gpus)

    def _predicted_util(self, obs: WindowObs, cand: PlantState) -> float:
        """Predicted utilization at a candidate: the window's measured
        decode demand spread over the candidate's capacity; cache moves
        model a ``cache_gain`` demand change instead."""
        cur = self.state
        util = self.utilization(obs, cand)
        gain = self.cfg.cache_gain * max(0.0, min(1.0, obs.decode_frac))
        if cand.cache_bytes_per_node > cur.cache_bytes_per_node:
            util *= (1.0 - gain)
        elif cand.cache_bytes_per_node < cur.cache_bytes_per_node:
            util *= (1.0 + gain)
        return util

    # -- candidate generation -------------------------------------------------
    def _with(self, **kw) -> PlantState:
        return dataclasses.replace(self.state, **kw)

    def _shard_down_safe(self) -> bool:
        if self.state.n_shards <= max(1, self.cfg.min_shards):
            return False
        return self._shard_guard() if self._shard_guard is not None else True

    def _candidates(self, up: bool) -> List:
        cfg, s = self.cfg, self.state
        out = []
        if up:
            if cfg.gpu_knob and s.gpus_per_node < cfg.max_gpus_per_node:
                out.append(("gpu_up",
                            self._with(gpus_per_node=s.gpus_per_node + 1)))
            if cfg.cache_knob and (s.cache_bytes_per_node * cfg.cache_step
                                   <= self._base_cache * cfg.max_cache_frac):
                out.append(("cache_up", self._with(
                    cache_bytes_per_node=s.cache_bytes_per_node
                    * cfg.cache_step)))
            if cfg.shard_knob and s.n_shards < cfg.max_shards:
                out.append(("shard_up", self._with(n_shards=s.n_shards + 1)))
        else:
            if cfg.gpu_knob and s.gpus_per_node > cfg.min_gpus_per_node:
                out.append(("gpu_down",
                            self._with(gpus_per_node=s.gpus_per_node - 1)))
            if cfg.cache_knob and (s.cache_bytes_per_node / cfg.cache_step
                                   >= self._base_cache * cfg.min_cache_frac):
                out.append(("cache_down", self._with(
                    cache_bytes_per_node=s.cache_bytes_per_node
                    / cfg.cache_step)))
            if cfg.shard_knob and self._shard_down_safe():
                out.append(("shard_down",
                            self._with(n_shards=s.n_shards - 1)))
        return out

    # -- the control step -----------------------------------------------------
    def step(self, obs: WindowObs) -> Optional[ScaleEvent]:
        """One control interval.  Returns the applied :class:`ScaleEvent`
        (``self.state`` already advanced) or ``None`` to hold."""
        self._window_index += 1
        if obs.requests <= 0 or obs.span_ms <= 0.0:
            return None                       # nothing observable: hold
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        cfg = self.cfg
        util = self.utilization(obs, self.state)
        breach = obs.queue_p99_ms > cfg.queue_slo_ms
        if util > cfg.util_high or breach:
            reason = (f"util {util:.2f} > {cfg.util_high:.2f}" if not breach
                      else f"queue p99 {obs.queue_p99_ms:.0f}ms > SLO "
                           f"{cfg.queue_slo_ms:.0f}ms")
            return self._act(obs, util, reason, up=True)
        if util < cfg.util_low and obs.queue_p99_ms < 0.5 * cfg.queue_slo_ms:
            return self._act(obs, util,
                             f"util {util:.2f} < {cfg.util_low:.2f}",
                             up=False)
        return None

    def _act(self, obs: WindowObs, util: float, reason: str,
             up: bool) -> Optional[ScaleEvent]:
        cfg = self.cfg
        cands = self._candidates(up)
        if not cands:
            return None
        if up:
            # cheapest candidate predicted back inside the band; if none
            # qualifies, the one buying the most headroom (lowest predicted
            # utilization) — partial relief beats holding under overload
            feas = [(a, s) for a, s in cands
                    if self._predicted_util(obs, s) <= cfg.util_high]
            if feas:
                action, new = min(feas, key=lambda c: self.cost_per_hr(c[1]))
            else:
                action, new = min(
                    cands, key=lambda c: self._predicted_util(obs, c[1]))
        else:
            # biggest $/hr saving whose predicted utilization stays under
            # the band MIDPOINT — the hysteresis gap that prevents a
            # shrink from immediately re-triggering a scale-up
            mid = 0.5 * (cfg.util_low + cfg.util_high)
            feas = [(a, s) for a, s in cands
                    if self._predicted_util(obs, s) <= mid]
            if not feas:
                return None
            action, new = min(feas, key=lambda c: self.cost_per_hr(c[1]))
        self.state = new
        self._cooldown = cfg.cooldown_windows
        if up:
            self.scale_ups += 1
        else:
            self.scale_downs += 1
        ev = ScaleEvent(self._window_index, action, reason, util,
                        obs.queue_p99_ms, new, self.cost_per_hr(new))
        self.events.append(ev)
        return ev

    # -- introspection --------------------------------------------------------
    def summary(self) -> dict:
        s = self.state
        return {"scale_up_events": self.scale_ups,
                "scale_down_events": self.scale_downs,
                "autoscale_windows": self._window_index,
                "autoscale_gpus_per_node": s.gpus_per_node,
                "autoscale_cache_bytes_per_node": s.cache_bytes_per_node,
                "autoscale_shards": s.n_shards,
                "autoscale_cost_per_hr": self.cost_per_hr(s)}
