"""Latency/hit metrics accumulators shared by the simulator, the serving
runtime, and the benchmarks."""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

OUTCOME_CODES = {"image_hit": 0, "latent_hit": 1, "full_miss": 2,
                 "regen_miss": 3,           # recipe-only object regenerated
                 "shed": 4,                 # admission rejected (no answer)
                 "degraded": 5}             # stale pixel-cache answer
OUTCOME_NAMES = {v: k for k, v in OUTCOME_CODES.items()}
#: Outcomes that produced a (full-quality) serving-path answer; shed and
#: degraded entries are excluded from latency percentiles.
SERVED_MAX_CODE = 3

SLO_CODES = {"interactive": 0, "batch": 1}
SLO_NAMES = {v: k for k, v in SLO_CODES.items()}


@dataclasses.dataclass
class RequestLog:
    """Columnar per-request log (numpy-friendly).

    The serving-runtime columns (``queue_delay_ms``, ``tenant``, ``slo``,
    ``deadline_ms``, ``deadline_met``) default so that closed-loop callers
    (cluster replay, backends) keep their historical ``add`` signature.
    ``queue_ms`` remains the *plant*-side queueing component (GPU queue
    inside the latency model); ``queue_delay_ms`` is the scheduler-side
    delay between arrival and microbatch dispatch.
    """

    arrival_ms: List[float] = dataclasses.field(default_factory=list)
    latency_ms: List[float] = dataclasses.field(default_factory=list)
    outcome: List[int] = dataclasses.field(default_factory=list)
    queue_ms: List[float] = dataclasses.field(default_factory=list)
    fetch_ms: List[float] = dataclasses.field(default_factory=list)
    decode_ms: List[float] = dataclasses.field(default_factory=list)
    net_ms: List[float] = dataclasses.field(default_factory=list)
    spilled: List[bool] = dataclasses.field(default_factory=list)
    coalesced: List[bool] = dataclasses.field(default_factory=list)
    node: List[int] = dataclasses.field(default_factory=list)
    queue_delay_ms: List[float] = dataclasses.field(default_factory=list)
    tenant: List[int] = dataclasses.field(default_factory=list)
    slo: List[int] = dataclasses.field(default_factory=list)
    deadline_ms: List[float] = dataclasses.field(default_factory=list)
    deadline_met: List[bool] = dataclasses.field(default_factory=list)

    def add(self, arrival_ms: float, latency_ms: float, outcome: str,
            queue_ms: float = 0.0, fetch_ms: float = 0.0,
            decode_ms: float = 0.0, net_ms: float = 0.0,
            spilled: bool = False, coalesced: bool = False,
            node: int = -1, queue_delay_ms: float = 0.0,
            tenant: int = 0, slo: str = "interactive",
            deadline_ms: float = math.inf,
            deadline_met: bool = True) -> None:
        self.arrival_ms.append(arrival_ms)
        self.latency_ms.append(latency_ms)
        self.outcome.append(OUTCOME_CODES[outcome])
        self.queue_ms.append(queue_ms)
        self.fetch_ms.append(fetch_ms)
        self.decode_ms.append(decode_ms)
        self.net_ms.append(net_ms)
        self.spilled.append(spilled)
        self.coalesced.append(coalesced)
        self.node.append(node)
        self.queue_delay_ms.append(queue_delay_ms)
        self.tenant.append(tenant)
        self.slo.append(SLO_CODES[slo])
        self.deadline_ms.append(deadline_ms)
        self.deadline_met.append(deadline_met)

    def arrays(self) -> Dict[str, np.ndarray]:
        return {f.name: np.asarray(getattr(self, f.name))
                for f in dataclasses.fields(self)}

    def summarize(self) -> Dict[str, float]:
        lat = np.asarray(self.latency_ms)
        out = np.asarray(self.outcome)
        n = len(lat)
        if n == 0:
            return {"n": 0}
        served = out <= SERVED_MAX_CODE   # shed/degraded never decode; their
        #                                   latencies would pollute the tail
        slat = lat[served] if served.any() else lat
        summary = {
            "n": float(n),
            "mean_ms": float(slat.mean()),
            "p50_ms": float(np.percentile(slat, 50)),
            "p95_ms": float(np.percentile(slat, 95)),
            "p99_ms": float(np.percentile(slat, 99)),
            "image_hit_frac": float(np.mean(out == 0)),
            "latent_hit_frac": float(np.mean(out == 1)),
            "full_miss_frac": float(np.mean(out == 2)),
            "regen_miss_frac": float(np.mean(out == 3)),
            "spill_frac": float(np.mean(self.spilled)) if self.spilled else 0.0,
            "coalesced_frac": float(np.mean(self.coalesced)) if self.coalesced else 0.0,
        }
        if (out > SERVED_MAX_CODE).any():
            summary["shed_frac"] = float(np.mean(out == 4))
            summary["degraded_frac"] = float(np.mean(out == 5))
        # Fig 7c/d-style breakdowns
        for code, name in OUTCOME_NAMES.items():
            mask = out == code
            if mask.any():
                for col in ("queue_ms", "fetch_ms", "decode_ms", "net_ms",
                            "latency_ms"):
                    v = np.asarray(getattr(self, col))[mask]
                    summary[f"{name}.{col.replace('_ms', '')}_ms"] = float(v.mean())
        hit_mask = out < 2               # both miss classes (durable, regen)
        #                                  pay the slow path; neither is a hit
        if hit_mask.any():
            summary["hit.queue_ms"] = float(
                np.asarray(self.queue_ms)[hit_mask].mean())
        return summary

    def slo_summary(self) -> Dict[str, float]:
        """Per-SLO-class and per-tenant accounting of a stream replay:
        latency/queue-delay percentiles over served requests plus SLO
        attainment (fraction of the class that met its deadline — shed
        requests count as misses, degraded answers count by whether the
        stale answer landed in budget)."""
        if not self.latency_ms:
            return {}
        out = np.asarray(self.outcome)
        lat = np.asarray(self.latency_ms)
        qd = np.asarray(self.queue_delay_ms)
        met = np.asarray(self.deadline_met)
        slo = np.asarray(self.slo)
        tenant = np.asarray(self.tenant)
        served = out <= SERVED_MAX_CODE
        summary: Dict[str, float] = {}
        for code, name in SLO_NAMES.items():
            cls = slo == code
            if not cls.any():
                continue
            summary[f"{name}.n"] = float(cls.sum())
            summary[f"{name}.slo_attainment"] = float(met[cls].mean())
            summary[f"{name}.shed_frac"] = float(np.mean(out[cls] == 4))
            summary[f"{name}.degraded_frac"] = float(np.mean(out[cls] == 5))
            cs = cls & served
            if cs.any():
                summary[f"{name}.p50_ms"] = float(np.percentile(lat[cs], 50))
                summary[f"{name}.p99_ms"] = float(np.percentile(lat[cs], 99))
                summary[f"{name}.queue_delay_p50_ms"] = float(
                    np.percentile(qd[cs], 50))
                summary[f"{name}.queue_delay_p99_ms"] = float(
                    np.percentile(qd[cs], 99))
        for t in np.unique(tenant):
            ts = tenant == t
            summary[f"tenant{int(t)}.n"] = float(ts.sum())
            summary[f"tenant{int(t)}.slo_attainment"] = float(met[ts].mean())
            tss = ts & served
            if tss.any():
                summary[f"tenant{int(t)}.p99_ms"] = float(
                    np.percentile(lat[tss], 99))
        return summary


def percentiles(values, ps=(50, 95, 99)) -> Dict[str, float]:
    arr = np.asarray(values, dtype=np.float64)
    out = {"mean": float(arr.mean())} if len(arr) else {"mean": float("nan")}
    for p in ps:
        out[f"p{p}"] = float(np.percentile(arr, p)) if len(arr) else float("nan")
    return out
