"""Latency/hit metrics accumulators shared by the simulator and benchmarks."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

OUTCOME_CODES = {"image_hit": 0, "latent_hit": 1, "full_miss": 2,
                 "regen_miss": 3}          # recipe-only object regenerated
OUTCOME_NAMES = {v: k for k, v in OUTCOME_CODES.items()}


@dataclasses.dataclass
class RequestLog:
    """Columnar per-request log (numpy-friendly)."""

    arrival_ms: List[float] = dataclasses.field(default_factory=list)
    latency_ms: List[float] = dataclasses.field(default_factory=list)
    outcome: List[int] = dataclasses.field(default_factory=list)
    queue_ms: List[float] = dataclasses.field(default_factory=list)
    fetch_ms: List[float] = dataclasses.field(default_factory=list)
    decode_ms: List[float] = dataclasses.field(default_factory=list)
    net_ms: List[float] = dataclasses.field(default_factory=list)
    spilled: List[bool] = dataclasses.field(default_factory=list)
    coalesced: List[bool] = dataclasses.field(default_factory=list)
    node: List[int] = dataclasses.field(default_factory=list)

    def add(self, arrival_ms: float, latency_ms: float, outcome: str,
            queue_ms: float = 0.0, fetch_ms: float = 0.0,
            decode_ms: float = 0.0, net_ms: float = 0.0,
            spilled: bool = False, coalesced: bool = False,
            node: int = -1) -> None:
        self.arrival_ms.append(arrival_ms)
        self.latency_ms.append(latency_ms)
        self.outcome.append(OUTCOME_CODES[outcome])
        self.queue_ms.append(queue_ms)
        self.fetch_ms.append(fetch_ms)
        self.decode_ms.append(decode_ms)
        self.net_ms.append(net_ms)
        self.spilled.append(spilled)
        self.coalesced.append(coalesced)
        self.node.append(node)

    def arrays(self) -> Dict[str, np.ndarray]:
        return {f.name: np.asarray(getattr(self, f.name))
                for f in dataclasses.fields(self)}

    def summarize(self) -> Dict[str, float]:
        lat = np.asarray(self.latency_ms)
        out = np.asarray(self.outcome)
        n = len(lat)
        if n == 0:
            return {"n": 0}
        summary = {
            "n": float(n),
            "mean_ms": float(lat.mean()),
            "p50_ms": float(np.percentile(lat, 50)),
            "p95_ms": float(np.percentile(lat, 95)),
            "p99_ms": float(np.percentile(lat, 99)),
            "image_hit_frac": float(np.mean(out == 0)),
            "latent_hit_frac": float(np.mean(out == 1)),
            "full_miss_frac": float(np.mean(out == 2)),
            "regen_miss_frac": float(np.mean(out == 3)),
            "spill_frac": float(np.mean(self.spilled)) if self.spilled else 0.0,
            "coalesced_frac": float(np.mean(self.coalesced)) if self.coalesced else 0.0,
        }
        # Fig 7c/d-style breakdowns
        for code, name in OUTCOME_NAMES.items():
            mask = out == code
            if mask.any():
                for col in ("queue_ms", "fetch_ms", "decode_ms", "net_ms",
                            "latency_ms"):
                    v = np.asarray(getattr(self, col))[mask]
                    summary[f"{name}.{col.replace('_ms', '')}_ms"] = float(v.mean())
        hit_mask = out < 2               # both miss classes (durable, regen)
        #                                  pay the slow path; neither is a hit
        if hit_mask.any():
            summary["hit.queue_ms"] = float(
                np.asarray(self.queue_ms)[hit_mask].mean())
        return summary


def percentiles(values, ps=(50, 95, 99)) -> Dict[str, float]:
    arr = np.asarray(values, dtype=np.float64)
    out = {"mean": float(arr.mean())} if len(arr) else {"mean": float("nan")}
    for p in ps:
        out[f"p{p}"] = float(np.percentile(arr, p)) if len(arr) else float("nan")
    return out
